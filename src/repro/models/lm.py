"""Language models for every assigned architecture family.

One module, four families, one contract:

* ``init_model(rng, cfg) -> (params, axes)``   — stacked-layer pytrees
* ``forward(params, cfg, tokens|embeds) -> (logits, metrics)``
* ``init_cache(cfg, batch, s_max) -> cache``   — family-specific cache pytree
* ``prefill(params, cfg, tokens|embeds, cache) -> (logits, cache)``
* ``decode_step(params, cfg, token|embed, length, cache) -> (logits, cache)``
* ``loss_fn(params, cfg, batch) -> (loss, metrics)``

Layers are stacked with ``lax.scan`` (one compiled block per family — critical
for 40-cell dry-run compile times) and every hot op dispatches through the
operation registry, so the same model runs on the Reference / XLA / Pallas
executors unchanged (the paper's separation applied at framework scale).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import attention as attn_lib
from repro.nn import mamba as mamba_lib
from repro.nn import moe as moe_lib
from repro.nn import rwkv as rwkv_lib
from repro.nn.attention import KVCache, MLACache
from repro.nn.common import ParamBuilder, map_axes, stack_axes
from repro.nn.layers import (
    embed,
    embedding_init,
    gelu_mlp,
    gelu_mlp_init,
    layernorm,
    layernorm_init,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
    unembed,
)
from repro.nn.mamba import MambaState
from repro.nn.rwkv import RWKVState


def _norm_init(rng, cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm_kind == "layernorm":
        return layernorm_init(rng, d)
    return rmsnorm_init(rng, d)


def _norm(p, x, cfg):
    if cfg.norm_kind == "layernorm":
        return layernorm(p, x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) -> (B, S, d) standard transformer sinusoidal embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    out = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if d % 2:
        out = jnp.pad(out, ((0, 0), (0, 0), (0, 1)))
    return out


def _vmap_init(layer_init, rng, n, cfg):
    """Stack ``n`` layers of params; axes from one extra trace + 'layers' prefix."""
    keys = jax.random.split(rng, n)
    params = jax.vmap(lambda k: layer_init(k, cfg)[0])(keys)
    _, axes = layer_init(keys[0], cfg)  # axes tree only (strings, not traceable)
    return params, stack_axes(axes)


# =============================================================================
# transformer family (dense / mla / moe)
# =============================================================================

def _tf_block_init(rng, cfg):
    pb = ParamBuilder(rng, _dtype(cfg))
    n1, a1 = _norm_init(pb.fork(), cfg)
    pb.child("norm1", n1, a1)
    if cfg.family == "mla":
        ap, aa = attn_lib.mla_init(pb.fork(), cfg, dtype=_dtype(cfg))
    else:
        ap, aa = attn_lib.gqa_init(pb.fork(), cfg, dtype=_dtype(cfg))
    pb.child("attn", ap, aa)
    n2, a2 = _norm_init(pb.fork(), cfg)
    pb.child("norm2", n2, a2)
    if cfg.family == "moe":
        mp, ma = moe_lib.moe_init(pb.fork(), cfg, dtype=_dtype(cfg))
        pb.child("moe", mp, ma)
    elif cfg.mlp_kind == "gelu":
        mp, ma = gelu_mlp_init(pb.fork(), cfg.d_model, cfg.d_ff, dtype=_dtype(cfg))
        pb.child("mlp", mp, ma)
    else:
        mp, ma = swiglu_init(pb.fork(), cfg.d_model, cfg.d_ff, dtype=_dtype(cfg))
        pb.child("mlp", mp, ma)
    return pb.build()


def _tf_block_forward(bp, x, cfg, positions, executor=None):
    rs = cfg.residual_scale
    h = _norm(bp["norm1"], x, cfg)
    if cfg.family == "mla":
        a = attn_lib.mla_forward(bp["attn"], h, cfg, positions, executor=executor)
    else:
        a = attn_lib.gqa_forward(bp["attn"], h, cfg, positions, executor=executor)
    x = x + rs * a
    h = _norm(bp["norm2"], x, cfg)
    metrics = {}
    if cfg.family == "moe":
        m, metrics = moe_lib.moe_forward(bp["moe"], h, cfg)
    elif cfg.mlp_kind == "gelu":
        m = gelu_mlp(bp["mlp"], h)
    else:
        m = swiglu(bp["mlp"], h)
    x = x + rs * m
    return x, metrics


def _tf_block_prefill(bp, x, cfg, positions, cache, executor=None):
    rs = cfg.residual_scale
    h = _norm(bp["norm1"], x, cfg)
    if cfg.family == "mla":
        a, cache = attn_lib.mla_prefill(bp["attn"], h, cfg, positions, cache, executor=executor)
    else:
        a, cache = attn_lib.gqa_prefill(bp["attn"], h, cfg, positions, cache, executor=executor)
    x = x + rs * a
    h = _norm(bp["norm2"], x, cfg)
    if cfg.family == "moe":
        m, _ = moe_lib.moe_forward(bp["moe"], h, cfg)
    elif cfg.mlp_kind == "gelu":
        m = gelu_mlp(bp["mlp"], h)
    else:
        m = swiglu(bp["mlp"], h)
    return x + rs * m, cache


def _tf_block_decode(bp, x, cfg, length, cache, executor=None):
    rs = cfg.residual_scale
    h = _norm(bp["norm1"], x, cfg)
    if cfg.family == "mla":
        a, cache = attn_lib.mla_decode(bp["attn"], h, cfg, length, cache, executor=executor)
    else:
        a, cache = attn_lib.gqa_decode(bp["attn"], h, cfg, length, cache, executor=executor)
    x = x + rs * a
    h = _norm(bp["norm2"], x, cfg)
    if cfg.family == "moe":
        m, _ = moe_lib.moe_forward(bp["moe"], h, cfg)
    elif cfg.mlp_kind == "gelu":
        m = gelu_mlp(bp["mlp"], h)
    else:
        m = swiglu(bp["mlp"], h)
    return x + rs * m, cache


# =============================================================================
# rwkv6 family
# =============================================================================

def _rwkv_block_init(rng, cfg):
    pb = ParamBuilder(rng, _dtype(cfg))
    n1, a1 = layernorm_init(pb.fork(), cfg.d_model)
    pb.child("ln1", n1, a1)
    tm, tma = rwkv_lib.time_mix_init(pb.fork(), cfg, dtype=_dtype(cfg))
    pb.child("time_mix", tm, tma)
    n2, a2 = layernorm_init(pb.fork(), cfg.d_model)
    pb.child("ln2", n2, a2)
    cm, cma = rwkv_lib.channel_mix_init(pb.fork(), cfg, dtype=_dtype(cfg))
    pb.child("channel_mix", cm, cma)
    return pb.build()


def _rwkv_block_forward(bp, x, cfg, state=None, executor=None):
    h = layernorm(bp["ln1"], x, cfg.norm_eps)
    a, state = rwkv_lib.time_mix_forward(bp["time_mix"], h, cfg, state, executor=executor)
    x = x + a
    h = layernorm(bp["ln2"], x, cfg.norm_eps)
    c, state = rwkv_lib.channel_mix_forward(bp["channel_mix"], h, cfg, state)
    return x + c, state


def _rwkv_block_step(bp, x, cfg, state):
    h = layernorm(bp["ln1"], x, cfg.norm_eps)
    a, state = rwkv_lib.time_mix_step(bp["time_mix"], h, cfg, state)
    x = x + a
    h = layernorm(bp["ln2"], x, cfg.norm_eps)
    c, state = rwkv_lib.channel_mix_forward(bp["channel_mix"], h, cfg, state)
    return x + c, state


# =============================================================================
# hybrid family (zamba2: mamba2 backbone + shared attention block)
# =============================================================================

def _shared_cfg(cfg):
    """The shared transformer block operates at width 2*d_model."""
    return dataclasses.replace(
        cfg,
        family="dense",
        d_model=2 * cfg.d_model,
        head_dim=2 * cfg.d_model // cfg.n_heads,
        d_ff=cfg.d_ff,
    )


def _zamba_shared_init(rng, cfg):
    scfg = _shared_cfg(cfg)
    pb = ParamBuilder(rng, _dtype(cfg))
    n1, a1 = _norm_init(pb.fork(), scfg)
    pb.child("norm1", n1, a1)
    ap, aa = attn_lib.gqa_init(pb.fork(), scfg, dtype=_dtype(cfg))
    pb.child("attn", ap, aa)
    n2, a2 = _norm_init(pb.fork(), scfg)
    pb.child("norm2", n2, a2)
    mp, ma = swiglu_init(pb.fork(), scfg.d_model, scfg.d_ff, dtype=_dtype(cfg))
    pb.child("mlp", mp, ma)
    pb.param(
        "out_proj", (scfg.d_model, cfg.d_model), ("mlp", "embed"),
        std=scfg.d_model ** -0.5,
    )
    return pb.build()


def _zamba_lora_init(rng, cfg):
    """Per-invocation LoRA deltas on the shared q/k/v projections."""
    scfg = _shared_cfg(cfg)
    d2 = scfg.d_model
    H, hd = scfg.n_heads, scfg.resolved_head_dim
    r = cfg.lora_rank
    pb = ParamBuilder(rng, _dtype(cfg))
    for name, dout in (("q", H * hd), ("k", H * hd), ("v", H * hd)):
        pb.param(f"{name}_a", (d2, r), ("embed", None), std=d2 ** -0.5)
        pb.param(f"{name}_b", (r, dout), (None, "heads"), std=1e-4)
    return pb.build()


def _zamba_shared_forward(sp, lp, x2, cfg, positions, cache=None, length=None,
                          mode="forward", executor=None):
    """Shared block with per-invocation LoRA. x2: (B, S, 2d)."""
    scfg = _shared_cfg(cfg)
    # apply LoRA deltas to the shared projections (functional update)
    ap = dict(sp["attn"])
    for name, key in (("q", "wq"), ("k", "wk"), ("v", "wv")):
        ap[key] = sp["attn"][key] + lp[f"{name}_a"] @ lp[f"{name}_b"]
    h = _norm(sp["norm1"], x2, scfg)
    if mode == "forward":
        a = attn_lib.gqa_forward(ap, h, scfg, positions, executor=executor)
    elif mode == "prefill":
        a, cache = attn_lib.gqa_prefill(ap, h, scfg, positions, cache, executor=executor)
    else:
        a, cache = attn_lib.gqa_decode(ap, h, scfg, length, cache, executor=executor)
    x2 = x2 + a
    h = _norm(sp["norm2"], x2, scfg)
    x2 = x2 + swiglu(sp["mlp"], h)
    return x2 @ sp["out_proj"], cache


def _zamba_groups(cfg):
    every = cfg.shared_attn_every
    if cfg.n_layers % every:
        raise ValueError(
            f"zamba: n_layers {cfg.n_layers} not a multiple of shared_attn_every {every}"
        )
    return cfg.n_layers // every, every


# =============================================================================
# model init
# =============================================================================

def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_model(rng, cfg) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    pb = ParamBuilder(rng, _dtype(cfg))
    ep, ea = embedding_init(pb.fork(), cfg.vocab, cfg.d_model, dtype=_dtype(cfg))
    pb.child("embedding", ep, ea)

    if cfg.family in ("dense", "mla", "moe"):
        lp, la = _vmap_init(_tf_block_init, pb.fork(), cfg.n_layers, cfg)
        pb.child("blocks", lp, la)
    elif cfg.family == "rwkv6":
        n0, a0 = layernorm_init(pb.fork(), cfg.d_model)
        pb.child("ln0", n0, a0)  # rwkv normalizes the embedding
        lp, la = _vmap_init(_rwkv_block_init, pb.fork(), cfg.n_layers, cfg)
        pb.child("blocks", lp, la)
    elif cfg.family == "hybrid":
        G, per = _zamba_groups(cfg)
        keys = jax.random.split(pb.fork(), G)

        def _minit(rng, c):
            return mamba_lib.mamba_init(rng, c, dtype=_dtype(c))

        mp = jax.vmap(lambda k: _vmap_init(_minit, k, per, cfg)[0])(keys)
        _, ma = _vmap_init(_minit, keys[0], per, cfg)
        pb.child("mamba", mp, stack_axes(ma))
        sp, sa = _zamba_shared_init(pb.fork(), cfg)
        pb.child("shared", sp, sa)
        lp, la = _vmap_init(_zamba_lora_init, pb.fork(), G, cfg)
        pb.child("lora", lp, la)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    nf, na = _norm_init(pb.fork(), cfg)
    pb.child("final_norm", nf, na)
    if not cfg.tie_embeddings:
        pb.param(
            "lm_head", (cfg.d_model, cfg.vocab), ("embed", "vocab"),
            std=cfg.d_model ** -0.5,
        )
    return pb.build()


# =============================================================================
# forward / loss
# =============================================================================

def _inputs_to_h(params, cfg, tokens, embeds, positions):
    if cfg.frontend == "stub_embeddings":
        if embeds is None:
            raise ValueError(f"{cfg.name}: stub-frontend model needs `embeds`")
        h = embeds.astype(_dtype(cfg))
    else:
        h = embed(params["embedding"], tokens) * cfg.emb_scale
    if cfg.pos_kind == "sinusoidal":
        h = h + _sinusoidal(positions, cfg.d_model).astype(h.dtype)
    return h


def _head(params, cfg, h):
    h = _norm(params["final_norm"], h, cfg)
    if cfg.tie_embeddings:
        logits = unembed(params["embedding"], h)
    else:
        logits = h @ params["lm_head"]
    return logits.astype(jnp.float32) * cfg.logit_scale


def _maybe_remat(fn, cfg):
    if cfg.remat == "block":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        # keep matmul outputs, recompute elementwise — trades temp memory for
        # ~20% less recompute vs full block remat (§Perf cell A, step 6)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return fn


def _sp(h, cfg):
    """Sequence-parallel residual sharding (Korthikanti-style TP-SP): between
    blocks the (B, S, d) stream is sharded (batch->data, seq->model), which
    divides the remat-stored residuals by the model-axis size; attention's
    kv all-gather is the (much smaller) price.  No-op when sp_spec is ()."""
    if not cfg.sp_spec:
        return h
    from jax.sharding import PartitionSpec as P

    batch_axes, seq_axis = cfg.sp_spec
    return jax.lax.with_sharding_constraint(h, P(batch_axes, seq_axis, None))


def forward(
    params,
    cfg,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    *,
    executor=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = _inputs_to_h(params, cfg, tokens, embeds, positions)
    metrics: Dict[str, jax.Array] = {}

    h = _sp(h, cfg)
    if cfg.family in ("dense", "mla", "moe"):
        def block(x, bp):
            x, m = _tf_block_forward(bp, x, cfg, positions, executor=executor)
            return _sp(x, cfg), m

        block = _maybe_remat(block, cfg)
        if cfg.scan_layers:
            h, ms = jax.lax.scan(lambda x, bp: block(x, bp), h, params["blocks"])
            metrics = {k: jnp.sum(v) for k, v in ms.items()}
        else:
            for i in range(cfg.n_layers):
                bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                h, m = block(h, bp)
                metrics = {k: metrics.get(k, 0.0) + v for k, v in m.items()}

    elif cfg.family == "rwkv6":
        h = layernorm(params["ln0"], h, cfg.norm_eps)

        def block(x, bp):
            x, _ = _rwkv_block_forward(bp, x, cfg, executor=executor)
            return _sp(x, cfg), None

        block = _maybe_remat(block, cfg)
        h, _ = jax.lax.scan(block, h, params["blocks"])

    elif cfg.family == "hybrid":
        emb0 = h

        def group(x, xs):
            mamba_group, lora_p = xs

            def mblock(xc, bp):
                y, _ = mamba_lib.mamba_forward(bp, xc, cfg, executor=executor)
                return _sp(xc + y, cfg), None

            x, _ = jax.lax.scan(mblock, x, mamba_group)
            x2 = jnp.concatenate([x, emb0], axis=-1)
            delta, _ = _zamba_shared_forward(
                params["shared"], lora_p, x2, cfg, positions, executor=executor
            )
            return _sp(x + delta, cfg), None

        group = _maybe_remat(group, cfg)
        h, _ = jax.lax.scan(group, h, (params["mamba"], params["lora"]))
    else:
        raise ValueError(cfg.family)

    return _head(params, cfg, h), metrics


def loss_fn(params, cfg, batch, *, executor=None):
    """batch: {"tokens"|"embeds", "labels"} -> (loss, metrics)."""
    logits, metrics = forward(
        params,
        cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        executor=executor,
    )
    labels = batch["labels"]
    # sharding-friendly CE: take_along_axis over a model-sharded vocab axis
    # would all-gather the logits; logsumexp + one-hot contraction keeps the
    # vocab axis sharded (the contraction lowers to a local sum + psum).
    log_z = jax.nn.logsumexp(logits, axis=-1)
    one_hot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.sum(logits * one_hot, axis=-1)
    loss = jnp.mean(log_z - label_logit)
    metrics = dict(metrics)
    metrics["ce_loss"] = loss
    if cfg.family == "moe":
        aux = cfg.router_aux_weight * metrics.get("moe_lb_loss", 0.0) / cfg.n_layers
        aux = aux + 1e-3 * metrics.get("moe_z_loss", 0.0) / cfg.n_layers
        loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


# =============================================================================
# caches / serving
# =============================================================================

def init_cache(cfg, batch: int, s_max: int):
    dt = _dtype(cfg)
    if cfg.family in ("dense", "moe"):
        hd = cfg.resolved_head_dim
        return KVCache(
            k=jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, s_max, hd), dt),
            v=jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, s_max, hd), dt),
        )
    if cfg.family == "mla":
        return MLACache(
            c_kv=jnp.zeros((cfg.n_layers, batch, s_max, cfg.kv_lora_rank), dt),
            k_rope=jnp.zeros((cfg.n_layers, batch, s_max, cfg.qk_rope_head_dim), dt),
        )
    if cfg.family == "rwkv6":
        H = cfg.d_model // cfg.rwkv_head_dim
        return RWKVState(
            wkv=jnp.zeros((cfg.n_layers, batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
            shift_tm=jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
            shift_cm=jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
        )
    if cfg.family == "hybrid":
        G, per = _zamba_groups(cfg)
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        scfg = _shared_cfg(cfg)
        hd2 = scfg.resolved_head_dim
        return {
            "mamba": MambaState(
                conv=jnp.zeros((G, per, batch, cfg.ssm_conv - 1, conv_dim), dt),
                ssm=jnp.zeros((G, per, batch, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
            ),
            "kv": KVCache(
                k=jnp.zeros((G, batch, scfg.n_kv_heads, s_max, hd2), dt),
                v=jnp.zeros((G, batch, scfg.n_kv_heads, s_max, hd2), dt),
            ),
        }
    raise ValueError(cfg.family)


def cache_axes(cfg):
    """Logical-axis annotations for the cache pytree (mirrors init_cache)."""
    if cfg.family in ("dense", "moe"):
        return KVCache(
            k=(None, "batch", "kv_heads", "kv_seq", None),
            v=(None, "batch", "kv_heads", "kv_seq", None),
        )
    if cfg.family == "mla":
        return MLACache(
            c_kv=(None, "batch", "kv_seq", None),
            k_rope=(None, "batch", "kv_seq", None),
        )
    if cfg.family == "rwkv6":
        return RWKVState(
            wkv=(None, "batch", "heads", None, None),
            shift_tm=(None, "batch", "embed"),
            shift_cm=(None, "batch", "embed"),
        )
    if cfg.family == "hybrid":
        return {
            "mamba": MambaState(
                conv=(None, None, "batch", None, "mlp"),
                ssm=(None, None, "batch", "heads", None, None),
            ),
            "kv": KVCache(
                k=(None, "batch", "kv_heads", "kv_seq", None),
                v=(None, "batch", "kv_heads", "kv_seq", None),
            ),
        }
    raise ValueError(cfg.family)


def prefill(params, cfg, tokens=None, embeds=None, cache=None, *, executor=None):
    """Process a prompt, fill the cache at offset 0, return logits."""
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = _inputs_to_h(params, cfg, tokens, embeds, positions)

    if cfg.family in ("dense", "mla", "moe"):
        def block(x, xs):
            bp, lc = xs
            x, lc = _tf_block_prefill(bp, x, cfg, positions, lc, executor=executor)
            return x, lc

        h, cache = jax.lax.scan(block, h, (params["blocks"], cache))

    elif cfg.family == "rwkv6":
        h = layernorm(params["ln0"], h, cfg.norm_eps)

        def block(x, xs):
            bp, st = xs
            x, st = _rwkv_block_forward(bp, x, cfg, st, executor=executor)
            return x, st

        h, cache = jax.lax.scan(block, h, (params["blocks"], cache))

    elif cfg.family == "hybrid":
        emb0 = h

        def group(x, xs):
            mamba_group, lora_p, mstate, kv = xs

            def mblock(xc, ys):
                bp, st = ys
                y, st = mamba_lib.mamba_forward(bp, xc, cfg, st, executor=executor)
                return xc + y, st

            x, mstate = jax.lax.scan(mblock, x, (mamba_group, mstate))
            x2 = jnp.concatenate([x, emb0], axis=-1)
            delta, kv = _zamba_shared_forward(
                params["shared"], lora_p, x2, cfg, positions, kv,
                mode="prefill", executor=executor,
            )
            return x + delta, (mstate, kv)

        h, (mstate, kv) = jax.lax.scan(
            group, h, (params["mamba"], params["lora"], cache["mamba"], cache["kv"])
        )
        cache = {"mamba": mstate, "kv": kv}
    else:
        raise ValueError(cfg.family)

    return _head(params, cfg, h), cache


def decode_step(params, cfg, tokens=None, embeds=None, length=None, cache=None,
                *, executor=None):
    """One-token step; ``length`` (scalar int32) = tokens already in cache."""
    B = tokens.shape[0] if tokens is not None else embeds.shape[0]
    positions = jnp.full((B, 1), length, jnp.int32)
    h = _inputs_to_h(params, cfg, tokens, embeds, positions)

    if cfg.family in ("dense", "mla", "moe"):
        def block(x, xs):
            bp, lc = xs
            x, lc = _tf_block_decode(bp, x, cfg, length, lc, executor=executor)
            return x, lc

        h, cache = jax.lax.scan(block, h, (params["blocks"], cache))

    elif cfg.family == "rwkv6":
        h = layernorm(params["ln0"], h, cfg.norm_eps)

        def block(x, xs):
            bp, st = xs
            x, st = _rwkv_block_step(bp, x, cfg, st)
            return x, st

        h, cache = jax.lax.scan(block, h, (params["blocks"], cache))

    elif cfg.family == "hybrid":
        emb0 = h

        def group(x, xs):
            mamba_group, lora_p, mstate, kv = xs

            def mblock(xc, ys):
                bp, st = ys
                y, st = mamba_lib.mamba_step(bp, xc, cfg, st)
                return xc + y, st

            x, mstate = jax.lax.scan(mblock, x, (mamba_group, mstate))
            x2 = jnp.concatenate([x, emb0], axis=-1)
            delta, kv = _zamba_shared_forward(
                params["shared"], lora_p, x2, cfg, positions, kv,
                length=length, mode="decode", executor=executor,
            )
            return x + delta, (mstate, kv)

        h, (mstate, kv) = jax.lax.scan(
            group, h, (params["mamba"], params["lora"], cache["mamba"], cache["kv"])
        )
        cache = {"mamba": mstate, "kv": kv}
    else:
        raise ValueError(cfg.family)

    return _head(params, cfg, h), cache
