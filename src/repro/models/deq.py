"""Deep-equilibrium regression model: a sparse implicit solve as the middle layer.

The model joins the solver half and the NN half of the repo.  An input feature
vector ``u`` is lifted to a right-hand side ``b = W_in u``, pushed through the
implicit layer ``x = A(theta)^{-1} b`` (a GMRES solve, adjoint backward via the
Transpose combinator — see :mod:`repro.nn.implicit`), and read out as
``y = w_out . x``.  The operator is an upwind convection-diffusion stencil with
a diagonal shift, perturbed by the trainable ``theta``:

    values = base + shift * (diag mask) + scale * tanh(theta)

``tanh`` bounds the perturbation so the shifted operator keeps a strict
diagonal-dominance margin (shift > scale * max row nnz) — GMRES stays
convergent for every parameter setting the optimizer can reach.

Training data is teacher-student: targets come from the same architecture with
a fixed hidden ``theta*``, so the loss has a known minimum and a smoke run can
assert strict decrease.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.implicit import make_implicit_solve
from repro.solvers.common import Stop
from repro.sparse.gallery import convection_diffusion_2d

__all__ = ["DeqConfig", "init_deq", "deq_forward", "deq_loss", "synthetic_batch"]

_SHIFT = 1.0  # diagonal shift: dominance margin
_SCALE = 0.05  # tanh perturbation scale; 5 nnz/row * 0.05 << shift


class DeqConfig:
    """Static configuration: grid side, input width, solver tolerances."""

    def __init__(self, n_side: int = 8, d_in: int = 4, peclet: float = 2.0,
                 restart: int = 20, tol: float = 1e-8):
        self.n_side = n_side
        self.d_in = d_in
        self.peclet = peclet
        self.restart = restart
        self.tol = tol
        indptr, indices, values, shape = convection_diffusion_2d(
            n_side, peclet=peclet, scheme="upwind"
        )
        rows = np.repeat(np.arange(shape[0]), np.diff(indptr))
        base = values.astype(np.float32).copy()
        base[rows == indices] += _SHIFT
        self.indptr = indptr
        self.indices = indices
        self.base_values = jnp.asarray(base)
        self.n = shape[0]
        self.nnz = len(values)
        self.solve = make_implicit_solve(
            indptr, indices, shape,
            restart=restart,
            stop=Stop(max_iters=400, reduction_factor=tol),
        )


def init_deq(rng: jax.Array, cfg: DeqConfig) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(rng)
    return {
        "theta": jnp.zeros((cfg.nnz,), jnp.float32),
        "w_in": jax.random.normal(k1, (cfg.n, cfg.d_in), jnp.float32)
        / np.sqrt(cfg.d_in),
        "w_out": jax.random.normal(k2, (cfg.n,), jnp.float32) / np.sqrt(cfg.n),
    }


def deq_forward(params: Dict[str, jax.Array], u: jax.Array, cfg: DeqConfig):
    """``u`` is (batch, d_in); returns (batch,) predictions."""
    values = cfg.base_values + _SCALE * jnp.tanh(params["theta"])
    b = u @ params["w_in"].T  # (batch, n)
    x = jax.vmap(lambda bi: cfg.solve(values, bi))(b)
    return x @ params["w_out"]


def deq_loss(params, batch: Tuple[jax.Array, jax.Array], cfg: DeqConfig):
    u, y = batch
    pred = deq_forward(params, u, cfg)
    return jnp.mean(jnp.square(pred - y))


def synthetic_batch(seed: int, batch_size: int, cfg: DeqConfig):
    """Teacher-student data: targets from a hidden theta* (same architecture)."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((batch_size, cfg.d_in)).astype(np.float32))
    teacher = init_deq(jax.random.PRNGKey(7), cfg)
    teacher = dict(
        teacher,
        theta=jnp.asarray(
            np.random.default_rng(7).standard_normal(cfg.nnz).astype(np.float32)
        ),
    )
    y = deq_forward(teacher, u, cfg)
    return u, jax.lax.stop_gradient(y)
