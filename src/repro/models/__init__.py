"""repro.models — the assigned architectures, one contract (see lm.py)."""

from repro.models import deq, lm

__all__ = ["lm"]
