"""granite-8b — IBM Granite 8B code [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152, llama-arch.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        vocab=49152,
        n_heads=32,
        n_kv_heads=8,
        rope_theta=10_000_000.0,
        d_ff=14336,
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        dtype="float32",
    )
