"""smollm-135m — SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, llama-arch small,
tied embeddings.  Also the end-to-end training example architecture.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        vocab=49152,
        n_heads=9,
        n_kv_heads=3,
        rope_theta=10000.0,
        d_ff=1536,
        tie_embeddings=True,
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        vocab=256,
        n_heads=3,
        n_kv_heads=1,
        d_ff=96,
        tie_embeddings=True,
        dtype="float32",
    )
