"""olmoe-1b-7b — OLMoE-1B-7B [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) d_ff(expert)=1024 vocab=50304,
MoE: 64 experts top-8, no shared experts.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        vocab=50304,
        n_heads=16,
        n_kv_heads=16,
        rope_theta=10000.0,
        d_ff=1024,
        n_experts=64,
        top_k=8,
        d_expert=1024,
        shared_expert_ff=0,
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        vocab=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        n_experts=8,
        top_k=2,
        d_expert=64,
        dtype="float32",
    )
