"""rwkv6-3b — RWKV-6 Finch 3B [arXiv:2404.05892], attention-free.

32L d_model=2560 d_ff=8960 vocab=65536, head_dim=64 (40 heads),
data-dependent per-channel decay.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="rwkv6",
        n_layers=32,
        d_model=2560,
        vocab=65536,
        d_ff=8960,
        rwkv_head_dim=64,
        lora_rank=96,  # Finch: decay/mix LoRA ranks ~64-128 at this scale
        norm_kind="layernorm",
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="rwkv6",
        n_layers=2,
        d_model=64,
        vocab=256,
        d_ff=128,
        rwkv_head_dim=16,
        lora_rank=16,
        norm_kind="layernorm",
        dtype="float32",
    )
