"""musicgen-large — MusicGen-Large decoder backbone [arXiv:2306.05284].

48L d_model=2048 32H d_ff=8192 vocab=2048 (EnCodec codebook), decoder-only
over audio tokens.  The EnCodec frontend (4 codebooks + delay pattern) is a
STUB: ``input_specs()`` provides precomputed frame embeddings (B, S, d_model);
the LM head predicts one 2048-way codebook stream (simplification noted in
DESIGN.md).  LayerNorm + GELU + sinusoidal positions per the paper's
standard-transformer decoder.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="dense",
        n_layers=48,
        d_model=2048,
        vocab=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        mlp_kind="gelu",
        norm_kind="layernorm",
        pos_kind="sinusoidal",
        frontend="stub_embeddings",
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        mlp_kind="gelu",
        norm_kind="layernorm",
        pos_kind="sinusoidal",
        frontend="stub_embeddings",
        dtype="float32",
    )
