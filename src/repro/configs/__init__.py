"""repro.configs — one module per assigned architecture + shape definitions."""

from repro.configs.base import (
    ARCH_ALIASES,
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cells,
    get_config,
    get_smoke_config,
)

__all__ = [
    "ARCH_IDS",
    "ARCH_ALIASES",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "cells",
    "get_config",
    "get_smoke_config",
]
