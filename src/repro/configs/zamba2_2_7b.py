"""zamba2-2.7b — Zamba2-2.7B hybrid [arXiv:2411.15242].

54L d_model=2560, Mamba2 backbone (ssm_state=64, head_dim=64, expand 2) with a
SHARED attention+MLP block (32H, d_ff=10240) applied every 6 mamba layers over
concat(hidden, original embedding) (width 2*d_model), with per-invocation LoRA
deltas (rank 128) on the shared q/k/v.  vocab=32000.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        vocab=32000,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_groups=2,
        ssm_expand=2,
        ssm_conv=4,
        shared_attn_every=6,
        lora_rank=128,
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        vocab=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_groups=2,
        ssm_expand=2,
        ssm_conv=4,
        shared_attn_every=2,
        lora_rank=8,
        dtype="float32",
    )
