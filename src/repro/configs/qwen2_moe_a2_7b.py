"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) d_ff(expert)=1408 vocab=151936,
MoE: 60 routed experts top-4 + 4 shared experts (shared hidden 4x1408=5632).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        vocab=151936,
        n_heads=16,
        n_kv_heads=16,
        rope_theta=1_000_000.0,
        d_ff=1408,
        n_experts=60,
        n_experts_padded=64,  # EP over a 16-wide model axis (60 -> 4/device)
        top_k=4,
        d_expert=1408,
        shared_expert_ff=5632,
        norm_eps=1e-6,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        vocab=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        n_experts=8,
        top_k=4,
        d_expert=96,
        shared_expert_ff=128,
        dtype="float32",
    )
