"""pixtral-12b — Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified].

Decoder backbone = Mistral-Nemo-style: 40L d_model=5120 32H (GQA kv=8)
head_dim=128 d_ff=14336 vocab=131072.  The Pixtral ViT vision frontend is a
STUB: ``input_specs()`` provides precomputed patch+text embeddings
(B, S, d_model).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        vocab=131072,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        rope_theta=1_000_000.0,
        d_ff=14336,
        frontend="stub_embeddings",
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        frontend="stub_embeddings",
        dtype="float32",
    )
