"""yi-9b — Yi-9B [arXiv:2403.04652].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, llama-arch GQA.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        n_layers=48,
        d_model=4096,
        vocab=64000,
        n_heads=32,
        n_kv_heads=4,
        rope_theta=10000.0,
        d_ff=11008,
        norm_eps=1e-5,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab=256,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        dtype="float32",
    )
