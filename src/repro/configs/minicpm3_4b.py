"""minicpm3-4b — MiniCPM3-4B [hf:openbmb/MiniCPM3-4B], MLA attention.

62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA: q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64.
MiniCPM scaling: scale_emb=12, scale_depth=1.4 (residual 1.4/sqrt(62)),
logits scaled by dim_model_base/d_model = 256/2560.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    n_layers = 62
    return ModelConfig(
        name="minicpm3-4b",
        family="mla",
        n_layers=n_layers,
        d_model=2560,
        vocab=73448,
        n_heads=40,
        n_kv_heads=40,
        rope_theta=10000.0,
        d_ff=6400,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        emb_scale=12.0,
        logit_scale=256.0 / 2560.0,
        residual_scale=1.4 / (n_layers ** 0.5),
        norm_eps=1e-6,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    n_layers = 2
    return ModelConfig(
        name="minicpm3-smoke",
        family="mla",
        n_layers=n_layers,
        d_model=64,
        vocab=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
        emb_scale=12.0,
        logit_scale=0.25,
        residual_scale=1.4 / (n_layers ** 0.5),
        dtype="float32",
    )
