"""Model / shape configuration system.

One :class:`ModelConfig` dataclass covers every assigned architecture family
(dense / MoE / MLA / SSM / hybrid / stub-frontend backbones); each
``src/repro/configs/<arch>.py`` exports ``config()`` (the exact published
configuration) and ``smoke_config()`` (a reduced same-family configuration for
CPU tests).  Input shapes are the four assigned (seq_len, global_batch) cells.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_IDS = (
    "qwen2_moe_a2_7b",
    "olmoe_1b_7b",
    "granite_8b",
    "minicpm3_4b",
    "smollm_135m",
    "yi_9b",
    "rwkv6_3b",
    "musicgen_large",
    "zamba2_2_7b",
    "pixtral_12b",
)

# assignment ids (with dashes/dots, e.g. "zamba2-2.7b") -> module names
def _normalize(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


ARCH_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "mla" | "rwkv6" | "hybrid"
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: Optional[int] = None  # default d_model // n_heads
    rope_theta: float = 10000.0
    pos_kind: str = "rope"  # "rope" | "sinusoidal" (musicgen)
    # mlp
    d_ff: int = 0
    mlp_kind: str = "swiglu"  # "swiglu" | "gelu"
    norm_kind: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    # embeddings / head
    tie_embeddings: bool = False
    emb_scale: float = 1.0  # minicpm3 scale_emb
    logit_scale: float = 1.0  # minicpm3 d_model / dim_model_base
    residual_scale: float = 1.0  # minicpm3 scale_depth / sqrt(n_layers)
    # frontends ([audio]/[vlm]: stub embeddings replace the token embedding)
    frontend: str = "tokens"  # "tokens" | "stub_embeddings"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_experts_padded: int = 0  # 0 = no padding; qwen2: 64 for EP over 16
    shared_expert_ff: int = 0  # total shared-expert hidden (qwen2: 4 x 1408)
    router_aux_weight: float = 0.01
    # expert-parallel dispatch spec: (batch_mesh_axes, expert_mesh_axis),
    # e.g. (("pod","data"), "model"); () = single-device sort dispatch.
    moe_spec: tuple = ()
    moe_capacity_factor: float = 1.25
    # "gather": tokens model-replicated, experts read their copy, psum combine.
    # "a2a":    tokens seq-sharded over the model axis, all_to_all dispatch +
    #           return (no activation all-gather, no output psum) — the
    #           collective-bound §Perf optimization.
    moe_dispatch: str = "gather"
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / rwkv6)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_conv: int = 4
    rwkv_head_dim: int = 64
    # hybrid (zamba2)
    shared_attn_every: int = 6
    lora_rank: int = 128
    # numerics / impl selection (xla-space attention variant; pallas executor
    # always uses the flash kernel)
    dtype: str = "float32"
    attn_impl: str = "dense"  # "dense" | "chunked"
    # kv-chunk length for the chunked variant; None -> resolved from the
    # executor's launch-configuration table (core/tuning.py)
    attn_chunk: Optional[int] = None
    # sequence-parallel activation sharding between blocks: a 2-tuple
    # (batch_mesh_axes, seq_mesh_axis), e.g. (("pod","data"), "model");
    # () disables (single-device tests).  Set by the launcher per mesh.
    sp_spec: tuple = ()
    remat: str = "none"  # "none" | "block" — activation checkpointing policy
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv6"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM / hybrid) — gates long_500k."""
        return self.family in ("rwkv6", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    arch = _normalize(ARCH_ALIASES.get(arch, arch))
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    arch = _normalize(ARCH_ALIASES.get(arch, arch))
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def cells(arch: str) -> Tuple[str, ...]:
    """The live (arch x shape) cells: long_500k only for sub-quadratic archs."""
    cfg = get_config(arch)
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        names.append("long_500k")
    return tuple(names)
