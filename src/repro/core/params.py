"""Hardware parameter tables — the analogue of Ginkgo's per-backend config headers.

Ginkgo stores one parameterized kernel skeleton in ``common/`` and instantiates it
per backend with architecture-specific parameters (warp size 32 vs 64,
``launch_bounds``, ...).  Here the same role is played by :class:`HardwareParams`
(per-target machine model: tile geometry, subgroup size, memory budgets, roofline
constants) which both the Pallas kernels and the roofline analysis read.

All bandwidth/FLOP constants are the grading harness' TPU v5e numbers:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class HardwareParams:
    """Machine model for one execution target.

    The fields mirror what Ginkgo's backends configure per architecture:

    * ``subgroup_size``   — the cooperative-group granularity (paper: subwarp
      size; here: contiguous-lane segment width used by :mod:`repro.core.coop`).
    * ``warp_size``       — the full "warp" width inside which subgroups live
      (paper: 32 on CUDA / 64 on HIP; here: a lane segment of the 128-lane VPU).
    * ``lane_count`` / ``sublane_count`` — VREG tile geometry (8, 128) on TPU.
    * ``mxu_dim``         — systolic array dimension; matmul tiles should be
      multiples of this.
    * ``vmem_limit_bytes``— VMEM budget a kernel invocation may claim.
    """

    name: str
    kernel_space: str  # "reference" | "xla" | "pallas"
    interpret: bool = False  # Pallas interpret mode (CPU validation path)

    # Cooperative-group geometry (paper §4 "Cooperative groups").
    warp_size: int = 32
    subgroup_size: int = 8

    # VPU / MXU geometry.
    lane_count: int = 128
    sublane_count: int = 8
    mxu_dim: int = 128

    # Memory system.
    vmem_limit_bytes: int = 64 * 1024 * 1024
    hbm_bytes: int = 16 * 1024**3

    # Roofline constants (per chip / per link).
    peak_flops_bf16: float = 197e12
    peak_flops_f32: float = 49e12
    hbm_bandwidth: float = 819e9
    ici_bandwidth: float = 50e9

    def subgroups_per_warp(self) -> int:
        return self.warp_size // self.subgroup_size


# --- Target table ------------------------------------------------------------
# The analogue of Ginkgo's {cuda,hip,dpcpp}/config headers: one entry per
# supported execution target.  ``cpu_interpret`` runs the *pallas* kernel space
# in interpret mode — the validation backend (paper: "reference" executor is the
# correctness oracle; our reference space plays that role, and interpret mode
# lets us validate the hardware-native kernels without the hardware).

TPU_V5E = HardwareParams(
    name="tpu_v5e",
    kernel_space="pallas",
    interpret=False,
    warp_size=32,
    subgroup_size=8,
    vmem_limit_bytes=96 * 1024 * 1024,
    hbm_bytes=16 * 1024**3,
    peak_flops_bf16=197e12,
    peak_flops_f32=49e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
)

TPU_V4 = HardwareParams(
    name="tpu_v4",
    kernel_space="pallas",
    interpret=False,
    warp_size=32,
    subgroup_size=8,
    vmem_limit_bytes=96 * 1024 * 1024,
    hbm_bytes=32 * 1024**3,
    peak_flops_bf16=275e12,
    peak_flops_f32=69e12,
    hbm_bandwidth=1228e9,
    ici_bandwidth=100e9,
)

CPU_INTERPRET = HardwareParams(
    name="cpu_interpret",
    kernel_space="pallas",
    interpret=True,
    warp_size=32,
    subgroup_size=8,
    # Generous "VMEM" so interpret-mode shapes never trip the budget check.
    vmem_limit_bytes=1024 * 1024 * 1024,
    hbm_bytes=32 * 1024**3,
    peak_flops_bf16=1e12,
    peak_flops_f32=5e11,
    hbm_bandwidth=50e9,
    ici_bandwidth=10e9,
)

CPU_XLA = HardwareParams(
    name="cpu_xla",
    kernel_space="xla",
    interpret=True,
    warp_size=32,
    subgroup_size=8,
    vmem_limit_bytes=1024 * 1024 * 1024,
    hbm_bytes=32 * 1024**3,
    peak_flops_bf16=1e12,
    peak_flops_f32=5e11,
    hbm_bandwidth=50e9,
    ici_bandwidth=10e9,
)

CPU_REFERENCE = dataclasses.replace(CPU_XLA, name="cpu_reference", kernel_space="reference")

TARGETS: Mapping[str, HardwareParams] = {
    p.name: p
    for p in (TPU_V5E, TPU_V4, CPU_INTERPRET, CPU_XLA, CPU_REFERENCE)
}


def get_target(name: str) -> HardwareParams:
    try:
        return TARGETS[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware target {name!r}; known: {sorted(TARGETS)}"
        ) from None
