"""Cooperative groups — Ginkgo §4, adapted from warp shuffles to TPU lane tiles.

The paper implements subwarp-granularity ``shfl_xor`` / ``ballot`` / ``any`` /
``all`` on top of full-warp primitives with computed masks::

    Size       = given subwarp size
    Rank       = tid % Size
    LaneOffset = floor(tid % warpsize / Size) * Size
    Mask       = ~0 >> (warpsize - Size) << LaneOffset

    subwarp.shfl_xor(data, bm) = warp.shfl_xor(data, bm, Size)
    subwarp.ballot(pred)       = (warp.ballot(pred) & Mask) >> LaneOffset
    subwarp.any(pred)          = (warp.ballot(pred) & Mask) != 0
    subwarp.all(pred)          = (warp.ballot(pred) & Mask) == Mask

TPU adaptation (see DESIGN.md §2): there are no warp shuffles on a TPU.  The VPU
operates on (8, 128) vector registers, and cross-lane exchange is expressed as
shape manipulation that the Mosaic compiler keeps in registers.  What *does*
transfer is the interface and the granularity parameterization: a "warp" is a
contiguous segment of ``warp_size`` lanes of the last axis, a subgroup is a
``size``-lane segment inside it, and the paper's Rank/LaneOffset/Mask arithmetic
is reproduced bit-for-bit for the ballot-style predicate ops (including the
uint32/uint64 ``lane_mask_type`` distinction and the ``popcnt`` overloads).

Implementation notes for Pallas compatibility:

* every index computation uses ``lax.broadcasted_iota`` (>= 2D on the real
  Mosaic backend; kernels may not capture array constants, so no host-side
  ``np.arange`` tables);
* all ops are pure jnp/lax, usable inside Pallas kernel bodies (interpret or
  compiled) and in plain XLA code — one source, many backends, which is the
  point of the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "lane_mask_type",
    "lane_mask_bits",
    "popcnt",
    "subgroup",
    "SubgroupView",
]


def lane_mask_type(warp_size: int):
    """Paper: architecture-agnostic (unsigned) integer type for a lane mask.

    32-bit warps (CUDA) -> uint32; 64-bit wavefronts (AMD) -> uint64.
    """
    if warp_size <= 32:
        return jnp.uint32
    if warp_size <= 64:
        if not jax.config.jax_enable_x64:
            raise ValueError(
                "64-lane warp ballots need uint64 lane masks; enable x64 "
                "(e.g. `with jax.experimental.enable_x64():`) — the paper's "
                "AMD wavefront-64 case maps to this configuration"
            )
        return jnp.uint64
    raise ValueError(f"warp_size {warp_size} exceeds 64-bit lane masks")


def lane_mask_bits(warp_size: int) -> int:
    return 32 if warp_size <= 32 else 64


def popcnt(x: jax.Array) -> jax.Array:
    """Paper: single ``popcnt`` with overloads for 32- and 64-bit integers."""
    if x.dtype not in (jnp.uint32, jnp.uint64, jnp.int32, jnp.int64):
        raise TypeError(f"popcnt expects a 32/64-bit integer array, got {x.dtype}")
    return jax.lax.population_count(x)


def _segment(x: jax.Array, size: int) -> jax.Array:
    """Reshape the last axis (..., L) -> (..., L//size, size)."""
    L = x.shape[-1]
    if L % size:
        raise ValueError(f"last axis {L} not divisible by subgroup size {size}")
    return x.reshape(*x.shape[:-1], L // size, size)


def _unsegment(x: jax.Array) -> jax.Array:
    return x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])


def _lane_iota(shape) -> jax.Array:
    """int32 iota along the last axis, broadcast to ``shape`` (Mosaic-safe)."""
    return jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)


def _take_last(x: jax.Array, idx: jax.Array) -> jax.Array:
    """take_along_axis over the last axis (idx broadcast to x's shape)."""
    return jnp.take_along_axis(x, idx, axis=-1)


class SubgroupView:
    """A subgroup-of-the-lane-axis view of an array — ``gko::group::tiled_partition``.

    ``x`` has its last axis interpreted as lanes; the view partitions those lanes
    into contiguous subgroups of ``size`` (paper: "we always use subwarps with
    contiguous threads").  All ops return arrays of x's full shape, with the
    subgroup-collective result broadcast to every member lane — matching the
    shuffle-based semantics where every thread ends up holding the value.
    """

    def __init__(self, x: jax.Array, size: int, warp_size: int = 32):
        if size & (size - 1):
            raise ValueError(f"subgroup size must be a power of two, got {size}")
        # Shuffle/reduce subgroups may exceed the warp (they are just lane
        # segments); the ballot ops below additionally require size <= warp
        # (checked there) since the paper's mask arithmetic lives inside warps.
        if warp_size % size and size % warp_size:
            raise ValueError(
                f"subgroup size {size} incompatible with warp_size {warp_size}"
            )
        self.data = x
        self.size = size
        self.warp_size = warp_size

    # -- identity (paper: thread_rank / size) ----------------------------------
    def thread_rank(self) -> jax.Array:
        """Rank = tid % Size, broadcast over x's shape."""
        return _lane_iota(self.data.shape) % self.size

    # -- shuffles ---------------------------------------------------------------
    def shfl_xor(self, bitmask: int) -> jax.Array:
        """subwarp.shfl_xor(data, bm): lane r receives data from lane r ^ bm."""
        if not 0 <= bitmask < self.size:
            raise ValueError(f"bitmask {bitmask} out of range for size {self.size}")
        seg = _segment(self.data, self.size)
        idx = _lane_iota(seg.shape) ^ bitmask
        return _unsegment(_take_last(seg, idx))

    def shfl(self, src_lane: int) -> jax.Array:
        """subwarp.shfl(data, lane): every lane receives lane ``src_lane``'s value."""
        seg = _segment(self.data, self.size)
        idx = jnp.full_like(_lane_iota(seg.shape), src_lane)
        return _unsegment(_take_last(seg, idx))

    def shfl_down(self, delta: int) -> jax.Array:
        """Lane r receives from lane r+delta; out-of-range lanes keep their own
        value (CUDA semantics)."""
        seg = _segment(self.data, self.size)
        lane = _lane_iota(seg.shape)
        idx = jnp.where(lane + delta >= self.size, lane, lane + delta)
        return _unsegment(_take_last(seg, idx))

    # -- reductions (built from shfl_xor exactly like the paper's Listing 2) ----
    def reduce(self, op=jnp.add) -> jax.Array:
        """Butterfly all-reduce within the subgroup; every lane gets the result.

        Implemented as the log2(size) shfl_xor butterfly from the paper's
        DPC++ Listing 2 — the same data movement a shuffle reduction performs,
        expressed as lane permutations the vector unit can fuse.
        """
        out = self.data
        bitmask = 1
        while bitmask < self.size:
            seg = _segment(out, self.size)
            idx = _lane_iota(seg.shape) ^ bitmask
            out = _unsegment(op(seg, _take_last(seg, idx)))
            bitmask <<= 1
        return out

    def sum(self) -> jax.Array:
        return self.reduce(jnp.add)

    def max(self) -> jax.Array:
        return self.reduce(jnp.maximum)

    def min(self) -> jax.Array:
        return self.reduce(jnp.minimum)

    def inclusive_scan(self, op=jnp.add) -> jax.Array:
        """Hillis-Steele inclusive scan within each subgroup (shfl_up based)."""
        seg = _segment(self.data, self.size)
        out = seg
        lane = _lane_iota(seg.shape)
        delta = 1
        while delta < self.size:
            src = jnp.maximum(lane - delta, 0)
            shifted = _take_last(out, src)
            out = jnp.where(lane >= delta, op(out, shifted), out)
            delta <<= 1
        return _unsegment(out)

    # -- ballots (paper's mask arithmetic, bit-for-bit) --------------------------
    def _warp_segment(self, x: jax.Array) -> jax.Array:
        """Reshape lanes into (..., warps, warp_size)."""
        L = x.shape[-1]
        if L % self.warp_size:
            raise ValueError(
                f"last axis {L} not divisible by warp_size {self.warp_size}"
            )
        return x.reshape(*x.shape[:-1], L // self.warp_size, self.warp_size)

    def _full_warp_ballot(self, pred: jax.Array) -> jax.Array:
        """warp.ballot: pack warp_size predicate bits into one integer per warp,
        broadcast back to every lane of the warp."""
        mt = lane_mask_type(self.warp_size)
        w = self._warp_segment(pred).astype(mt)
        weights = jnp.left_shift(
            jnp.ones((), mt), _lane_iota(w.shape).astype(mt)
        )
        packed = jnp.sum(w * weights, axis=-1, keepdims=True, dtype=mt)
        return _unsegment(jnp.broadcast_to(packed, w.shape))

    def _mask_and_offset(self, shape):
        """Paper: LaneOffset = floor(tid % warpsize / Size) * Size;
        Mask = ~0 >> (warpsize - Size) << LaneOffset."""
        if self.size > self.warp_size:
            raise ValueError(
                f"ballot ops need subgroup size ({self.size}) <= warp_size "
                f"({self.warp_size}) — the paper's masks live inside one warp"
            )
        mt = lane_mask_type(self.warp_size)
        bits = lane_mask_bits(self.warp_size)
        tid = _lane_iota(shape) % self.warp_size
        lane_offset = ((tid // self.size) * self.size).astype(mt)
        full = jnp.full((), (1 << bits) - 1 if bits < 64 else 0xFFFFFFFFFFFFFFFF, mt)
        mask = (full >> jnp.asarray(self.warp_size - self.size, mt)) << lane_offset
        return mask, lane_offset

    def ballot(self, pred: jax.Array) -> jax.Array:
        """subwarp.ballot(pred) = (warp.ballot(pred) & Mask) >> LaneOffset."""
        mask, lane_offset = self._mask_and_offset(pred.shape)
        warp = self._full_warp_ballot(pred)
        return (warp & mask) >> lane_offset

    def any(self, pred: jax.Array) -> jax.Array:
        """subwarp.any(pred) = (warp.ballot(pred) & Mask) != 0."""
        mask, _ = self._mask_and_offset(pred.shape)
        warp = self._full_warp_ballot(pred)
        return (warp & mask) != 0

    def all(self, pred: jax.Array) -> jax.Array:
        """subwarp.all(pred) = (warp.ballot(pred) & Mask) == Mask."""
        mask, _ = self._mask_and_offset(pred.shape)
        warp = self._full_warp_ballot(pred)
        return (warp & mask) == mask

    def count(self, pred: jax.Array) -> jax.Array:
        """popcnt(subwarp.ballot(pred)) — the paper's ballot+popcount idiom."""
        return popcnt(self.ballot(pred))


def subgroup(x: jax.Array, size: int, warp_size: int = 32) -> SubgroupView:
    """``gko::group::tiled_partition<size>(warp)`` analogue."""
    return SubgroupView(x, size, warp_size)
