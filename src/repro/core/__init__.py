"""repro.core — the paper's contribution: executor-based platform portability.

Public surface:

* :mod:`repro.core.linop` — the LinOp hierarchy (``gko::LinOp``): the one
  ``apply`` interface every format, preconditioner, and solver composes
  through, plus the combinators (Composition / Sum / ScaledIdentity /
  Transpose / MatrixFreeOp / Identity).
* :mod:`repro.core.executor` — the Executor hierarchy (Reference / Xla /
  PallasTpu / PallasInterpret) and the ambient-executor context.
* :mod:`repro.core.registry` — operation registration and dynamic dispatch
  (``GKO_REGISTER_OPERATION`` analogue).
* :mod:`repro.core.coop` — cooperative groups on TPU lane tiles.
* :mod:`repro.core.params` — per-target hardware parameter tables.
* :mod:`repro.core.tuning` — launch-configuration resolution (per-target
  tuning tables + autotune cache) behind ``Executor.launch_config``.
"""

from repro.core.linop import (
    Composition,
    Identity,
    LinOp,
    MatrixFreeOp,
    ScaledIdentity,
    Sum,
    Transpose,
    as_linop,
)
from repro.core.executor import (
    Executor,
    PallasInterpretExecutor,
    PallasTpuExecutor,
    ReferenceExecutor,
    XlaExecutor,
    current_executor,
    default_executor,
    make_executor,
    reset_default_executor,
    use_executor,
)
from repro.core.params import (
    CPU_INTERPRET,
    CPU_REFERENCE,
    CPU_XLA,
    TPU_V4,
    TPU_V5E,
    HardwareParams,
    get_target,
)
from repro.core.registry import (
    NotCompiledError,
    Operation,
    all_operations,
    instantiate_common,
    operation,
    register,
    registered_spaces,
)
from repro.core.tuning import LaunchConfig, TuningSpec
from repro.core import coop, tuning

__all__ = [
    "LinOp",
    "Composition",
    "Sum",
    "ScaledIdentity",
    "Transpose",
    "MatrixFreeOp",
    "Identity",
    "as_linop",
    "Executor",
    "ReferenceExecutor",
    "XlaExecutor",
    "PallasTpuExecutor",
    "PallasInterpretExecutor",
    "current_executor",
    "default_executor",
    "reset_default_executor",
    "use_executor",
    "make_executor",
    "LaunchConfig",
    "TuningSpec",
    "tuning",
    "HardwareParams",
    "get_target",
    "TPU_V5E",
    "TPU_V4",
    "CPU_INTERPRET",
    "CPU_XLA",
    "CPU_REFERENCE",
    "NotCompiledError",
    "Operation",
    "operation",
    "register",
    "registered_spaces",
    "all_operations",
    "instantiate_common",
    "coop",
]
