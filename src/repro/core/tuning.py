"""Launch-configuration subsystem — per-target kernel tile geometry.

Ginkgo's ``common/`` folder keeps one kernel skeleton per algorithm and each
backend instantiates it with architecture-specific launch parameters (warp
size, ``launch_bounds``, block dimensions).  This module is that layer for the
Pallas kernels: every kernel family registers a :class:`TuningSpec` describing
its tile parameters, how to derive them from a :class:`HardwareParams` table,
its VMEM working-set model, and its autotune candidate space.  Call sites never
hard-code block sizes — they ask the executor for a :class:`LaunchConfig`:

    cfg = executor.launch_config("nn_attention", {"S": 2048, "D": 128, ...})
    flash_attention(..., block_q=cfg["block_q"], block_kv=cfg["block_kv"])

Resolution order (``resolve``):

1. the shape-bucketed **autotune cache** (winners measured by
   ``benchmarks --autotune`` and persisted as a per-target table);
2. an explicit per-``(op, target)`` **table override** (the one-table change
   that onboards a new hardware target);
3. the spec's **seed** derivation from ``HardwareParams`` (mxu_dim,
   lane/sublane counts).

Whatever the source, the block geometry is then constrained to the target's
alignment rules and *shrunk* (never overflowed) until the estimated working
set fits ``vmem_limit_bytes / VMEM_HEADROOM`` — the paper's "the executor owns
the kernel configuration" discipline with a safety valve.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import warnings
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.params import TARGETS, HardwareParams

__all__ = [
    "LaunchConfig",
    "TuningSpec",
    "register_spec",
    "get_spec",
    "all_specs",
    "resolve",
    "set_table_entry",
    "table_entry",
    "default_table",
    "record_autotuned",
    "autotune_entries",
    "clear_autotune_cache",
    "save_table",
    "load_table",
    "bucket_shapes",
    "next_pow2",
    "prev_pow2",
    "VMEM_HEADROOM",
]

Shapes = Mapping[str, int]
Block = Dict[str, int]

#: fraction of ``vmem_limit_bytes`` one kernel invocation may claim — the rest
#: is headroom for double-buffered pipelining and compiler-managed spills.
VMEM_HEADROOM = 4

#: environment variable naming a persisted tuning table (JSON) to preload.
TUNING_PATH_ENV = "REPRO_TUNING_PATH"


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (bucketing granule for the autotune cache)."""
    n = int(n)
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def prev_pow2(n: int) -> int:
    """Largest power of two <= n (tile-alignment granule for constraints)."""
    n = int(n)
    if n <= 1:
        return 1
    return 1 << (n.bit_length() - 1)


def bucket_shapes(shapes: Shapes) -> Tuple[Tuple[str, int], ...]:
    """Canonical shape bucket: sizes rounded up to powers of two.

    ``itemsize`` is kept exact (4 vs 2 bytes is a real boundary, not a size
    regime), everything else is pow2-bucketed so a tiling measured at S=1000
    also serves S=1024.
    """
    return tuple(
        sorted(
            (k, int(v) if k == "itemsize" else next_pow2(v))
            for k, v in shapes.items()
        )
    )


@dataclasses.dataclass(frozen=True)
class LaunchConfig:
    """Resolved launch geometry for one (op, target, shape-bucket).

    ``block`` holds the named tile parameters the kernel wrapper consumes
    (e.g. ``block_q``/``block_kv`` for attention, ``chunk`` for the scans).
    ``vmem_bytes`` is the spec's working-set estimate for that geometry;
    ``fits_vmem`` is False only when no shrink step could bring it under the
    target's budget (the caller should fall back to a portable kernel space).
    ``source`` records where the geometry came from: ``"table"`` /
    ``"autotuned"`` with a ``"+shrunk"`` suffix when the budget check reduced
    it.
    """

    op: str
    target: str
    block: Mapping[str, int]
    vmem_bytes: int
    fits_vmem: bool
    source: str

    def __getitem__(self, key: str) -> int:
        return self.block[key]

    def get(self, key: str, default: Optional[int] = None) -> Optional[int]:
        return self.block.get(key, default)


def _default_vmem(shapes: Shapes, block: Block) -> int:
    return 0


@dataclasses.dataclass(frozen=True)
class TuningSpec:
    """Everything the resolver needs to know about one kernel family.

    * ``seed(hw)``            — shape-independent default geometry derived from
      the hardware table (Ginkgo: the per-architecture config header).
    * ``vmem_bytes(shapes, block)`` — working-set model for the budget check.
    * ``constrain(hw, shapes, block)`` — clamp/align a proposed geometry to the
      target's rules (sublane multiples, power-of-two lanes, divisibility).
    * ``floors``              — per-parameter lower bounds for the shrink loop.
    * ``candidates(hw, shapes)`` — the autotune sweep space.
    """

    op: str
    params: Tuple[str, ...]
    seed: Callable[[HardwareParams], Block]
    vmem_bytes: Callable[[Shapes, Block], int] = _default_vmem
    constrain: Optional[Callable[[HardwareParams, Shapes, Block], Block]] = None
    floors: Mapping[str, int] = dataclasses.field(default_factory=dict)
    candidates: Optional[Callable[[HardwareParams, Shapes], List[Block]]] = None

    def floor(self, param: str) -> int:
        return int(self.floors.get(param, 1))

    def shrink(self, block: Block) -> Optional[Block]:
        """One shrink step: halve the largest still-shrinkable parameter."""
        shrinkable = [
            (v, k) for k, v in block.items()
            if k in self.params and v // 2 >= self.floor(k)
        ]
        if not shrinkable:
            return None
        _, key = max(shrinkable)
        out = dict(block)
        out[key] = block[key] // 2
        return out


_LOCK = threading.Lock()
_SPECS: Dict[str, TuningSpec] = {}
#: explicit per-(op, target) geometry overrides — "the one-table change".
_TABLE: Dict[Tuple[str, str], Block] = {}
#: shape-bucketed autotune winners: (op, target, bucket) -> block.
_AUTOTUNED: Dict[Tuple[str, str, Tuple[Tuple[str, int], ...]], Block] = {}
_ENV_LOADED = False


def register_spec(spec: TuningSpec) -> TuningSpec:
    with _LOCK:
        existing = _SPECS.get(spec.op)
        if existing is not None and existing is not spec:
            raise ValueError(f"tuning spec for {spec.op!r} already registered")
        _SPECS[spec.op] = spec
    return spec


def _ensure_specs_loaded() -> None:
    # kernel families register their specs from their ops.py bindings; pulling
    # in repro.kernels is the analogue of linking the device backends.
    import repro.kernels  # noqa: F401


def get_spec(op: str) -> TuningSpec:
    if op not in _SPECS:
        _ensure_specs_loaded()
    try:
        return _SPECS[op]
    except KeyError:
        raise KeyError(
            f"no tuning spec registered for op {op!r}; known: {sorted(_SPECS)}"
        ) from None


def all_specs() -> Dict[str, TuningSpec]:
    _ensure_specs_loaded()
    return dict(_SPECS)


# -- tables -------------------------------------------------------------------


def set_table_entry(op: str, target: str, block: Mapping[str, int]) -> None:
    """Pin an explicit geometry for (op, target) — the new-target entry point."""
    with _LOCK:
        _TABLE[(op, target)] = dict(block)


def table_entry(op: str, target: str) -> Optional[Block]:
    entry = _TABLE.get((op, target))
    return dict(entry) if entry is not None else None


def default_table() -> Dict[Tuple[str, str], Block]:
    """The full seeded tuning table: every registered op x every known target.

    This is what Ginkgo's per-backend config headers flatten to — inspect it,
    or use it as the starting point for a new target's table file.
    """
    out: Dict[Tuple[str, str], Block] = {}
    for op, spec in all_specs().items():
        for name, hw in TARGETS.items():
            out[(op, name)] = _TABLE.get((op, name), spec.seed(hw))
    return out


# -- autotune cache -----------------------------------------------------------


def record_autotuned(
    op: str, target: str, shapes: Shapes, block: Mapping[str, int]
) -> None:
    """Store a measured winner for (op, target, bucket(shapes))."""
    with _LOCK:
        _AUTOTUNED[(op, target, bucket_shapes(shapes))] = dict(block)


def autotune_entries() -> List[Dict[str, Any]]:
    """The live cache as JSON-ready records (also the persistence format)."""
    with _LOCK:
        return [
            {
                "op": op,
                "target": target,
                "bucket": [list(kv) for kv in bucket],
                "block": dict(block),
            }
            for (op, target, bucket), block in sorted(_AUTOTUNED.items())
        ]


def clear_autotune_cache() -> None:
    with _LOCK:
        _AUTOTUNED.clear()


def save_table(path: str, *, target: Optional[str] = None) -> int:
    """Persist the autotune cache (optionally one target's slice) as JSON."""
    entries = [
        e for e in autotune_entries() if target is None or e["target"] == target
    ]
    payload = {"version": 1, "entries": entries}
    dirname = os.path.dirname(os.path.abspath(path))
    if dirname:
        os.makedirs(dirname, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return len(entries)


def load_table(path: str) -> int:
    """Load a persisted tuning table into the autotune cache."""
    with open(path) as f:
        payload = json.load(f)
    entries = payload.get("entries", [])
    with _LOCK:
        for e in entries:
            bucket = tuple((str(k), int(v)) for k, v in e["bucket"])
            _AUTOTUNED[(e["op"], e["target"], bucket)] = {
                k: int(v) for k, v in e["block"].items()
            }
    return len(entries)


def _maybe_load_env_table() -> None:
    global _ENV_LOADED
    if _ENV_LOADED:
        return
    _ENV_LOADED = True
    path = os.environ.get(TUNING_PATH_ENV)
    if path and os.path.exists(path):
        try:
            load_table(path)
        except (OSError, ValueError, KeyError, TypeError) as e:
            # a corrupt cache must not take the program down — seeds still work
            warnings.warn(
                f"ignoring unreadable tuning table {path!r} "
                f"({TUNING_PATH_ENV}): {e}"
            )


# -- resolution ---------------------------------------------------------------


def resolve(op: str, shapes: Shapes, hw: HardwareParams) -> LaunchConfig:
    """Resolve the launch geometry for ``op`` on target ``hw`` at ``shapes``.

    autotune cache -> table override -> HardwareParams seed, then constrain to
    the target's alignment rules and shrink until the working set fits the
    VMEM budget.
    """
    _maybe_load_env_table()
    spec = get_spec(op)
    shapes = dict(shapes)

    # entries missing spec params (hand-edited / older-spec table files) are
    # ignored rather than crashing the first kernel call downstream
    tuned = _AUTOTUNED.get((op, hw.name, bucket_shapes(shapes)))
    if tuned is not None and not set(spec.params) <= set(tuned):
        tuned = None
    if tuned is not None:
        block, source = dict(tuned), "autotuned"
    else:
        override = _TABLE.get((op, hw.name))
        if override is not None and not set(spec.params) <= set(override):
            override = None
        block = dict(override) if override is not None else spec.seed(hw)
        source = "table"

    if spec.constrain is not None:
        block = spec.constrain(hw, shapes, block)

    budget = hw.vmem_limit_bytes // VMEM_HEADROOM
    vmem = spec.vmem_bytes(shapes, block)
    shrunk = False
    while vmem > budget:
        nxt = spec.shrink(block)
        if nxt is None:
            break
        if spec.constrain is not None:
            nxt = spec.constrain(hw, shapes, nxt)
        if nxt == block:
            break
        block, shrunk = nxt, True
        vmem = spec.vmem_bytes(shapes, block)

    return LaunchConfig(
        op=op,
        target=hw.name,
        block=block,
        vmem_bytes=int(vmem),
        fits_vmem=vmem <= budget,
        source=source + ("+shrunk" if shrunk else ""),
    )
