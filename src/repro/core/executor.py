"""Executors — the paper's central abstraction, adapted to JAX.

Ginkgo §3: "the executor is a central class that provides all important
primitives for allocating/deallocating memory on a device, transferring data to
other supported devices, and basic intra-device communication (e.g.,
synchronization)"; kernels are selected "during execution via dynamic
polymorphism".

JAX adaptation:

* memory allocation / transfer  -> ``device_put`` with the executor's device or
  sharding (explicit copies, mirroring Ginkgo's decision to avoid UVM);
* synchronization               -> ``block_until_ready`` over a pytree;
* kernel selection              -> :mod:`repro.core.registry` dispatch over the
  executor's kernel-space chain at trace time;
* the "master executor" (host-side twin every device executor carries)
  -> :attr:`Executor.master`, a :class:`ReferenceExecutor` on CPU.

The four executors mirror the paper's backends:

=================  =====================  =======================================
Ginkgo backend     This repo              Role
=================  =====================  =======================================
Reference          ReferenceExecutor      sequential oracle; correctness tests
OpenMP             XlaExecutor            portable compiler-parallelized backend
CUDA / HIP         PallasTpuExecutor      hardware-native hand-written kernels
(HIP-on-nvcc)      PallasInterpretExec.   native kernels on foreign hw (validation)
=================  =====================  =======================================
"""

from __future__ import annotations

import contextlib
import contextvars
import collections
from typing import Any, Dict, Optional, Tuple

import jax

from repro.core import params as params_lib
from repro.core.params import HardwareParams
from repro.observability.events import DispatchEvent, DispatchLog

__all__ = [
    "Executor",
    "ReferenceExecutor",
    "XlaExecutor",
    "PallasTpuExecutor",
    "PallasInterpretExecutor",
    "current_executor",
    "use_executor",
    "default_executor",
    "reset_default_executor",
    "make_executor",
]


class Executor:
    """Base executor: owns a hardware parameter table and a kernel-space chain."""

    #: kernel spaces this executor may dispatch into, in preference order.
    spaces: Tuple[str, ...] = ("reference",)

    def __init__(
        self,
        hw: HardwareParams,
        *,
        strict: bool = False,
        device: Optional[jax.Device] = None,
    ):
        self.hw = hw
        self.strict = strict
        self.device = device
        #: dispatch telemetry: Counter face (op name -> count, used by
        #: portability tests and BENCH launch-count pins) plus a bounded
        #: deque of structured DispatchEvents filled while tracing is on.
        self.dispatch_log: DispatchLog = DispatchLog()
        #: most recent LaunchConfig resolved via :meth:`launch_config`
        #: (attached to the in-flight dispatch event by the registry).
        self._last_launch_config = None

    # -- identity ----------------------------------------------------------------
    @property
    def name(self) -> str:
        return f"{type(self).__name__}({self.hw.name})"

    @property
    def kernel_space(self) -> str:
        return self.spaces[0]

    @property
    def interpret(self) -> bool:
        """Pallas interpret mode flag (True on the CPU validation path)."""
        return self.hw.interpret

    # -- master executor (paper: every device executor has a CPU-side master) ----
    @property
    def master(self) -> "Executor":
        if isinstance(self, ReferenceExecutor):
            return self
        if not hasattr(self, "_master"):
            self._master = ReferenceExecutor(params_lib.CPU_REFERENCE)
        return self._master

    # -- memory primitives (gko::Executor::alloc / copy_from) --------------------
    def to_device(self, tree: Any) -> Any:
        """Explicit copy of a pytree onto this executor's device."""
        if self.device is None:
            return tree
        return jax.device_put(tree, self.device)

    def copy_to(self, other: "Executor", tree: Any) -> Any:
        """Transfer a pytree to another executor (paper: inter-device copies
        route through the master when no direct path exists; device_put is our
        direct path and the host bounce is XLA's problem, which we note)."""
        return other.to_device(tree)

    def synchronize(self, tree: Any) -> Any:
        """Block until all arrays in ``tree`` are ready (queue.wait analogue)."""
        return jax.block_until_ready(tree)

    # -- dispatch ----------------------------------------------------------------
    def run(self, op_name: str, *args, **kwargs):
        """Submit a registered operation to this executor (gko ``run``)."""
        from repro.core.registry import operation

        return operation(op_name)(*args, executor=self, **kwargs)

    def _note_dispatch(
        self, op_name: str, event: Optional[DispatchEvent] = None
    ) -> None:
        self.dispatch_log.record(op_name, event)

    @property
    def dispatch_events(self):
        """Structured dispatch events (only populated while tracing)."""
        return self.dispatch_log.events

    # -- launch configuration (paper: per-architecture kernel parameters) --------
    def launch_config(self, op_name: str, shapes: Dict[str, int]):
        """Resolve the tile geometry for ``op_name`` at ``shapes`` on this
        executor's hardware target (autotune cache -> tuning table ->
        HardwareParams seed, VMEM-budget checked)."""
        from repro.core import tuning

        cfg = tuning.resolve(op_name, shapes, self.hw)
        self._last_launch_config = cfg
        return cfg

    @contextlib.contextmanager
    def activate(self):
        """Make this the ambient executor for registered-op dispatch."""
        token = _CURRENT.set(self)
        try:
            yield self
        finally:
            _CURRENT.reset(token)

    def __repr__(self) -> str:
        return self.name


class ReferenceExecutor(Executor):
    """Sequential-semantics oracle. Pure jnp, no fusion tricks, no kernels."""

    spaces = ("reference",)

    def __init__(self, hw: HardwareParams = params_lib.CPU_REFERENCE, **kw):
        super().__init__(hw, **kw)


class XlaExecutor(Executor):
    """The portable compiler backend (Ginkgo's OpenMP slot): jnp lowered by XLA."""

    spaces = ("xla", "reference")

    def __init__(self, hw: HardwareParams = params_lib.CPU_XLA, **kw):
        super().__init__(hw, **kw)


class PallasTpuExecutor(Executor):
    """Hardware-native backend: hand-written Pallas TPU kernels."""

    spaces = ("pallas", "xla", "reference")

    def __init__(self, hw: HardwareParams = params_lib.TPU_V5E, **kw):
        super().__init__(hw, **kw)


class PallasInterpretExecutor(PallasTpuExecutor):
    """Pallas kernels executed in interpret mode on CPU.

    The validation backend: the same kernel bodies as :class:`PallasTpuExecutor`,
    run on foreign hardware — the analogue of compiling the HIP backend on the
    nvcc platform to check the portability layer itself.
    """

    def __init__(self, hw: HardwareParams = params_lib.CPU_INTERPRET, **kw):
        super().__init__(hw, **kw)


# -- ambient executor ---------------------------------------------------------

_CURRENT: contextvars.ContextVar[Optional[Executor]] = contextvars.ContextVar(
    "repro_current_executor", default=None
)
_DEFAULT: Optional[Executor] = None


def default_executor() -> Executor:
    """Pick the natural executor for the runtime platform (cached).

    TPU -> PallasTpuExecutor; anything else -> XlaExecutor.  (Mirrors Ginkgo
    applications constructing ``CudaExecutor`` when a GPU is present and
    ``OmpExecutor`` otherwise.)
    """
    global _DEFAULT
    if _DEFAULT is None:
        platform = jax.devices()[0].platform
        if platform == "tpu":
            _DEFAULT = PallasTpuExecutor(params_lib.TPU_V5E)
        else:
            _DEFAULT = XlaExecutor(params_lib.CPU_XLA)
    return _DEFAULT


def reset_default_executor() -> None:
    """Drop the cached platform-default executor.

    Tests (and anything that mutates the default target table) use this so the
    module-level cache cannot leak one test's executor into the next.
    """
    global _DEFAULT
    _DEFAULT = None


def current_executor() -> Executor:
    ex = _CURRENT.get()
    return ex if ex is not None else default_executor()


@contextlib.contextmanager
def use_executor(ex: Executor):
    with ex.activate():
        yield ex


_EXECUTOR_FACTORY = {
    "reference": lambda hw, **kw: ReferenceExecutor(hw or params_lib.CPU_REFERENCE, **kw),
    "xla": lambda hw, **kw: XlaExecutor(hw or params_lib.CPU_XLA, **kw),
    "pallas": lambda hw, **kw: PallasTpuExecutor(hw or params_lib.TPU_V5E, **kw),
    "pallas_interpret": lambda hw, **kw: PallasInterpretExecutor(
        hw or params_lib.CPU_INTERPRET, **kw
    ),
}


def _executor_for_params(hw: HardwareParams, **kw) -> Executor:
    """Pick the executor class a hardware target naturally runs under."""
    if hw.kernel_space == "pallas":
        cls = PallasInterpretExecutor if hw.interpret else PallasTpuExecutor
    elif hw.kernel_space == "xla":
        cls = XlaExecutor
    else:
        cls = ReferenceExecutor
    return cls(hw, **kw)


def make_executor(kind: str, hw: Optional[HardwareParams] = None, **kw) -> Executor:
    """Factory used by configs/CLIs: ``--executor pallas_interpret`` etc.

    ``kind`` is either a kernel-space kind (``reference`` / ``xla`` /
    ``pallas`` / ``pallas_interpret``) or a hardware target name from
    :data:`repro.core.params.TARGETS` (``tpu_v4``, ``cpu_interpret``, ...) —
    the latter picks both the parameter table and the executor class.
    """
    factory = _EXECUTOR_FACTORY.get(kind)
    if factory is not None:
        return factory(hw, **kw)
    if kind in params_lib.TARGETS:
        return _executor_for_params(hw or params_lib.get_target(kind), **kw)
    raise KeyError(
        f"unknown executor kind {kind!r}; known kinds: "
        f"{sorted(_EXECUTOR_FACTORY)}, targets: {sorted(params_lib.TARGETS)}"
    ) from None
