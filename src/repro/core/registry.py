"""Operation registry — the analogue of ``GKO_REGISTER_OPERATION`` + dynamic dispatch.

Ginkgo's core algorithms never name a backend: they submit *operations* to an
executor, and dynamic polymorphism selects the backend kernel at run time.  Here,
an :class:`Operation` is a named dispatch point; implementations are registered
per *kernel space* (``reference`` / ``xla`` / ``pallas``), and the active
:class:`~repro.core.executor.Executor` selects which space's implementation runs
(at trace time — JAX's analogue of run time for kernel selection).

Ginkgo semantics preserved:

* an executor without a registered kernel raises :class:`NotCompiledError`
  (Ginkgo's ``gko::NotCompiled``) in strict mode;
* in permissive mode the executor's fallback chain is walked
  (``pallas -> xla -> reference``), mirroring how applications in practice pair
  a hardware backend with the reference implementation for missing kernels;
* every implementation receives the executor as first argument so it can read
  the hardware parameter table (Ginkgo kernels receive
  ``std::shared_ptr<const Executor>``).
"""

from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Tuple

# stdlib-only modules, safe to import before JAX-heavy layers come up
from repro.observability import events as _events
from repro.observability import trace as _trace

__all__ = [
    "NotCompiledError",
    "Operation",
    "operation",
    "register",
    "registered_spaces",
    "all_operations",
    "instantiate_common",
]


class NotCompiledError(NotImplementedError):
    """Raised when an operation has no kernel for the executor's spaces.

    Analogue of ``gko::NotCompiled`` — in Ginkgo this means "this module was not
    compiled for this backend"; here it means "no implementation registered for
    any kernel space this executor may use".
    """


_OPERATIONS: Dict[str, "Operation"] = {}


class Operation:
    """A named, executor-dispatched operation (one ``GKO_REGISTER_OPERATION``)."""

    def __init__(self, name: str, doc: str = ""):
        if name in _OPERATIONS:
            raise ValueError(f"operation {name!r} already defined")
        self.name = name
        self.__doc__ = doc or f"executor-dispatched operation {name!r}"
        self._impls: Dict[str, Callable[..., Any]] = {}
        _OPERATIONS[name] = self

    # -- registration ---------------------------------------------------------
    def register(self, space: str) -> Callable[[Callable], Callable]:
        """Decorator: register ``fn(executor, *args, **kw)`` for ``space``."""

        def deco(fn: Callable) -> Callable:
            if space in self._impls:
                raise ValueError(
                    f"operation {self.name!r} already has a {space!r} kernel"
                )
            self._impls[space] = fn
            return fn

        return deco

    def resolve(self, executor) -> Tuple[str, Callable[..., Any]]:
        """``(kernel_space, implementation)`` that will serve ``executor``."""
        spaces = (executor.kernel_space,) if executor.strict else executor.spaces
        for space in spaces:
            impl = self._impls.get(space)
            if impl is not None:
                return space, impl
        raise NotCompiledError(
            f"operation {self.name!r} has no kernel for executor "
            f"{executor.name!r} (searched spaces {spaces}; "
            f"registered: {sorted(self._impls)})"
        )

    def implementation_for(self, executor) -> Callable[..., Any]:
        return self.resolve(executor)[1]

    def supports(self, executor) -> bool:
        """Does any of the executor's kernel spaces serve this operation?

        The *optional-op* capability probe: algorithm layers (the fused Krylov
        paths) ask before relying on an op that only some backends register,
        and fall back to the portable formulation when the answer is False —
        instead of tripping :class:`NotCompiledError` at dispatch time.
        """
        spaces = (executor.kernel_space,) if executor.strict else executor.spaces
        return any(space in self._impls for space in spaces)

    def space_used(self, executor) -> str:
        """Which kernel space would serve this executor (for tests/telemetry)."""
        spaces = (executor.kernel_space,) if executor.strict else executor.spaces
        for space in spaces:
            if space in self._impls:
                return space
        raise NotCompiledError(self.name)

    # -- dispatch ---------------------------------------------------------------
    def __call__(self, *args, executor=None, **kwargs):
        from repro.core.executor import current_executor

        ex = executor if executor is not None else current_executor()
        space, impl = self.resolve(ex)
        if not _trace.TRACING:
            # hot path: identical to the pre-observability dispatch — one
            # module-attribute check, no clock read, no allocation.
            out = impl(ex, *args, **kwargs)
            ex._note_dispatch(self.name)
            return out
        return self._traced_call(ex, space, impl, args, kwargs)

    def _traced_call(self, ex, space, impl, args, kwargs):
        """Instrumented dispatch: structured event + Chrome trace span.

        Wall time here is dispatch/trace-time cost (under ``jit`` each op
        runs once while tracing) — the event's value is launch *structure*:
        op, space, shapes, resolved LaunchConfig, bytes-moved estimate.
        """
        tracer = _trace.get_tracer()
        ex._last_launch_config = None  # repopulated if the kernel resolves one
        t0 = time.perf_counter()
        out = impl(ex, *args, **kwargs)
        wall_us = (time.perf_counter() - t0) * 1e6
        ts_us = tracer.rel_us(t0) if tracer is not None else 0.0
        event = _events.make_event(
            op=self.name,
            space=space,
            executor=ex,
            launch=ex._last_launch_config,
            wall_us=wall_us,
            ts_us=ts_us,
            operands=args,
            out=out,
        )
        ex._note_dispatch(self.name, event)
        if tracer is not None:
            tracer.complete(
                self.name, ts_us, wall_us, cat="dispatch", args=event.to_args()
            )
        from repro.observability import metrics as _metrics

        _metrics.observe_dispatch(event, getattr(ex.hw, "hbm_bandwidth", None))
        return out

    def __repr__(self) -> str:
        return f"Operation({self.name!r}, spaces={sorted(self._impls)})"


def operation(name: str, doc: str = "") -> Operation:
    """Create (or fetch) the named operation."""
    if name in _OPERATIONS:
        return _OPERATIONS[name]
    return Operation(name, doc)


def register(name: str, space: str) -> Callable[[Callable], Callable]:
    """Shorthand: ``@register("spmv_ell", "pallas")``."""
    return operation(name).register(space)


def registered_spaces(name: str) -> tuple:
    return tuple(sorted(_OPERATIONS[name]._impls))


def all_operations() -> Dict[str, "Operation"]:
    return dict(_OPERATIONS)


def instantiate_common(
    name: str,
    skeleton: Callable[..., Any],
    space_params: Dict[str, Dict[str, Any]],
) -> Operation:
    """Bind one kernel *skeleton* to several kernel spaces — the ``common/`` folder.

    Ginkgo keeps CUDA/HIP-identical kernels in ``common/`` parameterized by
    architecture-specific constants, and each backend includes the skeleton with
    its own parameter values.  ``instantiate_common`` is the JAX analogue: the
    skeleton is a function ``skeleton(executor, *args, **bound_params)`` and each
    kernel space binds its own parameter dict.

    Example::

        instantiate_common(
            "subgroup_reduce_bench",
            _reduce_skeleton,
            {
                "pallas": dict(block_rows=256),
                "xla": dict(block_rows=1024),
            },
        )
    """
    op = operation(name)
    for space, params in space_params.items():
        bound = functools.partial(skeleton, **params)
        functools.update_wrapper(bound, skeleton)
        op.register(space)(bound)
    return op
