"""Operation registry — the analogue of ``GKO_REGISTER_OPERATION`` + dynamic dispatch.

Ginkgo's core algorithms never name a backend: they submit *operations* to an
executor, and dynamic polymorphism selects the backend kernel at run time.  Here,
an :class:`Operation` is a named dispatch point; implementations are registered
per *kernel space* (``reference`` / ``xla`` / ``pallas``), and the active
:class:`~repro.core.executor.Executor` selects which space's implementation runs
(at trace time — JAX's analogue of run time for kernel selection).

Ginkgo semantics preserved:

* an executor without a registered kernel raises :class:`NotCompiledError`
  (Ginkgo's ``gko::NotCompiled``) in strict mode;
* in permissive mode the executor's fallback chain is walked
  (``pallas -> xla -> reference``), mirroring how applications in practice pair
  a hardware backend with the reference implementation for missing kernels;
* every implementation receives the executor as first argument so it can read
  the hardware parameter table (Ginkgo kernels receive
  ``std::shared_ptr<const Executor>``).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict

__all__ = [
    "NotCompiledError",
    "Operation",
    "operation",
    "register",
    "registered_spaces",
    "all_operations",
    "instantiate_common",
]


class NotCompiledError(NotImplementedError):
    """Raised when an operation has no kernel for the executor's spaces.

    Analogue of ``gko::NotCompiled`` — in Ginkgo this means "this module was not
    compiled for this backend"; here it means "no implementation registered for
    any kernel space this executor may use".
    """


_OPERATIONS: Dict[str, "Operation"] = {}


class Operation:
    """A named, executor-dispatched operation (one ``GKO_REGISTER_OPERATION``)."""

    def __init__(self, name: str, doc: str = ""):
        if name in _OPERATIONS:
            raise ValueError(f"operation {name!r} already defined")
        self.name = name
        self.__doc__ = doc or f"executor-dispatched operation {name!r}"
        self._impls: Dict[str, Callable[..., Any]] = {}
        _OPERATIONS[name] = self

    # -- registration ---------------------------------------------------------
    def register(self, space: str) -> Callable[[Callable], Callable]:
        """Decorator: register ``fn(executor, *args, **kw)`` for ``space``."""

        def deco(fn: Callable) -> Callable:
            if space in self._impls:
                raise ValueError(
                    f"operation {self.name!r} already has a {space!r} kernel"
                )
            self._impls[space] = fn
            return fn

        return deco

    def implementation_for(self, executor) -> Callable[..., Any]:
        spaces = (executor.kernel_space,) if executor.strict else executor.spaces
        for space in spaces:
            impl = self._impls.get(space)
            if impl is not None:
                return impl
        raise NotCompiledError(
            f"operation {self.name!r} has no kernel for executor "
            f"{executor.name!r} (searched spaces {spaces}; "
            f"registered: {sorted(self._impls)})"
        )

    def supports(self, executor) -> bool:
        """Does any of the executor's kernel spaces serve this operation?

        The *optional-op* capability probe: algorithm layers (the fused Krylov
        paths) ask before relying on an op that only some backends register,
        and fall back to the portable formulation when the answer is False —
        instead of tripping :class:`NotCompiledError` at dispatch time.
        """
        spaces = (executor.kernel_space,) if executor.strict else executor.spaces
        return any(space in self._impls for space in spaces)

    def space_used(self, executor) -> str:
        """Which kernel space would serve this executor (for tests/telemetry)."""
        spaces = (executor.kernel_space,) if executor.strict else executor.spaces
        for space in spaces:
            if space in self._impls:
                return space
        raise NotCompiledError(self.name)

    # -- dispatch ---------------------------------------------------------------
    def __call__(self, *args, executor=None, **kwargs):
        from repro.core.executor import current_executor

        ex = executor if executor is not None else current_executor()
        impl = self.implementation_for(ex)
        out = impl(ex, *args, **kwargs)
        ex._note_dispatch(self.name)
        return out

    def __repr__(self) -> str:
        return f"Operation({self.name!r}, spaces={sorted(self._impls)})"


def operation(name: str, doc: str = "") -> Operation:
    """Create (or fetch) the named operation."""
    if name in _OPERATIONS:
        return _OPERATIONS[name]
    return Operation(name, doc)


def register(name: str, space: str) -> Callable[[Callable], Callable]:
    """Shorthand: ``@register("spmv_ell", "pallas")``."""
    return operation(name).register(space)


def registered_spaces(name: str) -> tuple:
    return tuple(sorted(_OPERATIONS[name]._impls))


def all_operations() -> Dict[str, "Operation"]:
    return dict(_OPERATIONS)


def instantiate_common(
    name: str,
    skeleton: Callable[..., Any],
    space_params: Dict[str, Dict[str, Any]],
) -> Operation:
    """Bind one kernel *skeleton* to several kernel spaces — the ``common/`` folder.

    Ginkgo keeps CUDA/HIP-identical kernels in ``common/`` parameterized by
    architecture-specific constants, and each backend includes the skeleton with
    its own parameter values.  ``instantiate_common`` is the JAX analogue: the
    skeleton is a function ``skeleton(executor, *args, **bound_params)`` and each
    kernel space binds its own parameter dict.

    Example::

        instantiate_common(
            "subgroup_reduce_bench",
            _reduce_skeleton,
            {
                "pallas": dict(block_rows=256),
                "xla": dict(block_rows=1024),
            },
        )
    """
    op = operation(name)
    for space, params in space_params.items():
        bound = functools.partial(skeleton, **params)
        functools.update_wrapper(bound, skeleton)
        op.register(space)(bound)
    return op
