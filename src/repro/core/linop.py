"""The LinOp hierarchy — gko::LinOp for this repo.

Ginkgo's algorithm side rests on one abstraction: every matrix format, every
preconditioner, and every solver is a ``gko::LinOp`` composing through a
single ``apply`` interface.  That is what lets a solver precondition another
solver, a shifted system ``A + sigma * I`` be expressed without materializing
it, and a matrix-free user operator flow through any Krylov method unchanged.

This module is that abstraction.  It deliberately imports nothing from the
format / kernel layers, so every layer (``repro.sparse``, ``repro.precond``,
``repro.solvers``, ``repro.batch``) can build on it without cycles:

* :class:`LinOp` — the base: ``shape``, ``dtype``, simple ``apply(b)`` and
  advanced ``apply(alpha, b, beta, x)`` (Ginkgo's ``x = alpha*A*b + beta*x``),
  an ``executor`` slot threaded down through compositions, and ``__call__``
  aliasing the simple apply so a LinOp is a drop-in for the historical
  plain-callable preconditioner convention.
* :class:`Composition` — ``(A o B o ...) v`` applied right to left
  (``gko::Composition``).
* :class:`Sum` — ``(A + B + ...) v`` (``gko::Combination`` with unit
  coefficients; scale terms with :class:`ScaledIdentity` compositions).
* :class:`ScaledIdentity` — ``sigma * I``, the shifted-system building block:
  ``Sum(A, ScaledIdentity(sigma, n))`` is ``A + sigma*I`` without touching
  ``A``'s storage.
* :class:`Transpose` — lazy transpose over operators whose concrete type
  supports it (formats expose host-side ``transpose()``).
* :class:`MatrixFreeOp` — a user-supplied jittable apply with declared shape
  and dtype (``gko::matrix::Identity``-style wrappers, stencils, JVPs, ...).
* :class:`Identity` — the zero-storage identity operator (also the identity
  preconditioner; ``storage_bytes == 0``).

Executor threading: an ``executor=`` passed to ``apply`` overrides everything
below it in the operator tree; otherwise an operator's own ``executor``
attribute applies to its subtree; otherwise dispatch falls to the ambient
executor (:func:`repro.core.executor.current_executor`) at the registry level.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "LinOp",
    "Composition",
    "Sum",
    "ScaledIdentity",
    "Transpose",
    "MatrixFreeOp",
    "Identity",
    "as_linop",
]


class LinOp:
    """Base linear operator (gko::LinOp).

    Subclasses provide ``shape`` (as attribute or property), ``dtype``, and
    ``_apply(b, executor)``.  Everything else — the two ``apply`` arities,
    ``__call__``, the combinator sugar — comes from here.
    """

    #: executor this operator prefers; ``None`` defers to the caller/ambient.
    executor = None

    #: the distributed apply protocol (gko::experimental::distributed):
    #: operators whose storage is row-sharded over a mesh axis set this True
    #: and implement :meth:`local_operator`; the solver layer consults the
    #: flag to run the whole iteration under ``shard_map`` with per-shard
    #: kernels and ``psum`` reductions (see :mod:`repro.distributed.solvers`).
    is_distributed = False

    # -- subclass surface ------------------------------------------------------
    def _apply(self, b: jax.Array, executor) -> jax.Array:
        raise NotImplementedError(
            f"{type(self).__name__} does not implement _apply"
        )

    def local_operator(self, executor=None) -> "LinOp":
        """Per-shard operator for the distributed apply protocol.

        Called INSIDE a ``shard_map`` body on an operator whose array leaves
        carry a leading shard axis of size 1; returns the LinOp acting on
        this shard's padded-local vectors (collectives allowed — halo
        exchange, ``psum``).  Only meaningful when ``is_distributed``.
        """
        raise NotImplementedError(
            f"{type(self).__name__} is not a distributed operator "
            "(is_distributed is False)"
        )

    # -- the gko::LinOp::apply interface ---------------------------------------
    def apply(self, *args, executor=None) -> jax.Array:
        """``apply(b) -> A @ b`` or ``apply(alpha, b, beta, x) -> alpha*A@b + beta*x``.

        The four-argument form is Ginkgo's advanced apply; it is what lets IR
        fuse the residual update ``r = b - A x`` into one operator call:
        ``A.apply(-1.0, x, 1.0, b)``.
        """
        ex = executor if executor is not None else self.executor
        if len(args) == 1:
            return self._apply(args[0], ex)
        if len(args) == 4:
            alpha, b, beta, x = args
            return alpha * self._apply(b, ex) + beta * x
        raise TypeError(
            f"apply takes (b) or (alpha, b, beta, x); got {len(args)} arguments"
        )

    def __call__(self, b: jax.Array) -> jax.Array:
        return self.apply(b)

    # -- reporting -------------------------------------------------------------
    @property
    def storage_bytes(self) -> int:
        """Bytes of operator-owned auxiliary storage (0 unless overridden).

        Preconditioners report their generated data here (the adaptive-
        precision metric); matrix formats report their buffers via
        ``memory_bytes``.
        """
        return 0

    # -- combinator sugar ------------------------------------------------------
    def __matmul__(self, other):
        if isinstance(other, LinOp):
            return Composition(self, other)
        return NotImplemented

    def __add__(self, other):
        if isinstance(other, LinOp):
            return Sum(self, other)
        return NotImplemented


def _shape_of(op) -> Optional[Tuple[int, int]]:
    return getattr(op, "shape", None)


def _dtype_of(op):
    return getattr(op, "dtype", None)


def _combined_dtype(ops):
    """Result dtype across operands; None when no operand declares one."""
    dtypes = [d for d in map(_dtype_of, ops) if d is not None]
    return jnp.result_type(*dtypes) if dtypes else None


def _child_apply(op, b, executor):
    """Apply a child operator, threading the resolved executor down."""
    if isinstance(op, LinOp):
        return op.apply(b, executor=executor)
    # tolerated foreign objects (bare callables) — no executor to thread
    return op(b)


class Composition(LinOp):
    """``Composition(A, B, ...) v = A(B(... v))`` — gko::Composition.

    Operands apply right to left, matching matrix-product order; shapes must
    chain (``A.shape[1] == B.shape[0]`` where both are known).
    """

    def __init__(self, *ops, executor=None):
        if not ops:
            raise ValueError("Composition needs at least one operand")
        for left, right in zip(ops, ops[1:]):
            ls, rs = _shape_of(left), _shape_of(right)
            if ls is not None and rs is not None and ls[1] != rs[0]:
                raise ValueError(
                    f"composition shape mismatch: {ls} cannot follow {rs}"
                )
        self.ops = tuple(ops)
        self.executor = executor

    @property
    def shape(self) -> Tuple[int, int]:
        first, last = _shape_of(self.ops[0]), _shape_of(self.ops[-1])
        if first is None or last is None:
            raise AttributeError("composition over shapeless operands")
        return (first[0], last[1])

    @property
    def dtype(self):
        return _combined_dtype(self.ops)

    def _apply(self, b, executor):
        for op in reversed(self.ops):
            b = _child_apply(op, b, executor)
        return b


class Sum(LinOp):
    """``Sum(A, B, ...) v = A v + B v + ...`` — gko::Combination (unit coeffs).

    All operands must share a shape (where known).  Scale a term by composing
    it with :class:`ScaledIdentity`.
    """

    def __init__(self, *ops, executor=None):
        if not ops:
            raise ValueError("Sum needs at least one operand")
        shapes = [s for s in map(_shape_of, ops) if s is not None]
        if shapes and any(s != shapes[0] for s in shapes[1:]):
            raise ValueError(f"sum over mismatched shapes {shapes}")
        self.ops = tuple(ops)
        self.executor = executor

    @property
    def shape(self) -> Tuple[int, int]:
        for op in self.ops:
            s = _shape_of(op)
            if s is not None:
                return s
        raise AttributeError("sum over shapeless operands")

    @property
    def dtype(self):
        return _combined_dtype(self.ops)

    def _apply(self, b, executor):
        acc = _child_apply(self.ops[0], b, executor)
        for op in self.ops[1:]:
            acc = acc + _child_apply(op, b, executor)
        return acc


class ScaledIdentity(LinOp):
    """``sigma * I`` on an ``n``-vector — the shifted-system building block.

    ``Sum(A, ScaledIdentity(sigma, n))`` expresses ``A + sigma*I`` without
    modifying ``A``'s stored values (Ginkgo applies shifts the same way in
    its eigensolver drivers).
    """

    def __init__(self, scale, n: int, dtype=None, executor=None):
        self.scale = scale
        self.n = int(n)
        self._dtype = jnp.dtype(dtype) if dtype is not None else None
        self.executor = executor

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.n)

    @property
    def dtype(self):
        if self._dtype is not None:
            return self._dtype
        return jnp.result_type(self.scale)

    def _apply(self, b, executor):
        return jnp.asarray(self.scale, b.dtype) * b


class Identity(LinOp):
    """The identity operator — also the identity preconditioner.

    A real LinOp with ``storage_bytes == 0`` (it owns no generated data), not
    a bare function: benchmark and solver code can read storage, shape, and
    dtype uniformly across every ``M=``.
    """

    def __init__(self, n: Optional[int] = None, dtype=None):
        self.n = n
        self._dtype = jnp.dtype(dtype) if dtype is not None else None

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        return None if self.n is None else (self.n, self.n)

    @property
    def dtype(self):
        return self._dtype

    @property
    def storage_bytes(self) -> int:
        return 0

    def _apply(self, b, executor):
        return b


class Transpose(LinOp):
    """Lazy transpose of an operator whose concrete type supports it.

    The wrapped operator must expose ``transpose()`` (the sparse formats do,
    host-side); composed operators distribute through their operands
    recursively.  Operators without a transpose (matrix-free, solvers) raise
    ``NotImplementedError`` — exactly Ginkgo's ``Transposable`` contract.

    Executor threading matches the forward operator exactly: with no explicit
    ``executor=``, the wrap inherits the wrapped operator's pinned executor,
    so ``Transpose(Composition(...)).apply`` dispatches through the same
    ``Executor.launch_config`` path as ``Composition(...).apply`` — the
    implicit-layer backward (adjoint solve on ``Transpose(A)``) depends on
    the two passes landing in the same kernel space.
    """

    def __init__(self, op, executor=None):
        self.op = op
        self.executor = (
            executor if executor is not None else getattr(op, "executor", None)
        )
        self._t = _transpose(op)

    @property
    def shape(self) -> Tuple[int, int]:
        m, n = self.op.shape
        return (n, m)

    @property
    def dtype(self):
        return _dtype_of(self.op)

    def _apply(self, b, executor):
        return _child_apply(self._t, b, executor)


def _transpose(op):
    if isinstance(op, Transpose):
        return op.op
    if isinstance(op, (ScaledIdentity, Identity)):
        return op
    if isinstance(op, Composition):
        return Composition(
            *[Transpose(o) for o in reversed(op.ops)], executor=op.executor
        )
    if isinstance(op, Sum):
        return Sum(*[Transpose(o) for o in op.ops], executor=op.executor)
    t = getattr(op, "transpose", None)
    if callable(t):
        return t()
    raise NotImplementedError(
        f"{type(op).__name__} is not transposable (no transpose() support)"
    )


class MatrixFreeOp(LinOp):
    """A user-supplied jittable apply with declared shape/dtype.

    The matrix-free escape hatch: stencils, JVPs, anything ``v -> A v``.
    ``matvec`` must be a pure function of its vector argument (it is traced
    under ``jit`` inside the solvers).
    """

    def __init__(
        self,
        matvec: Callable[[jax.Array], jax.Array],
        shape: Optional[Tuple[int, int]] = None,
        dtype=None,
        executor=None,
    ):
        self.matvec = matvec
        self.shape = tuple(shape) if shape is not None else None
        self.dtype = jnp.dtype(dtype) if dtype is not None else None
        self.executor = executor

    def _apply(self, b, executor):
        return self.matvec(b)


def as_linop(A, *, shape=None, dtype=None, executor=None) -> LinOp:
    """Coerce ``A`` into a LinOp.

    LinOps (formats, preconditioners, solvers, combinators) pass through
    unchanged; bare callables wrap into :class:`MatrixFreeOp`.  This is the
    single coercion point the solver layer uses, so plain-callable operators
    keep working everywhere a LinOp is expected.
    """
    if isinstance(A, LinOp):
        return A
    if callable(A):
        return MatrixFreeOp(A, shape=shape, dtype=dtype, executor=executor)
    raise TypeError(
        f"cannot interpret {type(A).__name__} as a linear operator; expected "
        "a LinOp (format / preconditioner / solver / combinator) or a "
        "callable v -> A @ v"
    )
