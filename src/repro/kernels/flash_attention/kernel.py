"""Causal GQA flash attention — Pallas TPU kernel.

TPU-adapted blocking (DESIGN.md: rethink tiling for VMEM + MXU rather than
porting a CUDA flash kernel):

* grid = (B, Hq, Sq/block_q, Skv/block_kv); the kv axis is innermost, so the
  running softmax state (m, l, acc) persists in VMEM scratch across kv steps
  and is finalized on the last one (TPU grids execute sequentially — the
  revisit-accumulate idiom replaces CUDA's per-CTA inner loop);
* GQA is folded into the index_map: query head ``h`` reads kv head
  ``h // group`` — no repeated K/V materialization (paper's "consumer-specific
  kernel design": the kernel serves exactly the layer contract we need);
* fully-masked kv blocks (kv_start > q_end under causality) are predicated off
  with ``pl.when``;
* all softmax statistics are f32 regardless of input dtype; QK^T and PV hit
  the MXU with ``preferred_element_type=f32``.

The running max/denominator live in (block_q, 128) scratch tiles (value
broadcast across lanes) to stay VREG-aligned.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")
_LANES = 128


def _flash_kernel(
    q_ref,  # (1, 1, block_q, D)
    k_ref,  # (1, 1, block_kv, D)
    v_ref,  # (1, 1, block_kv, D)
    o_ref,  # (1, 1, block_q, D)
    m_scr,  # (block_q, LANES) f32
    l_scr,  # (block_q, LANES) f32
    acc_scr,  # (block_q, D) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_kv: int,
    kv_offset: int,
    kv_len: int,
    num_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block-level skip: kv block strictly above the diagonal band
    q_end = qi * block_q + block_q - 1 + kv_offset  # last absolute q position
    kv_start = ki * block_kv
    should_run = (kv_start <= q_end) if causal else True

    @pl.when(should_run)
    def _step():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        s = jax.lax.dot_general(
            q,
            k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (block_q, block_kv)

        kv_idx = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if causal:
            q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            s = jnp.where(q_idx + kv_offset >= kv_idx, s, NEG_INF)
        # mask padded kv columns (kv_len < padded Skv)
        s = jnp.where(kv_idx < kv_len, s, NEG_INF)

        m_prev = m_scr[...][:, :1]  # (block_q, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (all -inf) so exp() sees a finite argument
        m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(s == NEG_INF, 0.0, p)
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_safe))

        l_prev = l_scr[...][:, :1]
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)

        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype),
            v_ref[0, 0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # padded/fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, S, D = q.shape
    _, Hkv, Skv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not divisible by Hkv={Hkv}")
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    # `kv_offset` aligns the causal diagonal when Skv > S (queries are the
    # last S positions of the kv stream — chunked prefill / append decoding).
    kv_offset = Skv - S

    block_q = min(block_q, S)
    block_kv = min(block_kv, Skv)
    pq = ((S + block_q - 1) // block_q) * block_q
    pkv = ((Skv + block_kv - 1) // block_kv) * block_kv
    if pq != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq - S), (0, 0)))
    if pkv != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv - Skv), (0, 0)))
    nq = pq // block_q
    nkv = pkv // block_kv

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        kv_offset=kv_offset,
        kv_len=Skv,
        num_kv_blocks=nkv,
    )

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, pq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
