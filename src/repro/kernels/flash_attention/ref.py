"""Pure-jnp oracle for causal GQA flash attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mha_ref(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """Dense softmax attention with GQA head sharing (kv heads repeated)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    Skv = k.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)

    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        q_idx = jnp.arange(S)[:, None] + (Skv - S)  # align ends (prefill cache)
        kv_idx = jnp.arange(Skv)[None, :]
        mask = q_idx >= kv_idx
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)
