"""Registry bindings for attention (operation ``nn_attention``)."""

from __future__ import annotations

from typing import Optional

from repro.core import registry
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref

attention_op = registry.operation(
    "nn_attention", "softmax attention (B,Hq,S,D)x(B,Hkv,Skv,D) -> (B,Hq,S,D)"
)


@attention_op.register("reference")
def _attn_reference(ex, q, k, v, causal: bool = True, scale: Optional[float] = None):
    return mha_ref(q, k, v, causal=causal, scale=scale)


@attention_op.register("xla")
def _attn_xla(ex, q, k, v, causal: bool = True, scale: Optional[float] = None):
    # dense-materialized attention; XLA fuses but the S x Skv score matrix hits
    # HBM — the Pallas kernel is the memory-saving path
    return mha_ref(q, k, v, causal=causal, scale=scale)


def _vmem_bytes(block_q: int, block_kv: int, d: int, itemsize: int) -> int:
    """Working set per grid step: q/k/v/o tiles + f32 scratch (m, l, acc) +
    the (block_q, block_kv) score tile."""
    tiles = (block_q + 2 * block_kv + block_q) * d * itemsize
    scratch = block_q * (128 * 2 + d) * 4
    scores = block_q * block_kv * 4
    return tiles + scratch + scores


@attention_op.register("pallas")
def _attn_pallas(ex, q, k, v, causal: bool = True, scale: Optional[float] = None):
    # block shapes from the hardware table (MXU-aligned), shrunk until the
    # working set fits the target's VMEM budget (paper: per-architecture
    # kernel configuration parameters live with the executor, not the kernel)
    block_q = block_kv = max(ex.hw.mxu_dim, 128)
    d = q.shape[-1]
    budget = ex.hw.vmem_limit_bytes // 4  # leave headroom for double-buffering
    while (
        block_q > ex.hw.sublane_count
        and _vmem_bytes(block_q, block_kv, d, q.dtype.itemsize) > budget
    ):
        block_q //= 2
        block_kv //= 2
    return flash_attention(
        q,
        k,
        v,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        interpret=ex.interpret,
    )
