"""Registry bindings for attention (operation ``nn_attention``).

One skeleton serves all three kernel spaces (``instantiate_common`` — the
``common/`` folder idiom); the Pallas instantiation resolves its block
geometry through the executor's launch-configuration table instead of
hard-coding tile sizes.
"""

from __future__ import annotations

from typing import Optional

from repro.core import registry, tuning
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref


def _vmem_bytes(shapes, block) -> int:
    """Working set per grid step: q/k/v/o tiles + f32 scratch (m, l, acc) +
    the (block_q, block_kv) score tile."""
    bq, bkv = block["block_q"], block["block_kv"]
    d = shapes.get("D", 128)
    itemsize = shapes.get("itemsize", 4)
    tiles = (bq + 2 * bkv + bq) * d * itemsize
    scratch = bq * (128 * 2 + d) * 4
    scores = bq * bkv * 4
    return tiles + scratch + scores


def _constrain(hw, shapes, block):
    # power-of-two tiles keep the MXU happy and the shrink loop simple
    return {
        key: tuning.prev_pow2(max(int(block[key]), hw.sublane_count))
        for key in ("block_q", "block_kv")
    }


def _candidates(hw, shapes):
    base = max(hw.mxu_dim, 128)
    return [
        {"block_q": base // 2, "block_kv": base // 2},
        {"block_q": base, "block_kv": base},
        {"block_q": base, "block_kv": 2 * base},
        {"block_q": 2 * base, "block_kv": 2 * base},
    ]


ATTENTION_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="nn_attention",
        params=("block_q", "block_kv"),
        seed=lambda hw: {
            "block_q": max(hw.mxu_dim, 128),
            "block_kv": max(hw.mxu_dim, 128),
        },
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_q": 8, "block_kv": 8},
        candidates=_candidates,
    )
)

# kv-chunk length of the chunked-scan xla attention variant
# (repro.nn.attention.attention_xla_chunked): a launch parameter like any
# other — resolved per target when cfg.attn_chunk is None.  The scan never
# materializes (S, Skv), so the budget driver is just the per-chunk score block.
CHUNKED_ATTENTION_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="nn_attention_chunked",
        params=("chunk",),
        seed=lambda hw: {"chunk": max(hw.lane_count * 4, 512)},
        vmem_bytes=lambda shapes, block: 4
        * block["chunk"]
        * (shapes.get("S", 512) + 2 * shapes.get("D", 128)),
        constrain=lambda hw, shapes, block: {
            "chunk": max(
                int(block["chunk"]) - int(block["chunk"]) % hw.lane_count,
                hw.lane_count,
            )
        },
        floors={"chunk": 128},
        candidates=lambda hw, shapes: [{"chunk": c} for c in (256, 512, 1024)],
    )
)


def _attention_skeleton(
    ex, q, k, v, causal: bool = True, scale: Optional[float] = None, *, variant: str
):
    if variant != "pallas":
        # dense-materialized attention; XLA fuses but the S x Skv score matrix
        # hits HBM — the Pallas kernel is the memory-saving path
        return mha_ref(q, k, v, causal=causal, scale=scale)
    cfg = ex.launch_config(
        "nn_attention",
        {
            "S": q.shape[2],
            "Skv": k.shape[2],
            "D": q.shape[-1],
            "itemsize": q.dtype.itemsize,
        },
    )
    return flash_attention(
        q,
        k,
        v,
        causal=causal,
        scale=scale,
        block_q=cfg["block_q"],
        block_kv=cfg["block_kv"],
        interpret=ex.interpret,
    )


attention_op = registry.instantiate_common(
    "nn_attention",
    _attention_skeleton,
    {
        "reference": dict(variant="reference"),
        "xla": dict(variant="xla"),
        "pallas": dict(variant="pallas"),
    },
)
attention_op.__doc__ = "softmax attention (B,Hq,S,D)x(B,Hkv,Skv,D) -> (B,Hq,S,D)"
