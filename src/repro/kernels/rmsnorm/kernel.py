"""Fused RMSNorm Pallas TPU kernel.

One grid row per block of ``block_rows`` token rows; the full feature axis
stays resident in VMEM (d_model up to ~8k fits comfortably: 8k * block_rows *
4B).  The reduction runs in f32 regardless of input dtype (bf16-safe), and the
scale multiply is fused — one HBM read + one write per element, which is the
roofline for this op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    weight: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fused RMSNorm over the last axis of ``x`` (any leading shape)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    block_rows = max(min(block_rows, rows), 1)
    # pad rows to a multiple of block_rows (padding rows normalize garbage,
    # then get sliced away — they never produce NaN because var >= 0, eps > 0)
    padded = ((rows + block_rows - 1) // block_rows) * block_rows
    if padded != rows:
        x2 = jnp.pad(x2, ((0, padded - rows), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(padded // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out[:rows].reshape(orig_shape)
