"""Registry bindings for fused RMSNorm (operation ``nn_rmsnorm``)."""

from __future__ import annotations

from repro.core import registry
from repro.kernels.rmsnorm.kernel import rmsnorm as rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref

rmsnorm_op = registry.operation("nn_rmsnorm", "fused RMSNorm over the last axis")


@rmsnorm_op.register("reference")
def _rmsnorm_reference(ex, x, weight, eps: float = 1e-6):
    return rmsnorm_ref(x, weight, eps)


@rmsnorm_op.register("xla")
def _rmsnorm_xla(ex, x, weight, eps: float = 1e-6):
    # same math; XLA fuses this well — the Pallas win is explicit tiling
    return rmsnorm_ref(x, weight, eps)


@rmsnorm_op.register("pallas")
def _rmsnorm_pallas(ex, x, weight, eps: float = 1e-6):
    return rmsnorm_pallas(x, weight, eps=eps, interpret=ex.interpret)
