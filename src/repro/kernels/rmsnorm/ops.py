"""Registry bindings for fused RMSNorm (operation ``nn_rmsnorm``).

One skeleton, three kernel spaces; the Pallas instantiation takes its row-tile
from the launch-configuration table (sublane-aligned, VMEM-checked) instead of
a hard-coded ``block_rows``.
"""

from __future__ import annotations

from repro.core import registry, tuning
from repro.kernels.rmsnorm.kernel import rmsnorm as rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _vmem_bytes(shapes, block) -> int:
    # x tile in + out (native dtype) + the f32 compute copy + the weight row
    rows = block["block_rows"]
    d = shapes.get("d", 4096)
    itemsize = shapes.get("itemsize", 4)
    return rows * d * (2 * itemsize + 4) + d * itemsize


def _constrain(hw, shapes, block):
    rows = max(int(block["block_rows"]), hw.sublane_count)
    rows -= rows % hw.sublane_count  # keep tiles VREG-aligned (8 sublanes)
    return {"block_rows": rows}


RMSNORM_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="nn_rmsnorm",
        params=("block_rows",),
        seed=lambda hw: {"block_rows": hw.sublane_count * 32},
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_rows": 8},
        candidates=lambda hw, shapes: [
            {"block_rows": hw.sublane_count * m} for m in (8, 16, 32, 64, 128)
        ],
    )
)


def _rmsnorm_skeleton(ex, x, weight, eps: float = 1e-6, *, variant: str):
    if variant != "pallas":
        # same math; XLA fuses this well — the Pallas win is explicit tiling
        return rmsnorm_ref(x, weight, eps)
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    cfg = ex.launch_config(
        "nn_rmsnorm",
        {"rows": rows, "d": x.shape[-1], "itemsize": x.dtype.itemsize},
    )
    return rmsnorm_pallas(
        x, weight, eps=eps, block_rows=cfg["block_rows"], interpret=ex.interpret
    )


rmsnorm_op = registry.instantiate_common(
    "nn_rmsnorm",
    _rmsnorm_skeleton,
    {
        "reference": dict(variant="reference"),
        "xla": dict(variant="xla"),
        "pallas": dict(variant="pallas"),
    },
)
rmsnorm_op.__doc__ = "fused RMSNorm over the last axis"
