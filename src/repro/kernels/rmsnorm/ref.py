"""Pure-jnp oracle for fused RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """y = x / rms(x) * weight, rms over the last axis, computed in f32."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)
