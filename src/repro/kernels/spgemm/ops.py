"""Registry binding: Pallas SpGEMM expansion + transpose permutation.

The pallas space shares the host structure pass (row-nnz upper bound,
expansion maps, coalesce) with the reference/xla spaces — see
:mod:`repro.sparse.ops` — and replaces only the flop-carrying numeric pass
with the tiled kernels from :mod:`repro.kernels.spgemm.kernel`.  Geometry
resolves through ``Executor.launch_config`` against the ``spgemm``
:class:`~repro.core.tuning.TuningSpec` below (one spec for the family: the
permutation kernel reuses ``block_t``).  When the working set exceeds VMEM
the skeletons fall back to the xla formulations — graceful degradation, the
same contract as every kernel family.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import registry, tuning
from repro.kernels.spgemm.kernel import csr_permute, spgemm_expand
from repro.sparse.formats import Csr, csr_from_arrays


def _vmem_bytes(shapes, block) -> int:
    # idx tile (int32) + product tile, a-value tile, padded B values resident
    bt, bk = block["block_t"], block["block_k"]
    itemsize = shapes.get("itemsize", 4)
    nnzb = shapes.get("nnzb", 0)
    return bt * bk * (4 + itemsize) + bt * itemsize + (nnzb + 1) * itemsize


def _constrain(hw, shapes, block):
    bt = max(int(block["block_t"]), hw.sublane_count)
    bt -= bt % hw.sublane_count
    bk = tuning.prev_pow2(max(int(block["block_k"]), 8))
    return {"block_t": bt, "block_k": bk}


SPGEMM_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="spgemm",
        params=("block_t", "block_k"),
        seed=lambda hw: {
            "block_t": max(hw.sublane_count * 32, 8),
            "block_k": hw.lane_count,
        },
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_t": 8, "block_k": 8},
        candidates=lambda hw, shapes: [
            {"block_t": bt, "block_k": bk}
            for bt in (
                hw.sublane_count * 16,
                hw.sublane_count * 32,
                hw.sublane_count * 64,
            )
            for bk in (hw.lane_count // 2, hw.lane_count)
        ],
    )
)


def _spgemm_skeleton(ex, A: Csr, B: Csr, *, variant: str) -> Csr:
    from repro.sparse.ops import (
        _empty_csr,
        _finalize_spgemm,
        _spgemm_maps,
        _spgemm_xla,
    )

    m = A.shape[0]
    n = B.shape[1]
    rows_a, b_start, b_len, K = _spgemm_maps(A, B)
    if K == 0 or rows_a.size == 0:
        return _empty_csr(m, n, np.result_type(A.dtype, B.dtype))
    cfg = ex.launch_config(
        "spgemm",
        {
            "t": rows_a.size,
            "k": K,
            "nnzb": B.nnz,
            "itemsize": B.values.dtype.itemsize,
        },
    )
    if not cfg.fits_vmem:
        return _spgemm_xla(ex, A, B)
    q = np.arange(K)
    valid = q[None, :] < b_len[:, None]  # (nnzA, K) host bool
    # +1-shift into the zero-padded value vector: padding gathers 0.0
    idx1 = np.where(valid, b_start[:, None] + q[None, :] + 1, 0).astype(
        np.int32
    )
    b_pad = jnp.concatenate(
        [jnp.zeros(1, B.values.dtype), B.values]
    )
    prod = spgemm_expand(
        A.values,
        jnp.asarray(idx1),
        b_pad,
        block_t=cfg["block_t"],
        block_k=cfg["block_k"],
        interpret=ex.interpret,
    )
    # output columns are structure — computed host-side from the same maps
    bc_pad = np.concatenate([np.zeros(1, np.int64), np.asarray(B.indices)])
    cols = bc_pad[idx1]
    return _finalize_spgemm(rows_a, K, valid, cols, prod, m, n)


def _sptranspose_skeleton(ex, A: Csr, *, variant: str) -> Csr:
    from repro.sparse.ops import _sptranspose_xla

    m, n = A.shape
    nnz = A.nnz
    cfg = ex.launch_config(
        "spgemm",
        {"t": nnz, "k": 1, "nnzb": nnz, "itemsize": A.values.dtype.itemsize},
    )
    if not cfg.fits_vmem:
        return _sptranspose_xla(ex, A)
    # host structure pass: the column-major permutation and transposed indptr
    ai = np.asarray(A.indptr)
    cols = np.asarray(A.indices)
    rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(ai))
    order = np.lexsort((rows, cols)).astype(np.int32)
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(np.bincount(cols, minlength=n))
    # device value shuffle through the tiled permutation kernel
    vals = csr_permute(
        A.values,
        jnp.asarray(order),
        block_t=cfg["block_t"],
        interpret=ex.interpret,
    )
    return csr_from_arrays(
        indptr, rows[order].astype(np.int32), vals, (n, m)
    )


registry.instantiate_common(
    "spgemm", _spgemm_skeleton, {"pallas": dict(variant="pallas")}
)
registry.instantiate_common(
    "sptranspose", _sptranspose_skeleton, {"pallas": dict(variant="pallas")}
)
