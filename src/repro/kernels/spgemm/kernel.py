"""SpGEMM numeric-expansion + CSR-permutation Pallas TPU kernels.

SpGEMM (C = A·B for CSR operands) splits into a host structure pass and a
flop-carrying numeric pass (see :mod:`repro.sparse.ops`).  The numeric pass is
what these kernels accelerate:

* ``spgemm_expand`` — the expansion multiply.  Entry t of A contributes
  ``a_vals[t] · B.values[idx[t, q]]`` for each of up to K entries of B's row
  ``A.indices[t]``; the host pass flattens that into a rectangular gather map
  ``idx`` of shape (T, K) whose indices are +1-shifted into a zero-padded copy
  of B's values, so padding slots gather slot 0 and contribute exactly 0 — the
  predication-free padding idiom from the ELL kernels.  Each (block_t, block_k)
  tile is an independent gather-multiply against the VMEM-resident padded
  value vector; there is no cross-tile accumulation, so the grid is
  embarrassingly parallel.

* ``csr_permute`` — the transpose value shuffle: ``out[t] = values[order[t]]``
  with ``order`` the host-computed column-major permutation.  One gather per
  tile against the VMEM-resident source vector.

Both kernels keep the *data-dependent* parts (structure, sort order) on the
host where they are computed once per pattern, and stream the value-dependent
arithmetic through VMEM tiles — the split that lets the serve layer reuse
structure across value refreshes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spgemm_expand_kernel(a_ref, idx_ref, bpad_ref, o_ref):
    a = a_ref[...]  # (block_t,)
    idx = idx_ref[...]  # (block_t, block_k), +1-shifted, 0 = padding
    bpad = bpad_ref[...]  # (nnzb + 1,), slot 0 is the zero pad
    o_ref[...] = a[:, None] * bpad[idx]


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_k", "interpret")
)
def spgemm_expand(
    a_vals: jax.Array,
    idx: jax.Array,
    b_pad: jax.Array,
    *,
    block_t: int = 256,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Expansion products ``a_vals[:, None] * b_pad[idx]`` of shape (T, K).

    ``idx`` is +1-shifted into ``b_pad`` (whose slot 0 holds 0.0), so padded
    lanes contribute zero without predication.
    """
    t, k = idx.shape
    nb1 = b_pad.shape[0]

    block_t = max(min(block_t, t), 1)
    block_k = max(min(block_k, k), 1)
    pt = ((t + block_t - 1) // block_t) * block_t
    pk = ((k + block_k - 1) // block_k) * block_k
    if (pt, pk) != (t, k):
        idx = jnp.pad(idx, ((0, pt - t), (0, pk - k)))
    if pt != t:
        a_vals = jnp.pad(a_vals, (0, pt - t))

    out = pl.pallas_call(
        _spgemm_expand_kernel,
        grid=(pt // block_t, pk // block_k),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
            pl.BlockSpec((block_t, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((nb1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, block_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pt, pk), b_pad.dtype),
        interpret=interpret,
    )(a_vals, idx, b_pad)
    return out[:t, :k]


def _csr_permute_kernel(v_ref, ord_ref, o_ref):
    o_ref[...] = v_ref[...][ord_ref[...]]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def csr_permute(
    values: jax.Array,
    order: jax.Array,
    *,
    block_t: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``values[order]`` — the transpose value shuffle, tiled over ``order``."""
    nnz = values.shape[0]
    t = order.shape[0]
    block_t = max(min(block_t, t), 1)
    pt = ((t + block_t - 1) // block_t) * block_t
    if pt != t:
        order = jnp.pad(order, (0, pt - t))

    out = pl.pallas_call(
        _csr_permute_kernel,
        grid=(pt // block_t,),
        in_specs=[
            pl.BlockSpec((nnz,), lambda i: (0,)),
            pl.BlockSpec((block_t,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pt,), values.dtype),
        interpret=interpret,
    )(values, order)
    return out[:t]
