"""SpGEMM expansion + CSR permutation Pallas kernels."""
