"""Pure-jnp oracles for the SpGEMM kernel family."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spgemm_expand_ref(
    a_vals: jax.Array, idx: jax.Array, b_pad: jax.Array
) -> jax.Array:
    """Expansion products: ``a_vals[:, None] * b_pad[idx]``."""
    return a_vals[:, None] * b_pad[idx]


def csr_permute_ref(values: jax.Array, order: jax.Array) -> jax.Array:
    """Permutation gather: ``values[order]``."""
    return jnp.asarray(values)[order]
