"""Registry binding: the Pallas batched ELL SpMV serves ``spmv_batch_ell``.

The reference/xla spaces live in :mod:`repro.batch.ops`; this module binds the
hardware-native skeleton.  Tile geometry resolves through the executor's
launch-configuration table (new per-target ``spmv_batch_ell`` entries ride the
same autotune cache / table override / HardwareParams-seed chain as every
other kernel family — no hard-coded block sizes).
"""

from __future__ import annotations

from repro.batch.formats import BatchEll
from repro.core import registry, tuning
from repro.kernels.spmv_batch_ell.kernel import (
    spmv_batch_ell as spmv_batch_ell_pallas,
)


def _vmem_bytes(shapes, block) -> int:
    # shared col tile (int32) + one system's value tile, that system's x row
    # fully VMEM-resident, one output column — the batch axis streams, so it
    # adds no per-step working set.
    bm, bk = block["block_m"], block["block_k"]
    n = shapes.get("n", 0)
    itemsize = shapes.get("itemsize", 4)
    return bm * bk * (itemsize + 4) + n * itemsize + bm * itemsize


def _constrain(hw, shapes, block):
    bm = max(int(block["block_m"]), hw.sublane_count)
    bm -= bm % hw.sublane_count
    # power-of-two lanes keep the coop butterfly legal
    bk = tuning.prev_pow2(max(int(block["block_k"]), 8))
    return {"block_m": bm, "block_k": bk}


BATCH_ELL_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="spmv_batch_ell",
        params=("block_m", "block_k"),
        seed=lambda hw: {
            # batched systems are small (Ginkgo: O(100)-O(10k) rows each), so
            # seed a tighter row tile than the single-system kernel and let
            # the k axis take the full lane width
            "block_m": max(hw.sublane_count * 16, 8),
            "block_k": hw.lane_count,
        },
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_m": 8, "block_k": 8},
        candidates=lambda hw, shapes: [
            {"block_m": bm, "block_k": bk}
            for bm in (
                hw.sublane_count * 8,
                hw.sublane_count * 16,
                hw.sublane_count * 32,
            )
            for bk in (hw.lane_count // 2, hw.lane_count)
        ],
    )
)


def _spmv_batch_ell_skeleton(ex, A: BatchEll, X, *, variant: str):
    if X.ndim != 2:
        raise NotImplementedError("pallas batched ELL spmv wants (nb, n) rhs")
    cfg = ex.launch_config(
        "spmv_batch_ell",
        {
            "nb": A.values.shape[0],
            "m": A.values.shape[1],
            "k": A.values.shape[2],
            "n": X.shape[1],
            "itemsize": X.dtype.itemsize,
        },
    )
    if not cfg.fits_vmem:
        # one system's x row does not fit the residency strategy here — fall
        # through to the portable batched kernel (executor picks the variant
        # suited to the problem granularity).
        from repro.batch.ops import _spmv_batch_ell_xla

        return _spmv_batch_ell_xla(ex, A, X)
    return spmv_batch_ell_pallas(
        A.col_idx,
        A.values,
        X,
        block_m=cfg["block_m"],
        block_k=cfg["block_k"],
        use_coop=True,
        interpret=ex.interpret,
    )


registry.instantiate_common(
    "spmv_batch_ell", _spmv_batch_ell_skeleton, {"pallas": dict(variant="pallas")}
)
