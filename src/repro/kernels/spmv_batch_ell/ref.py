"""Pure-jnp oracle for the batched ELL SpMV kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_batch_ell_ref(
    col_idx: jax.Array, values: jax.Array, x: jax.Array
) -> jax.Array:
    """``y[b] = A[b] @ x[b]``: shared ``col_idx (m, k)``, ``values (nb, m, k)``,
    ``x (nb, n)`` -> ``(nb, m)``."""
    gathered = x[:, col_idx]  # (nb, m, k)
    return jnp.sum(values * gathered, axis=-1)
