"""Batched ELL SpMV Pallas TPU kernel — gko::batch::matrix::Ell::apply.

The batch dimension sits on the **outer** grid axis: grid =
(nb, m/block_m, k/block_k).  TPU grids iterate sequentially with the last
axis innermost, so one system's row/column tiles are swept to completion
before the next system starts — the shared ``col_idx`` block and the system's
``x`` row stay VMEM-resident across the whole sweep (Pallas skips the
re-fetch when a block's index map repeats), which is exactly Ginkgo's batched
kernel economics: amortize the index structure, stream only the values.

Per grid step the kernel sees the shared (block_m, block_k) column tile, one
system's matching value tile, and that system's full x row; the per-row
reduction reuses the cooperative-group butterfly from the single-system ELL
kernel (Ginkgo's "subwarp per row" strategy on lane segments).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import coop


def _spmv_batch_ell_kernel(cols_ref, vals_ref, x_ref, o_ref, *, use_coop: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = vals_ref[0]  # (block_m, block_k) — this system's value tile
    cols = cols_ref[...]  # (block_m, block_k) — shared across the batch
    x = x_ref[0]  # (n,) — this system's dense vector
    gathered = x[cols]
    prod = vals * gathered
    if use_coop:
        row_sum = coop.subgroup(prod, prod.shape[-1]).sum()[..., :1]
    else:
        row_sum = jnp.sum(prod, axis=-1, keepdims=True)
    o_ref[...] += row_sum[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "use_coop", "interpret"),
)
def spmv_batch_ell(
    col_idx: jax.Array,
    values: jax.Array,
    x: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    use_coop: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """``y[b] = A[b] @ x[b]`` for shared-pattern batched ELL.

    ``col_idx`` is ``(m, k)`` (shared), ``values`` is ``(nb, m, k)``,
    ``x`` is ``(nb, n)``; returns ``(nb, m)``.
    """
    nb, m, k = values.shape
    n = x.shape[1]

    block_m = max(min(block_m, m), 1)
    block_k = max(min(block_k, k), 1)
    # pad m and k to block multiples (padding: col 0, value 0 — contributes 0)
    pm = ((m + block_m - 1) // block_m) * block_m
    pk = ((k + block_k - 1) // block_k) * block_k
    if (pm, pk) != (m, k):
        col_idx = jnp.pad(col_idx, ((0, pm - m), (0, pk - k)))
        values = jnp.pad(values, ((0, 0), (0, pm - m), (0, pk - k)))
    use_coop = use_coop and (block_k & (block_k - 1) == 0)

    out = pl.pallas_call(
        functools.partial(_spmv_batch_ell_kernel, use_coop=use_coop),
        grid=(nb, pm // block_m, pk // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda b, i, j: (i, j)),
            pl.BlockSpec((1, block_m, block_k), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, n), lambda b, i, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, 1), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, pm, 1), values.dtype),
        interpret=interpret,
    )(col_idx, values, x)
    return out[:, :m, 0]
