"""Fused axpy + squared-norm kernel family (apply-with-reduction)."""
