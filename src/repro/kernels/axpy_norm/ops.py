"""Registry binding: the fused Pallas axpy+norm serves operation ``axpy_norm``.

The reference/xla spaces live in :mod:`repro.sparse.ops` (unfused composition,
bitwise identical to separate ``blas_axpy`` + ``blas_dot`` calls — the
fallback-parity contract).  This module binds the hardware-native fused
skeleton; batched ``(nb, n)`` operands fall through to the xla formulation
(the pallas kernel streams one vector — the batched solvers share the same
*operation* so the fusion fix lands in both paths, per-space coverage follows
the family's single-vector kernel).
"""

from __future__ import annotations

from repro.core import registry, tuning
from repro.kernels.axpy_norm.kernel import axpy_norm as axpy_norm_pallas


def _vmem_bytes(shapes, block) -> int:
    # x, y, z tiles plus the scalar accumulator
    bn = block["block_n"]
    itemsize = shapes.get("itemsize", 4)
    return 3 * bn * itemsize + 2 * itemsize


def _constrain(hw, shapes, block):
    bn = max(int(block["block_n"]), hw.lane_count)
    bn -= bn % hw.lane_count
    return {"block_n": bn}


AXPY_NORM_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="axpy_norm",
        params=("block_n",),
        seed=lambda hw: {"block_n": hw.lane_count * hw.sublane_count * 4},
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_n": 128},
        candidates=lambda hw, shapes: [
            {"block_n": hw.lane_count * hw.sublane_count * f}
            for f in (1, 2, 4, 8)
        ],
    )
)


def _axpy_norm_skeleton(ex, alpha, x, y, *, variant: str):
    if x.ndim != 1:
        # batched rows: delegate to the shared vectorized formulation
        from repro.sparse.ops import _axpy_norm_xla

        return _axpy_norm_xla(ex, alpha, x, y)
    cfg = ex.launch_config(
        "axpy_norm", {"n": x.shape[0], "itemsize": x.dtype.itemsize}
    )
    if not cfg.fits_vmem:
        from repro.sparse.ops import _axpy_norm_xla

        return _axpy_norm_xla(ex, alpha, x, y)
    return axpy_norm_pallas(
        alpha, x, y, block_n=cfg["block_n"], interpret=ex.interpret
    )


registry.instantiate_common(
    "axpy_norm", _axpy_norm_skeleton, {"pallas": dict(variant="pallas")}
)
