"""Fused axpy + squared-norm Pallas TPU kernel — apply-with-reduction.

The second arXiv:2011.08879 fusion on the Krylov hot path: every iteration
updates the residual (``r ← r - α·Ap``) and immediately needs ``‖r‖²`` for
the stopping criterion.  Unfused, that is three HBM round trips over the
vector (write z, read z, reduce); fused, the updated tile is reduced while it
is still in VMEM — one read of x and y, one write of z, and a scalar.

Grid = (n / block_n,): each step writes its z tile and adds ``Σ z²`` into a
(1, 1) accumulator block revisited by every step (TPU grids iterate
sequentially, so the read-modify-write is well-defined — the
:mod:`repro.kernels.spmv_ell` idiom).  ``alpha`` rides as a (1, 1) operand so
the kernel stays trace-compatible with solver loops where it is a traced
scalar.  Tail padding (x = y = 0) produces z = 0 and adds nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_norm_kernel(alpha_ref, x_ref, y_ref, z_ref, ss_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ss_ref[...] = jnp.zeros_like(ss_ref)

    z = alpha_ref[0, 0] * x_ref[...] + y_ref[...]
    z_ref[...] = z
    ss_ref[0, 0] += jnp.sum(z * z).astype(ss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def axpy_norm(
    alpha: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    block_n: int = 1024,
    interpret: bool = False,
):
    """(z, z·z) with z = alpha*x + y, computed in one pass over the vectors."""
    n = x.shape[0]
    block_n = max(min(block_n, n), 1)
    pn = ((n + block_n - 1) // block_n) * block_n
    if pn != n:
        x = jnp.pad(x, (0, pn - n))
        y = jnp.pad(y, (0, pn - n))
    alpha2d = jnp.asarray(alpha, x.dtype).reshape(1, 1)

    z, ss = pl.pallas_call(
        _axpy_norm_kernel,
        grid=(pn // block_n,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pn,), x.dtype),
            jax.ShapeDtypeStruct((1, 1), x.dtype),
        ],
        interpret=interpret,
    )(alpha2d, x, y)
    return z[:n], ss[0, 0]
