"""Pure-jnp oracle for the fused axpy + squared-norm kernel.

Contract: ``z = alpha * x + y`` and ``ss = z · z`` in one pass.  The oracle is
the unfused composition — which is also the bitwise definition the registry's
reference/xla spaces use, so fused-on and fused-off solver paths agree exactly
in those spaces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def axpy_norm_ref(alpha, x: jax.Array, y: jax.Array):
    """(z, z·z) with z = alpha*x + y (1-D vectors)."""
    z = alpha * x + y
    return z, jnp.vdot(z, z)
