"""Pure-jnp oracle for the fused ELL SpMV + dot kernel.

The contract the Pallas kernel is validated against: ``y = A @ x`` with the
usual ELL padding convention (col 0 / value 0 contributes nothing) and
``d = w · y`` accumulated in the same pass.  The oracle computes the two
results the unfused way — SpMV then vdot — which is also the bitwise
definition the registry's reference/xla spaces use, so fused-on and fused-off
solver paths agree exactly in those spaces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_dot_ell_ref(
    col_idx: jax.Array, values: jax.Array, x: jax.Array, w: jax.Array
):
    """(y, w·y) for ELL-format A given as (col_idx, values) of shape (m, k)."""
    y = jnp.sum(values * x[col_idx], axis=1)
    return y, jnp.vdot(w, y)
