"""Registry binding: the fused Pallas ELL SpMV+dot serves ``spmv_dot_ell``.

The reference/xla spaces live in :mod:`repro.sparse.ops` (they compute the
unfused SpMV followed by a vdot — bitwise identical to the unfused path, which
is what the fallback-parity tests pin).  This module binds the hardware-native
fused skeleton; its tile geometry resolves through the launch-configuration
table like every kernel family.

``spmv_dot_csr`` has no pallas space — mirroring the base ``spmv_csr``
coverage (the repo carries no hand-written CSR SpMV kernel); pallas executors
reach its xla formulation through the permissive fallback chain, and the
optional-op capability probe (:func:`repro.sparse.ops.has_fused_ops`) still
answers True because a serving space exists.
"""

from __future__ import annotations

from repro.core import registry, tuning
from repro.kernels.spmv_dot.kernel import spmv_dot_ell as spmv_dot_ell_pallas
from repro.sparse.formats import Ell


def _vmem_bytes(shapes, block) -> int:
    # cols (int32) + values tiles, x fully VMEM-resident, w + y column tiles,
    # one scalar accumulator
    bm, bk = block["block_m"], block["block_k"]
    n = shapes.get("n", 0)
    itemsize = shapes.get("itemsize", 4)
    return bm * bk * (itemsize + 4) + n * itemsize + 2 * bm * itemsize + itemsize


def _constrain(hw, shapes, block):
    bm = max(int(block["block_m"]), hw.sublane_count)
    bm -= bm % hw.sublane_count
    bk = tuning.prev_pow2(max(int(block["block_k"]), 8))
    return {"block_m": bm, "block_k": bk}


SPMV_DOT_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="spmv_dot",
        params=("block_m", "block_k"),
        seed=lambda hw: {
            "block_m": max(hw.sublane_count * 32, 8),
            "block_k": hw.lane_count,
        },
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_m": 8, "block_k": 8},
        candidates=lambda hw, shapes: [
            {"block_m": bm, "block_k": bk}
            for bm in (hw.sublane_count * 16, hw.sublane_count * 32, hw.sublane_count * 64)
            for bk in (hw.lane_count // 2, hw.lane_count)
        ],
    )
)


def _spmv_dot_ell_skeleton(ex, A: Ell, x, w, *, variant: str):
    if x.ndim != 1:
        raise NotImplementedError("pallas fused ELL spmv_dot is single-rhs")
    cfg = ex.launch_config(
        "spmv_dot",
        {
            "m": A.values.shape[0],
            "k": A.values.shape[1],
            "n": x.shape[0],
            "itemsize": x.dtype.itemsize,
        },
    )
    if not cfg.fits_vmem:
        from repro.sparse.ops import _spmv_dot_ell_xla

        return _spmv_dot_ell_xla(ex, A, x, w)
    return spmv_dot_ell_pallas(
        A.col_idx,
        A.values,
        x,
        w,
        block_m=cfg["block_m"],
        block_k=cfg["block_k"],
        use_coop=True,
        interpret=ex.interpret,
    )


registry.instantiate_common(
    "spmv_dot_ell", _spmv_dot_ell_skeleton, {"pallas": dict(variant="pallas")}
)
