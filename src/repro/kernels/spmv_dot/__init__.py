"""Fused SpMV + dot-product kernel family (apply-with-reduction)."""
