"""Fused ELL SpMV + dot Pallas TPU kernel — apply-with-reduction.

The arXiv:2011.08879 fusion: Krylov iterations follow every SpMV with a dot
product against the same vectors (``p·Ap`` in CG, ``r̂·v`` in BiCGSTAB), and
launching the dot separately re-streams ``y`` through HBM.  This kernel emits
the partial reduction in the same pass: each (block_m, block_k) tile adds its
row partials into the revisited y block AND adds ``Σ_r w_r · partial_r`` into
a scalar accumulator block — both well-defined because TPU grids iterate
sequentially (same read-modify-write idiom as :mod:`repro.kernels.spmv_ell`).

The dot is linear in the tile contributions
(``w·y = Σ_{i,j} Σ_{r∈tile_i} w_r partial(r, j)``), so accumulation order only
changes rounding, never the result's definition.  ``w`` rides in one
(block_m,) tile per row-block; padding rows carry w = 0 and contribute
nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import coop


def _spmv_dot_ell_kernel(
    cols_ref, vals_ref, x_ref, w_ref, o_ref, d_ref, *, use_coop: bool
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_y():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when((i == 0) & (j == 0))
    def _init_dot():
        d_ref[...] = jnp.zeros_like(d_ref)

    vals = vals_ref[...]  # (block_m, block_k)
    cols = cols_ref[...]
    x = x_ref[...]  # (n,)
    gathered = x[cols]
    prod = vals * gathered
    if use_coop:
        row_sum = coop.subgroup(prod, prod.shape[-1]).sum()[..., :1]
    else:
        row_sum = jnp.sum(prod, axis=-1, keepdims=True)
    o_ref[...] += row_sum.astype(o_ref.dtype)
    # the fused reduction: this tile's contribution to w·y
    w = w_ref[...]  # (block_m,)
    d_ref[0, 0] += jnp.sum(w * row_sum[:, 0]).astype(d_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "use_coop", "interpret"),
)
def spmv_dot_ell(
    col_idx: jax.Array,
    values: jax.Array,
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    use_coop: bool = True,
    interpret: bool = False,
):
    """(y, w·y) = (A @ x, dot) for ELL-format A of shape (m, k), one pass."""
    m, k = values.shape
    n = x.shape[0]

    block_m = max(min(block_m, m), 1)
    block_k = max(min(block_k, k), 1)
    pm = ((m + block_m - 1) // block_m) * block_m
    pk = ((k + block_k - 1) // block_k) * block_k
    if (pm, pk) != (m, k):
        col_idx = jnp.pad(col_idx, ((0, pm - m), (0, pk - k)))
        values = jnp.pad(values, ((0, pm - m), (0, pk - k)))
    if pm != m:
        # padding rows must not contribute to the dot
        w = jnp.pad(w, (0, pm - m))
    use_coop = use_coop and (block_k & (block_k - 1) == 0)

    y, d = pl.pallas_call(
        functools.partial(_spmv_dot_ell_kernel, use_coop=use_coop),
        grid=(pm // block_m, pk // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pm, 1), values.dtype),
            jax.ShapeDtypeStruct((1, 1), values.dtype),
        ],
        interpret=interpret,
    )(col_idx, values, x, w)
    return y[:m, 0], d[0, 0]
