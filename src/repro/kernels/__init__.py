"""repro.kernels — hardware-native Pallas TPU kernels (the CUDA/HIP slot).

Importing this package registers every Pallas implementation in the operation
registry (the analogue of compiling Ginkgo's device backends: without this
import, executors fall back to the ``xla`` / ``reference`` kernel spaces, or
raise ``NotCompiledError`` in strict mode).

Layout: one directory per hot-spot, each with
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
  ops.py    — registry bindings / jit wrappers
  ref.py    — the pure-jnp oracle the kernel is validated against
"""

import repro.kernels.axpy_norm.ops  # noqa: F401
import repro.kernels.block_jacobi.ops  # noqa: F401
import repro.kernels.flash_attention.ops  # noqa: F401
import repro.kernels.rmsnorm.ops  # noqa: F401
import repro.kernels.rwkv6.ops  # noqa: F401
import repro.kernels.spgemm.ops  # noqa: F401
import repro.kernels.spmv_batch_ell.ops  # noqa: F401
import repro.kernels.spmv_dot.ops  # noqa: F401
import repro.kernels.spmv_ell.ops  # noqa: F401
import repro.kernels.spmv_sellp.ops  # noqa: F401
import repro.kernels.ssd.ops  # noqa: F401

from repro.kernels.axpy_norm.kernel import axpy_norm
from repro.kernels.block_jacobi.kernel import block_jacobi_apply
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rwkv6.kernel import rwkv6_scan, rwkv6_scan_log
from repro.kernels.spgemm.kernel import csr_permute, spgemm_expand
from repro.kernels.spmv_batch_ell.kernel import spmv_batch_ell
from repro.kernels.spmv_dot.kernel import spmv_dot_ell
from repro.kernels.spmv_ell.kernel import spmv_ell
from repro.kernels.spmv_sellp.kernel import spmv_sellp
from repro.kernels.ssd.kernel import ssd_scan

__all__ = [
    "axpy_norm",
    "block_jacobi_apply",
    "csr_permute",
    "spgemm_expand",
    "flash_attention",
    "rmsnorm",
    "rwkv6_scan",
    "rwkv6_scan_log",
    "spmv_batch_ell",
    "spmv_dot_ell",
    "spmv_ell",
    "spmv_sellp",
    "ssd_scan",
]
