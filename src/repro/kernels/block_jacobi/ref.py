"""Reference oracle for the block-Jacobi apply.

The apply is a batched small-matvec: given the explicitly inverted diagonal
blocks ``inv_blocks (nb, bs, bs)`` (possibly stored in a reduced precision)
and the block-gathered vector segments ``vp (nb, bs)``, produce
``y[b] = inv_blocks[b] @ vp[b]``.  Arithmetic always happens in the vector's
precision — reduced precision is a *storage* format only (the adaptive
block-Jacobi design of arXiv:2006.16852: value storage decoupled from
arithmetic precision).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_jacobi_apply_ref(inv_blocks: jax.Array, vp: jax.Array) -> jax.Array:
    """y[b] = inv_blocks[b] @ vp[b], computed in vp's dtype."""
    blocks = inv_blocks.astype(vp.dtype)
    return jnp.einsum("nij,nj->ni", blocks, vp)
