"""Block-Jacobi apply Pallas TPU kernel.

One grid step processes ``block_nb`` diagonal blocks: a
``(block_nb, bs, bs)`` tile of inverted blocks and the matching
``(block_nb, bs)`` tile of gathered vector segments, producing
``(block_nb, bs)`` outputs.  The block batch axis is the only grid axis —
each step's working set is independent, so there is no cross-step
accumulation (unlike the SpMV kernels).

Mixed precision: ``inv_blocks`` may arrive in a reduced *storage* precision
(bf16/fp16 — the adaptive block-Jacobi selection); the kernel upcasts inside
the body so the VMEM traffic pays the reduced footprint while the arithmetic
stays in the vector's precision (arXiv:2006.16852's storage/arithmetic
decoupling).

Padding blocks (appended to round ``nb`` up to a ``block_nb`` multiple) are
zero everywhere, contribute zero rows, and are sliced off by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_jacobi_kernel(inv_ref, v_ref, o_ref):
    blocks = inv_ref[...].astype(o_ref.dtype)  # (block_nb, bs, bs)
    v = v_ref[...].astype(o_ref.dtype)  # (block_nb, bs)
    o_ref[...] = jnp.sum(blocks * v[:, None, :], axis=-1)


@functools.partial(jax.jit, static_argnames=("block_nb", "interpret"))
def block_jacobi_apply(
    inv_blocks: jax.Array,
    vp: jax.Array,
    *,
    block_nb: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[b] = inv_blocks[b] @ vp[b] for (nb, bs, bs) blocks, (nb, bs) segments."""
    nb, bs, _ = inv_blocks.shape
    out_dtype = vp.dtype
    block_nb = max(min(block_nb, nb), 1)
    pnb = ((nb + block_nb - 1) // block_nb) * block_nb
    if pnb != nb:
        inv_blocks = jnp.pad(inv_blocks, ((0, pnb - nb), (0, 0), (0, 0)))
        vp = jnp.pad(vp, ((0, pnb - nb), (0, 0)))

    out = pl.pallas_call(
        _block_jacobi_kernel,
        grid=(pnb // block_nb,),
        in_specs=[
            pl.BlockSpec((block_nb, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_nb, bs), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_nb, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pnb, bs), out_dtype),
        interpret=interpret,
    )(inv_blocks, vp)
    return out[:nb]
