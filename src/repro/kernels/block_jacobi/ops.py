"""Registry binding: the block-Jacobi apply serves ``block_jacobi_apply``.

Three kernel spaces:

* ``reference`` — the sequential-semantics einsum oracle (:mod:`.ref`);
* ``xla``       — the same formulation handed to the compiler (small batched
  matvecs fuse well; Ginkgo's OpenMP slot);
* ``pallas``    — the hardware-native tile kernel (:mod:`.kernel`), its block
  batch tile resolved through ``Executor.launch_config`` with the registered
  ``block_jacobi`` :class:`~repro.core.tuning.TuningSpec` — no hard-coded
  geometry, per-target entries ride the same autotune cache / table override /
  HardwareParams-seed chain as every other kernel family.
"""

from __future__ import annotations

from repro.core import registry, tuning
from repro.kernels.block_jacobi.kernel import block_jacobi_apply as bj_pallas
from repro.kernels.block_jacobi.ref import block_jacobi_apply_ref


def _vmem_bytes(shapes, block) -> int:
    # inv-block tile (storage itemsize) + gathered segments and outputs (f32)
    bnb = block["block_nb"]
    bs = shapes.get("bs", 8)
    itemsize = shapes.get("itemsize", 4)
    return bnb * bs * bs * itemsize + 2 * bnb * bs * 4


def _constrain(hw, shapes, block):
    bnb = max(int(block["block_nb"]), hw.sublane_count)
    bnb -= bnb % hw.sublane_count
    return {"block_nb": bnb}


BLOCK_JACOBI_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="block_jacobi",
        params=("block_nb",),
        seed=lambda hw: {
            # blocks are subwarp-sized (bs <= subgroup width), so a generous
            # batch tile keeps the VPU fed without pressuring VMEM
            "block_nb": max(hw.sublane_count * 16, 8),
        },
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_nb": 8},
        candidates=lambda hw, shapes: [
            {"block_nb": hw.sublane_count * f} for f in (8, 16, 32, 64)
        ],
    )
)


def _block_jacobi_skeleton(ex, inv_blocks, vp, *, variant: str):
    if variant != "pallas":
        return block_jacobi_apply_ref(inv_blocks, vp)
    cfg = ex.launch_config(
        "block_jacobi",
        {
            "nb": inv_blocks.shape[0],
            "bs": inv_blocks.shape[1],
            "itemsize": inv_blocks.dtype.itemsize,
        },
    )
    if not cfg.fits_vmem:
        # no tile fits this target's budget — portable formulation instead
        return block_jacobi_apply_ref(inv_blocks, vp)
    return bj_pallas(
        inv_blocks, vp, block_nb=cfg["block_nb"], interpret=ex.interpret
    )


registry.instantiate_common(
    "block_jacobi_apply",
    _block_jacobi_skeleton,
    {
        "reference": dict(variant="reference"),
        "xla": dict(variant="xla"),
        "pallas": dict(variant="pallas"),
    },
)
