"""SELL-P SpMV Pallas TPU kernel with scalar-prefetched slice offsets.

This is the paper's throughput format adapted to TPU ragged-block idioms:

* slices are ``C`` rows tall (C = 8 sublanes by default, vs Ginkgo's GPU 64);
* each slice stores ``slice_cols[s]`` padded columns (multiple of
  ``stride_factor``), values column-major within the slice — so one *block* of
  ``block_cols`` columns is a contiguous ``(block_cols, C)`` VMEM tile of the
  flat buffer;
* ``slice_sets`` rides in scalar-prefetch SMEM and drives the data-dependent
  ``index_map`` — the TPU analogue of a GPU kernel reading per-slice offsets
  from global memory (same trick Pallas uses for ragged attention / MoE);
* grid = (num_slices, max_blocks); blocks beyond a slice's width are predicated
  off with ``pl.when`` and their loads clamped in-bounds (they read the next
  slice's data and discard it — benign, and cheaper than a branchy loader).

Requires ``stride_factor % block_cols == 0`` (or block_cols % stride... we pick
``block_cols = stride_factor``) so slice offsets land on block boundaries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sellp_kernel(
    slice_sets_ref,  # scalar prefetch: (num_slices+1,) int32
    cols_ref,  # (block_cols, C) tile of the flat col_idx
    vals_ref,  # (block_cols, C) tile of the flat values
    x_ref,  # (n,) — x resident in VMEM
    o_ref,  # (1, C) output tile for this slice
    *,
    block_cols: int,
):
    s = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    width = slice_sets_ref[s + 1] - slice_sets_ref[s]

    @pl.when(j * block_cols < width)
    def _accumulate():
        vals = vals_ref[...]  # (block_cols, C)
        cols = cols_ref[...]
        x = x_ref[...]
        contrib = vals * x[cols]
        # zero the tail block's columns that spill past this slice's width
        col_in_slice = j * block_cols + jax.lax.broadcasted_iota(
            jnp.int32, contrib.shape, 0
        )
        contrib = jnp.where(col_in_slice < width, contrib, 0.0)
        o_ref[...] += jnp.sum(contrib, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("m", "slice_size", "block_cols", "max_slice_cols", "interpret"),
)
def spmv_sellp(
    col_idx: jax.Array,
    values: jax.Array,
    slice_sets: jax.Array,
    x: jax.Array,
    *,
    m: int,
    slice_size: int,
    block_cols: int,
    max_slice_cols: int,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x for SELL-P A (flat layout, see repro.sparse.formats.Sellp)."""
    C = slice_size
    num_slices = slice_sets.shape[0] - 1
    n = x.shape[0]
    total = values.shape[0]
    total_blocks = total // (block_cols * C)
    max_blocks = max(-(-max_slice_cols // block_cols), 1)

    def block_index(s, j, ss_ref):
        # flat-block index of (slice s, column-block j); clamped in-bounds for
        # the predicated-off tail blocks.
        idx = ss_ref[s] // block_cols + j
        return jnp.minimum(idx, total_blocks - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_slices, max_blocks),
        in_specs=[
            pl.BlockSpec((block_cols, C), lambda s, j, ss: (block_index(s, j, ss), 0)),
            pl.BlockSpec((block_cols, C), lambda s, j, ss: (block_index(s, j, ss), 0)),
            pl.BlockSpec((n,), lambda s, j, ss: (0,)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda s, j, ss: (s, 0)),
    )

    out = pl.pallas_call(
        functools.partial(_sellp_kernel, block_cols=block_cols),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_slices, C), values.dtype),
        interpret=interpret,
    )(
        slice_sets,
        col_idx.reshape(total // C, C),
        values.reshape(total // C, C),
        x,
    )
    return out.reshape(num_slices * C)[:m]
