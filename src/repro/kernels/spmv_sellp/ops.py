"""Registry binding: the Pallas SELL-P SpMV serves operation ``spmv_sellp``.

The reference/xla spaces live in :mod:`repro.sparse.ops`; this module binds the
hardware-native skeleton.  ``block_cols`` comes from the launch-configuration
table, constrained to divide the format's ``stride_factor`` so slice offsets
always land on block boundaries.
"""

from __future__ import annotations

from repro.core import registry, tuning
from repro.kernels.spmv_sellp.kernel import spmv_sellp as spmv_sellp_pallas
from repro.sparse.formats import Sellp


def _vmem_bytes(shapes, block) -> int:
    # cols (int32) + values tiles of (block_cols, C), x VMEM-resident, (1, C) out
    bc = block["block_cols"]
    C = shapes.get("slice_size", 8)
    n = shapes.get("n", 0)
    itemsize = shapes.get("itemsize", 4)
    return bc * C * (itemsize + 4) + n * itemsize + C * itemsize


def _constrain(hw, shapes, block):
    bc = max(int(block["block_cols"]), 1)
    sf = int(shapes.get("stride_factor", bc))
    bc = min(bc, sf)
    while sf % bc:  # slice offsets are stride_factor multiples; stay divisible
        bc -= 1
    return {"block_cols": bc}


SELLP_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="spmv_sellp",
        params=("block_cols",),
        seed=lambda hw: {"block_cols": hw.sublane_count},
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_cols": 1},
        candidates=lambda hw, shapes: [
            {"block_cols": c}
            for c in (hw.sublane_count // 2, hw.sublane_count, hw.sublane_count * 2)
            if c >= 1
        ],
    )
)


def _spmv_sellp_skeleton(ex, A: Sellp, x, *, variant: str):
    if x.ndim != 1:
        raise NotImplementedError("pallas SELL-P spmv is single-rhs")
    cfg = ex.launch_config(
        "spmv_sellp",
        {
            "m": A.shape[0],
            "n": x.shape[0],
            "slice_size": A.slice_size,
            "stride_factor": A.stride_factor,
            "itemsize": x.dtype.itemsize,
        },
    )
    if not cfg.fits_vmem:
        from repro.sparse.ops import _spmv_sellp_xla

        return _spmv_sellp_xla(ex, A, x)
    return spmv_sellp_pallas(
        A.col_idx,
        A.values,
        A.slice_sets,
        x,
        m=A.shape[0],
        slice_size=A.slice_size,
        block_cols=cfg["block_cols"],
        max_slice_cols=A.max_slice_cols,
        interpret=ex.interpret,
    )


registry.instantiate_common(
    "spmv_sellp", _spmv_sellp_skeleton, {"pallas": dict(variant="pallas")}
)
