"""Registry binding: the Pallas SELL-P SpMV serves operation ``spmv_sellp``."""

from __future__ import annotations

from repro.core import registry
from repro.kernels.spmv_sellp.kernel import spmv_sellp as spmv_sellp_pallas
from repro.sparse.formats import Sellp


@registry.register("spmv_sellp", "pallas")
def _spmv_sellp_pallas(ex, A: Sellp, x):
    if x.ndim != 1:
        raise NotImplementedError("pallas SELL-P spmv is single-rhs")
    n = x.shape[0]
    if n * x.dtype.itemsize > ex.hw.vmem_limit_bytes // 4:
        from repro.sparse.ops import _spmv_sellp_xla

        return _spmv_sellp_xla(ex, A, x)
    return spmv_sellp_pallas(
        A.col_idx,
        A.values,
        A.slice_sets,
        x,
        m=A.shape[0],
        slice_size=A.slice_size,
        block_cols=A.stride_factor,
        max_slice_cols=A.max_slice_cols,
        interpret=ex.interpret,
    )
