"""Pure-jnp oracle for the SELL-P SpMV kernel (flat slice layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def spmv_sellp_ref(
    col_idx: jax.Array,
    values: jax.Array,
    slice_sets,  # host-readable (numpy) — oracle iterates slices in Python
    x: jax.Array,
    m: int,
    slice_size: int,
) -> jax.Array:
    """Direct readback of the SELL-P layout, slice by slice."""
    C = slice_size
    ss = np.asarray(slice_sets)
    num_slices = ss.shape[0] - 1
    y = jnp.zeros((num_slices * C,), dtype=values.dtype)
    for s in range(num_slices):
        lo, hi = int(ss[s]), int(ss[s + 1])
        width = hi - lo
        block_v = values[lo * C : hi * C].reshape(width, C)
        block_c = col_idx[lo * C : hi * C].reshape(width, C)
        contrib = (block_v * x[block_c]).sum(axis=0)
        y = y.at[s * C : (s + 1) * C].set(contrib)
    return y[:m]
