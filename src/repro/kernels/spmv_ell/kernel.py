"""ELL SpMV Pallas TPU kernel.

Layout (DESIGN.md §2): row-major (m, max_nnz) blocks — a (block_m, block_k)
VMEM tile per grid step, with ``x`` held entirely in VMEM (the benchmark
matrices keep n*4B well under the VMEM budget; the wrapper enforces this via
the executor's ``vmem_limit_bytes``).

The per-row reduction over the k axis uses the cooperative-group butterfly
(:mod:`repro.core.coop`) when ``block_k`` is the lane axis — Ginkgo's
"subwarp per row" ELL strategy mapped to lane-segment collectives.

Grid = (m/block_m, k/block_k), k innermost; partial sums accumulate in the
revisited output block (TPU grids iterate sequentially, so read-modify-write
on o_ref across k steps is well-defined).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import coop


def _spmv_ell_kernel(cols_ref, vals_ref, x_ref, o_ref, *, use_coop: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = vals_ref[...]  # (block_m, block_k)
    cols = cols_ref[...]
    x = x_ref[...]  # (n,)
    gathered = x[cols]  # gather along lanes (see DESIGN.md lowering note)
    prod = vals * gathered
    if use_coop:
        # Ginkgo ELL: one subwarp reduces one row; here the "subwarp" is the
        # full lane segment of the row tile (butterfly shfl_xor reduction).
        row_sum = coop.subgroup(prod, prod.shape[-1]).sum()[..., :1]
    else:
        row_sum = jnp.sum(prod, axis=-1, keepdims=True)
    o_ref[...] += row_sum.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "use_coop", "interpret"),
)
def spmv_ell(
    col_idx: jax.Array,
    values: jax.Array,
    x: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    use_coop: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x for ELL-format A given as (col_idx, values) of shape (m, k)."""
    m, k = values.shape
    n = x.shape[0]

    block_m = max(min(block_m, m), 1)
    block_k = max(min(block_k, k), 1)
    # pad m and k to block multiples (padding: col 0, value 0 — contributes 0)
    pm = ((m + block_m - 1) // block_m) * block_m
    pk = ((k + block_k - 1) // block_k) * block_k
    if (pm, pk) != (m, k):
        col_idx = jnp.pad(col_idx, ((0, pm - m), (0, pk - k)))
        values = jnp.pad(values, ((0, pm - m), (0, pk - k)))
    use_coop = use_coop and (block_k & (block_k - 1) == 0)

    out = pl.pallas_call(
        functools.partial(_spmv_ell_kernel, use_coop=use_coop),
        grid=(pm // block_m, pk // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pm, 1), values.dtype),
        interpret=interpret,
    )(col_idx, values, x)
    return out[:m, 0]
