"""Pure-jnp oracle for the ELL SpMV kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ell_ref(col_idx: jax.Array, values: jax.Array, x: jax.Array) -> jax.Array:
    """y[i] = sum_k values[i,k] * x[col_idx[i,k]] (padding: col 0 / value 0)."""
    return jnp.sum(values * x[col_idx], axis=1)
