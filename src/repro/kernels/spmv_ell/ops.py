"""Registry binding: the Pallas ELL SpMV serves operation ``spmv_ell``."""

from __future__ import annotations

from repro.core import registry
from repro.kernels.spmv_ell.kernel import spmv_ell as spmv_ell_pallas
from repro.sparse.formats import Ell


@registry.register("spmv_ell", "pallas")
def _spmv_ell_pallas(ex, A: Ell, x):
    if x.ndim != 1:
        raise NotImplementedError("pallas ELL spmv is single-rhs")
    n = x.shape[0]
    if n * x.dtype.itemsize > ex.hw.vmem_limit_bytes // 4:
        # x would not fit the VMEM residency strategy on this target —
        # fall through to the XLA kernel (Ginkgo: executor picks the kernel
        # variant suited to the problem granularity).
        from repro.sparse.ops import _spmv_ell_xla

        return _spmv_ell_xla(ex, A, x)
    # block shape from the hardware table: sublane-aligned rows, lane-sized k
    block_m = max(ex.hw.sublane_count * 32, 8)
    block_k = ex.hw.lane_count
    return spmv_ell_pallas(
        A.col_idx,
        A.values,
        x,
        block_m=block_m,
        block_k=block_k,
        use_coop=True,
        interpret=ex.interpret,
    )
