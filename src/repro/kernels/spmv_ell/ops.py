"""Registry binding: the Pallas ELL SpMV serves operation ``spmv_ell``.

The reference/xla spaces live in :mod:`repro.sparse.ops`; this module binds the
hardware-native skeleton, whose (block_m, block_k) tile and x-residency
feasibility both come from the launch-configuration table.
"""

from __future__ import annotations

from repro.core import registry, tuning
from repro.kernels.spmv_ell.kernel import spmv_ell as spmv_ell_pallas
from repro.sparse.formats import Ell


def _vmem_bytes(shapes, block) -> int:
    # cols (int32) + values tiles, x fully VMEM-resident, output column
    bm, bk = block["block_m"], block["block_k"]
    n = shapes.get("n", 0)
    itemsize = shapes.get("itemsize", 4)
    return bm * bk * (itemsize + 4) + n * itemsize + bm * itemsize


def _constrain(hw, shapes, block):
    bm = max(int(block["block_m"]), hw.sublane_count)
    bm -= bm % hw.sublane_count
    # power-of-two lanes keep the coop butterfly legal
    bk = tuning.prev_pow2(max(int(block["block_k"]), 8))
    return {"block_m": bm, "block_k": bk}


ELL_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="spmv_ell",
        params=("block_m", "block_k"),
        seed=lambda hw: {
            "block_m": max(hw.sublane_count * 32, 8),
            "block_k": hw.lane_count,
        },
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"block_m": 8, "block_k": 8},
        candidates=lambda hw, shapes: [
            {"block_m": bm, "block_k": bk}
            for bm in (hw.sublane_count * 16, hw.sublane_count * 32, hw.sublane_count * 64)
            for bk in (hw.lane_count // 2, hw.lane_count)
        ],
    )
)


def _spmv_ell_skeleton(ex, A: Ell, x, *, variant: str):
    if x.ndim != 1:
        raise NotImplementedError("pallas ELL spmv is single-rhs")
    cfg = ex.launch_config(
        "spmv_ell",
        {
            "m": A.values.shape[0],
            "k": A.values.shape[1],
            "n": x.shape[0],
            "itemsize": x.dtype.itemsize,
        },
    )
    if not cfg.fits_vmem:
        # x would not fit the VMEM residency strategy on this target —
        # fall through to the XLA kernel (Ginkgo: executor picks the kernel
        # variant suited to the problem granularity).
        from repro.sparse.ops import _spmv_ell_xla

        return _spmv_ell_xla(ex, A, x)
    return spmv_ell_pallas(
        A.col_idx,
        A.values,
        x,
        block_m=cfg["block_m"],
        block_k=cfg["block_k"],
        use_coop=True,
        interpret=ex.interpret,
    )


registry.instantiate_common(
    "spmv_ell", _spmv_ell_skeleton, {"pallas": dict(variant="pallas")}
)
