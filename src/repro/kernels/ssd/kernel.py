"""Mamba2 SSD chunked scan — Pallas TPU kernel.

The SSD duality: within a chunk of length L the recurrence is evaluated as a
(masked, decay-weighted) matmul block; across chunks only the (N, P) state is
carried.  TPU mapping:

* grid = (B*H, S/L) with the chunk axis innermost — the carried state lives in
  a VMEM scratch tile across grid steps (sequential TPU grid), replacing the
  GPU implementation's inter-block state passing through global memory;
* the three in-chunk contractions (C B^T, G @ x, C @ h) are MXU matmuls with
  f32 accumulation; decay weights are computed from a cumulative sum of
  dt*A per chunk (numerically safe: all exponents are <= 0);
* per-head scalars (A) ride in scalar-prefetch SMEM.

Outputs y (B,S,H,P) and the final state (B,H,N,P) — the latter feeds chunked
prefill and decode initialization.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    A_ref,  # scalar prefetch: (H,) f32
    x_ref,  # (1, L, 1, P)
    dt_ref,  # (1, L, 1)
    B_ref,  # (1, L, 1, N)
    C_ref,  # (1, L, 1, N)
    y_ref,  # (1, L, 1, P) out
    state_ref,  # (1, 1, N, P) out (written on last chunk)
    h_scr,  # (N, P) f32 scratch
    *,
    H: int,
    num_chunks: int,
):
    bh = pl.program_id(0)
    c = pl.program_id(1)
    h_idx = bh % H

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    a = dt * A_ref[h_idx]  # (L,) all <= 0

    acum = jnp.cumsum(a)  # (L,) A_cum[t] = sum_{r<=t} a_r
    L = x.shape[0]

    # decay matrix: Ldec[t, s] = exp(acum[t] - acum[s]) for s <= t else 0
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    diff = acum[:, None] - acum[None, :]
    Ldec = jnp.where(t_idx >= s_idx, jnp.exp(diff), 0.0)

    # intra-chunk: y[t] = sum_{s<=t} (C_t . B_s) Ldec[t,s] dt_s x[s]
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    G = CB * Ldec * dt[None, :]
    y_intra = jax.lax.dot_general(
        G, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, P)

    # inter-chunk: y[t] += (C_t * exp(acum[t])) @ h_prev
    C_scaled = Cm * jnp.exp(acum)[:, None]
    y_inter = jax.lax.dot_general(
        C_scaled, h_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (L, P)

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(acum[-1]) h_prev + sum_s exp(acum[-1]-acum[s]) dt_s B_s x_s^T
    chunk_decay = jnp.exp(acum[-1])
    B_scaled = Bm * (jnp.exp(acum[-1] - acum) * dt)[:, None]  # (L, N)
    dh = jax.lax.dot_general(
        B_scaled, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (N, P)
    h_scr[...] = chunk_decay * h_scr[...] + dh

    @pl.when(c == num_chunks - 1)
    def _emit_state():
        state_ref[0, 0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    B_mat: jax.Array,  # (B, S, G, N)
    C: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan; returns (y, final_state)."""
    Bsz, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    group = H // G
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        # zero dt => zero decay update and zero contribution: exp(0)=1 decay,
        # dt=0 kills both the input term and y contribution of padded steps.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Bsz * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bh, c, A_s, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, c, A_s, H=H: (bh // H, c, bh % H)),
            pl.BlockSpec(
                (1, chunk, 1, N),
                lambda bh, c, A_s, H=H, g=group: (bh // H, c, (bh % H) // g, 0),
            ),
            pl.BlockSpec(
                (1, chunk, 1, N),
                lambda bh, c, A_s, H=H, g=group: (bh // H, c, (bh % H) // g, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bh, c, A_s, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bh, c, A_s, H=H: (bh // H, bh % H, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
    )

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, H=H, num_chunks=nc),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Sp, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, B_mat, C)
    return y[:, :S], state
