"""Chunked SSD scan in pure jnp — the optimized *portable* (XLA-space) path.

Same chunk algebra as the Pallas kernel (kernel.py), expressed as batched
einsums inside a ``lax.scan`` over chunks: XLA gets large MXU-friendly
contractions instead of a length-S sequential scan.  The sequential oracle
stays in ref.py (reference space), mirroring Ginkgo's reference-vs-optimized
kernel split.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssd_chunked_xla(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    B_mat: jax.Array,  # (B, S, G, N)
    C: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    Bsz, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    group = H // G
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    L = chunk

    xf = x.astype(jnp.float32).reshape(Bsz, nc, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, L, H)
    Bf = B_mat.astype(jnp.float32).reshape(Bsz, nc, L, G, N)
    Cf = C.astype(jnp.float32).reshape(Bsz, nc, L, G, N)
    Af = A.astype(jnp.float32)

    # scan over chunks (chunk axis to front)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )

    t_idx = jnp.arange(L)[:, None]
    s_idx = jnp.arange(L)[None, :]
    lower = t_idx >= s_idx  # (L, L)

    def step(h, inp):
        xc, dtc, Bc, Cc = inp  # (B,L,H,P), (B,L,H), (B,L,G,N) x2
        a = dtc * Af  # (B,L,H), <= 0
        acum = jnp.cumsum(a, axis=1)  # (B,L,H)
        # decay matrix (B,L,L,H)
        diff = acum[:, :, None, :] - acum[:, None, :, :]
        Ldec = jnp.where(lower[None, :, :, None], jnp.exp(diff), 0.0)
        # intra: scores per group expanded to heads
        CB = jnp.einsum("blgn,bsgn->blsg", Cc, Bc)  # (B,L,L,G)
        CBh = jnp.repeat(CB, group, axis=-1)  # (B,L,L,H)
        Gmat = CBh * Ldec * dtc[:, None, :, :]  # dt_s
        y_intra = jnp.einsum("blsh,bshp->blhp", Gmat, xc)
        # inter: C scaled by exp(acum) against carried state
        Ch = jnp.repeat(Cc, group, axis=2)  # (B,L,H,N)
        Cs = Ch * jnp.exp(acum)[..., None]
        y_inter = jnp.einsum("blhn,bhnp->blhp", Cs, h)
        # state update
        chunk_decay = jnp.exp(acum[:, -1, :])  # (B,H)
        Bh = jnp.repeat(Bc, group, axis=2)  # (B,L,H,N)
        Bs = Bh * (jnp.exp(acum[:, -1:, :] - acum) * dtc)[..., None]
        h = chunk_decay[..., None, None] * h + jnp.einsum("blhn,blhp->bhnp", Bs, xc)
        return h, y_intra + y_inter

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(x.dtype), h_final
