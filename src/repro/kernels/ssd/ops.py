"""Registry bindings for the Mamba2 SSD scan (operation ``nn_ssd_scan``)."""

from __future__ import annotations

from repro.core import registry
from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ref import ssd_ref

ssd_op = registry.operation(
    "nn_ssd_scan", "Mamba2 SSD scan -> (y, final_state)"
)


@ssd_op.register("reference")
def _ssd_reference(ex, x, dt, A, B_mat, C):
    return ssd_ref(x, dt, A, B_mat, C)


@ssd_op.register("xla")
def _ssd_xla(ex, x, dt, A, B_mat, C):
    # chunked batched-einsum formulation (xla.py) — the optimized portable path
    from repro.kernels.ssd.xla import ssd_chunked_xla

    return ssd_chunked_xla(x, dt, A, B_mat, C, chunk=64)


@ssd_op.register("pallas")
def _ssd_pallas(ex, x, dt, A, B_mat, C):
    return ssd_scan(x, dt, A, B_mat, C, chunk=64, interpret=ex.interpret)
