"""Registry bindings for the Mamba2 SSD scan (operation ``nn_ssd_scan``).

One skeleton, three spaces; chunk length comes from the launch-configuration
table (the (L, L) decay/score matrices and the carried (N, P) state set the
VMEM working set).
"""

from __future__ import annotations

from repro.core import registry, tuning
from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ref import ssd_ref


def _vmem_bytes(shapes, block) -> int:
    # Ldec + CB/G (L, L) f32 matrices + x/B/C chunk tiles + (N, P) state scratch
    L = block["chunk"]
    N = shapes.get("N", 64)
    P = shapes.get("P", 64)
    return 4 * (2 * L * L + L * (2 * N + 2 * P) + N * P)


def _constrain(hw, shapes, block):
    return {"chunk": tuning.prev_pow2(max(int(block["chunk"]), 8))}


SSD_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="nn_ssd_scan",
        params=("chunk",),
        seed=lambda hw: {"chunk": max(hw.sublane_count * 8, 32)},
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"chunk": 8},
        candidates=lambda hw, shapes: [{"chunk": c} for c in (32, 64, 128)],
    )
)


def _ssd_skeleton(ex, x, dt, A, B_mat, C, *, variant: str):
    if variant == "reference":
        return ssd_ref(x, dt, A, B_mat, C)
    cfg = ex.launch_config(
        "nn_ssd_scan",
        {
            "S": x.shape[1],
            "N": B_mat.shape[-1],
            "P": x.shape[-1],
            "itemsize": x.dtype.itemsize,
        },
    )
    if variant == "xla":
        # chunked batched-einsum formulation (xla.py) — the optimized portable path
        from repro.kernels.ssd.xla import ssd_chunked_xla

        return ssd_chunked_xla(x, dt, A, B_mat, C, chunk=cfg["chunk"])
    return ssd_scan(x, dt, A, B_mat, C, chunk=cfg["chunk"], interpret=ex.interpret)


ssd_op = registry.instantiate_common(
    "nn_ssd_scan",
    _ssd_skeleton,
    {
        "reference": dict(variant="reference"),
        "xla": dict(variant="xla"),
        "pallas": dict(variant="pallas"),
    },
)
ssd_op.__doc__ = "Mamba2 SSD scan -> (y, final_state)"
