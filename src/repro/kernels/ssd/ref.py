"""Pure-jnp oracle for the Mamba2 SSD scan (sequential recurrence)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,  # (B, S, H, P)  head channels
    dt: jax.Array,  # (B, S, H)     positive step sizes (post-softplus)
    A: jax.Array,  # (H,)          negative per-head decay rate
    B_mat: jax.Array,  # (B, S, G, N)  input projection (G groups, H % G == 0)
    C: jax.Array,  # (B, S, G, N)  output projection
    h0: jax.Array = None,  # (B, H, N, P) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Sequential state-space recurrence:

        h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T      (h: (N, P))
        y_t = C_t^T h_t                                  (y: (P,))

    Returns (y, final_state) with y: (B, S, H, P), state: (B, H, N, P).
    """
    Bsz, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    group = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B_mat.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def step(h, inputs):
        x_t, dt_t, B_t, C_t = inputs  # (B,H,P), (B,H), (B,G,N), (B,G,N)
        Bh = jnp.repeat(B_t, group, axis=1)  # (B,H,N)
        Ch = jnp.repeat(C_t, group, axis=1)
        decay = jnp.exp(dt_t * Af[None, :])  # (B,H)
        update = dt_t[..., None, None] * Bh[..., :, None] * x_t[..., None, :]
        h = decay[..., None, None] * h + update  # (B,H,N,P)
        y_t = jnp.einsum("bhn,bhnp->bhp", Ch, h)
        return h, y_t

    inputs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), inputs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B, S, H, P)
    return y, h_final
