"""Registry bindings for the RWKV6 WKV scan (operation ``nn_rwkv6_scan``)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import registry
from repro.kernels.rwkv6.kernel import rwkv6_scan_log
from repro.kernels.rwkv6.ref import rwkv6_ref

rwkv6_op = registry.operation(
    "nn_rwkv6_scan", "RWKV6 WKV scan (log-space decay) -> (y, final_state)"
)


@rwkv6_op.register("reference")
def _rwkv6_reference(ex, r, k, v, logw, u):
    return rwkv6_ref(r, k, v, jnp.exp(logw.astype(jnp.float32)), u)


@rwkv6_op.register("xla")
def _rwkv6_xla(ex, r, k, v, logw, u):
    # chunked batched-einsum formulation (xla.py) — the optimized portable path
    from repro.kernels.rwkv6.xla import rwkv6_chunked_xla

    return rwkv6_chunked_xla(r, k, v, logw, u, chunk=32)


@rwkv6_op.register("pallas")
def _rwkv6_pallas(ex, r, k, v, logw, u):
    return rwkv6_scan_log(r, k, v, logw, u, chunk=32, interpret=ex.interpret)
