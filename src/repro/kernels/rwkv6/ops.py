"""Registry bindings for the RWKV6 WKV scan (operation ``nn_rwkv6_scan``).

One skeleton, three spaces; both the optimized XLA formulation and the Pallas
kernel take their chunk length from the launch-configuration table — the
(L, L, K) stability tensor is the VMEM driver, so the chunk must shrink on
small-VMEM targets rather than overflow.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import registry, tuning
from repro.kernels.rwkv6.kernel import rwkv6_scan_log
from repro.kernels.rwkv6.ref import rwkv6_ref


def _vmem_bytes(shapes, block) -> int:
    # (L, L, K) ratio tensor + the (L, L) G matrix + r/k/v/logw chunk tiles
    # + the carried (K, V) state scratch, all f32
    L = block["chunk"]
    K = shapes.get("K", 64)
    V = shapes.get("V", K)
    return 4 * (L * L * K + L * L + L * (3 * K + V) + K * V)


def _constrain(hw, shapes, block):
    return {"chunk": tuning.prev_pow2(max(int(block["chunk"]), 8))}


RWKV6_SPEC = tuning.register_spec(
    tuning.TuningSpec(
        op="nn_rwkv6_scan",
        params=("chunk",),
        seed=lambda hw: {"chunk": max(hw.sublane_count * 4, 16)},
        vmem_bytes=_vmem_bytes,
        constrain=_constrain,
        floors={"chunk": 8},
        candidates=lambda hw, shapes: [{"chunk": c} for c in (16, 32, 64)],
    )
)


def _rwkv6_skeleton(ex, r, k, v, logw, u, *, variant: str):
    if variant == "reference":
        return rwkv6_ref(r, k, v, jnp.exp(logw.astype(jnp.float32)), u)
    cfg = ex.launch_config(
        "nn_rwkv6_scan",
        {
            "S": r.shape[1],
            "K": r.shape[-1],
            "V": v.shape[-1],
            "itemsize": r.dtype.itemsize,
        },
    )
    if variant == "xla":
        # chunked batched-einsum formulation (xla.py) — the optimized portable path
        from repro.kernels.rwkv6.xla import rwkv6_chunked_xla

        return rwkv6_chunked_xla(r, k, v, logw, u, chunk=cfg["chunk"])
    return rwkv6_scan_log(r, k, v, logw, u, chunk=cfg["chunk"], interpret=ex.interpret)


rwkv6_op = registry.instantiate_common(
    "nn_rwkv6_scan",
    _rwkv6_skeleton,
    {
        "reference": dict(variant="reference"),
        "xla": dict(variant="xla"),
        "pallas": dict(variant="pallas"),
    },
)
rwkv6_op.__doc__ = "RWKV6 WKV scan (log-space decay) -> (y, final_state)"
