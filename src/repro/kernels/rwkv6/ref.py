"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rwkv6_ref(
    r: jax.Array,  # (B, S, H, K) receptance
    k: jax.Array,  # (B, S, H, K) key
    v: jax.Array,  # (B, S, H, V) value
    w: jax.Array,  # (B, S, H, K) data-dependent decay in (0, 1)
    u: jax.Array,  # (H, K) bonus for the current token
    s0: jax.Array = None,  # (B, H, K, V) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Sequential WKV6:

        y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T

    Returns (y, final_state) with y: (B, S, H, V), state: (B, H, K, V).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)

    def step(state, inputs):
        r_t, k_t, v_t, w_t = inputs  # (B,H,K), (B,H,K), (B,H,V), (B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,K,V)
        att = state + uf[None, :, :, None] * kv
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t, att)
        state = w_t[..., :, None] * state + kv
        return state, y_t

    inputs = (
        jnp.moveaxis(rf, 1, 0),
        jnp.moveaxis(kf, 1, 0),
        jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(wf, 1, 0),
    )
    s_final, ys = jax.lax.scan(step, s0.astype(jnp.float32), inputs)
    y = jnp.moveaxis(ys, 0, 1).astype(r.dtype)
    return y, s_final
