"""RWKV6 (Finch) WKV chunked scan — Pallas TPU kernel.

Chunked form of the per-channel-decay recurrence (DESIGN.md: the GPU
implementations carry per-warp state in registers; on TPU the (K, V) state is a
VMEM scratch tile carried across the sequential chunk grid):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)

Within a chunk of length L, with W[t] = sum_{r<=t} log w_r:

    y_t = (r_t * e^{W[t-1]}) . S_0
        + sum_{s<t} [sum_c r_tc k_sc e^{W[t-1,c]-W[s,c]}] v_s
        + (r_t . (u * k_t)) v_t

Numerical-stability choice: the pairwise term uses the *ratio* form
e^{W[t-1]-W[s]} (always <= 1 for s < t) materialized as an (L, L, K) tensor,
NOT the scaled-matmul factorization (r*e^W)(k*e^-W) whose right factor
overflows f32 for strong decays.  This trades MXU utilization for
unconditional stability; chunk length defaults to 32 so the (L,L,K) tile stays
small (32*32*64*4B = 256 KiB).  The inter-chunk and state-update terms are
MXU matmuls (exponents <= 0, stable).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(
    r_ref,  # (1, L, 1, K)
    k_ref,  # (1, L, 1, K)
    v_ref,  # (1, L, 1, V)
    logw_ref,  # (1, L, 1, K)  log of decay (<= 0)
    u_ref,  # (1, K)
    y_ref,  # (1, L, 1, V) out
    state_ref,  # (1, 1, K, V) out (last chunk)
    s_scr,  # (K, V) f32 scratch
    *,
    num_chunks: int,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (L, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (L, K)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (L, V)
    logw = logw_ref[0, :, 0, :].astype(jnp.float32)  # (L, K)
    u = u_ref[0, :].astype(jnp.float32)  # (K,)

    L, K = r.shape
    W = jnp.cumsum(logw, axis=0)  # (L, K), W[t] = sum_{r<=t} log w_r
    Wprev = W - logw  # W[t-1] with W[-1] = 0

    # inter-chunk: (r * e^{W[t-1]}) @ S_0   — MXU matmul, exponents <= 0
    r_dec = r * jnp.exp(Wprev)
    y_inter = jax.lax.dot_general(
        r_dec, s_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (L, V)

    # intra-chunk pairwise term, ratio form (stable): G[t,s] = sum_c r_tc k_sc
    # e^{W[t-1,c] - W[s,c]} for s < t; diagonal handled by the u-bonus term.
    diff = Wprev[:, None, :] - W[None, :, :]  # (L, L, K), <= 0 for s < t
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    strict = t_idx > s_idx
    ratio = jnp.exp(jnp.where(strict[..., None], diff, 0.0))
    G = jnp.sum(
        r[:, None, :] * k[None, :, :] * ratio, axis=-1
    )  # (L, L)
    G = jnp.where(strict, G, 0.0)
    y_intra = jax.lax.dot_general(
        G, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, V)

    # current-token bonus: (r_t . (u * k_t)) v_t
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)  # (L, 1)
    y_ref[0, :, 0, :] = (y_inter + y_intra + bonus * v).astype(y_ref.dtype)

    # state update: S = diag(e^{W[L-1]}) S_0 + sum_s (k_s e^{W[L-1]-W[s]}) v_s^T
    chunk_dec = jnp.exp(W[-1])  # (K,)
    k_dec = k * jnp.exp(W[-1][None, :] - W)  # (L, K), exponents <= 0
    dS = jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (K, V)
    s_scr[...] = chunk_dec[:, None] * s_scr[...] + dS

    @pl.when(c == num_chunks - 1)
    def _emit_state():
        state_ref[0, 0] = s_scr[...]


def rwkv6_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0, 1)
    u: jax.Array,
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Convenience wrapper taking the decay in linear space.

    Prefer :func:`rwkv6_scan_log` — RWKV6 parameterizes w = exp(-exp(x)), so
    the layer owns ``logw = -exp(x)`` exactly; taking ``log(w)`` here loses
    that and underflows for strong decays, hence the clamp.
    """
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))
    return rwkv6_scan_log(r, k, v, logw, u, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_log(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,  # (B, S, H, K)
    v: jax.Array,  # (B, S, H, V)
    logw: jax.Array,  # (B, S, H, K) log-decay, finite and <= 0
    u: jax.Array,  # (H, K)
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6 scan (log-space decay); returns (y, final_state)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    logw = logw.astype(jnp.float32)
    if S % chunk:
        pad = chunk - S % chunk
        # padding: k=0 (no contribution), logw=0 (identity decay), r=0
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = r.shape[1]
    nc = Sp // chunk

    y, state = pl.pallas_call(
        functools.partial(_rwkv6_kernel, num_chunks=nc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, K), lambda bh, c, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda bh, c, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, chunk, 1, V), lambda bh, c, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda bh, c, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, K), lambda bh, c, H=H: (bh % H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, V), lambda bh, c, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, 1, K, V), lambda bh, c, H=H: (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, H, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return y[:, :S], state
