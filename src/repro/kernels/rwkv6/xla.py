"""Chunked RWKV6 WKV scan in pure jnp — the optimized portable (XLA) path.

Same chunk algebra as kernel.py (ratio-form pairwise decays for unconditional
f32 stability), batched over (B, H) and scanned over chunks.  The sequential
oracle lives in ref.py (reference space).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rwkv6_chunked_xla(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, S, H, V)
    logw: jax.Array,  # (B, S, H, K) finite, <= 0
    u: jax.Array,  # (H, K)
    *,
    chunk: int = 32,
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    logw = logw.astype(jnp.float32)
    if S % chunk:
        pad = chunk - S % chunk
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = r.shape[1]
    nc = Sp // chunk
    L = chunk

    def resh(t, d):
        return jnp.moveaxis(
            t.astype(jnp.float32).reshape(B, nc, L, H, d), 1, 0
        )  # (nc,B,L,H,d)

    xs = (resh(r, K), resh(k, K), resh(v, V), resh(logw, K))
    uf = u.astype(jnp.float32)

    t_idx = jnp.arange(L)[:, None]
    s_idx = jnp.arange(L)[None, :]
    strict = t_idx > s_idx  # (L, L)

    def step(S0, inp):
        rc, kc, vc, lw = inp  # (B,L,H,*)
        W = jnp.cumsum(lw, axis=1)  # (B,L,H,K)
        Wprev = W - lw
        r_dec = rc * jnp.exp(Wprev)
        y_inter = jnp.einsum("blhk,bhkv->blhv", r_dec, S0)
        # ratio-form pairwise decays (B,L,L,H,K) per chunk
        diff = Wprev[:, :, None] - W[:, None, :]  # (B,L,L,H,K)
        ratio = jnp.exp(jnp.where(strict[None, :, :, None, None], diff, 0.0))
        G = jnp.einsum("blhk,bshk,blshk->blsh", rc, kc, ratio)
        G = jnp.where(strict[None, :, :, None], G, 0.0)
        y_intra = jnp.einsum("blsh,bshv->blhv", G, vc)
        bonus = jnp.einsum("blhk,hk,blhk->blh", rc, uf, kc)
        y = y_inter + y_intra + bonus[..., None] * vc
        # state update
        chunk_dec = jnp.exp(W[:, -1])  # (B,H,K)
        k_dec = kc * jnp.exp(W[:, -1:][:, :, :] - W)  # broadcast (B,L,H,K)
        dS = jnp.einsum("blhk,blhv->bhkv", k_dec, vc)
        S1 = chunk_dec[..., None] * S0 + dS
        return S1, y

    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    S_final, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, V)[:, :S]
    return y.astype(r.dtype), S_final
