"""repro.runtime — fault tolerance: preemption, stragglers, restarts."""

from repro.runtime.fault_tolerance import (
    PreemptionHandler,
    StragglerMonitor,
    run_with_restarts,
)

__all__ = ["PreemptionHandler", "StragglerMonitor", "run_with_restarts"]
