"""Fault-tolerance runtime: preemption handling, straggler detection, restarts.

Single-host CI exercises the *logic*; the cluster actions (re-scheduling a
slow host, draining a pod) are the documented policy hooks.

* :class:`PreemptionHandler` — SIGTERM/SIGINT flip a flag; the train loop
  checkpoints and exits cleanly at the next step boundary (the standard
  maintenance-event dance on TPU pods).
* :class:`StragglerMonitor` — per-step wall-times in a ring buffer; a step
  slower than ``factor`` x the rolling p50 raises the alarm, with a policy
  callback (default: log; a cluster deployment wires eviction/re-dispatch).
* :func:`run_with_restarts` — supervisor that restarts a failing step loop
  from the latest committed checkpoint, up to ``max_restarts`` times
  (exercised in tests with injected faults).
"""

from __future__ import annotations

import collections
import signal
import time
from typing import Callable, Deque, Optional

import numpy as np


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = False
        self._prev = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def uninstall(self) -> None:
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        self._prev.clear()

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def preempted(self) -> bool:
        return self._flag

    def simulate(self) -> None:  # tests
        self._flag = True


class StragglerMonitor:
    """Rolling-median step-time alarm.

    On a cluster, per-host step times arrive via the coordination service; the
    same rule applies per host and the policy callback names the offender.
    """

    def __init__(
        self,
        window: int = 50,
        factor: float = 3.0,
        min_samples: int = 10,
        policy: Optional[Callable[[float, float], None]] = None,
    ):
        self.window = window
        self.factor = factor
        self.min_samples = min_samples
        self.times: Deque[float] = collections.deque(maxlen=window)
        self.alarms = 0
        self.policy = policy
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self) -> bool:
        """Record; return True if this step was a straggler."""
        if self._t0 is None:
            return False
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.record(dt)

    def record(self, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.min_samples:
            p50 = float(np.median(self.times))
            if dt > self.factor * p50:
                is_straggler = True
                self.alarms += 1
                if self.policy is not None:
                    self.policy(dt, p50)
        self.times.append(dt)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


def run_with_restarts(
    make_state: Callable[[], object],
    step_loop: Callable[[object], object],
    *,
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
):
    """Supervisor: (re)build state (restoring the latest checkpoint inside
    ``make_state``) and run ``step_loop`` until it returns, restarting on
    exceptions up to ``max_restarts`` times."""
    attempt = 0
    while True:
        state = make_state()
        try:
            return step_loop(state)
        except KeyboardInterrupt:
            raise
        except BaseException as e:
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
