"""Structured dispatch events — the record behind ``Executor.dispatch_log``.

PR-6 and earlier kept a bare ``Counter`` of op names on each executor.  That
counter is load-bearing (launch-count pins in ``BENCH_pr*.json``, portability
tests), so it stays — but it is now a *derived view*: :class:`DispatchLog`
subclasses ``Counter`` and additionally keeps a bounded deque of
:class:`DispatchEvent` records when tracing is enabled.  Each event captures
what Ginkgo's operation logger sees at a kernel launch:

* which operation ran, and which **kernel space** served it
  (``reference`` / ``xla`` / ``pallas``);
* the executor and hardware **target** it ran on;
* operand **shapes** and a power-of-two **shape bucket** (the same bucketing
  the tuning tables key on);
* the resolved :class:`~repro.core.tuning.LaunchConfig`, when the kernel
  consulted one;
* **wall time** of the dispatch (trace-time under ``jit`` — structure, not
  steady-state perf; see :mod:`repro.observability.trace`) and **estimated
  bytes moved**, the roofline numerator.

This module is stdlib-only on purpose: it is imported by
``repro.core.registry`` at module load, before JAX-heavy modules come up.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "EVENT_CAPACITY",
    "DispatchEvent",
    "DispatchLog",
    "summarize_operands",
    "shape_bucket",
    "make_event",
    "roofline_summary",
]

#: bounded so a long-running traced process cannot grow without limit; the
#: Chrome trace keeps the full stream, this deque is the queryable tail.
EVENT_CAPACITY = 4096


def _next_pow2(n: int) -> int:
    if n <= 1:
        return 1
    return 1 << (int(n) - 1).bit_length()


def shape_bucket(shapes) -> int:
    """Power-of-two bucket of the largest operand's element count.

    Mirrors the bucketing the tuning tables key on, so events can be joined
    against autotune entries.
    """
    biggest = 0
    for shp in shapes:
        size = 1
        for d in shp:
            size *= int(d)
        biggest = max(biggest, size)
    return _next_pow2(biggest)


def summarize_operands(objs) -> Tuple[List[tuple], int]:
    """Extract ``(shapes, estimated_bytes)`` from a bag of operands.

    Understands three operand kinds, in priority order: format objects
    exposing ``memory_bytes`` (CSR/ELL/...), array-likes with
    ``shape``/``dtype`` (including tracers — only static metadata is read),
    and containers (tuple/list/dict), walked recursively.  Scalars and
    unknown objects are ignored.
    """
    shapes: List[tuple] = []
    nbytes = 0
    stack = list(objs)
    budget = 256  # defensive bound on pathological nesting
    while stack and budget:
        budget -= 1
        o = stack.pop()
        if o is None or isinstance(o, (bool, int, float, complex, str, bytes)):
            continue
        shp = getattr(o, "shape", None)
        if shp is not None:
            try:
                shp = tuple(int(d) for d in shp)
            except (TypeError, ValueError):
                continue
            shapes.append(shp)
            mb = getattr(o, "memory_bytes", None)
            if mb is not None:
                try:
                    nbytes += int(mb)
                    continue
                except (TypeError, ValueError):
                    pass
            dt = getattr(o, "dtype", None)
            itemsize = int(getattr(dt, "itemsize", 0) or 4)
            size = 1
            for d in shp:
                size *= d
            nbytes += size * itemsize
        elif isinstance(o, (tuple, list)):
            stack.extend(o)
        elif isinstance(o, dict):
            stack.extend(o.values())
    return shapes, nbytes


@dataclasses.dataclass(frozen=True)
class DispatchEvent:
    """One operation dispatch, fully described."""

    op: str
    space: str
    executor: str
    target: str
    shapes: Tuple[tuple, ...]
    shape_bucket: int
    launch: Optional[Dict[str, Any]]
    wall_us: float
    est_bytes: int
    ts_us: float

    def to_args(self) -> Dict[str, Any]:
        """The ``args`` payload of the Chrome trace event for this dispatch."""
        args: Dict[str, Any] = {
            "space": self.space,
            "executor": self.executor,
            "target": self.target,
            "shapes": [list(s) for s in self.shapes],
            "shape_bucket": self.shape_bucket,
            "est_bytes": self.est_bytes,
        }
        if self.launch is not None:
            args["launch"] = self.launch
        return args

    @property
    def gbs(self) -> float:
        """Achieved GB/s of this dispatch (wall-time based; 0 when unknown)."""
        if self.wall_us <= 0.0:
            return 0.0
        return self.est_bytes / (self.wall_us * 1e-6) / 1e9


def make_event(
    *,
    op: str,
    space: str,
    executor,
    launch,
    wall_us: float,
    ts_us: float,
    operands,
    out,
) -> DispatchEvent:
    """Build a :class:`DispatchEvent` from a finished dispatch."""
    in_shapes, in_bytes = summarize_operands(operands)
    out_shapes, out_bytes = summarize_operands([out])
    launch_dict = None
    if launch is not None and dataclasses.is_dataclass(launch):
        launch_dict = dataclasses.asdict(launch)
    return DispatchEvent(
        op=op,
        space=space,
        executor=type(executor).__name__,
        target=executor.hw.name,
        shapes=tuple(in_shapes),
        shape_bucket=shape_bucket(in_shapes),
        launch=launch_dict,
        wall_us=wall_us,
        est_bytes=in_bytes + out_bytes,
        ts_us=ts_us,
    )


class DispatchLog(collections.Counter):
    """``Counter`` of op names + bounded deque of structured events.

    The counter face is bitwise-identical to the pre-PR-7 ``dispatch_log``
    (portability tests and BENCH launch-count pins diff it exactly); the
    ``events`` deque only fills while tracing is enabled.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.events: collections.deque = collections.deque(maxlen=EVENT_CAPACITY)

    def record(self, op_name: str, event: Optional[DispatchEvent] = None) -> None:
        self[op_name] += 1
        if event is not None:
            self.events.append(event)

    def clear(self) -> None:  # tests clear counts + events as one unit
        super().clear()
        self.events.clear()


def roofline_summary(
    events,
    hbm_bandwidth: Optional[float] = None,
) -> List[Dict[str, Any]]:
    """Aggregate dispatch events into per-(op, space, target) roofline rows.

    Each row reports dispatch count, total estimated bytes, total wall time,
    achieved GB/s, and — when ``hbm_bandwidth`` (bytes/s) is given — the
    fraction of the bandwidth bound, i.e. the live analogue of the
    ``frac_spmv_*`` pins in the BENCH snapshots.
    """
    agg: Dict[tuple, Dict[str, Any]] = {}
    for ev in events:
        key = (ev.op, ev.space, ev.target)
        row = agg.get(key)
        if row is None:
            row = agg[key] = {
                "op": ev.op,
                "space": ev.space,
                "target": ev.target,
                "count": 0,
                "est_bytes": 0,
                "wall_us": 0.0,
            }
        row["count"] += 1
        row["est_bytes"] += ev.est_bytes
        row["wall_us"] += ev.wall_us
    rows = []
    for key in sorted(agg):
        row = agg[key]
        wall_s = row["wall_us"] * 1e-6
        row["gbs"] = row["est_bytes"] / wall_s / 1e9 if wall_s > 0 else 0.0
        if hbm_bandwidth:
            row["bound_gbs"] = hbm_bandwidth / 1e9
            row["frac_of_bound"] = row["gbs"] / (hbm_bandwidth / 1e9)
        rows.append(row)
    return rows
