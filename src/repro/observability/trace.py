"""Span-based tracer with Chrome trace-event export — the ``gko::log`` analogue.

Ginkgo's logging subsystem hangs Logger objects off executors and operations so
every allocation, kernel launch, and solver iteration can be observed without
touching algorithm code.  This module is that seam for the repo: a process-wide
tracer that

* records **nested spans** (``with trace.span("solve", n=4096): ...``) and
  **complete events** (used by the dispatch layer for per-op kernel records);
* costs **near zero when disabled** — the dispatch hot path reads one module
  attribute (:data:`TRACING`) and ``span()`` returns a shared no-op context
  manager, no allocation, no clock read;
* exports the **Chrome trace-event JSON** format (``{"traceEvents": [...]}``),
  viewable in Perfetto / ``chrome://tracing``.

Activation:

* ``REPRO_TRACE=1`` in the environment enables tracing at import time and
  registers an atexit export to ``REPRO_TRACE_PATH`` (default
  ``repro_trace.json``);
* every driver in :mod:`repro.launch` takes ``--trace out.json``;
* programmatic: ``with trace.tracing("out.json"): ...`` or
  ``trace.enable(...)`` / ``trace.export()`` / ``trace.disable()``.

Timing caveat (documented, deliberate): under ``jit``, registered operations
run once at *trace time* — dispatch events therefore measure dispatch/trace
cost and launch *structure* (counts, shapes, geometry), while wall-clock truth
lives in the driver-level spans that wrap ``block_until_ready``.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "TRACING",
    "Tracer",
    "enable",
    "disable",
    "enabled",
    "get_tracer",
    "span",
    "instant",
    "export",
    "tracing",
    "validate_trace",
    "maybe_enable_from_env",
    "ENV_FLAG",
    "ENV_PATH",
]

#: fast-path flag read by the dispatch layer on every operation call.  Module
#: attribute access is the cheapest check Python offers short of inlining.
TRACING: bool = False

ENV_FLAG = "REPRO_TRACE"
ENV_PATH = "REPRO_TRACE_PATH"
DEFAULT_PATH = "repro_trace.json"

_TRACER: Optional["Tracer"] = None
_EXPORT_PATH: Optional[str] = None
_ATEXIT_REGISTERED = False
_LOCK = threading.Lock()

#: phases understood by the Chrome trace-event format that we emit/validate.
_VALID_PHASES = ("X", "i", "I", "B", "E", "C", "M")


class _NullSpan:
    """Shared no-op context manager returned by ``span()`` when disabled.

    A singleton with empty ``__slots__``: entering/exiting allocates nothing,
    which is what the overhead-guard test pins.
    """

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Open span: records one complete ("X") event on exit."""

    __slots__ = ("tracer", "name", "cat", "args", "start_us")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.start_us = 0.0

    def __enter__(self):
        self.start_us = self.tracer.now_us()
        return self

    def __exit__(self, *exc):
        end = self.tracer.now_us()
        self.tracer.complete(
            self.name, self.start_us, end - self.start_us,
            cat=self.cat, args=self.args,
        )
        return False


class Tracer:
    """Accumulates trace events; one per process is the normal arrangement."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self.t0 = time.perf_counter()
        self.pid = os.getpid()

    # -- clock ----------------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self.t0) * 1e6

    def rel_us(self, perf_counter_s: float) -> float:
        """Convert an absolute ``time.perf_counter()`` stamp to trace time."""
        return (perf_counter_s - self.t0) * 1e6

    # -- event emission -------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self.events.append(ev)

    def complete(
        self,
        name: str,
        start_us: float,
        dur_us: float,
        *,
        cat: str = "span",
        args: Optional[dict] = None,
    ) -> None:
        """Record a complete ("X") event: a closed [start, start+dur) span."""
        self._emit({
            "name": name,
            "cat": cat,
            "ph": "X",
            "ts": round(start_us, 3),
            "dur": round(max(dur_us, 0.0), 3),
            "pid": self.pid,
            "tid": threading.get_ident(),
            "args": args or {},
        })

    def instant(self, name: str, *, cat: str = "instant", **args) -> None:
        self._emit({
            "name": name,
            "cat": cat,
            "ph": "i",
            "s": "t",
            "ts": round(self.now_us(), 3),
            "pid": self.pid,
            "tid": threading.get_ident(),
            "args": args,
        })

    def span(self, name: str, *, cat: str = "span", **args) -> _Span:
        return _Span(self, name, cat, args)

    # -- export ---------------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        with self._lock:
            events = list(self.events)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.observability.trace"},
        }

    def export(self, path: str) -> str:
        data = self.to_json()
        with open(path, "w") as f:
            # default=str: span args may carry dtypes/shapes/dataclasses
            json.dump(data, f, default=str)
            f.write("\n")
        return path


# =============================================================================
# module-level switchboard
# =============================================================================


def enable(path: Optional[str] = None) -> Tracer:
    """Turn tracing on (idempotent).  ``path`` registers an atexit export."""
    global TRACING, _TRACER, _EXPORT_PATH, _ATEXIT_REGISTERED
    with _LOCK:
        if _TRACER is None:
            _TRACER = Tracer()
        if path is not None:
            _EXPORT_PATH = path
            if not _ATEXIT_REGISTERED:
                atexit.register(_export_at_exit)
                _ATEXIT_REGISTERED = True
        TRACING = True
        return _TRACER


def disable() -> None:
    global TRACING
    TRACING = False


def enabled() -> bool:
    return TRACING


def get_tracer() -> Optional[Tracer]:
    """The active tracer, or None when tracing has never been enabled."""
    return _TRACER


def reset() -> None:
    """Drop the tracer and its events (tests)."""
    global TRACING, _TRACER, _EXPORT_PATH
    with _LOCK:
        TRACING = False
        _TRACER = None
        _EXPORT_PATH = None


def span(name: str, *, cat: str = "span", **args):
    """A span context manager — the shared no-op singleton when disabled."""
    if not TRACING or _TRACER is None:
        return _NULL_SPAN
    return _TRACER.span(name, cat=cat, **args)


def instant(name: str, *, cat: str = "instant", **args) -> None:
    if TRACING and _TRACER is not None:
        _TRACER.instant(name, cat=cat, **args)


def export(path: Optional[str] = None) -> Optional[str]:
    """Write the accumulated trace; ``None`` uses the configured path."""
    target = path or _EXPORT_PATH
    if _TRACER is None or target is None:
        return None
    return _TRACER.export(target)


def _export_at_exit() -> None:
    try:
        export()
    except Exception:
        pass  # never let telemetry break process teardown


class _TracingContext:
    def __init__(self, path: Optional[str]):
        self.path = path

    def __enter__(self) -> Tracer:
        return enable(self.path)

    def __exit__(self, *exc):
        if self.path is not None:
            export(self.path)
        disable()
        return False


def tracing(path: Optional[str] = None) -> _TracingContext:
    """``with trace.tracing("out.json"):`` — enable, run, export, disable."""
    return _TracingContext(path)


# =============================================================================
# validation — the CI trace-schema gate
# =============================================================================


def validate_trace(data) -> List[str]:
    """Validate a Chrome trace-event object (or a path to one).

    Returns a list of human-readable problems; empty means valid.  Checks the
    envelope and per-event requirements Perfetto relies on: ``name``/``ph``/
    ``ts`` everywhere, ``dur`` on complete events, integer ``pid``/``tid``.
    """
    errors: List[str] = []
    if isinstance(data, (str, os.PathLike)):
        try:
            with open(data) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return [f"unreadable trace file: {e}"]
    if not isinstance(data, dict):
        return [f"top level must be an object, got {type(data).__name__}"]
    events = data.get("traceEvents")
    if not isinstance(events, list):
        return ["missing 'traceEvents' list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing/empty 'name'")
        ph = ev.get("ph")
        if ph not in _VALID_PHASES:
            errors.append(f"{where}: bad phase {ph!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"{where}: missing numeric 'ts'")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: complete event needs 'dur' >= 0")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errors.append(f"{where}: missing integer {key!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where}: 'args' must be an object")
        if len(errors) > 50:
            errors.append("... (truncated)")
            break
    return errors


def add_cli_flag(parser) -> None:
    """Attach the standard ``--trace out.json`` flag to a launch driver."""
    parser.add_argument(
        "--trace",
        metavar="OUT_JSON",
        default=None,
        help="write a Chrome trace-event file (perfetto-viewable) of this run",
    )


def enable_from_args(args) -> Optional[str]:
    """Honor a parsed ``--trace`` flag; returns the export path if enabled.

    Drivers call this right after ``parse_args`` and :func:`export` before
    returning (the atexit hook is only the backstop for abnormal exits).
    """
    path = getattr(args, "trace", None)
    if path:
        enable(path)
        return path
    return None


def maybe_enable_from_env() -> bool:
    """Honor ``REPRO_TRACE=1`` (export to ``REPRO_TRACE_PATH`` at exit)."""
    flag = os.environ.get(ENV_FLAG, "").strip().lower()
    if flag in ("1", "true", "yes", "on"):
        enable(os.environ.get(ENV_PATH, DEFAULT_PATH))
        return True
    return False


maybe_enable_from_env()
