"""Observability: tracing, dispatch events, metrics, convergence telemetry.

The repo's ``gko::log`` layer.  Four pieces, each usable alone:

* :mod:`repro.observability.trace` — span tracer with Chrome trace-event
  export (``REPRO_TRACE=1`` or ``--trace out.json`` on launch drivers);
* :mod:`repro.observability.events` — structured dispatch events behind
  ``Executor.dispatch_log`` (the Counter face is a derived view);
* :mod:`repro.observability.metrics` — counters/gauges/histograms with
  JSONL and table exporters;
* :mod:`repro.observability.convergence` — jit-safe residual-history ring
  buffer powering the ``history=`` option on every solver.

``trace``/``events``/``metrics`` are stdlib-only so the core dispatch layer
can import them unconditionally; ``convergence`` needs ``jax.numpy`` and is
imported lazily here.
"""

from repro.observability import events, metrics, trace
from repro.observability.events import DispatchEvent, DispatchLog, roofline_summary
from repro.observability.trace import span, validate_trace

__all__ = [
    "events",
    "metrics",
    "trace",
    "convergence",
    "DispatchEvent",
    "DispatchLog",
    "roofline_summary",
    "span",
    "validate_trace",
]


def __getattr__(name):
    if name == "convergence":
        import importlib

        return importlib.import_module("repro.observability.convergence")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
