"""Jit-safe convergence history: a fixed-capacity residual-norm ring buffer.

Ginkgo's ``gko::log::Convergence`` logger hangs off a stopping-criterion
factory and records the residual norm each time the criterion is checked.
The JAX translation has one extra constraint: solver loops are
``lax.while_loop`` bodies under ``jit``, so the recording structure must be a
fixed-shape array threaded through the loop carry — no Python-side appends.

The scheme used by every solver in this repo:

* ``cap = capacity(history, stop)`` maps the user-facing ``history=`` option
  (``None``/``False`` -> 0, ``True`` -> ``stop.max_iters``, ``int`` -> that
  many slots) to a static buffer size;
* ``hist = init(cap)`` is a ``(cap,)`` NaN-filled carry (``(cap, nb)`` for
  batched solves); capacity 0 yields a ``(0,)`` array so the *same* loop body
  works with history on or off — :func:`push` is a static no-op on size-0
  buffers, which jit constant-folds away, keeping the disabled path free;
* the loop body calls ``hist = push(hist, k, rnorm)`` with the 0-based
  iteration index; when iterations exceed ``cap`` the buffer wraps (ring
  semantics: the last ``cap`` residuals survive);
* ``finalize(hist)`` maps the size-0 buffer back to ``None`` for
  ``SolveResult.history``; unfilled slots stay NaN.

psum-awareness: the distributed path runs solver source unchanged under
``shard_map`` with all reductions psum'd, so the recorded norms are *global*
and identical on every shard — ``dist_solve`` returns shard 0's copy.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["capacity", "init", "push", "finalize", "trim"]


def capacity(history, stop) -> int:
    """Static buffer size for a ``history=`` option against a Stop rule."""
    if history is None or history is False:
        return 0
    if history is True:
        return int(stop.max_iters)
    cap = int(history)
    if cap < 0:
        raise ValueError(f"history capacity must be >= 0, got {cap}")
    return cap


def init(cap: int, *, batch: Optional[int] = None, dtype=jnp.float32):
    """NaN-filled ring buffer carry: ``(cap,)`` or ``(cap, batch)``."""
    shape: Tuple[int, ...] = (cap,) if batch is None else (cap, batch)
    return jnp.full(shape, jnp.nan, dtype=dtype)


def push(hist, k, value):
    """Record ``value`` at iteration ``k`` (traced ok); no-op when disabled.

    The ``cap == 0`` branch is decided on static shape information, so the
    disabled path adds nothing to the compiled loop body.
    """
    cap = hist.shape[0]
    if cap == 0:
        return hist
    return hist.at[jnp.mod(k, cap)].set(
        jnp.asarray(value, dtype=hist.dtype)
    )


def finalize(hist):
    """Ring buffer -> ``SolveResult.history`` (``None`` when disabled)."""
    if hist is None or hist.shape[0] == 0:
        return None
    return hist


def trim(history, iterations: Optional[int] = None):
    """Drop unfilled (NaN) slots — host-side convenience for tools/tests.

    ``iterations`` (when known) takes the first that-many entries; otherwise
    every non-NaN entry is kept.  Returns a host numpy array.
    """
    import numpy as np

    if history is None:
        return None
    h = np.asarray(history)
    if iterations is not None:
        return h[: min(int(iterations), h.shape[0])]
    mask = ~np.isnan(h if h.ndim == 1 else h[:, 0])
    return h[mask]
