"""Metrics registry: counters, gauges, histograms with JSONL/table export.

A minimal, dependency-free metrics substrate for the repo's telemetry —
enough for the benchmark harness to publish achieved GB/s per
op x format x executor live, and for the future solve-server to report
latency percentiles, without inventing ad-hoc dicts in every module.

* :class:`Counter` — monotonically increasing (dispatch counts, iterations);
* :class:`Gauge` — last-write-wins (achieved GB/s, frac-of-bound);
* :class:`Histogram` — count/sum/min/max + power-of-two bucket counts
  (wall-time distributions; pow2 buckets match the shape buckets used by
  dispatch events and tuning tables).

Metrics are named and labelled (``gauge("spmv_gbs", op="spmv_csr",
executor="xla")``); a ``(name, labels)`` pair identifies one time series.
Exporters: :func:`export_jsonl` (one JSON object per series, greppable and
CI-artifact-friendly) and :func:`render_table` (aligned human table).
"""

from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "counter",
    "gauge",
    "histogram",
    "samples",
    "export_jsonl",
    "render_table",
    "reset",
]


#: smallest sub-unit bucket exponent: values below 2^-30 (~0.93 ns when the
#: unit is seconds) clamp into the 2^-30 bucket.
_MIN_BUCKET_EXP = -30


def _bucket_of(v: float):
    """Power-of-two bucket upper bound containing ``v``.

    Buckets ``>= 1`` keep their historical integer labels (1, 2, 4, ...);
    values in ``(0, 1]`` land in fractional buckets ``2^-1 .. 2^-30`` (the
    smallest bucket also absorbs everything at or below ``2^-30``, including
    non-positive values).  Without the sub-unit buckets every wall-time
    histogram measured in seconds collapsed into the ``1`` bin, making
    p50/p99 unreadable — exactly the statistics the solve-serve loop reports.
    """
    if v > 1:
        b = 1
        while b < v and b < (1 << 62):
            b <<= 1
        return b
    if v > 0.5:
        return 1
    floor = 2.0 ** _MIN_BUCKET_EXP
    b = 0.5
    while b * 0.5 >= v and b > floor:
        b *= 0.5
    return b


class Counter:
    __slots__ = ("value",)

    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        self.value += amount

    def sample(self) -> Dict[str, Any]:
        return {"value": self.value}


class Gauge:
    __slots__ = ("value",)

    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def sample(self) -> Dict[str, Any]:
        return {"value": self.value}


class Histogram:
    __slots__ = ("count", "sum", "min", "max", "buckets")

    kind = "histogram"

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.buckets: Dict[int, int] = {}

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        b = _bucket_of(max(value, 0.0))
        self.buckets[b] = self.buckets.get(b, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> Optional[float]:
        """Upper bound of the bucket holding the ``q``-quantile (0 <= q <= 1).

        Resolution is one power of two — coarse, but monotone and cheap, and
        with the sub-unit buckets it distinguishes microseconds from
        milliseconds from seconds, which is what a p50/p99 latency report
        needs.  Returns None on an empty histogram.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if not self.count:
            return None
        target = q * self.count
        cum = 0
        bound = None
        for b in sorted(self.buckets):
            bound = b
            cum += self.buckets[b]
            if cum >= target:
                break
        return float(bound)

    def sample(self) -> Dict[str, Any]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "mean": self.mean,
            "buckets": {str(k): v for k, v in sorted(self.buckets.items())},
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Holds named, labelled metric series; thread-safe get-or-create."""

    def __init__(self):
        self._series: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Any] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, labels: Dict[str, Any]):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        with self._lock:
            m = self._series.get(key)
            if m is None:
                m = self._series[key] = _KINDS[kind]()
            elif m.kind != kind:
                raise TypeError(
                    f"metric {name!r}{dict(key[1])} already registered as "
                    f"{m.kind}, requested {kind}"
                )
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # -- export ---------------------------------------------------------------
    def samples(self) -> List[Dict[str, Any]]:
        with self._lock:
            items = sorted(self._series.items())
        out = []
        for (name, labels), metric in items:
            rec = {"name": name, "kind": metric.kind, "labels": dict(labels)}
            rec.update(metric.sample())
            out.append(rec)
        return out

    def export_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for rec in self.samples():
                f.write(json.dumps(rec, default=str))
                f.write("\n")
        return path

    def render_table(self) -> str:
        rows = []
        for rec in self.samples():
            labels = ",".join(f"{k}={v}" for k, v in sorted(rec["labels"].items()))
            if rec["kind"] == "histogram":
                val = (
                    f"n={rec['count']} mean={rec['mean']:.3g} "
                    f"min={rec['min']:.3g} max={rec['max']:.3g}"
                    if rec["count"]
                    else "n=0"
                )
            else:
                val = f"{rec['value']:.6g}"
            rows.append((rec["name"], labels, rec["kind"], val))
        if not rows:
            return "(no metrics recorded)"
        widths = [max(len(r[i]) for r in rows) for i in range(3)]
        header = ("metric".ljust(widths[0]), "labels".ljust(widths[1]),
                  "kind".ljust(widths[2]), "value")
        lines = ["  ".join(header)]
        lines.append("  ".join("-" * len(h) for h in header))
        for r in rows:
            lines.append("  ".join(
                (r[0].ljust(widths[0]), r[1].ljust(widths[1]),
                 r[2].ljust(widths[2]), r[3])))
        return "\n".join(lines)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _DEFAULT


def counter(name: str, **labels) -> Counter:
    return _DEFAULT.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _DEFAULT.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return _DEFAULT.histogram(name, **labels)


def samples() -> List[Dict[str, Any]]:
    return _DEFAULT.samples()


def export_jsonl(path: str) -> str:
    return _DEFAULT.export_jsonl(path)


def render_table() -> str:
    return _DEFAULT.render_table()


def reset() -> None:
    _DEFAULT.reset()


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    """Read back an exported metrics JSONL file (inspect tool, tests)."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def observe_dispatch(event, hbm_bandwidth: Optional[float] = None) -> None:
    """Fold one :class:`~repro.observability.events.DispatchEvent` into the
    default registry — dispatch counts, wall-time histograms, and (when the
    event carries a bytes estimate) live achieved-GB/s gauges per
    op x space x target, with frac-of-bound against ``hbm_bandwidth``."""
    labels = {"op": event.op, "space": event.space, "target": event.target}
    counter("dispatch_total", **labels).inc()
    histogram("dispatch_wall_us", **labels).observe(event.wall_us)
    if event.est_bytes and event.wall_us > 0:
        g = event.gbs
        gauge("dispatch_gbs", **labels).set(g)
        if hbm_bandwidth:
            gauge("dispatch_frac_of_bound", **labels).set(
                g / (hbm_bandwidth / 1e9)
            )
