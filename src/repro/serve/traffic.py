"""Synthetic solve traffic: Poisson arrivals over a pattern gallery.

Models the workload the setup cache exists for — a service receiving many
small systems where sparsity patterns recur heavily (device simulation
batches, time-stepping with fixed meshes): exponential inter-arrival gaps at
``rate_hz``, patterns drawn from a gallery of ``gallery_size`` distinct SPD
stencils, and ``repeat_ratio`` controlling how often a request reuses a
previously issued (pattern, values) pair — with a fresh right-hand side, so
repeats are real solves, not memoizable no-ops.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.serve.request import SolveRequest
from repro.sparse.gallery import BANDED_OFFSETS, convection_diffusion_2d, spd_banded

__all__ = ["TrafficConfig", "pattern_gallery", "nonsym_gallery", "generate_traffic"]


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    num_requests: int = 64
    rate_hz: float = 500.0
    gallery_size: int = 4
    #: probability a request reuses a previously issued (pattern, values)
    #: pair — these hit both cache tiers; non-repeats draw a gallery pattern
    #: with fresh values (pattern-tier hit once the pattern has been seen)
    repeat_ratio: float = 0.6
    n: int = 24
    seed: int = 0
    #: probability a non-repeat request draws a nonsymmetric convection-
    #: diffusion pattern instead of an SPD stencil; requires ``n`` to be a
    #: perfect square and an engine solver that tolerates nonsymmetric A
    #: (``ServeConfig(solver="bicgstab")``)
    nonsym_ratio: float = 0.0


def pattern_gallery(cfg: TrafficConfig):
    """``gallery_size`` distinct (indptr, indices) patterns with a values
    generator per pattern (drawn from :func:`repro.sparse.gallery.spd_banded`).
    """
    if cfg.gallery_size > len(BANDED_OFFSETS):
        raise ValueError(
            f"gallery_size {cfg.gallery_size} exceeds the "
            f"{len(BANDED_OFFSETS)} available distinct stencils"
        )
    rng = np.random.default_rng(cfg.seed)
    gallery = []
    for g in range(cfg.gallery_size):
        offsets = BANDED_OFFSETS[g]
        shift = 3.0 + g

        def make_values(offsets=offsets, shift=shift):
            return spd_banded(cfg.n, offsets, shift, rng)[:3]

        indptr, indices, _, _ = spd_banded(cfg.n, offsets, shift,
                                           np.random.default_rng(0))
        gallery.append((indptr, indices, make_values))
    return gallery


def nonsym_gallery(cfg: TrafficConfig):
    """Nonsymmetric convection-diffusion patterns (one per Péclet regime).

    Fresh values multiply the stencil by a small random field, so repeats of
    a pattern still exercise the values-tier cache miss path.
    """
    side = int(round(cfg.n ** 0.5))
    if side * side != cfg.n:
        raise ValueError(
            f"nonsym traffic needs a square grid: n={cfg.n} is not a square"
        )
    rng = np.random.default_rng(cfg.seed + 17)
    gallery = []
    for peclet in (0.5, 5.0):
        indptr, indices, base, _ = convection_diffusion_2d(side, peclet=peclet)

        def make_values(base=base):
            return base * (1.0 + 0.05 * rng.random(len(base))).astype(np.float32)

        gallery.append((indptr, indices, make_values))
    return gallery


def generate_traffic(
    cfg: TrafficConfig,
) -> List[Tuple[float, SolveRequest]]:
    """``[(inter_arrival_gap_s, request), ...]`` — a Poisson request stream.

    Deterministic for a given seed.  Right-hand sides are always fresh;
    matrices repeat according to ``repeat_ratio``.
    """
    rng = np.random.default_rng(cfg.seed + 1)
    gallery = pattern_gallery(cfg)
    ns_gallery = nonsym_gallery(cfg) if cfg.nonsym_ratio > 0.0 else []
    seen: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    out: List[Tuple[float, SolveRequest]] = []
    for _ in range(cfg.num_requests):
        gap = float(rng.exponential(1.0 / cfg.rate_hz))
        if seen and rng.random() < cfg.repeat_ratio:
            indptr, indices, values = seen[rng.integers(len(seen))]
        else:
            if ns_gallery and rng.random() < cfg.nonsym_ratio:
                g = int(rng.integers(len(ns_gallery)))
                indptr, indices = ns_gallery[g][0], ns_gallery[g][1]
                values = ns_gallery[g][2]()
            else:
                g = int(rng.integers(len(gallery)))
                indptr, indices = gallery[g][0], gallery[g][1]
                _, _, values = gallery[g][2]()
            seen.append((indptr, indices, values))
        b = rng.normal(size=cfg.n).astype(np.float32)
        out.append((gap, SolveRequest(
            indptr=indptr, indices=indices, values=values, b=b,
            shape=(cfg.n, cfg.n),
        )))
    return out
