"""Async request-queue front end over the continuous-batching engine.

``SolveService`` owns a background worker thread: callers ``submit()``
requests from any thread and later ``result()`` (or ``gather()``) the
responses; the worker drains the inbox into the engine and ticks it while
work remains.  The engine itself stays single-threaded — only the worker
touches it — so every cache/parity property of the inline engine holds
unchanged under the async boundary.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Dict, List, Optional

from repro.serve.engine import ContinuousBatchEngine, ServeConfig
from repro.serve.request import SolveRequest, SolveResponse

__all__ = ["SolveService"]


class SolveService:
    """Threaded solve server: async queue in, responses out.

    Use as a context manager::

        with SolveService(config, executor=ex) as svc:
            rid = svc.submit(request)
            resp = svc.result(rid, timeout=30)
    """

    def __init__(
        self,
        config: ServeConfig = ServeConfig(),
        *,
        executor=None,
        idle_sleep_s: float = 1e-4,
    ):
        self.engine = ContinuousBatchEngine(config, executor=executor)
        self._inbox: "queue.Queue[SolveRequest]" = queue.Queue()
        self._results: Dict[int, SolveResponse] = {}
        self._done = threading.Condition()
        self._ids = itertools.count()
        self._idle_sleep_s = idle_sleep_s
        self._stop_flag = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "SolveService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._thread = threading.Thread(target=self._run, name="solve-serve",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_flag.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "SolveService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API -----------------------------------------------------------
    def submit(self, req: SolveRequest) -> int:
        """Enqueue a request; returns its id immediately."""
        if self._thread is None:
            raise RuntimeError("service not started")
        if req.request_id is None:
            req.request_id = next(self._ids)
        if req.submitted_s is None:
            req.submitted_s = time.perf_counter()
        self._inbox.put(req)
        return req.request_id

    def result(self, request_id: int,
               timeout: Optional[float] = None) -> SolveResponse:
        """Block until the response for ``request_id`` arrives."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._done:
            while request_id not in self._results:
                if self._error is not None:
                    raise RuntimeError(
                        "solve-serve worker died"
                    ) from self._error
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"no response for request {request_id} "
                        f"within {timeout}s"
                    )
                self._done.wait(timeout=remaining)
            return self._results.pop(request_id)

    def gather(self, request_ids: List[int],
               timeout: Optional[float] = None) -> List[SolveResponse]:
        return [self.result(rid, timeout=timeout) for rid in request_ids]

    # -- worker ---------------------------------------------------------------
    def _run(self) -> None:
        try:
            while not self._stop_flag.is_set():
                moved = False
                while True:
                    try:
                        req = self._inbox.get_nowait()
                    except queue.Empty:
                        break
                    # ids were assigned at submit(); the engine respects them
                    self.engine.submit(req)
                    moved = True
                if self.engine.has_work:
                    responses = self.engine.tick()
                    if responses:
                        with self._done:
                            for resp in responses:
                                self._results[resp.request_id] = resp
                            self._done.notify_all()
                elif not moved:
                    time.sleep(self._idle_sleep_s)
        except BaseException as e:  # surface worker death to blocked callers
            with self._done:
                self._error = e
                self._done.notify_all()
