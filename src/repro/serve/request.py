"""Request/response records for the solve service.

A request carries one sparse system in host CSR arrays (the wire format a
service boundary would deserialize into) plus its right-hand side; the
response carries the per-system slice of the batched solve outcome together
with serving telemetry (cache-hit flags, admission/retire timestamps).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["SolveRequest", "SolveResponse"]


@dataclasses.dataclass
class SolveRequest:
    """One sparse linear system ``A x = b`` submitted to the service.

    ``indptr``/``indices``/``shape`` define the sparsity pattern (the setup
    cache key); ``values`` the per-request numerics; ``b`` the right-hand
    side.  Timestamps are ``time.perf_counter()`` seconds, filled in as the
    request moves through the pipeline.
    """

    indptr: np.ndarray
    indices: np.ndarray
    values: np.ndarray
    b: np.ndarray
    shape: Tuple[int, int]
    request_id: Optional[int] = None
    #: set by the submitter (service/driver) at enqueue time
    submitted_s: Optional[float] = None
    #: set by the engine when the request enters a batch slot
    admitted_s: Optional[float] = None

    @property
    def n(self) -> int:
        return int(self.shape[0])

    @property
    def nnz(self) -> int:
        return int(np.asarray(self.values).size)

    @classmethod
    def from_csr(cls, A, b, **kw) -> "SolveRequest":
        """Build from a single-system :class:`repro.sparse.formats.Csr`."""
        return cls(
            indptr=np.asarray(A.indptr),
            indices=np.asarray(A.indices),
            values=np.asarray(A.values),
            b=np.asarray(b),
            shape=tuple(A.shape),
            **kw,
        )


@dataclasses.dataclass
class SolveResponse:
    """Outcome of one served solve — the per-request slice of a batch."""

    request_id: int
    x: np.ndarray
    iterations: int
    residual_norm: float
    converged: bool
    #: True when the sparsity pattern's setup products were already cached
    pattern_hit: bool = False
    #: True when the inverted preconditioner factors for this exact value
    #: set were already cached (implies no values-tier generation either)
    factors_hit: bool = False
    #: end-to-end latency (submit -> retire), perf_counter seconds
    latency_s: Optional[float] = None
    retired_s: Optional[float] = None
