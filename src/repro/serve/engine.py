"""Continuous-batching solve engine — slots, admit/advance/retire.

One *lane* per sparsity pattern holds a fixed number of batch **slots**; each
slot carries one in-flight system through the masked batched Krylov loop.
The engine's tick cycle is:

* **admit** — pending requests are scattered into free slots (values, rhs,
  cached preconditioner factors), then one jitted ``refresh`` recomputes the
  solver init state and stopping threshold for exactly the newly seeded rows
  (``jnp.where`` on the admission mask — untouched rows ride through
  bitwise unchanged);
* **advance** — one jitted chunked call into
  :func:`repro.batch.solvers.batch_cg_advance` /
  :func:`~repro.batch.solvers.batch_bicgstab_advance` runs up to
  ``chunk_sweeps`` masked sweeps (JAX cannot admit work into a live
  ``while_loop``, so the loop yields to the host between chunks — that is
  the continuous-batching seam);
* **retire** — converged (or iteration-capped) slots are read back, their
  responses emitted, and the slot freed by setting its threshold to +inf
  (a frozen row: every batched op is row-independent, so it costs one lane
  row of flops and changes nothing).

Because every batched operation reduces row-independently, a slot's iterate
sequence is bitwise identical to a solo ``batch_cg`` on that one system —
the acceptance property the parity tests pin.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.batch import ops
from repro.batch.formats import BatchCsr, BatchEll
from repro.batch.solvers import (
    BatchBicgstabState,
    BatchCgState,
    batch_bicgstab_advance,
    batch_bicgstab_init,
    batch_cg_advance,
    batch_cg_init,
)
from repro.observability import convergence, metrics, trace
from repro.precond import batch_block_jacobi_from_factors
from repro.precond.amg import batch_amg_apply
from repro.solvers.parilu import batch_parilu_apply
from repro.serve.cache import (
    PatternSetup,
    SetupCache,
    pattern_key,
    serve_generate_factors_op,
    serve_generate_pattern_op,
    values_fingerprint,
)
from repro.serve.request import SolveRequest, SolveResponse
from repro.solvers.common import Stop

__all__ = ["ServeConfig", "PatternLane", "ContinuousBatchEngine"]

#: sweep cap handed to the chunked advance — per-request iteration limits are
#: enforced host-side at retire (the lane's global sweep counter never stops
#: the loop; ``num_sweeps`` bounds each chunk instead)
_UNBOUNDED_ITERS = (1 << 31) - 1


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine configuration (fixed per engine; baked into jit closures)."""

    slots: int = 8
    chunk_sweeps: int = 8
    solver: str = "cg"  # cg | bicgstab
    fmt: str = "csr"  # csr | ell
    precond: str = "block_jacobi"  # block_jacobi | parilu | amg | none
    block_size: int = 4
    stop: Stop = Stop(max_iters=500, reduction_factor=1e-5)
    cache_patterns: int = 32
    cache_factors: int = 8

    def pattern_config(self) -> str:
        """The config part of the pattern-cache key: everything that changes
        the generated tables/layout maps (solver/stop live in closure keys —
        they do not affect the pattern tier's products)."""
        return f"{self.fmt}|{self.precond}|bs{self.block_size}"

    def closure_key(self):
        return (self.slots, self.solver, self.chunk_sweeps, self.stop)


def _zero_state(solver: str, S: int, n: int, dtype):
    """Host-built all-frozen state for a fresh lane (no dispatches)."""
    z2 = jnp.zeros((S, n), dtype)
    z1 = jnp.zeros((S,), dtype)
    it = jnp.zeros((S,), jnp.int32)
    hist = convergence.init(0, batch=S, dtype=dtype)
    if solver == "cg":
        return BatchCgState(z2, z2, z2, z2, z1, it, jnp.int32(0), z1, hist)
    if solver == "bicgstab":
        return BatchBicgstabState(z2, z2, z2, z2, z1, it, jnp.int32(0), z1,
                                  hist)
    raise ValueError(f"unknown serve solver {solver!r} (cg | bicgstab)")


def _build_closures(setup: PatternSetup, config: ServeConfig, ex):
    """jit-compiled (refresh, advance) pair for one (pattern, config).

    Stored in the pattern's cache entry, so repeat-pattern traffic reuses the
    compiled XLA executables along with the tables — compilation is part of
    what the setup cache amortizes.
    """
    run_stop = dataclasses.replace(config.stop, max_iters=_UNBOUNDED_ITERS)
    shape = setup.shape

    if setup.fmt == "csr":
        indptr = jnp.asarray(setup.indptr, jnp.int32)
        indices = jnp.asarray(setup.indices, jnp.int32)

        def mk_A(values):
            return BatchCsr(indptr, indices, values, shape)
    else:
        col_idx = setup.col_idx
        m, kk = col_idx.shape

        def mk_A(values):
            return BatchEll(col_idx, values.reshape(-1, m, kk), shape)

    def mk_M(inv, S):
        if setup.jacobi is not None:
            return batch_block_jacobi_from_factors(inv, S, setup.jacobi,
                                                   executor=ex)
        if setup.parilu is not None:
            st = setup.parilu
            nl = int(st.l_rows.size)
            return lambda R: batch_parilu_apply(st, inv[:, :nl], inv[:, nl:],
                                                R)
        if setup.amg is not None:
            return lambda R: batch_amg_apply(setup.amg, inv, R)
        return None

    cg = config.solver == "cg"

    @jax.jit
    def refresh(values, inv, B, state, thresh, newly):
        """Recompute init state + threshold for the ``newly`` admitted rows."""
        A = mk_A(values)
        M = mk_M(inv, values.shape[0])
        bnorm = ops.batch_norm2(B, executor=ex)
        fresh_thresh = config.stop.threshold(bnorm)
        n2 = newly[:, None]
        if cg:
            init = batch_cg_init(A, B, jnp.zeros_like(B), M=M, executor=ex)
            state = BatchCgState(
                X=jnp.where(n2, init.X, state.X),
                R=jnp.where(n2, init.R, state.R),
                Z=jnp.where(n2, init.Z, state.Z),
                P=jnp.where(n2, init.P, state.P),
                rz=jnp.where(newly, init.rz, state.rz),
                iters=jnp.where(newly, init.iters, state.iters),
                k=state.k,
                rnorm=jnp.where(newly, init.rnorm, state.rnorm),
                hist=state.hist,
            )
        else:
            init = batch_bicgstab_init(A, B, jnp.zeros_like(B), executor=ex)
            state = BatchBicgstabState(
                X=jnp.where(n2, init.X, state.X),
                R=jnp.where(n2, init.R, state.R),
                R_hat=jnp.where(n2, init.R_hat, state.R_hat),
                P=jnp.where(n2, init.P, state.P),
                rho=jnp.where(newly, init.rho, state.rho),
                iters=jnp.where(newly, init.iters, state.iters),
                k=state.k,
                rnorm=jnp.where(newly, init.rnorm, state.rnorm),
                hist=state.hist,
            )
        return state, jnp.where(newly, fresh_thresh, thresh)

    @jax.jit
    def advance(values, inv, state, thresh):
        A = mk_A(values)
        M = mk_M(inv, values.shape[0])
        step = batch_cg_advance if cg else batch_bicgstab_advance
        return step(A, state, thresh, stop=run_stop, M=M,
                    num_sweeps=config.chunk_sweeps, executor=ex)

    return refresh, advance


class PatternLane:
    """Batch slots + solver state for one sparsity pattern."""

    def __init__(self, setup: PatternSetup, config: ServeConfig, executor):
        S = config.slots
        n = setup.n
        dtype = jnp.float32
        self.setup = setup
        self.config = config
        self.executor = executor
        self.values = jnp.zeros((S, setup.flat_value_len), dtype)
        self.B = jnp.zeros((S, n), dtype)
        if setup.jacobi is not None:
            nbl, bs = setup.jacobi.num_blocks, setup.jacobi.block_size
            self.inv = jnp.zeros((S * nbl, bs, bs), dtype)
        elif setup.flat_factor_len is not None:
            # parilu / amg lanes store one flat factor row per slot
            self.inv = jnp.zeros((S, setup.flat_factor_len), dtype)
        else:
            self.inv = jnp.zeros((0, 1, 1), dtype)
        self.thresh = jnp.full((S,), jnp.inf, dtype)
        self.state = _zero_state(config.solver, S, n, dtype)
        self.requests: List[Optional[SolveRequest]] = [None] * S
        self.pending: "deque[SolveRequest]" = deque()
        ckey = config.closure_key()
        if ckey not in setup.closures:
            setup.closures[ckey] = _build_closures(setup, config, executor)
        self.refresh_fn, self.advance_fn = setup.closures[ckey]

    @property
    def occupied(self) -> int:
        return sum(r is not None for r in self.requests)

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or self.occupied > 0


class ContinuousBatchEngine:
    """Deterministic host loop: ``submit()`` requests, ``tick()`` the lanes.

    Single-threaded by design — the async boundary lives in
    :class:`repro.serve.service.SolveService`; keeping the engine inline
    makes the cache/parity behavior exactly reproducible in tests.
    """

    def __init__(
        self,
        config: ServeConfig = ServeConfig(),
        *,
        executor=None,
        cache: Optional[SetupCache] = None,
    ):
        if executor is None:
            from repro.core.executor import current_executor

            executor = current_executor()
        # fail fast on degenerate stopping criteria (instead of at trace time
        # inside the first refresh)
        config.stop.threshold(jnp.zeros((0,), jnp.float32))
        self.config = config
        self.executor = executor
        self.cache = cache if cache is not None else SetupCache(
            config.cache_patterns, config.cache_factors
        )
        self.lanes: Dict[str, PatternLane] = {}
        self._ids = itertools.count()
        #: request_id -> [pattern_hit, factors_hit]
        self._flags: Dict[int, List[bool]] = {}

    # -- intake ---------------------------------------------------------------
    def submit(self, req: SolveRequest) -> int:
        if req.request_id is None:
            req.request_id = next(self._ids)
        if req.submitted_s is None:
            req.submitted_s = time.perf_counter()
        key = pattern_key(req.indptr, req.indices, req.shape,
                          self.config.pattern_config())
        setup, hit = self.cache.setup(
            key,
            build=lambda: serve_generate_pattern_op(
                req.indptr, req.indices, req.shape,
                fmt=self.config.fmt,
                precond=self.config.precond,
                block_size=self.config.block_size,
                executor=self.executor,
            ),
        )
        lane = self.lanes.get(key)
        if lane is None:
            lane = self.lanes[key] = PatternLane(setup, self.config,
                                                 self.executor)
        elif lane.setup is not setup:
            # the pattern was evicted and regenerated since this lane was
            # built — rebind so closures/factors stay consistent
            lane.setup = setup
            ckey = self.config.closure_key()
            if ckey not in setup.closures:
                setup.closures[ckey] = _build_closures(setup, self.config,
                                                       self.executor)
            lane.refresh_fn, lane.advance_fn = setup.closures[ckey]
        self._flags[req.request_id] = [hit, False]
        lane.pending.append(req)
        metrics.counter("serve_requests").inc()
        return req.request_id

    # -- the tick cycle -------------------------------------------------------
    def tick(self) -> List[SolveResponse]:
        """One admit -> advance -> retire cycle over every lane."""
        responses: List[SolveResponse] = []
        for lane in self.lanes.values():
            self._admit(lane)
        for lane in self.lanes.values():
            if lane.occupied:
                lane.state = lane.advance_fn(lane.values, lane.inv,
                                             lane.state, lane.thresh)
        for lane in self.lanes.values():
            responses.extend(self._retire(lane))
        metrics.gauge("serve_slots_occupied").set(
            sum(lane.occupied for lane in self.lanes.values())
        )
        return responses

    @property
    def has_work(self) -> bool:
        return any(lane.has_work for lane in self.lanes.values())

    def drain(self, max_ticks: int = 100_000) -> List[SolveResponse]:
        """Tick until every submitted request has retired."""
        out: List[SolveResponse] = []
        for _ in range(max_ticks):
            if not self.has_work:
                return out
            out.extend(self.tick())
        raise RuntimeError(
            f"serve engine failed to drain within {max_ticks} ticks"
        )

    # -- internals ------------------------------------------------------------
    def _admit(self, lane: PatternLane) -> None:
        if not lane.pending:
            return
        S = self.config.slots
        newly = np.zeros(S, bool)
        for s in range(S):
            if lane.requests[s] is not None or not lane.pending:
                continue
            req = lane.pending.popleft()
            vals = lane.setup.lane_values(req.values)
            lane.values = lane.values.at[s].set(
                jnp.asarray(vals, lane.values.dtype)
            )
            lane.B = lane.B.at[s].set(jnp.asarray(req.b, lane.B.dtype))
            if lane.setup.has_factors:
                fp = values_fingerprint(vals)
                inv_rows, fhit = self.cache.factors(
                    lane.setup, fp,
                    build=lambda v=vals: serve_generate_factors_op(
                        jnp.asarray(v, lane.values.dtype), lane.setup,
                        executor=self.executor,
                    ),
                )
                if lane.setup.jacobi is not None:
                    nbl = lane.setup.jacobi.num_blocks
                    lane.inv = lane.inv.at[s * nbl:(s + 1) * nbl].set(inv_rows)
                else:
                    lane.inv = lane.inv.at[s].set(inv_rows)
                self._flags[req.request_id][1] = fhit
            req.admitted_s = time.perf_counter()
            lane.requests[s] = req
            newly[s] = True
            trace.instant("serve.admit", slot=s, request=req.request_id,
                          pattern=lane.setup.key[:12])
        if newly.any():
            lane.state, lane.thresh = lane.refresh_fn(
                lane.values, lane.inv, lane.B, lane.state, lane.thresh,
                jnp.asarray(newly),
            )

    def _retire(self, lane: PatternLane) -> List[SolveResponse]:
        out: List[SolveResponse] = []
        if not lane.occupied:
            return out
        rnorm = np.asarray(lane.state.rnorm)
        th = np.asarray(lane.thresh)
        iters = np.asarray(lane.state.iters)
        max_iters = self.config.stop.max_iters
        done = [
            s for s, r in enumerate(lane.requests)
            if r is not None and (rnorm[s] <= th[s] or iters[s] >= max_iters)
        ]
        if not done:
            return out
        X = np.asarray(lane.state.X)
        tracer = trace.get_tracer()
        now = time.perf_counter()
        for s in done:
            req = lane.requests[s]
            flags = self._flags.pop(req.request_id, [False, False])
            latency = (now - req.submitted_s
                       if req.submitted_s is not None else None)
            resp = SolveResponse(
                request_id=req.request_id,
                x=X[s].copy(),
                iterations=int(iters[s]),
                residual_norm=float(rnorm[s]),
                converged=bool(rnorm[s] <= th[s]),
                pattern_hit=flags[0],
                factors_hit=flags[1],
                latency_s=latency,
                retired_s=now,
            )
            lane.requests[s] = None
            lane.thresh = lane.thresh.at[s].set(jnp.inf)
            metrics.counter("serve_solves").inc()
            metrics.counter("serve_iterations").inc(resp.iterations)
            if not resp.converged:
                metrics.counter("serve_failures").inc()
            if latency is not None:
                metrics.histogram("serve_latency_s").observe(latency)
            if tracer is not None and req.submitted_s is not None:
                # retroactive request span: submit -> retire
                tracer.complete(
                    "serve.request",
                    tracer.rel_us(req.submitted_s),
                    (now - req.submitted_s) * 1e6,
                    cat="serve",
                    args={
                        "request": req.request_id,
                        "iterations": resp.iterations,
                        "pattern_hit": flags[0],
                        "factors_hit": flags[1],
                        "converged": resp.converged,
                    },
                )
            out.append(resp)
        return out
