"""Pattern-keyed setup cache — Ginkgo's generate/apply separation as a cache.

Ginkgo splits every preconditioner/solver factory into an expensive
``generate`` (analyze the matrix, build factors) and a cheap ``apply``.  In a
serving loop the same sparsity patterns recur constantly, so the generate
products are cached in two tiers:

* **pattern tier** — everything derivable from the sparsity structure alone:
  block pointers, value-slot tables, ELL layout maps, gather indices, and
  (via the engine) the jit-compiled solver closures.  Keyed by
  :func:`pattern_key`, a hash over ``(indptr, indices, shape, config)``.
* **values tier** — the numeric factors for one concrete value set, keyed
  inside its pattern entry by :func:`values_fingerprint`: inverted
  block-Jacobi blocks, ParILU sweep factors ``[L | U]``, or the AMG two-level
  row ``[inv_diag | A_c⁻¹]`` depending on the lane's preconditioner.

Generation itself runs through *registered operations*
(``serve_generate_pattern`` / ``serve_generate_factors``) — the analogue of
``GKO_REGISTER_OPERATION`` for the setup path — so the executor's dispatch
log pins the acceptance claim directly: a cache-hit request shows **zero**
generation dispatches.

Both tiers are LRU with hit/miss/eviction counters in the PR-7 metrics
registry (``serve_cache_{hits,misses,evictions}`` labelled by tier).
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import registry
from repro.observability import metrics
from repro.precond import (
    BatchBlockJacobiPattern,
    batch_block_jacobi_factors,
    batch_block_jacobi_pattern,
)
from repro.precond.amg import (
    AmgServePattern,
    amg_serve_factors,
    amg_serve_pattern,
)
from repro.solvers.parilu import ParILUStructure, parilu_factorize, parilu_setup
from repro.sparse.formats import csr_from_arrays

__all__ = [
    "PatternSetup",
    "SetupCache",
    "pattern_key",
    "values_fingerprint",
    "serve_generate_pattern_op",
    "serve_generate_factors_op",
]


def pattern_key(
    indptr: np.ndarray,
    indices: np.ndarray,
    shape: Tuple[int, int],
    config: str = "",
) -> str:
    """Hash of the sparsity pattern + lane configuration.

    Two requests share setup products iff their CSR index structure, matrix
    shape, and lane config (format / solver / preconditioner geometry —
    anything that changes the generated tables or compiled closures) agree.
    """
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(np.asarray(indptr, np.int64)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(indices, np.int64)).tobytes())
    h.update(f"{tuple(shape)}|{config}".encode())
    return h.hexdigest()


def values_fingerprint(values: np.ndarray) -> str:
    """Hash of one concrete value set (the values-tier cache key)."""
    a = np.ascontiguousarray(np.asarray(values))
    return hashlib.sha1(a.tobytes() + str(a.dtype).encode()).hexdigest()


@dataclasses.dataclass(eq=False)
class PatternSetup:
    """Pattern-tier generate products for one (pattern, config) key."""

    key: str
    indptr: np.ndarray
    indices: np.ndarray
    shape: Tuple[int, int]
    fmt: str  # "csr" | "ell"
    #: ELL column block (m, k) and the CSR-slot -> ELL-slot value map, when
    #: the lane batches into BatchEll; None for CSR lanes
    col_idx: Optional[jax.Array] = None
    ell_map: Optional[np.ndarray] = None
    #: block-Jacobi pattern tier (slot tables, gather maps); None when the
    #: lane runs unpreconditioned
    jacobi: Optional[BatchBlockJacobiPattern] = None
    #: ParILU sparsity analysis (L/U patterns, dependency tables); None unless
    #: the lane preconditions with ``parilu``
    parilu: Optional[ParILUStructure] = None
    #: AMG two-level hierarchy (aggregation + Galerkin maps); None unless the
    #: lane preconditions with ``amg``
    amg: Optional[AmgServePattern] = None
    #: engine-owned: jit-compiled refresh/advance closures per (slots, solver)
    closures: Dict[Any, Any] = dataclasses.field(default_factory=dict)
    #: values-tier LRU: values_fingerprint -> factors — (nblocks, bs, bs)
    #: inverted blocks for block-Jacobi, a flat row for parilu/amg
    factors: "OrderedDict[str, jax.Array]" = dataclasses.field(
        default_factory=OrderedDict
    )

    @property
    def n(self) -> int:
        return int(self.shape[0])

    @property
    def nnz(self) -> int:
        return int(np.asarray(self.indices).size)

    @property
    def flat_value_len(self) -> int:
        """Length of one system's flattened value row in lane storage."""
        if self.fmt == "ell":
            m, k = self.col_idx.shape
            return int(m * k)
        return self.nnz

    def lane_values(self, values: np.ndarray) -> np.ndarray:
        """CSR request values -> the lane's flat value layout."""
        if self.fmt == "ell":
            out = np.zeros(self.flat_value_len, np.asarray(values).dtype)
            out[self.ell_map] = np.asarray(values)
            return out
        return np.asarray(values)

    def csr_values(self, flat):
        """The lane's flat value row -> CSR-order values (factorize input)."""
        if self.fmt == "ell":
            return flat[jnp.asarray(self.ell_map)]
        return flat

    @property
    def has_factors(self) -> bool:
        """Whether this lane carries values-tier factors at all."""
        return (
            self.jacobi is not None
            or self.parilu is not None
            or self.amg is not None
        )

    @property
    def flat_factor_len(self) -> Optional[int]:
        """Per-system factor-row length for the 2-D factor lanes.

        ``None`` for block-Jacobi (whose factors are ``(nblocks, bs, bs)``
        stacks) and for unpreconditioned lanes.
        """
        if self.parilu is not None:
            return int(self.parilu.l_rows.size + self.parilu.u_rows.size)
        if self.amg is not None:
            return int(self.amg.flat_len)
        return None


# =============================================================================
# Generation as registered operations (visible in the dispatch log)
# =============================================================================

serve_generate_pattern_op = registry.operation(
    "serve_generate_pattern",
    "pattern-tier setup: block discovery, slot tables, layout maps",
)

serve_generate_factors_op = registry.operation(
    "serve_generate_factors",
    "values-tier setup: block gather + batched Gauss-Jordan inversion",
)


@serve_generate_pattern_op.register("reference")
def _generate_pattern_ref(
    ex,
    indptr: np.ndarray,
    indices: np.ndarray,
    shape: Tuple[int, int],
    *,
    fmt: str = "csr",
    precond: str = "block_jacobi",
    block_size: int = 4,
) -> PatternSetup:
    from repro.batch.formats import BatchCsr, BatchEll

    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices, np.int64)
    m = int(shape[0])
    col_idx = None
    ell_map = None
    if fmt == "ell":
        # CSR column order per row, padded with (col 0, value 0) at the tail —
        # the convention _batch_slot_table and the ELL SpMV kernels share
        row_nnz = np.diff(indptr)
        k = int(row_nnz.max()) if m else 1
        cols = np.zeros((m, max(k, 1)), np.int32)
        emap = np.zeros(indices.size, np.int64)
        for i in range(m):
            lo, hi = int(indptr[i]), int(indptr[i + 1])
            q = np.arange(hi - lo)
            cols[i, : hi - lo] = indices[lo:hi]
            emap[lo:hi] = i * cols.shape[1] + q
        col_idx = jnp.asarray(cols)
        ell_map = emap
        proto = BatchEll(
            col_idx=col_idx,
            values=jnp.zeros((1, m, cols.shape[1]), jnp.float32),
            shape=tuple(shape),
        )
    elif fmt == "csr":
        proto = BatchCsr(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(indices, jnp.int32),
            values=jnp.zeros((1, indices.size), jnp.float32),
            shape=tuple(shape),
        )
    else:
        raise ValueError(f"unknown lane format {fmt!r} (csr | ell)")

    jacobi = parilu = amg = None
    if precond == "block_jacobi":
        jacobi = batch_block_jacobi_pattern(proto, block_size, executor=ex)
    elif precond == "parilu":
        parilu = parilu_setup(csr_from_arrays(
            indptr, indices, np.zeros(indices.size, np.float32), shape
        ))
    elif precond == "amg":
        amg = amg_serve_pattern(indptr, indices, m)
    elif precond != "none":
        raise ValueError(
            f"unknown serve preconditioner {precond!r} "
            "(none | block_jacobi | parilu | amg)"
        )

    return PatternSetup(
        key="",
        indptr=indptr,
        indices=indices,
        shape=tuple(shape),
        fmt=fmt,
        col_idx=col_idx,
        ell_map=ell_map,
        jacobi=jacobi,
        parilu=parilu,
        amg=amg,
    )


@serve_generate_factors_op.register("reference")
def _generate_factors_ref(ex, values: jax.Array, setup: PatternSetup):
    """Values-tier factors for one system's flat lane-layout value row.

    * block-Jacobi: inverted blocks ``(nblocks, bs, bs)`` — the slot gather
      and Gauss-Jordan inversion are the shared tier-2 helpers, so a factor
      built here is bitwise the one :func:`repro.precond.batch_block_jacobi`
      builds inside a cold solve;
    * parilu: the Chow–Patel sweep factors, flattened to ``[L | U]``;
    * amg: the two-level row ``[inv_diag | A_c⁻¹]`` from
      :func:`repro.precond.amg.amg_serve_factors` — hierarchy maps come from
      the pattern tier, so a refresh is gathers + one segment-sum.
    """
    values = jnp.asarray(values)
    if setup.jacobi is not None:
        return batch_block_jacobi_factors(values[None, :], setup.jacobi)
    csr_vals = setup.csr_values(values)
    if setup.parilu is not None:
        A = csr_from_arrays(setup.indptr, setup.indices, csr_vals, setup.shape)
        l_vals, u_vals, _ = parilu_factorize(A, setup.parilu)
        return jnp.concatenate([l_vals, u_vals])
    if setup.amg is not None:
        return amg_serve_factors(setup.amg, csr_vals)
    raise ValueError("lane has no preconditioner — no factors to generate")


# =============================================================================
# The two-tier LRU
# =============================================================================


class SetupCache:
    """LRU cache of :class:`PatternSetup` entries with nested factor LRUs.

    ``capacity`` bounds the number of pattern entries; evicting a pattern
    drops its factors and compiled closures with it.  ``factors_capacity``
    bounds the per-pattern values-tier LRU.  Hit/miss/eviction counts are
    published to the metrics registry under ``serve_cache_*`` with a ``tier``
    label, so the serve driver's report and the BENCH snapshot read them
    straight from :func:`repro.observability.metrics.samples`.
    """

    def __init__(self, capacity: int = 32, factors_capacity: int = 8):
        if capacity <= 0 or factors_capacity <= 0:
            raise ValueError("cache capacities must be positive")
        self.capacity = capacity
        self.factors_capacity = factors_capacity
        self._entries: "OrderedDict[str, PatternSetup]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    @property
    def keys(self):
        """Pattern keys, LRU -> MRU order."""
        return tuple(self._entries)

    @staticmethod
    def _count(name: str, tier: str):
        return metrics.counter(name, tier=tier)

    def setup(
        self, key: str, build: Callable[[], PatternSetup]
    ) -> Tuple[PatternSetup, bool]:
        """Pattern-tier lookup: ``(entry, hit)``; ``build`` runs on miss."""
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            self._count("serve_cache_hits", "pattern").inc()
            return entry, True
        self._count("serve_cache_misses", "pattern").inc()
        entry = build()
        entry.key = key
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self._count("serve_cache_evictions", "pattern").inc()
        return entry, False

    def factors(
        self,
        entry: PatternSetup,
        fingerprint: str,
        build: Callable[[], jax.Array],
    ) -> Tuple[jax.Array, bool]:
        """Values-tier lookup inside ``entry``: ``(factors, hit)``."""
        inv = entry.factors.get(fingerprint)
        if inv is not None:
            entry.factors.move_to_end(fingerprint)
            self._count("serve_cache_hits", "values").inc()
            return inv, True
        self._count("serve_cache_misses", "values").inc()
        inv = build()
        entry.factors[fingerprint] = inv
        while len(entry.factors) > self.factors_capacity:
            entry.factors.popitem(last=False)
            self._count("serve_cache_evictions", "values").inc()
        return inv, False

    def stats(self) -> Dict[str, float]:
        """Current counter values (zeros for series never touched)."""
        out = {}
        for name in ("serve_cache_hits", "serve_cache_misses",
                     "serve_cache_evictions"):
            for tier in ("pattern", "values"):
                out[f"{name}_{tier}"] = metrics.counter(name, tier=tier).value
        return out
