"""repro.serve — persistent solve service (the library meets traffic).

The ROADMAP's production-scale story for the solver stack: a long-running
service that aggregates many small incoming systems into batched masked-Krylov
launches with **continuous batching** (new systems are admitted into mask
slots as converged systems retire — :mod:`repro.serve.engine`), backed by a
**pattern-keyed setup cache** exploiting Ginkgo's generate/apply separation
(:mod:`repro.serve.cache`): expensive generation — block discovery, slot
tables, block-Jacobi inversion, jit-compiled solver closures — is keyed by
the sparsity-pattern hash, so repeat-pattern traffic pays only numeric-values
cost and repeat-values traffic pays neither.

:mod:`repro.serve.service` wraps the engine in a background thread behind an
async request queue; :mod:`repro.serve.traffic` generates synthetic Poisson
traffic over a pattern gallery for benchmarks and the CI smoke gate.
"""

from repro.serve.cache import (
    PatternSetup,
    SetupCache,
    pattern_key,
    values_fingerprint,
)
from repro.serve.engine import ContinuousBatchEngine, PatternLane, ServeConfig
from repro.serve.request import SolveRequest, SolveResponse
from repro.serve.service import SolveService
from repro.serve.traffic import TrafficConfig, generate_traffic, pattern_gallery

__all__ = [
    "ContinuousBatchEngine",
    "PatternLane",
    "PatternSetup",
    "ServeConfig",
    "SetupCache",
    "SolveRequest",
    "SolveResponse",
    "SolveService",
    "TrafficConfig",
    "generate_traffic",
    "pattern_gallery",
    "pattern_key",
    "values_fingerprint",
]
