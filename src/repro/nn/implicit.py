"""Implicit (deep-equilibrium) layers: a sparse solve as a differentiable op.

The layer's forward pass is a generated :class:`~repro.solvers.krylov.GmresSolver`
apply, ``x = A(values)^{-1} b`` for a CSR operand with a *static* sparsity
pattern and trainable ``values``.  The backward pass is the adjoint method:
for a scalar loss ``L`` with incoming cotangent ``g = dL/dx``,

    lambda        = A^{-T} g                       (one transposed solve)
    dL/d b        = lambda
    dL/d values_t = -lambda[row_t] * x[col_t]

The transposed system is solved through the :class:`~repro.core.linop.Transpose`
combinator — the same operator algebra the forward pass uses, dispatching
through the same :class:`~repro.core.executor.Executor` (Transpose inherits the
wrapped operator's executor), so forward and backward land in one kernel
space.  ``Csr.transpose`` keeps traced *values* on device and only touches the
(concrete) structure host-side, which is exactly the pattern-static case here.

Differentiating through a fixed unrolled iteration count would be both wrong
(the iterate is not the solution) and memory-hungry (checkpointing every
Arnoldi basis); the adjoint needs nothing but the converged ``x`` and one more
solve of the same cost as the forward one.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linop import Transpose
from repro.solvers.common import Stop
from repro.solvers.krylov import GmresSolver, gmres
from repro.sparse.formats import Csr

__all__ = ["make_implicit_solve"]


def make_implicit_solve(
    indptr,
    indices,
    shape,
    *,
    restart: int = 30,
    stop: Stop = Stop(max_iters=400, reduction_factor=1e-8),
    bwd_stop: Optional[Stop] = None,
    executor=None,
):
    """Build ``solve(values, b) -> x`` differentiable in both arguments.

    ``indptr``/``indices``/``shape`` fix the CSR sparsity pattern at trace
    time (host arrays, closed over); ``values`` and ``b`` are the
    differentiable inputs.  ``bwd_stop`` defaults to the forward ``stop`` —
    loosening it trades gradient accuracy for backward-pass time (the classic
    inexact-adjoint knob).
    """
    n_rows, n_cols = shape
    if n_rows != n_cols:
        raise ValueError(f"implicit solve needs a square operator, got {shape}")
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices, np.int64)
    # host-precomputed row index of every stored entry, for the values
    # cotangent gather: d L / d values_t = -lambda[row_t] * x[col_t]
    rows = jnp.asarray(np.repeat(np.arange(n_rows), np.diff(indptr)))
    cols = jnp.asarray(indices)
    adj_stop = bwd_stop if bwd_stop is not None else stop
    # structure arrays built eagerly, once: inside a jit trace they stay
    # concrete closure constants, so Csr.transpose's host-side structure
    # work is legal while the values remain traced
    indptr_dev = jnp.asarray(indptr, jnp.int32)
    indices_dev = jnp.asarray(indices, jnp.int32)

    def _operator(values):
        return Csr(values=values, indices=indices_dev, indptr=indptr_dev,
                   shape=shape)

    @jax.custom_vjp
    def solve(values, b):
        A = _operator(values)
        return GmresSolver(A, restart=restart, stop=stop, executor=executor).apply(b)

    def solve_fwd(values, b):
        x = solve(values, b)
        return x, (values, x)

    def solve_bwd(res, g):
        values, x = res
        At = Transpose(_operator(values), executor=executor)
        lam = gmres(At, g, restart=restart, stop=adj_stop, executor=executor).x
        bar_values = -lam[rows] * x[cols]
        return bar_values.astype(values.dtype), lam.astype(g.dtype)

    solve.defvjp(solve_fwd, solve_bwd)
    return solve
