"""Shared NN utilities: parameter construction with logical sharding axes.

Parameters are plain pytrees (nested dicts of arrays).  Every leaf has a
*logical axis* annotation carried in a parallel tree of tuples — e.g. a dense
projection (d_model, d_ff) is ``("embed", "mlp")``.  The distribution layer
(:mod:`repro.distributed.sharding`) maps logical axes onto mesh axes; models
never name a mesh axis, the same way Ginkgo algorithms never name a backend.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]

__all__ = [
    "Params",
    "Axes",
    "ParamBuilder",
    "truncated_normal_init",
    "zeros_init",
    "ones_init",
    "cast_tree",
]


def truncated_normal_init(rng, shape, std, dtype):
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )


def zeros_init(rng, shape, std, dtype):
    del rng, std
    return jnp.zeros(shape, dtype)


def ones_init(rng, shape, std, dtype):
    del rng, std
    return jnp.ones(shape, dtype)


class ParamBuilder:
    """Accumulates a (params, axes) pair with auto-split rng keys.

    Usage::

        pb = ParamBuilder(rng, dtype=jnp.float32)
        pb.param("wq", (d, H, hd), ("embed", "heads", "head_dim"), std=0.02)
        sub_params, sub_axes = some_layer_init(pb.fork(), cfg)
        pb.child("attn", sub_params, sub_axes)
        params, axes = pb.build()
    """

    def __init__(self, rng: jax.Array, dtype=jnp.float32):
        self._rng = rng
        self.dtype = dtype
        self.params: Params = {}
        self.axes: Axes = {}

    def _next_rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def fork(self) -> jax.Array:
        return self._next_rng()

    def param(
        self,
        name: str,
        shape: Tuple[int, ...],
        axes: Tuple[Optional[str], ...],
        *,
        std: Optional[float] = None,
        init=truncated_normal_init,
        dtype=None,
    ):
        if len(shape) != len(axes):
            raise ValueError(f"{name}: shape {shape} vs axes {axes} rank mismatch")
        if std is None:
            std = 0.02
        value = init(self._next_rng(), shape, std, dtype or self.dtype)
        self.params[name] = value
        self.axes[name] = axes
        return value

    def child(self, name: str, params: Params, axes: Axes):
        self.params[name] = params
        self.axes[name] = axes

    def build(self) -> Tuple[Params, Axes]:
        return self.params, self.axes


def map_axes(fn, axes):
    """Walk an axes tree (nested dicts with tuple/None leaves) applying fn."""
    if isinstance(axes, dict):
        return {k: map_axes(fn, v) for k, v in axes.items()}
    return fn(axes)


def stack_axes(axes, axis_name: Optional[str] = None):
    """Prepend a (stacked-layers) axis to every leaf annotation."""
    return map_axes(lambda t: (axis_name,) + tuple(t or ()), axes)


def cast_tree(tree, dtype):
    """Cast all floating-point leaves to ``dtype``."""
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(cast, tree)
