"""Basic layers: linear, norms, rope, MLPs — all executor-dispatched where hot.

The norm goes through the registered ``nn_rmsnorm`` operation (reference / xla
/ pallas); matmuls are jnp einsums (XLA's MXU lowering is already optimal for
dense GEMM — a Pallas matmul would only re-derive it, so per DESIGN.md the
kernel space covers attention/scan/spmv hot-spots instead).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.nn.common import ParamBuilder, ones_init, zeros_init

# make sure the kernel spaces are populated
import repro.kernels  # noqa: F401

_rmsnorm_op = registry.operation("nn_rmsnorm")


# -- linear ---------------------------------------------------------------------

def linear_init(
    rng,
    d_in: int,
    d_out: int,
    axes: Tuple[Optional[str], Optional[str]],
    *,
    dtype=jnp.float32,
    std: Optional[float] = None,
    bias: bool = False,
):
    pb = ParamBuilder(rng, dtype)
    pb.param("w", (d_in, d_out), axes, std=std if std is not None else d_in ** -0.5)
    if bias:
        pb.param("b", (d_out,), (axes[1],), init=zeros_init)
    return pb.build()


def linear(p, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# -- norms ------------------------------------------------------------------------

def rmsnorm_init(rng, d: int, *, dtype=jnp.float32):
    pb = ParamBuilder(rng, dtype)
    pb.param("scale", (d,), ("embed",), init=ones_init)
    return pb.build()


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return _rmsnorm_op(x, p["scale"], eps)


def layernorm_init(rng, d: int, *, dtype=jnp.float32):
    pb = ParamBuilder(rng, dtype)
    pb.param("scale", (d,), ("embed",), init=ones_init)
    pb.param("bias", (d,), ("embed",), init=zeros_init)
    return pb.build()


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


def groupnorm(x: jax.Array, num_groups: int, eps: float = 1e-5) -> jax.Array:
    """Parameter-free group norm over the last axis (RWKV6 head norm)."""
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.reshape(*lead, d).astype(x.dtype)


# -- rotary embeddings -------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """(head_dim/2,) inverse frequencies (f32)."""
    if head_dim % 2:
        raise ValueError(f"rope head_dim must be even, got {head_dim}")
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(
    x: jax.Array,  # (B, S, H, D) or (B, S, D) for shared rope dims
    positions: jax.Array,  # (B, S) int32 absolute positions
    theta: float = 10000.0,
) -> jax.Array:
    """Llama-style interleaved-half rotary embedding."""
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B, S, D/2)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    if x.ndim == 4:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# -- MLPs -------------------------------------------------------------------------

def swiglu_init(rng, d: int, d_ff: int, *, dtype=jnp.float32):
    pb = ParamBuilder(rng, dtype)
    pb.param("gate", (d, d_ff), ("embed", "mlp"), std=d ** -0.5)
    pb.param("up", (d, d_ff), ("embed", "mlp"), std=d ** -0.5)
    pb.param("down", (d_ff, d), ("mlp", "embed"), std=d_ff ** -0.5)
    return pb.build()


def swiglu(p, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["gate"]) * (x @ p["up"])) @ p["down"]


def gelu_mlp_init(rng, d: int, d_ff: int, *, dtype=jnp.float32, bias: bool = True):
    pb = ParamBuilder(rng, dtype)
    pb.param("up", (d, d_ff), ("embed", "mlp"), std=d ** -0.5)
    pb.param("down", (d_ff, d), ("mlp", "embed"), std=d_ff ** -0.5)
    if bias:
        pb.param("up_b", (d_ff,), ("mlp",), init=zeros_init)
        pb.param("down_b", (d,), ("embed",), init=zeros_init)
    return pb.build()


def gelu_mlp(p, x: jax.Array) -> jax.Array:
    h = x @ p["up"]
    if "up_b" in p:
        h = h + p["up_b"]
    h = jax.nn.gelu(h)
    y = h @ p["down"]
    if "down_b" in p:
        y = y + p["down_b"]
    return y


# -- embedding ----------------------------------------------------------------------

def embedding_init(rng, vocab: int, d: int, *, dtype=jnp.float32, std=0.02):
    pb = ParamBuilder(rng, dtype)
    pb.param("table", (vocab, d), ("vocab", "embed"), std=std)
    return pb.build()


def embed(p, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed(p, h: jax.Array) -> jax.Array:
    """logits = h @ table^T (used for tied embeddings and LM heads)."""
    return h @ p["table"].T
