"""repro.nn — layer library built on executor-dispatched operations."""

from repro.nn import attention, common, implicit, layers, mamba, moe, rwkv

__all__ = ["attention", "common", "implicit", "layers", "mamba", "moe", "rwkv"]
