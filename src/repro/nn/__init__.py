"""repro.nn — layer library built on executor-dispatched operations."""

from repro.nn import attention, common, layers, mamba, moe, rwkv

__all__ = ["attention", "common", "layers", "mamba", "moe", "rwkv"]
