"""RWKV6 (Finch) blocks: time-mix (WKV attention) + channel-mix.

Faithful to the Finch paper's structure (arXiv:2404.05892): token shift with
data-dependent linear interpolation (LoRA-projected deltas), per-channel
data-dependent decay ``w = exp(-exp(w0 + lora(x)))`` (we keep ``logw = -exp(.)``
in log space end-to-end — see kernels/rwkv6), bonus ``u``, head-wise group
norm, and the squared-ReLU channel mix.  The WKV recurrence is the registered
``nn_rwkv6_scan`` operation (reference scan / xla scan / Pallas chunked kernel).

Simplification noted in DESIGN.md: one shared LoRA produces the five
interpolation deltas (r,k,v,w,g) instead of five separate LoRAs.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.nn.common import ParamBuilder, zeros_init
from repro.nn.layers import groupnorm

_rwkv6_op = registry.operation("nn_rwkv6_scan")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RWKVState:
    """Per-layer recurrent state for decode."""

    wkv: jax.Array  # (B, H, K, V) WKV matrix state
    shift_tm: jax.Array  # (B, d) previous token (time-mix)
    shift_cm: jax.Array  # (B, d) previous token (channel-mix)

    @staticmethod
    def zeros(batch, n_heads, head_dim, d, dtype):
        return RWKVState(
            wkv=jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
            shift_tm=jnp.zeros((batch, d), dtype),
            shift_cm=jnp.zeros((batch, d), dtype),
        )


def time_mix_init(rng, cfg, *, dtype=jnp.float32):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    K = cfg.rwkv_head_dim
    r = cfg.lora_rank // 2 if cfg.lora_rank else 64
    pb = ParamBuilder(rng, dtype)
    # token-shift interpolation bases (five channels: r,k,v,w,g)
    pb.param("mix_base", (5, d), (None, "embed"), std=0.02)
    pb.param("mix_lora_a", (d, r), ("embed", None), std=d ** -0.5)
    pb.param("mix_lora_b", (r, 5 * d), (None, "embed"), init=zeros_init)
    # projections
    pb.param("wr", (d, d), ("embed", "heads"), std=d ** -0.5)
    pb.param("wk", (d, d), ("embed", "heads"), std=d ** -0.5)
    pb.param("wv", (d, d), ("embed", "heads"), std=d ** -0.5)
    pb.param("wg", (d, d), ("embed", "heads"), std=d ** -0.5)
    pb.param("wo", (d, d), ("heads", "embed"), std=d ** -0.5)
    # decay: logw = -exp(w0 + lora(x))
    pb.param("w0", (d,), ("embed",), init=zeros_init)
    pb.param("w_lora_a", (d, r), ("embed", None), std=d ** -0.5)
    pb.param("w_lora_b", (r, d), (None, "embed"), init=zeros_init)
    pb.param("u", (H, K), ("heads", None), std=0.02)
    return pb.build()


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x[t-1] with x[-1] = prev (B, d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mixed(p, x, xs):
    """Data-dependent lerp between x and shifted xs for 5 channels."""
    delta = jax.nn.tanh(x @ p["mix_lora_a"]) @ p["mix_lora_b"]  # (B,S,5d)
    B, S, _ = x.shape
    d = x.shape[-1]
    mix = p["mix_base"][None, None] + delta.reshape(B, S, 5, d)  # (B,S,5,d)
    mix = jax.nn.sigmoid(mix)
    diff = (xs - x)[:, :, None, :]
    out = x[:, :, None, :] + mix * diff  # (B,S,5,d)
    return tuple(out[:, :, i, :] for i in range(5))


def time_mix_forward(
    p, x: jax.Array, cfg, state: RWKVState = None, *, executor=None
) -> Tuple[jax.Array, RWKVState]:
    """Full-sequence WKV time-mix. Returns (y, new_state or None)."""
    B, S, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    prev = state.shift_tm if state is not None else jnp.zeros((B, d), x.dtype)
    xs = _token_shift(x, prev)
    xr, xk, xv, xw, xg = _mixed(p, x, xs)

    r = (xr @ p["wr"]).reshape(B, S, H, K)
    k = (xk @ p["wk"]).reshape(B, S, H, K)
    v = (xv @ p["wv"]).reshape(B, S, H, K)
    g = xg @ p["wg"]
    logw = -jnp.exp(
        (p["w0"] + jax.nn.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    ).reshape(B, S, H, K)

    y, wkv_state = _rwkv6_op(r, k, v, logw, p["u"], executor=executor)
    y = groupnorm(y.reshape(B, S, d), H, eps=64e-5)
    y = y * jax.nn.silu(g)
    out = y @ p["wo"]
    new_state = None
    if state is not None:
        new_state = RWKVState(wkv=wkv_state, shift_tm=x[:, -1, :], shift_cm=state.shift_cm)
    return out, new_state


def time_mix_step(p, x: jax.Array, cfg, state: RWKVState) -> Tuple[jax.Array, RWKVState]:
    """Single-token recurrent step (decode)."""
    B, _, d = x.shape  # (B, 1, d)
    K = cfg.rwkv_head_dim
    H = d // K
    xs = state.shift_tm[:, None, :]
    xr, xk, xv, xw, xg = _mixed(p, x, xs)

    r = (xr @ p["wr"]).reshape(B, H, K).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, H, K).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, H, K).astype(jnp.float32)
    g = xg @ p["wg"]
    logw = -jnp.exp(
        (p["w0"] + jax.nn.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    ).reshape(B, H, K)
    u = p["u"].astype(jnp.float32)

    kv = k[..., :, None] * v[..., None, :]  # (B,H,K,V)
    att = state.wkv + u[None, :, :, None] * kv
    y = jnp.einsum("bhk,bhkv->bhv", r, att)  # (B,H,V)
    wkv = jnp.exp(logw)[..., None] * state.wkv + kv

    y = groupnorm(y.reshape(B, 1, d).astype(x.dtype), H, eps=64e-5)
    y = y * jax.nn.silu(g)
    out = y @ p["wo"]
    return out, RWKVState(wkv=wkv, shift_tm=x[:, -1, :], shift_cm=state.shift_cm)


def channel_mix_init(rng, cfg, *, dtype=jnp.float32):
    d, dff = cfg.d_model, cfg.d_ff
    pb = ParamBuilder(rng, dtype)
    pb.param("mix_k", (d,), ("embed",), std=0.02)
    pb.param("mix_r", (d,), ("embed",), std=0.02)
    pb.param("wk", (d, dff), ("embed", "mlp"), std=d ** -0.5)
    pb.param("wv", (dff, d), ("mlp", "embed"), std=dff ** -0.5)
    pb.param("wr", (d, d), ("embed", "embed"), std=d ** -0.5)
    return pb.build()


def channel_mix_forward(
    p, x: jax.Array, cfg, state: RWKVState = None
) -> Tuple[jax.Array, RWKVState]:
    B, S, d = x.shape
    prev = state.shift_cm if state is not None else jnp.zeros((B, d), x.dtype)
    xs = _token_shift(x, prev)
    mk = jax.nn.sigmoid(p["mix_k"])
    mr = jax.nn.sigmoid(p["mix_r"])
    xk = x + mk * (xs - x)
    xr = x + mr * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    new_state = None
    if state is not None:
        new_state = RWKVState(wkv=state.wkv, shift_tm=state.shift_tm, shift_cm=x[:, -1, :])
    return out, new_state
