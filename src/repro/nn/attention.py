"""Attention layers: GQA (llama-style) and MLA (DeepSeek/MiniCPM3-style).

The core softmax attention is the registered ``nn_attention`` operation
(reference = dense oracle, xla = dense or chunked-scan variant, pallas = flash
kernel).  Decode (single-token with KV cache) is pure-jnp math — a bandwidth-
bound matvec XLA lowers optimally, so no kernel (DESIGN.md).

Chunked-scan xla attention (``cfg.attn_impl == "chunked"``) is the beyond-paper
memory optimization: a lax.scan over kv blocks with running softmax statistics
(flash algorithm expressed in XLA) that avoids materializing the (S, Skv) score
matrix in HBM.  It is the §Perf hillclimb lever for the memory-bound cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.nn.common import ParamBuilder
from repro.nn.layers import apply_rope, rmsnorm_init, rmsnorm

_attention_op = registry.operation("nn_attention")

NEG_INF = float("-inf")


# =============================================================================
# chunked xla attention (flash algorithm in pure XLA, scan over kv blocks)
#
# Forward: online-softmax scan over kv chunks — never materializes (S, Skv).
# Backward: flash-style custom VJP — saves only (q, k, v, out, lse) and
# re-derives each chunk's probabilities in a second scan, so the residual
# footprint is O(S) instead of O(S * nkv) carries the naive scan-transpose
# would store.  This is THE memory lever for the long-context cells (§Perf).
# =============================================================================

import functools as _functools


@_functools.lru_cache(maxsize=None)
def _chunked_attn_core(causal: bool, scale: float, chunk: int, kv_len: int):
    """Build the custom-vjp core for a static (causal, scale, chunk, kv_len)."""

    def _masked_scores(qf, ks, ki, S, kv_offset):
        s = jnp.einsum("bhgsd,bhtd->bhgst", qf, ks.astype(jnp.float32)) * scale
        kv_idx = ki * chunk + jnp.arange(chunk)
        mask = kv_idx[None, :] < kv_len
        if causal:
            q_pos = jnp.arange(S) + kv_offset
            mask = mask & (q_pos[:, None] >= kv_idx[None, :])
        return jnp.where(mask[None, None, None], s, NEG_INF)

    def forward(q, k, v):
        # q: (B, Hkv, g, S, Dqk); k: (B, Hkv, Skv_p, Dqk); v: (B, Hkv, Skv_p, Dv)
        B, Hkv, g, S, D = q.shape
        Dv = v.shape[-1]
        pkv = k.shape[2]
        nkv = pkv // chunk
        kv_offset = kv_len - S
        qf = q.astype(jnp.float32)

        def step(carry, ki):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ki * chunk, chunk, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, ki * chunk, chunk, axis=2)
            s = _masked_scores(qf, ks, ki, S, kv_offset)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
            p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - m_safe))
            corr = jnp.where(m == NEG_INF, 0.0, jnp.exp(m - m_safe))
            l_new = corr * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum(
                "bhgst,bhtd->bhgsd", p, vs.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, g, S, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, S, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, S, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nkv))
        l_safe = jnp.where(l == 0.0, 1.0, l)
        out = (acc / l_safe).astype(q.dtype)
        lse = jnp.where(m == NEG_INF, NEG_INF, m + jnp.log(l_safe))  # logsumexp
        return out, lse

    @jax.custom_vjp
    def core(q, k, v):
        return forward(q, k, v)[0]

    def core_fwd(q, k, v):
        out, lse = forward(q, k, v)
        return out, (q, k, v, out, lse)

    def core_bwd(res, dout):
        q, k, v, out, lse = res
        B, Hkv, g, S, D = q.shape
        pkv = k.shape[2]
        nkv = pkv // chunk
        kv_offset = kv_len - S
        qf = q.astype(jnp.float32)
        doutf = dout.astype(jnp.float32)
        # D_i = sum_d dout * out (per row)
        Drow = jnp.sum(doutf * out.astype(jnp.float32), axis=-1, keepdims=True)

        def step(dq, ki):
            ks = jax.lax.dynamic_slice_in_dim(k, ki * chunk, chunk, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, ki * chunk, chunk, axis=2)
            s = _masked_scores(qf, ks, ki, S, kv_offset)
            p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - jnp.where(lse == NEG_INF, 0.0, lse)))
            dv_j = jnp.einsum("bhgst,bhgsd->bhtd", p, doutf)
            dp = jnp.einsum("bhgsd,bhtd->bhgst", doutf, vs.astype(jnp.float32))
            ds = p * (dp - Drow) * scale
            dq = dq + jnp.einsum("bhgst,bhtd->bhgsd", ds, ks.astype(jnp.float32))
            dk_j = jnp.einsum("bhgst,bhgsd->bhtd", ds, qf)
            return dq, (dk_j, dv_j)

        dq0 = jnp.zeros((B, Hkv, g, S, D), jnp.float32)
        dq, (dk_chunks, dv_chunks) = jax.lax.scan(step, dq0, jnp.arange(nkv))
        # (nkv, B, Hkv, chunk, D*) -> (B, Hkv, pkv, D*)
        Dv = v.shape[-1]
        dk = jnp.moveaxis(dk_chunks, 0, 2).reshape(B, Hkv, pkv, D)
        dv = jnp.moveaxis(dv_chunks, 0, 2).reshape(B, Hkv, pkv, Dv)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    core.defvjp(core_fwd, core_bwd)
    return core


def attention_xla_chunked(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    chunk: int = 512,
) -> jax.Array:
    B, Hq, S, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    chunk = min(chunk, Skv)
    pkv = ((Skv + chunk - 1) // chunk) * chunk
    if pkv != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv - Skv), (0, 0)))
    core = _chunked_attn_core(causal, float(scale), chunk, Skv)
    qg = q.reshape(B, Hkv, group, S, D)
    out = core(qg, k, v)
    return out.reshape(B, Hq, S, v.shape[-1])


def _attention_core(q, k, v, cfg, causal=True, scale=None, executor=None):
    """Dispatch: chunked-xla override, else the registered operation."""
    if cfg is not None and cfg.attn_impl == "chunked":
        from repro.core.executor import current_executor

        ex = executor if executor is not None else current_executor()
        if ex.kernel_space != "pallas":
            chunk = cfg.attn_chunk
            if chunk is None:
                chunk = ex.launch_config(
                    "nn_attention_chunked",
                    {"S": q.shape[2], "Skv": k.shape[2], "D": q.shape[-1],
                     "itemsize": q.dtype.itemsize},
                )["chunk"]
            return attention_xla_chunked(
                q, k, v, causal=causal, scale=scale, chunk=chunk
            )
    return _attention_op(q, k, v, causal=causal, scale=scale, executor=executor)


# =============================================================================
# KV cache
# =============================================================================

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KVCache:
    k: jax.Array  # (B, Hkv, Smax, D)
    v: jax.Array  # (B, Hkv, Smax, D)

    @staticmethod
    def zeros(batch, n_kv, s_max, d, dtype):
        return KVCache(
            k=jnp.zeros((batch, n_kv, s_max, d), dtype),
            v=jnp.zeros((batch, n_kv, s_max, d), dtype),
        )

    def write(self, pos: jax.Array, k_new: jax.Array, v_new: jax.Array) -> "KVCache":
        """Insert (B, Hkv, T, D) at sequence offset ``pos`` (scalar int32)."""
        k = jax.lax.dynamic_update_slice(self.k, k_new.astype(self.k.dtype), (0, 0, pos, 0))
        v = jax.lax.dynamic_update_slice(self.v, v_new.astype(self.v.dtype), (0, 0, pos, 0))
        return KVCache(k, v)


def decode_attention(
    q: jax.Array,  # (B, Hq, 1, D)
    cache: KVCache,
    length: jax.Array,  # scalar int32: number of valid positions INCLUDING current
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against the cache (positions < length)."""
    B, Hq, _, D = q.shape
    Hkv = cache.k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, group, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg, cache.k.astype(jnp.float32)) * scale
    valid = jnp.arange(cache.k.shape[2])[None, None, None, :] < length
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, cache.v.astype(jnp.float32))
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


# =============================================================================
# GQA attention layer
# =============================================================================

def gqa_init(rng, cfg, *, dtype=jnp.float32):
    d = cfg.d_model
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pb = ParamBuilder(rng, dtype)
    pb.param("wq", (d, H * hd), ("embed", "heads"), std=d ** -0.5)
    pb.param("wk", (d, Hkv * hd), ("embed", "kv_heads"), std=d ** -0.5)
    pb.param("wv", (d, Hkv * hd), ("embed", "kv_heads"), std=d ** -0.5)
    pb.param("wo", (H * hd, d), ("heads", "embed"), std=(H * hd) ** -0.5)
    return pb.build()


def gqa_forward(
    p,
    x: jax.Array,  # (B, S, d)
    cfg,
    positions: jax.Array,  # (B, S) absolute positions
    *,
    executor=None,
) -> jax.Array:
    """Full (training / prefill) forward, causal."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.pos_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = _attention_core(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        cfg,
        causal=True,
        executor=executor,
    )
    return out.transpose(0, 2, 1, 3).reshape(B, S, H * hd) @ p["wo"]


def gqa_prefill(p, x, cfg, positions, cache: KVCache, *, executor=None):
    """Prefill: full causal forward that also fills the cache at offset 0."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.pos_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kT, vT = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    out = _attention_core(
        q.transpose(0, 2, 1, 3), kT, vT, cfg, causal=True, executor=executor
    )
    cache = cache.write(0, kT, vT)
    y = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd) @ p["wo"]
    return y, cache


def gqa_decode(p, x, cfg, length, cache: KVCache, *, executor=None):
    """One-token step. ``length`` = tokens already in cache (current pos)."""
    B, S, d = x.shape  # S == 1
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pos = jnp.full((B, 1), length, jnp.int32)
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, hd)
    if cfg.pos_kind == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    cache = cache.write(length, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    out = decode_attention(q.transpose(0, 2, 1, 3), cache, length + 1)
    y = out.transpose(0, 2, 1, 3).reshape(B, 1, H * hd) @ p["wo"]
    return y, cache


# =============================================================================
# MLA attention (MiniCPM3 / DeepSeek-style multi-head latent attention)
# =============================================================================

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MLACache:
    """Latent cache: compressed kv + shared rope key — the MLA memory win."""

    c_kv: jax.Array  # (B, Smax, kv_lora_rank)
    k_rope: jax.Array  # (B, Smax, qk_rope_head_dim)

    @staticmethod
    def zeros(batch, s_max, kv_rank, rope_dim, dtype):
        return MLACache(
            c_kv=jnp.zeros((batch, s_max, kv_rank), dtype),
            k_rope=jnp.zeros((batch, s_max, rope_dim), dtype),
        )

    def write(self, pos, c_kv_new, k_rope_new) -> "MLACache":
        return MLACache(
            jax.lax.dynamic_update_slice(
                self.c_kv, c_kv_new.astype(self.c_kv.dtype), (0, pos, 0)
            ),
            jax.lax.dynamic_update_slice(
                self.k_rope, k_rope_new.astype(self.k_rope.dtype), (0, pos, 0)
            ),
        )


def mla_init(rng, cfg, *, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    pb = ParamBuilder(rng, dtype)
    pb.param("q_down", (d, qr), ("embed", None), std=d ** -0.5)
    qp, qa = rmsnorm_init(pb.fork(), qr, dtype=dtype)
    pb.child("q_norm", qp, qa)
    pb.param("q_up", (qr, H * (dn + dr)), (None, "heads"), std=qr ** -0.5)
    pb.param("kv_down", (d, kvr + dr), ("embed", None), std=d ** -0.5)
    kvp, kva = rmsnorm_init(pb.fork(), kvr, dtype=dtype)
    pb.child("kv_norm", kvp, kva)
    pb.param("k_up", (kvr, H * dn), (None, "heads"), std=kvr ** -0.5)
    pb.param("v_up", (kvr, H * dv), (None, "heads"), std=kvr ** -0.5)
    pb.param("wo", (H * dv, d), ("heads", "embed"), std=(H * dv) ** -0.5)
    return pb.build()


def _mla_qkv(p, x, cfg, positions):
    """Materialize per-head q, k, v from latents (prefill/training path)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    cq = rmsnorm(p["q_norm"], x @ p["q_down"], cfg.norm_eps)
    q = (cq @ p["q_up"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["kv_down"]  # (B, S, kvr + dr)
    c_kv, k_rope = kv[..., :kvr], kv[..., kvr:]
    c_kv_n = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # shared across heads

    k_nope = (c_kv_n @ p["k_up"]).reshape(B, S, H, dn)
    v = (c_kv_n @ p["v_up"]).reshape(B, S, H, dv)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], axis=-1
    )
    return q_full, k_full, v, c_kv, k_rope


def mla_forward(p, x, cfg, positions, *, executor=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_full, k_full, v, _, _ = _mla_qkv(p, x, cfg, positions)
    scale = 1.0 / ((dn + dr) ** 0.5)
    out = _mla_attention(q_full, k_full, v, cfg, scale, executor)
    return out.reshape(B, S, H * dv) @ p["wo"]


def _mla_attention(q_full, k_full, v, cfg, scale, executor):
    """MLA core attention with dv != dqk.

    The reference/chunked paths consume v at its native head dim (the softmax
    weights only depend on q/k).  Only the Pallas flash kernel requires a
    uniform head dim, so the pad-to-dqk/slice-back dance is confined to that
    dispatch (a §Perf win for the portable path: padding v 64->96 cost 1.5x
    on the PV traffic).
    """
    dv = v.shape[-1]
    dqk = q_full.shape[-1]
    from repro.core.executor import current_executor

    ex = executor if executor is not None else current_executor()
    pad_needed = ex.kernel_space == "pallas"  # flash kernel wants uniform D
    if pad_needed and dv < dqk:
        v_in = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - dv)))
    else:
        v_in = v
    out = _attention_core(
        q_full.transpose(0, 2, 1, 3),
        k_full.transpose(0, 2, 1, 3),
        v_in.transpose(0, 2, 1, 3),
        cfg,
        causal=True,
        scale=scale,
        executor=executor,
    )
    return out.transpose(0, 2, 1, 3)[..., :dv]


def mla_prefill(p, x, cfg, positions, cache: MLACache, *, executor=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_full, k_full, v, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    scale = 1.0 / ((dn + dr) ** 0.5)
    out = _mla_attention(q_full, k_full, v, cfg, scale, executor)
    cache = cache.write(0, c_kv, k_rope)
    return out.reshape(B, S, H * dv) @ p["wo"], cache


def mla_decode(p, x, cfg, length, cache: MLACache, *, executor=None):
    """Latent-cache decode: scores via the absorbed form (q_nope absorbed into
    k_up) so only the (kvr + dr) latents are read per cached token."""
    B, S, _ = x.shape  # S == 1
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    pos = jnp.full((B, 1), length, jnp.int32)

    cq = rmsnorm(p["q_norm"], x @ p["q_down"], cfg.norm_eps)
    q = (cq @ p["q_up"]).reshape(B, 1, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    kv = x @ p["kv_down"]
    c_kv_new, k_rope_new = kv[..., :kvr], kv[..., kvr:]
    k_rope_new = apply_rope(k_rope_new, pos, cfg.rope_theta)
    cache = cache.write(length, c_kv_new, k_rope_new)

    c_kv_n = rmsnorm(p["kv_norm"], cache.c_kv, cfg.norm_eps)  # (B, Smax, kvr)
    # absorbed q: q_nope^T k_nope = (q_nope W_kup^T) . c_kv
    k_up = p["k_up"].reshape(kvr, H, dn)
    q_abs = jnp.einsum("bshd,khd->bshk", q_nope.astype(jnp.float32), k_up.astype(jnp.float32))
    s_nope = jnp.einsum("bshk,btk->bhst", q_abs, c_kv_n.astype(jnp.float32))
    s_rope = jnp.einsum(
        "bshd,btd->bhst", q_rope.astype(jnp.float32), cache.k_rope.astype(jnp.float32)
    )
    s = (s_nope + s_rope) / ((dn + dr) ** 0.5)
    valid = jnp.arange(cache.c_kv.shape[1])[None, None, None, :] < length + 1
    s = jnp.where(valid, s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)  # (B, H, 1, Smax)
    # absorbed v: out = (p . c_kv) W_vup
    ctx = jnp.einsum("bhst,btk->bshk", pattn, c_kv_n.astype(jnp.float32))
    v_up = p["v_up"].reshape(kvr, H, dv)
    out = jnp.einsum("bshk,khd->bshd", ctx, v_up.astype(jnp.float32))
    out = out.reshape(B, 1, H * dv).astype(x.dtype)
    return out @ p["wo"], cache
