"""Mamba2 block (SSD) — used by the zamba2 hybrid architecture.

Structure follows Mamba2 (Dao & Gu 2024): input projection producing
(z, x, B, C, dt), short causal depthwise conv over (x, B, C), SSD scan over
heads (the registered ``nn_ssd_scan`` operation: reference/xla sequential scan,
Pallas chunked kernel), gated RMSNorm, output projection.

Decode keeps a (conv window, ssm state) recurrent state and steps in O(1).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.nn.common import ParamBuilder, ones_init, zeros_init

_ssd_op = registry.operation("nn_ssd_scan")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MambaState:
    conv: jax.Array  # (B, conv_w - 1, conv_dim) rolling conv window
    ssm: jax.Array  # (B, H, N, P) f32

    @staticmethod
    def zeros(batch, conv_w, conv_dim, n_heads, d_state, head_dim, dtype):
        return MambaState(
            conv=jnp.zeros((batch, conv_w - 1, conv_dim), dtype),
            ssm=jnp.zeros((batch, n_heads, d_state, head_dim), jnp.float32),
        )


def _dims(cfg):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    G = cfg.ssm_groups
    return d, d_inner, H, P, N, G


def mamba_init(rng, cfg, *, dtype=jnp.float32):
    d, d_inner, H, P, N, G = _dims(cfg)
    conv_dim = d_inner + 2 * G * N
    pb = ParamBuilder(rng, dtype)
    # in_proj -> [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
    pb.param(
        "in_proj",
        (d, 2 * d_inner + 2 * G * N + H),
        ("embed", "mlp"),
        std=d ** -0.5,
    )
    pb.param("conv_w", (cfg.ssm_conv, conv_dim), (None, "mlp"), std=0.5)
    pb.param("conv_b", (conv_dim,), ("mlp",), init=zeros_init)
    pb.param("dt_bias", (H,), ("heads",), init=zeros_init)
    # A in (-exp space): A = -exp(A_log), init A ~ -1
    pb.param("A_log", (H,), ("heads",), init=zeros_init)
    pb.param("D", (H,), ("heads",), init=ones_init)
    pb.param("norm_scale", (d_inner,), ("mlp",), init=ones_init)
    pb.param("out_proj", (d_inner, d), ("mlp", "embed"), std=d_inner ** -0.5)
    return pb.build()


def _split_proj(proj, cfg):
    d, d_inner, H, P, N, G = _dims(cfg)
    z = proj[..., :d_inner]
    x = proj[..., d_inner : 2 * d_inner]
    Bc = proj[..., 2 * d_inner : 2 * d_inner + G * N]
    Cc = proj[..., 2 * d_inner + G * N : 2 * d_inner + 2 * G * N]
    dt = proj[..., 2 * d_inner + 2 * G * N :]
    return z, x, Bc, Cc, dt


def _gated_norm(scale, y, z, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array):
    """Depthwise causal conv; ``prev`` is the (conv_w-1) left context."""
    conv_w = w.shape[0]
    xin = jnp.concatenate([prev, xBC], axis=1)  # (B, S + cw - 1, C)
    out = sum(
        xin[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(conv_w)
    )
    return jax.nn.silu(out + b), xin[:, -(conv_w - 1) :, :]


def mamba_forward(
    p, xin: jax.Array, cfg, state: MambaState = None, *, executor=None
) -> Tuple[jax.Array, MambaState]:
    B, S, _ = xin.shape
    d, d_inner, H, P, N, G = _dims(cfg)
    proj = xin @ p["in_proj"]
    z, x, Bc, Cc, dt = _split_proj(proj, cfg)

    xBC = jnp.concatenate([x, Bc, Cc], axis=-1)
    prev = (
        state.conv
        if state is not None
        else jnp.zeros((B, cfg.ssm_conv - 1, xBC.shape[-1]), xBC.dtype)
    )
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], prev)
    x, Bc, Cc = (
        xBC[..., :d_inner],
        xBC[..., d_inner : d_inner + G * N],
        xBC[..., d_inner + G * N :],
    )

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(B, S, H, P)
    Bm = Bc.reshape(B, S, G, N)
    Cm = Cc.reshape(B, S, G, N)

    y, ssm_state = _ssd_op(xh, dt, A, Bm, Cm, executor=executor)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = _gated_norm(p["norm_scale"], y, z, cfg.norm_eps)
    out = y @ p["out_proj"]

    new_state = None
    if state is not None:
        new_state = MambaState(conv=conv_state, ssm=ssm_state)
    return out, new_state


def mamba_step(p, xin: jax.Array, cfg, state: MambaState) -> Tuple[jax.Array, MambaState]:
    """O(1) single-token recurrence (decode)."""
    B, _, _ = xin.shape  # (B, 1, d)
    d, d_inner, H, P, N, G = _dims(cfg)
    proj = xin @ p["in_proj"]
    z, x, Bc, Cc, dt = _split_proj(proj, cfg)

    xBC = jnp.concatenate([x, Bc, Cc], axis=-1)  # (B, 1, C)
    window = jnp.concatenate([state.conv, xBC], axis=1)  # (B, cw, C)
    conv_out = jnp.einsum("btc,tc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC1 = jax.nn.silu(conv_out)[:, None, :]
    conv_state = window[:, 1:, :]

    x1, B1, C1 = (
        xBC1[..., :d_inner],
        xBC1[..., d_inner : d_inner + G * N],
        xBC1[..., d_inner + G * N :],
    )
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[
        :, 0, :
    ]  # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    xh = x1.reshape(B, H, P).astype(jnp.float32)
    group = H // G
    Bh = jnp.repeat(B1.reshape(B, G, N), group, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C1.reshape(B, G, N), group, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt1 * A[None, :])  # (B, H)
    update = dt1[..., None, None] * Bh[..., :, None] * xh[..., None, :]
    ssm = decay[..., None, None] * state.ssm + update
    y = jnp.einsum("bhn,bhnp->bhp", Ch, ssm)
    y = y + A.dtype.type(0)  # keep f32
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(xin.dtype)
    y = _gated_norm(p["norm_scale"], y, z, cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, MambaState(conv=conv_state, ssm=ssm)
