"""Mixture-of-Experts layer: top-k router + grouped-GEMM expert dispatch.

Two dispatch formulations, same math:

* ``sort`` (default): tokens are replicated k ways, sorted by expert id, and
  the expert SwiGLU runs as three ``jax.lax.ragged_dot`` grouped GEMMs — the
  MaxText-style sparse path.  Compiles on CPU and under GSPMD; on TPU the
  ragged dot lowers to the native grouped-matmul kernels.
* ``dense``: every expert processes every token, combined with the routing
  weights (einsum over the expert axis).  O(E/k) more FLOPs — used only as the
  smoke-test oracle for the sort path.

Expert parallelism at scale (DESIGN.md §5): expert weight arrays carry the
("expert", ...) logical axis which the sharding rules map to the "model" mesh
axis; under pjit, GSPMD turns the gather/scatter around the ragged dots into
all-to-alls across the expert shards.

Router: softmax -> top-k -> renormalize (qwen2/olmoe convention), with the
standard load-balance auxiliary loss (Switch-style fraction*prob) and router
z-loss returned as metrics.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import axis_size
from repro.launch.mesh import shard_map
from repro.nn.common import ParamBuilder


def padded_experts(cfg) -> int:
    """Expert count padded for even expert-parallel sharding (qwen2: 60->64).

    Padded experts receive -inf router logits and zero ragged-dot groups —
    dead weight sharded away, never compute.
    """
    return cfg.n_experts_padded or cfg.n_experts


def moe_init(rng, cfg, *, dtype=jnp.float32):
    d, E, dff = cfg.d_model, padded_experts(cfg), cfg.d_expert
    pb = ParamBuilder(rng, dtype)
    pb.param("router", (d, cfg.n_experts), ("embed", None), std=d ** -0.5,
             dtype=jnp.float32)
    pb.param("gate", (E, d, dff), ("expert", "embed", "expert_mlp"), std=d ** -0.5)
    pb.param("up", (E, d, dff), ("expert", "embed", "expert_mlp"), std=d ** -0.5)
    pb.param("down", (E, dff, d), ("expert", "expert_mlp", "embed"), std=dff ** -0.5)
    if cfg.shared_expert_ff:
        sff = cfg.shared_expert_ff
        pb.param("sh_gate", (d, sff), ("embed", "mlp"), std=d ** -0.5)
        pb.param("sh_up", (d, sff), ("embed", "mlp"), std=d ** -0.5)
        pb.param("sh_down", (sff, d), ("mlp", "embed"), std=sff ** -0.5)
        # qwen2-moe gates the shared expert with a sigmoid scalar per token
        pb.param("sh_gate_proj", (d, 1), ("embed", None), std=d ** -0.5)
    return pb.build()


def _router(p, x2, cfg):
    """x2: (T, d) -> (weights (T, k), ids (T, k), aux_metrics)."""
    T = x2.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    logits = x2.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)  # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # Switch-style load-balance loss: E * sum_e fraction_e * mean_prob_e
    counts = jnp.sum(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=(0, 1))  # (E,)
    fraction = counts / jnp.maximum(T * k, 1)
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(fraction * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return weights, ids, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _experts_sort(p, x2, weights, ids, cfg):
    """Sort-based dispatch + ragged grouped GEMM."""
    T, d = x2.shape
    E, k = p["gate"].shape[0], cfg.top_k  # padded expert count

    flat_ids = ids.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_ids)  # stable
    inv = jnp.argsort(order)
    token_of = order // k  # source token per sorted slot
    xs = x2[token_of]  # (T*k, d) gathered tokens in expert order

    group_sizes = jnp.sum(
        jax.nn.one_hot(flat_ids, E, dtype=jnp.int32), axis=0
    )  # (E,)

    gate = jax.lax.ragged_dot(xs, p["gate"], group_sizes)
    up = jax.lax.ragged_dot(xs, p["up"], group_sizes)
    h = jax.nn.silu(gate) * up
    out_s = jax.lax.ragged_dot(h, p["down"], group_sizes)  # (T*k, d)

    out = out_s[inv].reshape(T, k, d)
    return jnp.sum(out * weights[..., None].astype(out.dtype), axis=1)


def _experts_dense(p, x2, weights, ids, cfg):
    """Oracle: every expert on every token, masked combine."""
    E, k = p["gate"].shape[0], cfg.top_k
    gate = jnp.einsum("td,edf->tef", x2, p["gate"])
    up = jnp.einsum("td,edf->tef", x2, p["up"])
    h = jax.nn.silu(gate) * up
    out_e = jnp.einsum("tef,efd->ted", h, p["down"])  # (T, E, d)
    combine = jnp.zeros((x2.shape[0], E), jnp.float32)
    one_hot = jax.nn.one_hot(ids, E, dtype=jnp.float32)  # (T, k, E)
    combine = jnp.sum(one_hot * weights[..., None], axis=1)  # (T, E)
    return jnp.einsum("te,ted->td", combine.astype(out_e.dtype), out_e)


# =============================================================================
# expert-parallel dispatch (shard_map): the at-scale path
#
# Layout: activations are data-sharded and model-replicated (the TP layout the
# rest of the block already uses), experts are sharded over the "model" axis.
# Because every model column holds the tokens already, dispatch needs NO
# all-to-all: each column selects the tokens routed to ITS experts into a
# fixed-capacity buffer (GShard-style capacity with drop), runs three ragged
# grouped GEMMs, scatters back, and one psum over the model axis combines the
# columns — the same reduction a TP dense MLP pays.  Capacity keeps every
# shape static; overflow tokens fall back to the shared expert / residual.
# =============================================================================


def _capacity(cfg, T: int, n_cols: int) -> int:
    c = int(cfg.moe_capacity_factor * T * cfg.top_k / max(n_cols, 1))
    return max((c + 7) // 8 * 8, 8)


def _experts_ep_body(x2, router_w, gate_l, up_l, down_l, cfg, model_axis):
    """Per-device body. x2: (T, d) local tokens; *_l: this column's experts."""
    T, d = x2.shape
    E_pad_local = gate_l.shape[0]
    m = jax.lax.axis_index(model_axis)
    n_cols = axis_size(model_axis)
    k = cfg.top_k

    # router (replicated weights; computed redundantly per column — cheap)
    logits = x2.astype(jnp.float32) @ router_w  # (T, E_real)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    counts = jnp.sum(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    fraction = counts / jnp.maximum(T * k, 1)
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = cfg.n_experts * jnp.sum(fraction * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    flat_ids = ids.reshape(-1)  # (T*k,)
    flat_w = weights.reshape(-1)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k

    lo = m * E_pad_local
    mine = (flat_ids >= lo) & (flat_ids < lo + E_pad_local)
    pos = jnp.cumsum(mine.astype(jnp.int32)) - 1
    C = _capacity(cfg, T, n_cols)
    keep = mine & (pos < C)
    slot = jnp.where(keep, pos, C)  # C = overflow slot

    # scatter tokens + local expert ids into the fixed buffer
    buf = jnp.zeros((C + 1, d), x2.dtype).at[slot].add(
        jnp.where(keep[:, None], x2[tok], 0)
    )
    eid = jnp.zeros((C + 1,), jnp.int32).at[slot].max(
        jnp.where(keep, flat_ids - lo, 0)
    )

    # order by local expert id; empty slots carry zeros into expert 0 (no-op)
    order = jnp.argsort(eid[:C])
    xs = buf[:C][order]
    sorted_eid = eid[:C][order]
    group_sizes = jnp.sum(
        jax.nn.one_hot(sorted_eid, E_pad_local, dtype=jnp.int32), axis=0
    )

    gate = jax.lax.ragged_dot(xs, gate_l, group_sizes)
    up = jax.lax.ragged_dot(xs, up_l, group_sizes)
    h = jax.nn.silu(gate) * up
    out_s = jax.lax.ragged_dot(h, down_l, group_sizes)  # (C, d)

    inv = jnp.argsort(order)
    out_buf = jnp.concatenate([out_s[inv], jnp.zeros((1, d), out_s.dtype)], axis=0)

    contrib = out_buf[slot] * jnp.where(keep, flat_w, 0.0)[:, None].astype(out_s.dtype)
    y2 = jnp.sum(contrib.reshape(T, k, d), axis=1)  # partial: this column only
    drop_frac = 1.0 - jnp.sum(keep) / jnp.maximum(jnp.sum(mine), 1)
    return y2, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
                "moe_drop_frac": drop_frac}


def _experts_ep_a2a_body(x2, router_w, gate_l, up_l, down_l, cfg, model_axis):
    """all_to_all dispatch body. x2: (T_l, d) — this device's seq shard.

    Tokens stay sequence-sharded over the model axis; each device sends the
    tokens routed to remote experts through one all_to_all (fixed per-pair
    capacity), computes its local experts' ragged GEMMs on the received set,
    and a second all_to_all returns results to the owning device — no
    model-axis activation all-gather and no output psum.
    """
    T, d = x2.shape
    E_local = gate_l.shape[0]
    n_cols = axis_size(model_axis)
    k = cfg.top_k

    logits = x2.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    counts = jnp.sum(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    lb_loss = cfg.n_experts * jnp.sum(
        counts / jnp.maximum(T * k, 1) * jnp.mean(probs, axis=0)
    )
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    flat_ids = ids.reshape(-1)  # (T*k,)
    flat_w = weights.reshape(-1)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k
    dest = flat_ids // E_local  # owning column per assignment
    local_eid = flat_ids % E_local

    # per-destination positions (running count of assignments to each column)
    dest_onehot = jax.nn.one_hot(dest, n_cols, dtype=jnp.int32)  # (T*k, ncols)
    pos = jnp.cumsum(dest_onehot, axis=0) - dest_onehot  # exclusive
    pos = jnp.sum(pos * dest_onehot, axis=1)  # (T*k,)

    # pair capacity: expected T*k/n_cols with slack (pair-level balance is
    # noisier than device-level, hence the 2x)
    C = max(int(2.0 * cfg.moe_capacity_factor * T * k / max(n_cols, 1) + 7) // 8 * 8, 8)
    keep = pos < C
    slot = jnp.where(keep, dest * C + pos, n_cols * C)  # overflow slot

    send_x = jnp.zeros((n_cols * C + 1, d), x2.dtype).at[slot].add(
        jnp.where(keep[:, None], x2[tok], 0)
    )[:-1]
    send_eid = jnp.zeros((n_cols * C + 1,), jnp.int32).at[slot].max(
        jnp.where(keep, local_eid, 0)
    )[:-1]
    send_valid = jnp.zeros((n_cols * C + 1,), jnp.bool_).at[slot].max(keep)[:-1]

    # exchange: (ncols, C, ...) -> first axis becomes source column
    recv_x = jax.lax.all_to_all(
        send_x.reshape(n_cols, C, d), model_axis, 0, 0, tiled=False
    ).reshape(n_cols * C, d)
    recv_eid = jax.lax.all_to_all(
        send_eid.reshape(n_cols, C), model_axis, 0, 0, tiled=False
    ).reshape(n_cols * C)
    recv_valid = jax.lax.all_to_all(
        send_valid.reshape(n_cols, C), model_axis, 0, 0, tiled=False
    ).reshape(n_cols * C)

    recv_eid = jnp.where(recv_valid, recv_eid, 0)  # invalid slots -> expert 0
    order = jnp.argsort(recv_eid)
    xs = recv_x[order]
    group_sizes = jnp.sum(
        jax.nn.one_hot(recv_eid[order], E_local, dtype=jnp.int32), axis=0
    )
    gate = jax.lax.ragged_dot(xs, gate_l, group_sizes)
    up = jax.lax.ragged_dot(xs, up_l, group_sizes)
    out_s = jax.lax.ragged_dot(jax.nn.silu(gate) * up, down_l, group_sizes)
    inv = jnp.argsort(order)
    out_buf = out_s[inv] * recv_valid[:, None].astype(out_s.dtype)

    # return exchange
    back = jax.lax.all_to_all(
        out_buf.reshape(n_cols, C, d), model_axis, 0, 0, tiled=False
    ).reshape(n_cols * C, d)
    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)

    contrib = back[slot] * jnp.where(keep, flat_w, 0.0)[:, None].astype(back.dtype)
    y2 = jnp.zeros((T, d), x2.dtype).at[tok].add(contrib.astype(x2.dtype))
    drop_frac = 1.0 - jnp.sum(keep) / jnp.maximum(T * k, 1)
    return y2, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
                "moe_drop_frac": drop_frac}


def _experts_ep(p, x, cfg):
    """shard_map expert-parallel MoE. x: (B, S, d) -> (y, metrics)."""
    batch_axes, model_axis = cfg.moe_spec
    P = jax.sharding.PartitionSpec
    has_shared = "sh_gate" in p
    a2a = cfg.moe_dispatch == "a2a"

    def body(x_l, router_w, gate_l, up_l, down_l, *shared):
        B_l, S_l, d = x_l.shape
        x2 = x_l.reshape(B_l * S_l, d)
        if a2a:
            y2, metrics = _experts_ep_a2a_body(
                x2, router_w, gate_l, up_l, down_l, cfg, model_axis
            )
        else:
            y2, metrics = _experts_ep_body(
                x2, router_w, gate_l, up_l, down_l, cfg, model_axis
            )
        if has_shared:
            sh_gate_l, sh_up_l, sh_down_l, sh_gate_proj = shared
            shp = (jax.nn.silu(x2 @ sh_gate_l) * (x2 @ sh_up_l)) @ sh_down_l
            gate_sc = jax.nn.sigmoid(x2.astype(jnp.float32) @ sh_gate_proj)
            y2 = y2 + shp.astype(y2.dtype) * gate_sc.astype(y2.dtype)
        if not a2a:
            y2 = jax.lax.psum(y2, model_axis)  # combine expert columns
        metrics = {k: jax.lax.pmean(jax.lax.pmean(v, model_axis), batch_axes)
                   for k, v in metrics.items()}
        return y2.reshape(B_l, S_l, d), metrics

    # a2a: tokens stay sequence-sharded over the model axis (the SP layout);
    # gather: tokens model-replicated, experts read their local copy
    x_spec = P(batch_axes, model_axis, None) if a2a else P(batch_axes, None, None)
    in_specs = [
        x_spec,
        P(None, None),  # router replicated
        P(model_axis, None, None),  # experts sharded
        P(model_axis, None, None),
        P(model_axis, None, None),
    ]
    args = [x, p["router"], p["gate"], p["up"], p["down"]]
    if has_shared:
        if a2a:
            # shared experts run on local tokens with full weights (69 MB at
            # qwen2 scale — cheaper than reintroducing the output psum)
            in_specs += [P(None, None), P(None, None), P(None, None), P(None, None)]
        else:
            in_specs += [
                P(None, model_axis),  # shared-expert hidden sharded over model
                P(None, model_axis),
                P(model_axis, None),
                P(None, None),
            ]
        args += [p["sh_gate"], p["sh_up"], p["sh_down"], p["sh_gate_proj"]]

    out_specs = (x_spec, {
        "moe_lb_loss": P(), "moe_z_loss": P(), "moe_drop_frac": P()})
    return shard_map(
        body, in_specs=tuple(in_specs), out_specs=out_specs
    )(*args)


def moe_forward(p, x: jax.Array, cfg, *, impl: str = None):
    """x: (B, S, d) -> (y, metrics).  impl: "sort" | "dense" | "ep" (default:
    "ep" when cfg.moe_spec is set, else "sort")."""
    if impl is None:
        impl = "ep" if cfg.moe_spec else "sort"
    if impl == "ep":
        return _experts_ep(p, x, cfg)

    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    weights, ids, metrics = _router(p, x2, cfg)
    if impl == "sort":
        y2 = _experts_sort(p, x2, weights, ids, cfg)
    elif impl == "dense":
        y2 = _experts_dense(p, x2, weights, ids, cfg)
    else:
        raise ValueError(f"unknown moe impl {impl!r}")

    if "sh_gate" in p:
        sh = (jax.nn.silu(x2 @ p["sh_gate"]) * (x2 @ p["sh_up"])) @ p["sh_down"]
        sh_gate = jax.nn.sigmoid(x2.astype(jnp.float32) @ p["sh_gate_proj"])
        y2 = y2 + sh.astype(y2.dtype) * sh_gate.astype(y2.dtype)

    return y2.reshape(B, S, d), metrics
