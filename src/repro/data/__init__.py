"""repro.data — deterministic, shard-aware, resumable synthetic pipeline."""

from repro.data.pipeline import (
    DataConfig,
    DataIterator,
    entropy_floor,
    global_step_batch,
    shard_batch_np,
)

__all__ = [
    "DataConfig",
    "DataIterator",
    "entropy_floor",
    "global_step_batch",
    "shard_batch_np",
]
