"""Deterministic, shard-aware, resumable synthetic LM data pipeline.

Design goals (the ones that matter at cluster scale):

* **Determinism**: batch contents are a pure function of (seed, step, shard) —
  a restarted job resumes mid-epoch with identical batches; no filesystem
  state.
* **Sharding**: each data-parallel shard draws its own slice; the global batch
  is the concatenation over shards (``global_step_batch`` assembles it for
  single-host tests; on a cluster each host materializes only its shard).
* **Resumability**: iterator state is just the integer step — checkpointed
  with the train state.

The token stream is a learnable synthetic process (a noisy modular-offset
Markov chain): next = prev + delta (mod V), delta drawn from a fixed small
set with seed-determined probabilities.  A model that learns p(delta) reaches
~H(delta) nats — visibly below the log(V) random floor — so the end-to-end
example can demonstrate real learning.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

DELTAS = np.array([1, 2, 3, 5, 8], dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_shards: int = 1
    seed: int = 0
    stub_embed_dim: int = 0  # >0: emit "embeds" (stub frontends) besides labels


def _shard_batch(cfg: DataConfig) -> int:
    if cfg.global_batch % cfg.num_shards:
        raise ValueError(
            f"global_batch {cfg.global_batch} not divisible by shards {cfg.num_shards}"
        )
    return cfg.global_batch // cfg.num_shards


def _delta_probs(seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 7777)
    p = rng.dirichlet(np.ones(len(DELTAS)) * 2.0)
    return p


def shard_batch_np(cfg: DataConfig, step: int, shard: int) -> Dict[str, np.ndarray]:
    """Pure function (seed, step, shard) -> one shard's batch (numpy)."""
    b = _shard_batch(cfg)
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard, 0xD47A])
    )
    probs = _delta_probs(cfg.seed)
    start = rng.integers(0, cfg.vocab, size=(b, 1))
    # seq_len + 1 positions; deltas lead INTO each successive token
    deltas = DELTAS[rng.choice(len(DELTAS), p=probs, size=(b, cfg.seq_len))]
    seq = (start + np.concatenate(
        [np.zeros((b, 1), np.int64), np.cumsum(deltas, axis=1)], axis=1
    )) % cfg.vocab  # (b, seq_len + 1)
    tokens = seq[:, :-1].astype(np.int32)
    labels = seq[:, 1:].astype(np.int32)  # labels[t] == tokens[t+1]
    out = {"tokens": tokens, "labels": labels}
    if cfg.stub_embed_dim:
        # stub modality frontend: embeddings derived deterministically from the
        # token stream (hash -> gaussian), stands in for EnCodec/ViT outputs
        e_rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard, 0xE3BED])
        )
        out["embeds"] = e_rng.normal(
            size=(b, cfg.seq_len, cfg.stub_embed_dim)
        ).astype(np.float32)
        del out["tokens"]
    return out


def global_step_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Assemble the full global batch (single-host testing path)."""
    shards = [shard_batch_np(cfg, step, s) for s in range(cfg.num_shards)]
    return {k: np.concatenate([s[k] for s in shards], axis=0) for k in shards[0]}


@dataclasses.dataclass
class DataIterator:
    """Resumable iterator; ``state()``/``restore()`` round-trip through ckpt."""

    cfg: DataConfig
    step: int = 0
    shard: Optional[int] = None  # None = assemble the global batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self.shard is None:
            batch = global_step_batch(self.cfg, self.step)
        else:
            batch = shard_batch_np(self.cfg, self.step, self.shard)
        self.step += 1
        return batch

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def restore(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])


def entropy_floor(cfg: DataConfig) -> float:
    """H(delta): the loss a perfect model of the chain converges to (nats)."""
    p = _delta_probs(cfg.seed)
    return float(-(p * np.log(p)).sum())
