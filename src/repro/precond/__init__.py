"""repro.precond — preconditioner subsystem (gko::preconditioner analogue).

The flagship member is the adaptive-precision block-Jacobi
(:mod:`repro.precond.block_jacobi`, arXiv:2006.16852): host-side block
discovery, format-aware extraction, batched Gauss-Jordan inversion, and an
executor-dispatched apply whose per-block storage precision is selected by a
condition-number rule.  :func:`make_preconditioner` is the string-keyed
factory the solvers use to resolve ``M="block_jacobi"``-style arguments.
"""

from __future__ import annotations

from repro.precond.block_jacobi import (
    ADAPTIVE_TAU,
    BatchBlockJacobi,
    BatchBlockJacobiPattern,
    BlockJacobi,
    batch_block_jacobi,
    batch_block_jacobi_blocks,
    batch_block_jacobi_factors,
    batch_block_jacobi_from_factors,
    batch_block_jacobi_pattern,
    block_jacobi,
    invert_blocks,
    natural_blocks,
    select_block_precisions,
    uniform_block_ptrs,
    unit_roundoff,
)

__all__ = [
    "ADAPTIVE_TAU",
    "BlockJacobi",
    "BatchBlockJacobi",
    "BatchBlockJacobiPattern",
    "block_jacobi",
    "batch_block_jacobi",
    "batch_block_jacobi_pattern",
    "batch_block_jacobi_blocks",
    "batch_block_jacobi_factors",
    "batch_block_jacobi_from_factors",
    "invert_blocks",
    "natural_blocks",
    "select_block_precisions",
    "uniform_block_ptrs",
    "unit_roundoff",
    "make_preconditioner",
    "Multigrid",
    "amg_preconditioner",
]

from repro.precond.amg import Multigrid, amg_preconditioner  # noqa: E402


def make_preconditioner(A, kind: str, *, executor=None, **opts):
    """Resolve a preconditioner by name — the solvers' ``M=<str>`` path.

    Kinds: ``identity``, ``jacobi`` (scalar), ``block_jacobi`` (accepts
    ``block_size``/``blocks``/``adaptive``/``tau``), ``parilu``, ``amg``
    (smoothed-aggregation multigrid; accepts ``theta``/``cycle``/
    ``smoother``/``coarse_solver``/... — see
    :class:`repro.precond.amg.Multigrid`).
    """
    if kind == "identity":
        if opts:
            raise ValueError(
                f"identity preconditioner takes no options, got {sorted(opts)}"
            )
        from repro.solvers.common import identity_preconditioner

        return identity_preconditioner
    if kind == "jacobi":
        from repro.solvers.common import jacobi_preconditioner

        return jacobi_preconditioner(A, executor=executor, **opts)
    if kind == "block_jacobi":
        return block_jacobi(A, executor=executor, **opts)
    if kind == "parilu":
        from repro.solvers.parilu import parilu_preconditioner

        return parilu_preconditioner(A, **opts)
    if kind == "amg":
        from repro.precond.amg import amg_preconditioner

        return amg_preconditioner(A, executor=executor, **opts)
    raise KeyError(
        f"unknown preconditioner kind {kind!r}; known: "
        "identity, jacobi, block_jacobi, parilu, amg"
    )
