"""Algebraic multigrid — smoothed aggregation on the SpGEMM kernel family.

The ``gko::multigrid`` analogue (arXiv:2006.16852 §solvers): on PDE-like
matrices, Krylov iteration counts grow with √κ, and AMG is the O(√κ) → O(1)
jump — a hierarchy of coarse operators built *algebraically* from the matrix,
each level damping the error frequencies its smoother can see.

Setup pipeline (all sparse-sparse composition through the registered
``spgemm`` / ``sptranspose`` ops, so it runs in whichever kernel space the
executor selects):

  1. strength-of-connection — entry (i, j) is *strong* when
     ``|a_ij| ≥ θ·√(a_ii·a_jj)`` (the classical SA filter; anisotropic
     problems drop their weak direction here);
  2. greedy aggregation — 3 passes: seed aggregates around rows whose strong
     neighborhood is untouched, attach leftovers to a neighboring aggregate,
     sweep singletons;
  3. tentative prolongator ``T`` (one unit entry per row: fine point → its
     aggregate), optionally *smoothed* — ``P = (I − ω·D⁻¹A)·T`` via one
     SpGEMM — which is what buys grid-independent convergence;
  4. Galerkin triple product ``A_c = R·A·P`` with ``R = Pᵀ`` — two SpGEMMs
     and one sparse transpose.

The cycle (V or W) runs weighted-Jacobi or block-Jacobi smoothers per level
and a dense-inverse (default) or CG coarse solve; the recursion is unrolled
at trace time, so :meth:`Multigrid._apply` is jit-traceable and works inside
``lax.while_loop`` — the requirement for serving as ``M`` in every Krylov
solver through :func:`repro.precond.make_preconditioner` (``M="amg"``).

Setup emits ``amg.setup`` / ``amg.level`` dispatch-trace spans and per-level
``amg_level_rows`` / ``amg_level_nnz`` gauges plus the operator complexity
(Σ level nnz / fine nnz) — the standard AMG cost metric.

The serve layer uses the pattern-only subset at the bottom of this module:
aggregation from the sparsity pattern alone plus an additive two-level
correction whose values are pure gathers/segment-sums of the fine values —
what lets a cached pattern-tier hierarchy be refreshed per values without
re-running setup (see :mod:`repro.serve.cache`).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.linop import LinOp
from repro.observability import metrics, trace
from repro.sparse.formats import (
    Csr,
    Ell,
    csr_from_arrays,
    csr_host_arrays,
    ell_from_csr_host,
)
from repro.sparse.ops import _coalesce_host, apply as sp_apply, spgemm, sptranspose, to_dense

__all__ = [
    "AmgLevel",
    "AmgServePattern",
    "Multigrid",
    "aggregate",
    "amg_preconditioner",
    "amg_serve_factors",
    "amg_serve_pattern",
    "batch_amg_apply",
    "strength_mask",
    "tentative_prolongator",
]


# =============================================================================
# Setup: strength, aggregation, prolongators, Galerkin product
# =============================================================================


def strength_mask(
    indptr: np.ndarray,
    indices: np.ndarray,
    values: np.ndarray,
    theta: float = 0.08,
) -> np.ndarray:
    """Boolean mask over nnz: ``|a_ij| ≥ θ·√(a_ii·a_jj)``, diagonal excluded.

    The smoothed-aggregation strength-of-connection filter: weak couplings
    (e.g. the ε-direction of anisotropic diffusion) drop out of aggregation
    so aggregates align with the direction the smoother cannot damp.
    """
    n = indptr.shape[0] - 1
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    cols = np.asarray(indices, dtype=np.int64)
    diag = np.ones(n, np.float64)
    dmask = rows == cols
    diag[rows[dmask]] = np.abs(values[dmask].astype(np.float64))
    ref = theta * np.sqrt(diag[rows] * diag[cols])
    return (~dmask) & (np.abs(values.astype(np.float64)) >= ref)


def aggregate(
    indptr: np.ndarray,
    indices: np.ndarray,
    strong: np.ndarray,
    n: int,
) -> Tuple[np.ndarray, int]:
    """Greedy aggregation: ``(agg, n_agg)`` with ``agg[i]`` the aggregate of
    row i.  Three passes (seed / attach / singleton-sweep) — the standard
    SA coarsening, sequential by construction (host setup path).
    """
    ip = np.asarray(indptr).tolist()
    ix = np.asarray(indices).tolist()
    st = np.asarray(strong).tolist()
    agg = [-1] * n
    n_agg = 0
    # pass 1: rows whose strong neighborhood is entirely unaggregated seed a
    # new aggregate containing themselves + that neighborhood
    for i in range(n):
        if agg[i] != -1:
            continue
        nbrs = [ix[t] for t in range(ip[i], ip[i + 1]) if st[t]]
        if any(agg[j] != -1 for j in nbrs):
            continue
        agg[i] = n_agg
        for j in nbrs:
            agg[j] = n_agg
        n_agg += 1
    # pass 2: attach leftovers to any strongly-connected aggregate
    for i in range(n):
        if agg[i] != -1:
            continue
        for t in range(ip[i], ip[i + 1]):
            if st[t] and agg[ix[t]] != -1:
                agg[i] = agg[ix[t]]
                break
    # pass 3: whatever remains (isolated rows) becomes a singleton aggregate
    for i in range(n):
        if agg[i] == -1:
            agg[i] = n_agg
            n_agg += 1
    return np.asarray(agg, np.int64), n_agg


def tentative_prolongator(agg: np.ndarray, n_agg: int) -> Csr:
    """``T``: (n, n_agg) CSR with one unit entry per row (piecewise-constant
    interpolation from aggregates to fine points)."""
    n = agg.shape[0]
    return csr_from_arrays(
        np.arange(n + 1, dtype=np.int64),
        agg.astype(np.int32),
        np.ones(n, np.float32),
        (n, n_agg),
    )


def _csr_diag(indptr, indices, values, n) -> np.ndarray:
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    diag = np.zeros(n, values.dtype)
    m = rows == indices
    diag[rows[m]] = values[m]
    return diag


def _ell_of(A: Csr) -> Ell:
    indptr, indices, values = csr_host_arrays(A)
    return ell_from_csr_host(indptr, indices, values, A.shape)


def _csr_sub_scaled(Tm: Csr, S: Csr, row_scale: np.ndarray) -> Csr:
    """Host sparse combination ``T − diag(row_scale)·S`` (same shape)."""
    ti, tc, tv = csr_host_arrays(Tm)
    si, sc, sv = csr_host_arrays(S)
    m, n = Tm.shape
    t_rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(ti))
    s_rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(si))
    rows = np.concatenate([t_rows, s_rows])
    cols = np.concatenate([tc.astype(np.int64), sc.astype(np.int64)])
    vals = np.concatenate([tv, -row_scale[s_rows] * sv])
    indptr, out_c, out_v = _coalesce_host(rows, cols, vals, m)
    return csr_from_arrays(indptr, out_c, out_v, (m, n))


@dataclasses.dataclass
class AmgLevel:
    """One level of the hierarchy: its operator, grid-transfer pair, and the
    smoother data (inverse diagonal for weighted Jacobi, or a block-Jacobi
    LinOp when the hierarchy was built with ``smoother="block_jacobi"``).

    The CSR forms are what the Galerkin composition produced (and what tests
    introspect); the ``*_op`` ELL mirrors are what the cycle *applies* — PDE
    hierarchies have near-uniform row counts, and the ELL SpMV needs no
    per-apply row-id reconstruction, which is what keeps the V-cycle's
    per-iteration cost within a small factor of one fine-grid SpMV.
    """

    A: Csr
    P: Csr  # prolongation: coarse -> fine
    R: Csr  # restriction:  fine -> coarse (Pᵀ)
    A_op: Ell
    P_op: Ell
    R_op: Ell
    inv_diag: jax.Array
    smoother: Optional[LinOp] = None


class Multigrid(LinOp):
    """AMG V/W-cycle as a LinOp (gko::multigrid::Pgm + gko::solver::Multigrid).

    ``apply(r)`` runs one cycle from a zero initial guess — i.e. it is the
    preconditioner application ``M⁻¹ r``.  The cycle recursion is unrolled at
    trace time (the level count is static), so the apply jits and can run
    inside a Krylov solver's ``lax.while_loop``.  With symmetric smoothing
    (the default weighted Jacobi, same pre/post sweep counts) the V-cycle is
    an SPD operator — safe as CG's ``M``.
    """

    def __init__(
        self,
        A: Csr,
        *,
        theta: float = 0.08,
        omega: float = 2.0 / 3.0,
        smooth_prolongator: bool = True,
        cycle: str = "v",
        pre_sweeps: int = 1,
        post_sweeps: int = 1,
        max_levels: int = 10,
        coarse_size: int = 64,
        coarse_solver: str = "dense",
        smoother: str = "jacobi",
        smoother_opts: Optional[dict] = None,
        executor=None,
    ):
        if cycle not in ("v", "w"):
            raise ValueError(f"cycle must be 'v' or 'w', got {cycle!r}")
        if coarse_solver not in ("dense", "cg"):
            raise ValueError(
                f"coarse_solver must be 'dense' or 'cg', got {coarse_solver!r}"
            )
        if smoother not in ("jacobi", "block_jacobi"):
            raise ValueError(
                f"smoother must be 'jacobi' or 'block_jacobi', got {smoother!r}"
            )
        self.executor = executor
        self.cycle = cycle
        self.omega = float(omega)
        self.pre_sweeps = int(pre_sweeps)
        self.post_sweeps = int(post_sweeps)
        self._shape = A.shape
        self._dtype = A.values.dtype
        self.levels: List[AmgLevel] = []

        fine_nnz = max(A.nnz, 1)
        with trace.span("amg.setup", cat="amg", n=A.shape[0], nnz=A.nnz,
                        theta=theta, cycle=cycle):
            level = 0
            while A.shape[0] > coarse_size and level < max_levels:
                indptr, indices, values = csr_host_arrays(A)
                n = A.shape[0]
                strong = strength_mask(indptr, indices, values, theta)
                agg, n_agg = aggregate(indptr, indices, strong, n)
                if n_agg >= n:
                    break  # coarsening stalled — stop descending
                with trace.span("amg.level", cat="amg", level=level,
                                rows=n, nnz=A.nnz, coarse_rows=n_agg):
                    T = tentative_prolongator(agg, n_agg)
                    if smooth_prolongator:
                        diag = _csr_diag(indptr, indices, values, n)
                        inv_d = np.where(diag != 0, 1.0 / diag, 0.0).astype(
                            values.dtype
                        )
                        AT = spgemm(A, T, executor=executor)
                        P = _csr_sub_scaled(T, AT, self.omega * inv_d)
                    else:
                        P = T
                    R = sptranspose(P, executor=executor)
                    A_c = spgemm(R, spgemm(A, P, executor=executor),
                                 executor=executor)
                diag = _csr_diag(indptr, indices, values, n)
                inv_diag = jnp.asarray(
                    np.where(diag != 0, 1.0 / diag, 0.0).astype(values.dtype)
                )
                sm = None
                if smoother == "block_jacobi":
                    from repro.precond.block_jacobi import block_jacobi

                    sm = block_jacobi(
                        A, executor=executor, **(smoother_opts or {})
                    )
                self.levels.append(
                    AmgLevel(
                        A=A, P=P, R=R,
                        A_op=_ell_of(A), P_op=_ell_of(P), R_op=_ell_of(R),
                        inv_diag=inv_diag, smoother=sm,
                    )
                )
                metrics.gauge("amg_level_rows", level=level).set(n)
                metrics.gauge("amg_level_nnz", level=level).set(A.nnz)
                A = A_c
                level += 1

            self.coarse_A = A
            metrics.gauge("amg_level_rows", level=level).set(A.shape[0])
            metrics.gauge("amg_level_nnz", level=level).set(A.nnz)
            total_nnz = sum(l.A.nnz for l in self.levels) + A.nnz
            self.operator_complexity = total_nnz / fine_nnz
            metrics.gauge("amg_operator_complexity").set(
                self.operator_complexity
            )
            with trace.span("amg.coarse_solver", cat="amg",
                            kind=coarse_solver, rows=A.shape[0]):
                if coarse_solver == "dense":
                    dense = to_dense(A, executor=executor)
                    self._coarse_inv = jnp.linalg.inv(
                        dense.astype(jnp.float32)
                    ).astype(self._dtype)
                    self._coarse_solver = None
                else:
                    from repro.solvers.common import Stop
                    from repro.solvers.krylov import CgSolver

                    self._coarse_inv = None
                    self._coarse_solver = CgSolver(
                        A,
                        stop=Stop(max_iters=50, reduction_factor=1e-8),
                        executor=executor,
                    )

    @property
    def shape(self):
        return self._shape

    @property
    def dtype(self):
        return self._dtype

    @property
    def num_levels(self) -> int:
        # counting the coarse grid, matching gko::solver::Multigrid
        return len(self.levels) + 1

    # -- the cycle -------------------------------------------------------------

    def _smooth(self, L: AmgLevel, x, r, sweeps: int, executor):
        for _ in range(sweeps):
            res = r - sp_apply(L.A_op, x, executor=executor)
            if L.smoother is not None:
                x = x + L.smoother.apply(res, executor=executor)
            else:
                x = x + self.omega * L.inv_diag * res
        return x

    def _coarse_solve(self, r, executor):
        if self._coarse_inv is not None:
            return self._coarse_inv @ r
        return self._coarse_solver.apply(r, executor=executor)

    def _cycle(self, lvl: int, r, executor):
        if lvl == len(self.levels):
            return self._coarse_solve(r, executor)
        L = self.levels[lvl]
        x = self._smooth(L, jnp.zeros_like(r), r, self.pre_sweeps, executor)
        rc = sp_apply(L.R_op, r - sp_apply(L.A_op, x, executor=executor),
                      executor=executor)
        xc = self._cycle(lvl + 1, rc, executor)
        if self.cycle == "w" and lvl + 1 < len(self.levels):
            # second recursive visit (γ = 2): correct with the updated
            # coarse residual before interpolating back up (the coarsest
            # visit is exact already — no second solve there)
            rc2 = rc - sp_apply(
                self.levels[lvl + 1].A_op, xc, executor=executor
            )
            xc = xc + self._cycle(lvl + 1, rc2, executor)
        x = x + sp_apply(L.P_op, xc, executor=executor)
        return self._smooth(L, x, r, self.post_sweeps, executor)

    def _apply(self, r: jax.Array, executor) -> jax.Array:
        ex = executor if executor is not None else self.executor
        if not self.levels:
            return self._coarse_solve(r, ex)
        return self._cycle(0, r, ex)


def amg_preconditioner(A: Csr, *, executor=None, **opts) -> Multigrid:
    """``M="amg"`` factory — one V(1,1)-cycle of smoothed aggregation."""
    if not isinstance(A, Csr):
        raise TypeError(
            f"amg preconditioner needs a CSR operand, got {type(A).__name__}"
        )
    return Multigrid(A, executor=executor, **opts)


# =============================================================================
# Serve-path AMG: pattern-tier hierarchy + values-tier refresh
# =============================================================================
#
# The serve engine caches per *pattern* (indptr, indices) and refreshes per
# *values*, so the hierarchy must split the same way: aggregation from the
# pattern alone (every off-diagonal is treated as strong), an UNsmoothed
# prolongator (so P is values-free), and Galerkin coarse values that are pure
# segment-sums of the fine values over a pattern-derived map.  The cycle is
# the additive two-level correction  M⁻¹ r = ω·D⁻¹ r + P·A_c⁻¹·Pᵀ r  — SPD,
# batched over the lane's solve slots, and needing only the flat factor row
# ``[inv_diag | A_c⁻¹.flatten()]`` the values tier stores.


@dataclasses.dataclass(frozen=True)
class AmgServePattern:
    """Pattern-tier hierarchy data: values-independent, cacheable."""

    agg: np.ndarray        # (n,)  fine row -> aggregate
    n_agg: int
    coarse_indptr: np.ndarray   # coarse pattern (n_agg + 1,)
    coarse_indices: np.ndarray  # (coarse_nnz,)
    #: fine nnz slot -> coarse nnz slot (Galerkin product collapses to a
    #: segment-sum because P is the unit tentative prolongator)
    seg: np.ndarray
    #: fine nnz slots holding the diagonal, and their row ids
    diag_slots: np.ndarray
    n: int

    @property
    def flat_len(self) -> int:
        return self.n + self.n_agg * self.n_agg


def amg_serve_pattern(
    indptr: np.ndarray, indices: np.ndarray, n: int
) -> AmgServePattern:
    """Build the values-free two-level hierarchy from a sparsity pattern."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    nnz = indices.shape[0]
    strong = np.ones(nnz, bool)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    strong[rows == indices] = False
    agg, n_agg = aggregate(indptr, indices, strong, n)
    # Galerkin pattern: fine entry (i, j) lands at coarse (agg[i], agg[j])
    crows = agg[rows]
    ccols = agg[indices]
    order = np.lexsort((ccols, crows))
    head = np.ones(nnz, bool)
    head[1:] = (crows[order][1:] != crows[order][:-1]) | (
        ccols[order][1:] != ccols[order][:-1]
    )
    group = np.cumsum(head) - 1  # coarse slot per *sorted* fine entry
    seg = np.empty(nnz, np.int64)
    seg[order] = group
    starts = np.flatnonzero(head)
    c_indptr = np.zeros(n_agg + 1, np.int64)
    c_indptr[1:] = np.cumsum(np.bincount(crows[order][starts], minlength=n_agg))
    c_indices = ccols[order][starts].astype(np.int32)
    diag_slots = np.flatnonzero(rows == indices)
    return AmgServePattern(
        agg=agg,
        n_agg=n_agg,
        coarse_indptr=c_indptr,
        coarse_indices=c_indices,
        seg=seg,
        diag_slots=diag_slots,
        n=n,
    )


def amg_serve_factors(pat: AmgServePattern, values: jax.Array) -> jax.Array:
    """Values-tier refresh: flat row ``[inv_diag | A_c⁻¹.flatten()]``.

    Pure gathers and one segment-sum over pattern-derived maps — no
    re-aggregation, which is what hierarchy reuse in the setup cache means.
    """
    values = jnp.asarray(values)
    diag = values[jnp.asarray(pat.diag_slots)]
    inv_diag = jnp.where(diag != 0, 1.0 / diag, 0.0)
    c_vals = jax.ops.segment_sum(
        values, jnp.asarray(pat.seg),
        num_segments=int(pat.coarse_indices.shape[0]),
    )
    crows = np.repeat(
        np.arange(pat.n_agg, dtype=np.int64), np.diff(pat.coarse_indptr)
    )
    dense = jnp.zeros((pat.n_agg, pat.n_agg), values.dtype)
    dense = dense.at[jnp.asarray(crows), jnp.asarray(pat.coarse_indices)].add(
        c_vals
    )
    c_inv = jnp.linalg.inv(dense.astype(jnp.float32)).astype(values.dtype)
    return jnp.concatenate([inv_diag, c_inv.reshape(-1)])


def batch_amg_apply(
    pat: AmgServePattern, flat: jax.Array, R: jax.Array, omega: float = 2.0 / 3.0
) -> jax.Array:
    """Additive two-level correction over a batch: ``(nb, n) -> (nb, n)``.

    ``flat`` is the ``(nb, flat_len)`` stack of per-system factor rows from
    :func:`amg_serve_factors`.  ``M⁻¹ R = ω·D⁻¹ R + P·A_c⁻¹·Pᵀ R`` with the
    unit P — restriction is a scatter-add over aggregates, interpolation a
    gather; every op reduces row-independently, so a slot's apply matches the
    solo two-level correction bitwise.
    """
    n, nc = pat.n, pat.n_agg
    inv_diag = flat[:, :n]
    c_inv = flat[:, n:].reshape(-1, nc, nc)
    agg = jnp.asarray(pat.agg)
    rc = jnp.zeros((R.shape[0], nc), R.dtype).at[:, agg].add(R)
    xc = jnp.einsum("sc,sdc->sd", rc, c_inv)
    return omega * inv_diag * R + xc[:, agg]
