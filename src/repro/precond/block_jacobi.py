"""Block-Jacobi preconditioner with adaptive per-block storage precision.

The real ``gko::preconditioner::Jacobi``: the matrix's diagonal blocks are
discovered host-side (setup time, like Ginkgo's ``generate``), extracted
format-aware from CSR/ELL/SELL-P/COO/Dense without densifying, explicitly
inverted by a batched Gauss-Jordan with partial pivoting, and applied as a
batched small-matvec through the executor-dispatched ``block_jacobi_apply``
kernel family (reference / xla / pallas spaces, tile geometry from the
launch-configuration table).

Adaptive precision (arXiv:2006.16852 §"adaptive precision block-Jacobi"):
each inverted block is stored in the cheapest precision that preserves the
preconditioner quality.  A per-block 1-norm condition estimate
``kappa = ||B||_1 * ||B^-1||_1`` drives the rule

    store in precision p  iff  kappa * u_p <= tau

with ``u_p`` the unit roundoff of p (fp16: 2^-11, bf16: 2^-8) and ``tau`` the
quality budget; fp16 additionally requires the inverse's entries to fit its
narrow exponent range, with bf16 as the wide-range 16-bit fallback —
otherwise the block stays in full precision.  Storage is *decoupled from
arithmetic*: blocks are grouped into per-precision stacked sub-batches
(static shapes — the apply stays jittable) and upcast to the vector's dtype
inside the apply kernel, so reduced precision only shrinks the memory
footprint and bandwidth, never the arithmetic.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.batch.linop import BatchLinOp
from repro.core import registry
from repro.core.linop import LinOp
from repro.sparse.formats import csr_host_arrays

__all__ = [
    "ADAPTIVE_TAU",
    "BlockJacobi",
    "BatchBlockJacobiPattern",
    "block_jacobi",
    "batch_block_jacobi",
    "batch_block_jacobi_pattern",
    "batch_block_jacobi_blocks",
    "batch_block_jacobi_factors",
    "batch_block_jacobi_from_factors",
    "natural_blocks",
    "uniform_block_ptrs",
    "invert_blocks",
    "select_block_precisions",
    "unit_roundoff",
]

#: default quality budget for the adaptive storage-precision rule.
ADAPTIVE_TAU = 1e-2

#: largest finite fp16 magnitude (bf16 shares fp32's exponent range).
_FP16_MAX = 65504.0


def unit_roundoff(dtype) -> float:
    """Unit roundoff ``u = eps/2`` of a floating storage dtype.

    The quantity the adaptive-precision rule multiplies by the condition
    estimate (``kappa * u_p <= tau``); also what mixed-precision IR
    (:mod:`repro.solvers.ir`) uses to budget its inner-solve tolerance.
    fp16 -> 2^-11, bf16 -> 2^-8, f32 -> 2^-24, f64 -> 2^-53.
    """
    return float(jnp.finfo(jnp.dtype(dtype)).eps) / 2.0

block_jacobi_apply_op = registry.operation(
    "block_jacobi_apply", "batched small-matvec y[b] = inv_blocks[b] @ v[b]"
)

# bind the kernel spaces (reference/xla/pallas) for the apply — the analogue
# of linking the device backends; without this the op has no implementations
import repro.kernels.block_jacobi.ops  # noqa: E402,F401


# =============================================================================
# Block discovery (host-side, setup time)
# =============================================================================


def uniform_block_ptrs(n: int, block_size: int) -> np.ndarray:
    """Uniform partition of [0, n) into ceil(n / block_size) blocks."""
    if block_size <= 0:
        raise ValueError(f"block_size must be positive, got {block_size}")
    return np.append(np.arange(0, n, block_size, dtype=np.int64), n)


def _host_csr(A) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(indptr, indices, values) numpy triplet for any single-system format.

    Delegates to :func:`repro.sparse.formats.csr_host_arrays` — the shared
    setup-time conversion hub (Ginkgo's ``convert_to``); explicit stored
    zeros in padded formats are dropped — they contribute nothing to the
    blocks.
    """
    try:
        return csr_host_arrays(A)
    except TypeError:
        raise TypeError(f"cannot extract diagonal blocks from {type(A)}") from None


def natural_blocks(A, max_block_size: int = 8) -> np.ndarray:
    """Supervariable-agglomeration block discovery (Ginkgo's natural blocks).

    Consecutive rows join one block while they are coupled — row ``i+1`` has a
    nonzero in some column the block already spans (or vice versa) — and the
    block stays within ``max_block_size``.  Returns block pointers ``(nb+1,)``.
    """
    indptr, indices, _ = _host_csr(A)
    n = A.shape[0]
    ptrs = [0]
    start = 0
    for i in range(1, n):
        size = i - start
        if size >= max_block_size:
            ptrs.append(i)
            start = i
            continue
        row = indices[indptr[i] : indptr[i + 1]]
        coupled = bool(((row >= start) & (row < i)).any())
        if not coupled:
            # symmetric check: does any block row reach column i?
            for j in range(start, i):
                cols = indices[indptr[j] : indptr[j + 1]]
                if ((cols == i)).any():
                    coupled = True
                    break
        if not coupled:
            ptrs.append(i)
            start = i
    ptrs.append(n)
    return np.asarray(ptrs, np.int64)


def _extract_blocks_host(A, block_ptrs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Padded diagonal-block tensor ``(nb, bs, bs)`` + per-block sizes.

    Format-aware gather over the sparsity structure — no densification.
    Padding rows/cols carry an identity diagonal; structurally empty rows
    inside a real block also fall back to identity (the regularization the
    scale-only predecessor applied via a diagonal ridge).
    """
    indptr, indices, values = _host_csr(A)
    sizes = np.diff(block_ptrs).astype(np.int64)
    nb = len(sizes)
    bs = int(sizes.max()) if nb else 1
    dtype = values.dtype if values.size else np.float32
    blocks = np.zeros((nb, bs, bs), dtype)
    for b in range(nb):
        lo, hi = int(block_ptrs[b]), int(block_ptrs[b + 1])
        for i in range(lo, hi):
            cols = indices[indptr[i] : indptr[i + 1]]
            vals = values[indptr[i] : indptr[i + 1]]
            keep = (cols >= lo) & (cols < hi)
            blocks[b, i - lo, cols[keep] - lo] = vals[keep]
        # identity padding beyond the block's true size
        for l in range(hi - lo, bs):
            blocks[b, l, l] = 1.0
        # empty-row fallback: a structurally zero row cannot be inverted
        for l in range(hi - lo):
            if not blocks[b, l].any():
                blocks[b, l, l] = 1.0
    return blocks, sizes


# =============================================================================
# Batched Gauss-Jordan inversion (device, jittable)
# =============================================================================


def _gauss_jordan(a: jax.Array):
    """Invert one (bs, bs) block by Gauss-Jordan with partial pivoting.

    Returns ``(inverse, ok)``: ``ok`` is False when some elimination step
    found no usable pivot — the block is rank-deficient and the "inverse"
    (computed with the zero pivot substituted by 1 to keep the loop finite)
    is garbage the caller must discard.
    """
    bs = a.shape[0]
    aug = jnp.concatenate([a, jnp.eye(bs, dtype=a.dtype)], axis=1)

    def step(k, carry):
        aug, ok = carry
        col = aug[:, k]
        eligible = jnp.arange(bs) >= k
        p = jnp.argmax(jnp.where(eligible, jnp.abs(col), -1.0))
        rk, rp = aug[k], aug[p]
        aug = aug.at[k].set(rp).at[p].set(rk)
        piv = aug[k, k]
        ok = ok & (jnp.abs(piv) > 0)
        piv = jnp.where(jnp.abs(piv) > 0, piv, jnp.ones_like(piv))
        row = aug[k] / piv
        aug = aug.at[k].set(row)
        factors = aug[:, k].at[k].set(0.0)
        return aug - factors[:, None] * row[None, :], ok

    aug, ok = jax.lax.fori_loop(0, bs, step, (aug, jnp.asarray(True)))
    return aug[:, bs:], ok


@jax.jit
def invert_blocks(blocks: jax.Array) -> jax.Array:
    """Batched explicit inversion of ``(nb, bs, bs)`` diagonal blocks.

    Gauss-Jordan with partial pivoting (Ginkgo inverts Jacobi blocks the same
    way on GPUs — one subwarp per block).  Rank-deficient blocks (pivot
    exhausted mid-elimination) and any non-finite results degrade to an
    identity fallback rather than silently preconditioning with garbage.
    """
    inv, ok = jax.vmap(_gauss_jordan)(blocks)
    bad = ~ok[:, None, None] | ~jnp.all(
        jnp.isfinite(inv), axis=(-2, -1), keepdims=True
    )
    eye = jnp.eye(blocks.shape[-1], dtype=blocks.dtype)
    return jnp.where(bad, eye, inv)


# =============================================================================
# Adaptive storage-precision selection (host, setup time)
# =============================================================================


def _masked_norm1(t: np.ndarray, sizes: np.ndarray) -> np.ndarray:
    """Per-block 1-norm restricted to each block's true (size, size) corner."""
    nb, bs, _ = t.shape
    idx = np.arange(bs)
    valid = idx[None, :] < sizes[:, None]  # (nb, bs)
    masked = np.abs(t) * valid[:, :, None] * valid[:, None, :]
    return masked.sum(axis=1).max(axis=1)  # max column sum


def select_block_precisions(
    blocks: np.ndarray,
    inv_blocks: np.ndarray,
    sizes: np.ndarray,
    *,
    tau: float = ADAPTIVE_TAU,
) -> np.ndarray:
    """Per-block storage class: 0 = full precision, 1 = bf16, 2 = fp16.

    The cheapest storage whose unit roundoff keeps ``kappa * u_p`` under the
    quality budget; fp16 preferred among the 16-bit classes (more mantissa)
    when the inverse's magnitudes fit its exponent range, bf16 as the
    wide-range fallback.
    """
    kappa = np.maximum(
        _masked_norm1(blocks, sizes) * _masked_norm1(inv_blocks, sizes), 1.0
    )
    maxabs = np.abs(inv_blocks).reshape(len(blocks), -1).max(axis=1)
    fits_fp16 = (kappa * unit_roundoff(jnp.float16) <= tau) & (maxabs < _FP16_MAX)
    fits_bf16 = kappa * unit_roundoff(jnp.bfloat16) <= tau
    return np.where(fits_fp16, 2, np.where(fits_bf16, 1, 0)).astype(np.int32)


def _storage_classes(base_dtype) -> Tuple:
    return (jnp.dtype(base_dtype), jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16))


def _class_ids(adaptive, blocks_np, inv_np, sizes, tau, base_dtype) -> np.ndarray:
    nb = len(blocks_np)
    if adaptive is False or adaptive is None:
        return np.zeros(nb, np.int32)
    if adaptive is True:
        return select_block_precisions(blocks_np, inv_np, sizes, tau=tau)
    # explicit dtype: force every block into that storage class
    forced = jnp.dtype(adaptive)
    for cid, d in enumerate(_storage_classes(base_dtype)):
        if d == forced:
            return np.full(nb, cid, np.int32)
    raise ValueError(
        f"adaptive={adaptive!r} is not a supported storage dtype "
        f"(expected True/False or one of {_storage_classes(base_dtype)})"
    )


# =============================================================================
# The preconditioner object
# =============================================================================


@dataclasses.dataclass(frozen=True, eq=False)
class BlockJacobi(LinOp):
    """Generated block-Jacobi preconditioner LinOp: ``M^{-1} v`` via inverted
    blocks.

    ``inv_blocks`` holds one stacked sub-batch per storage precision present
    (class-ordered, static shapes); ``gather_idx``/``scatter_idx`` are the
    host-precomputed maps between vector rows and (block, local-row) slots in
    that class order.  A LinOp — use directly as a solver's ``M`` or inside
    any operator composition.
    """

    inv_blocks: Tuple[jax.Array, ...]
    gather_idx: jax.Array  # (nb, bs) int32; n = zero-pad slot
    scatter_idx: jax.Array  # (n,) int32 into the flat (nb*bs,) apply output
    n: int
    block_size: int  # bs (padded/max block size)
    num_blocks: int
    executor: Optional[object] = None

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.inv_blocks[0].dtype if self.inv_blocks else None

    @property
    def storage_dtypes(self) -> Tuple[str, ...]:
        return tuple(str(t.dtype) for t in self.inv_blocks)

    @property
    def precision_counts(self) -> Tuple[Tuple[str, int], ...]:
        return tuple((str(t.dtype), int(t.shape[0])) for t in self.inv_blocks)

    @property
    def storage_bytes(self) -> int:
        """Bytes held by the inverted-block storage (the adaptive metric)."""
        return sum(int(t.size) * t.dtype.itemsize for t in self.inv_blocks)

    def _apply(self, v: jax.Array, executor) -> jax.Array:
        if not self.inv_blocks:  # degenerate 0-row system
            return v
        vpad = jnp.concatenate([v, jnp.zeros((1,), v.dtype)])
        vp = vpad[self.gather_idx]  # (nb, bs), class-ordered
        outs = []
        off = 0
        for t in self.inv_blocks:
            nbc = t.shape[0]
            outs.append(
                block_jacobi_apply_op(
                    t, jax.lax.slice_in_dim(vp, off, off + nbc), executor=executor
                )
            )
            off += nbc
        y = jnp.concatenate(outs, axis=0).reshape(-1)
        return y[self.scatter_idx]

    def transpose(self) -> "BlockJacobi":
        """``M^{-T}``: the same block structure with each inverted block
        transposed — ``(blockdiag(B_i)^{-1})^T = blockdiag(B_i^{-T})``."""
        return dataclasses.replace(
            self,
            inv_blocks=tuple(jnp.swapaxes(t, -1, -2) for t in self.inv_blocks),
        )


def block_jacobi(
    A,
    block_size: Optional[int] = None,
    *,
    blocks: Optional[Sequence[int]] = None,
    adaptive: Union[bool, str, jnp.dtype] = False,
    tau: float = ADAPTIVE_TAU,
    executor=None,
) -> BlockJacobi:
    """Generate the block-Jacobi preconditioner for ``A``.

    ``blocks`` pins explicit block pointers (e.g. from :func:`natural_blocks`);
    otherwise the partition is uniform with ``block_size`` (default: the
    executor's cooperative-subgroup width, Ginkgo's subwarp-tuned storage).
    ``adaptive=True`` turns on per-block storage-precision selection;
    a dtype forces every block into that storage.
    """
    n = A.shape[0]
    if blocks is not None:
        block_ptrs = np.asarray(blocks, np.int64)
        if block_ptrs[0] != 0 or block_ptrs[-1] != n or (np.diff(block_ptrs) <= 0).any():
            raise ValueError(
                f"block pointers must cover [0, {n}) with positive sizes, "
                f"got {block_ptrs}"
            )
    else:
        if block_size is None:
            from repro.core.executor import current_executor

            ex = executor if executor is not None else current_executor()
            block_size = ex.hw.subgroup_size
        block_ptrs = uniform_block_ptrs(n, block_size)

    blocks_np, sizes = _extract_blocks_host(A, block_ptrs)
    nb, bs = blocks_np.shape[0], blocks_np.shape[1]
    inv = invert_blocks(jnp.asarray(blocks_np))
    inv_np = np.asarray(inv)
    base_dtype = inv.dtype

    class_id = _class_ids(adaptive, blocks_np, inv_np, sizes, tau, base_dtype)
    order = np.argsort(class_id, kind="stable")

    # gather/scatter maps in class order (host-precomputed, device gathers)
    gather = np.full((nb, bs), n, np.int32)
    scatter = np.zeros(n, np.int32)
    for pos, b in enumerate(order):
        lo, size = int(block_ptrs[b]), int(sizes[b])
        gather[pos, :size] = np.arange(lo, lo + size, dtype=np.int32)
        scatter[lo : lo + size] = pos * bs + np.arange(size, dtype=np.int32)

    classes = _storage_classes(base_dtype)
    tensors = []
    sorted_ids = class_id[order]
    for cid, dtype in enumerate(classes):
        members = order[sorted_ids == cid]
        if len(members) == 0:
            continue
        tensors.append(jnp.asarray(inv_np[members]).astype(dtype))

    return BlockJacobi(
        inv_blocks=tuple(tensors),
        gather_idx=jnp.asarray(gather),
        scatter_idx=jnp.asarray(scatter),
        n=n,
        block_size=bs,
        num_blocks=nb,
        executor=executor,
    )


# =============================================================================
# Batched variant — gko::batch::preconditioner::Jacobi with bs > 1
# =============================================================================


@dataclasses.dataclass(frozen=True, eq=False)
class BatchBlockJacobi(BatchLinOp):
    """Per-system block-Jacobi over a shared-pattern batch — a BatchLinOp.

    Blocks of all systems are flattened into one class-ordered stack (the
    per-precision sub-batches span the whole batch), so the apply is the same
    executor-dispatched batched small-matvec as the single-system path.
    """

    inv_blocks: Tuple[jax.Array, ...]  # per class, (count, bs, bs)
    perm: jax.Array  # (ns*nblocks,) int32 flat (system, block) -> class order
    inv_perm: jax.Array  # inverse permutation
    gather_idx: jax.Array  # (nblocks, bs) int32 into a padded system row
    n: int
    num_blocks: int  # per system
    block_size: int
    executor: Optional[object] = None

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.inv_blocks[0].dtype if self.inv_blocks else None

    @property
    def storage_bytes(self) -> int:
        return sum(int(t.size) * t.dtype.itemsize for t in self.inv_blocks)

    @property
    def precision_counts(self) -> Tuple[Tuple[str, int], ...]:
        return tuple((str(t.dtype), int(t.shape[0])) for t in self.inv_blocks)

    def _apply(self, V: jax.Array, executor) -> jax.Array:
        ns = V.shape[0]
        Vpad = jnp.concatenate([V, jnp.zeros((ns, 1), V.dtype)], axis=1)
        vp = Vpad[:, self.gather_idx]  # (ns, nblocks, bs)
        flat = vp.reshape(ns * self.num_blocks, self.block_size)[self.perm]
        outs = []
        off = 0
        for t in self.inv_blocks:
            nbc = t.shape[0]
            outs.append(
                block_jacobi_apply_op(
                    t,
                    jax.lax.slice_in_dim(flat, off, off + nbc),
                    executor=executor,
                )
            )
            off += nbc
        y = jnp.concatenate(outs, axis=0)[self.inv_perm]
        y = y.reshape(ns, self.num_blocks * self.block_size)
        return y[:, : self.n]


def _batch_slot_table(A, block_ptrs: np.ndarray, bs: int) -> np.ndarray:
    """(nblocks, bs, bs) table of flat value slots (+1; 0 = structurally absent).

    Built once from the shared sparsity pattern — per-system block extraction
    is then a single gather over each system's value row.
    """
    from repro.batch.formats import BatchCsr, BatchEll

    nb = len(block_ptrs) - 1
    table = np.zeros((nb, bs, bs), np.int64)
    if isinstance(A, BatchCsr):
        indptr = np.asarray(A.indptr)
        indices = np.asarray(A.indices)
        for b in range(nb):
            lo, hi = int(block_ptrs[b]), int(block_ptrs[b + 1])
            for i in range(lo, hi):
                for t in range(int(indptr[i]), int(indptr[i + 1])):
                    j = int(indices[t])
                    if lo <= j < hi:
                        table[b, i - lo, j - lo] = t + 1
        return table
    if isinstance(A, BatchEll):
        cols = np.asarray(A.col_idx)  # (m, k)
        m, k = cols.shape
        for b in range(nb):
            lo, hi = int(block_ptrs[b]), int(block_ptrs[b + 1])
            for i in range(lo, min(hi, m)):
                for q in range(k):
                    j = int(cols[i, q])
                    # ELL padding is (col 0, value 0) at the row's tail; CSR
                    # column order means a *real* col-0 entry sits at q == 0,
                    # so any later col-0 slot is padding and must not
                    # overwrite the real slot in the table
                    if j == 0 and q > 0:
                        continue
                    if lo <= j < hi:
                        table[b, i - lo, j - lo] = i * k + q + 1
        return table
    raise TypeError(f"unknown batched format {type(A)}")


# -----------------------------------------------------------------------------
# Generate/apply split (Ginkgo's generate, factored into two tiers)
#
# Tier 1 — *pattern*: everything derivable from the shared sparsity structure
# alone (block pointers, value-slot table, gather map, padding identity).
# Tier 2 — *values*: the per-system numeric work (block gather + batched
# Gauss-Jordan inversion).  A pattern-keyed setup cache stores tier 1 once per
# sparsity pattern and tier 2 once per value set; repeat-pattern traffic pays
# only tier 2, repeat-values traffic pays neither.
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class BatchBlockJacobiPattern:
    """Values-independent half of batched block-Jacobi generation.

    Built once per sparsity pattern; combined with any ``(ns, nnz)`` value
    tensor sharing that pattern it yields the inverted factors via
    :func:`batch_block_jacobi_factors`.
    """

    block_ptrs: np.ndarray  # (nblocks+1,) host-side
    sizes: np.ndarray  # (nblocks,) true block sizes
    slot_table: np.ndarray  # (nblocks, bs, bs) flat value slots (+1; 0 absent)
    pad_add: jax.Array  # (nblocks, bs, bs) identity padding addend
    gather_idx: jax.Array  # (nblocks, bs) int32 into a padded system row
    n: int
    num_blocks: int
    block_size: int

    @property
    def storage_bytes(self) -> int:
        """Host + device bytes the cached pattern tier holds."""
        return int(
            self.block_ptrs.nbytes + self.sizes.nbytes + self.slot_table.nbytes
            + self.pad_add.size * self.pad_add.dtype.itemsize
            + self.gather_idx.size * self.gather_idx.dtype.itemsize
        )


def batch_block_jacobi_pattern(
    A, block_size: Optional[int] = None, *, executor=None
) -> BatchBlockJacobiPattern:
    """Pattern-tier generation: block discovery + slot tables, no values read."""
    n = A.shape[0]
    if block_size is None:
        from repro.core.executor import current_executor

        ex = executor if executor is not None else current_executor()
        block_size = ex.hw.subgroup_size
    block_ptrs = uniform_block_ptrs(n, block_size)
    sizes = np.diff(block_ptrs).astype(np.int64)
    nb = len(sizes)
    bs = int(sizes.max()) if nb else 1

    table = _batch_slot_table(A, block_ptrs, bs)

    # identity on padding rows/cols beyond each block's true size
    pad_diag = np.zeros((nb, bs), np.float32)
    idx = np.arange(bs)
    pad_diag[idx[None, :] >= sizes[:, None]] = 1.0
    pad_add = jnp.asarray(pad_diag[:, :, None] * np.eye(bs))

    gather = np.full((nb, bs), n, np.int32)
    for b in range(nb):
        lo, size = int(block_ptrs[b]), int(sizes[b])
        gather[b, :size] = np.arange(lo, lo + size, dtype=np.int32)

    return BatchBlockJacobiPattern(
        block_ptrs=block_ptrs,
        sizes=sizes,
        slot_table=table,
        pad_add=pad_add,
        gather_idx=jnp.asarray(gather),
        n=n,
        num_blocks=nb,
        block_size=bs,
    )


def batch_block_jacobi_blocks(
    values: jax.Array, pattern: BatchBlockJacobiPattern
) -> jax.Array:
    """Per-system diagonal blocks ``(ns*nblocks, bs, bs)`` gathered from a
    ``(ns, nnz_flat)`` value tensor through the pattern's slot table."""
    ns = values.shape[0]
    flat_vals = values.reshape(ns, -1)
    nb, bs = pattern.num_blocks, pattern.block_size
    padded = jnp.concatenate(
        [jnp.zeros((ns, 1), flat_vals.dtype), flat_vals], axis=1
    )
    blocks = padded[:, jnp.asarray(pattern.slot_table.reshape(-1))].reshape(
        ns, nb, bs, bs
    )
    blocks = blocks + pattern.pad_add[None]
    # per-system empty-row fallback: a block row that gathered only zeros
    # (structurally empty row, or a system whose stored entries there are all
    # zero) gets an identity diagonal — the same rule the single-system
    # extraction applies host-side.  Structural detection via the slot table
    # is not enough: an ELL padding slot at q == 0 is indistinguishable from
    # a real col-0 entry, so the check must look at the gathered values.
    row_zero = jnp.all(blocks == 0, axis=3)  # (ns, nb, bs)
    eye = jnp.asarray(np.eye(bs, dtype=np.float32))
    blocks = blocks + row_zero[..., None] * eye
    return blocks.reshape(ns * nb, bs, bs)


def batch_block_jacobi_factors(
    values: jax.Array, pattern: BatchBlockJacobiPattern
) -> jax.Array:
    """Values-tier generation: gather blocks and invert them in one batch.

    The expensive numeric half of generate — exactly what a setup cache
    stores per (pattern, values) pair.
    """
    return invert_blocks(batch_block_jacobi_blocks(values, pattern))


def batch_block_jacobi_from_factors(
    inv: jax.Array,
    ns: int,
    pattern: BatchBlockJacobiPattern,
    *,
    executor=None,
) -> BatchBlockJacobi:
    """Assemble the BatchLinOp from precomputed inverted factors.

    Single storage class, identity permutation — bitwise the same operator
    :func:`batch_block_jacobi` builds with ``adaptive=False``, but without
    re-running discovery or inversion (the cache-hit apply path).
    """
    ar = jnp.arange(ns * pattern.num_blocks, dtype=jnp.int32)
    return BatchBlockJacobi(
        inv_blocks=(inv,),
        perm=ar,
        inv_perm=ar,
        gather_idx=pattern.gather_idx,
        n=pattern.n,
        num_blocks=pattern.num_blocks,
        block_size=pattern.block_size,
        executor=executor,
    )


def batch_block_jacobi(
    A,
    block_size: Optional[int] = None,
    *,
    adaptive: Union[bool, str, jnp.dtype] = False,
    tau: float = ADAPTIVE_TAU,
    executor=None,
) -> BatchBlockJacobi:
    """Per-system block-Jacobi for a shared-pattern batched matrix.

    Composes the two generation tiers (pattern, then values); the serve-path
    setup cache calls the tiers separately and reuses their products.
    """
    ns = A.num_batch
    pattern = batch_block_jacobi_pattern(A, block_size, executor=executor)
    nb, bs = pattern.num_blocks, pattern.block_size
    flat_blocks = batch_block_jacobi_blocks(A.values.reshape(ns, -1), pattern)
    inv = invert_blocks(flat_blocks)
    if adaptive is False or adaptive is None:
        return batch_block_jacobi_from_factors(inv, ns, pattern,
                                               executor=executor)

    inv_np = np.asarray(inv)
    base_dtype = inv.dtype
    flat_sizes = np.tile(pattern.sizes, ns)
    class_id = _class_ids(
        adaptive, np.asarray(flat_blocks), inv_np, flat_sizes, tau, base_dtype
    )
    order = np.argsort(class_id, kind="stable")
    inv_perm = np.empty_like(order)
    inv_perm[order] = np.arange(len(order))

    classes = _storage_classes(base_dtype)
    tensors = []
    sorted_ids = class_id[order]
    for cid, dtype in enumerate(classes):
        members = order[sorted_ids == cid]
        if len(members) == 0:
            continue
        tensors.append(jnp.asarray(inv_np[members]).astype(dtype))

    return BatchBlockJacobi(
        inv_blocks=tuple(tensors),
        perm=jnp.asarray(order.astype(np.int32)),
        inv_perm=jnp.asarray(inv_perm.astype(np.int32)),
        gather_idx=pattern.gather_idx,
        n=pattern.n,
        num_blocks=nb,
        block_size=bs,
        executor=executor,
    )
