"""BatchLinOp — the batched analogue of the LinOp hierarchy.

gko::batch::BatchLinOp: one operator whose apply maps a whole batch of
right-hand sides ``X (nb, n)`` at once, with every system independent.  The
combinators are the core ones specialized to the batch calling convention —
``shape`` stays the *per-system* ``(m, n)`` (matching
:class:`~repro.batch.formats.BatchCsr`), and ``num_batch`` reports the batch
extent where one is known.

Because the core combinators' apply logic is already shape-agnostic
(compose right-to-left, sum termwise, scale elementwise), the batch variants
inherit it and only add the batch face; the point of the distinct classes is
the type marker the batched solvers accept (a plain LinOp is *not* a valid
batched operator — its apply contract is a single vector).
"""

from __future__ import annotations

from typing import Optional

from repro.core.linop import (
    Composition,
    Identity,
    LinOp,
    MatrixFreeOp,
    ScaledIdentity,
    Sum,
)

__all__ = [
    "BatchLinOp",
    "BatchComposition",
    "BatchSum",
    "BatchScaledIdentity",
    "BatchMatrixFreeOp",
    "BatchIdentity",
]


class BatchLinOp(LinOp):
    """Marker + interface base for batched operators.

    ``apply(X)`` takes and returns ``(nb, n)`` batches; ``shape`` is the
    per-system ``(m, n)``.
    """

    @property
    def num_batch(self) -> Optional[int]:
        return None

    # the combinator sugar must stay inside the batched hierarchy — a plain
    # Sum/Composition over batched operands would not be a valid BatchLinOp
    def __matmul__(self, other):
        if isinstance(other, BatchLinOp):
            return BatchComposition(self, other)
        return NotImplemented

    def __add__(self, other):
        if isinstance(other, BatchLinOp):
            return BatchSum(self, other)
        return NotImplemented


def _first_num_batch(ops) -> Optional[int]:
    for op in ops:
        nb = getattr(op, "num_batch", None)
        if nb is not None:
            return nb
    return None


class BatchComposition(Composition, BatchLinOp):
    """``(A o B o ...) X`` applied right to left, per system."""

    @property
    def num_batch(self) -> Optional[int]:
        return _first_num_batch(self.ops)


class BatchSum(Sum, BatchLinOp):
    """``(A + B + ...) X`` termwise, per system."""

    @property
    def num_batch(self) -> Optional[int]:
        return _first_num_batch(self.ops)


class BatchScaledIdentity(ScaledIdentity, BatchLinOp):
    """``sigma * I`` on every system — the batched shift building block."""


class BatchMatrixFreeOp(MatrixFreeOp, BatchLinOp):
    """User-supplied jittable batched apply ``X (nb, n) -> Y (nb, m)``."""

    def __init__(self, matvec, shape=None, dtype=None, num_batch=None, executor=None):
        super().__init__(matvec, shape=shape, dtype=dtype, executor=executor)
        self._num_batch = num_batch

    @property
    def num_batch(self) -> Optional[int]:
        return self._num_batch


class BatchIdentity(Identity, BatchLinOp):
    """The batched identity — also the batched identity preconditioner
    (``storage_bytes == 0``)."""
