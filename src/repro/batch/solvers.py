"""Masked batched Krylov solvers — gko::batch::solver::{Cg, Bicgstab}.

One launch solves the whole batch: every iteration advances all systems inside
a single ``lax.while_loop``, a per-system convergence mask freezes systems
whose residual is already under their threshold (their state is carried
through unchanged by ``where``), and the loop exits when every system has
converged or the iteration cap hits.  This is Ginkgo's batched-solver design:
thousands of small independent systems, one kernel launch, individual
stopping — not a fixed iteration count imposed batch-wide.

Every vector operation goes through the executor-dispatched batched BLAS-1 /
SpMV operations (:mod:`repro.batch.ops`), so one solver source serves the
reference / xla / pallas kernel spaces unchanged.

Per-system iteration counts and converged flags are reported in
:class:`BatchSolveResult` and match what a loop of single-system solves
produces: a system is counted as iterating exactly while its own residual
exceeds its own threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.batch import ops
from repro.batch.formats import BatchCsr, BatchEll
from repro.batch.linop import BatchIdentity, BatchLinOp
from repro.core import registry
from repro.observability import convergence
from repro.solvers.common import Stop
from repro.sparse.ops import _csr_row_ids

__all__ = [
    "BatchSolveResult",
    "BatchScalarJacobi",
    "batch_cg",
    "batch_bicgstab",
    "batch_jacobi_preconditioner",
    "batch_block_jacobi_preconditioner",
    "batch_identity_preconditioner",
]

BatchMatrixLike = Union[
    BatchLinOp, BatchCsr, BatchEll, Callable[[jax.Array], jax.Array]
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchSolveResult:
    """Per-system outcome of one batched solve.

    Everything is per-system: ``x (nb, n)``, ``iterations (nb,) int32``,
    ``residual_norms (nb,)``, ``converged (nb,) bool``.
    """

    x: jax.Array
    iterations: jax.Array
    residual_norms: jax.Array
    converged: jax.Array
    #: per-iteration residual norms, shape ``(cap, nb)``, when the solve ran
    #: with ``history=`` (NaN in unfilled slots); None otherwise.
    history: Optional[jax.Array] = None

    @property
    def num_batch(self) -> int:
        return self.x.shape[0]


def _apply(A: BatchMatrixLike, X: jax.Array, executor) -> jax.Array:
    if isinstance(A, BatchLinOp):
        # formats and composed operators alike — executor threads down
        return A.apply(X, executor=executor)
    if callable(A) and not hasattr(A, "values"):
        return A(X)
    return ops.apply_batch(A, X, executor=executor)


def _setup(A, B, X0, M, executor=None, precond_opts=None):
    X = jnp.zeros_like(B) if X0 is None else X0
    if isinstance(M, str):
        opts = dict(precond_opts or {})
        if M == "identity":
            if opts:
                raise ValueError(
                    f"identity preconditioner takes no options, got {sorted(opts)}"
                )
            M = batch_identity_preconditioner
        elif M == "jacobi":
            M = batch_jacobi_preconditioner(A, executor=executor, **opts)
        elif M == "block_jacobi":
            M = batch_block_jacobi_preconditioner(A, executor=executor, **opts)
        else:
            raise KeyError(
                f"unknown batched preconditioner kind {M!r}; known: "
                "identity, jacobi, block_jacobi"
            )
    elif precond_opts:
        raise ValueError("precond_opts is only meaningful when M is a kind name")
    M = M or batch_identity_preconditioner
    return X, M


# =============================================================================
# Preconditioners
# =============================================================================

batch_extract_diag_op = registry.operation(
    "batch_extract_diagonal", "per-system diagonals of a batched matrix"
)


@batch_extract_diag_op.register("reference")
def _batch_extract_diag_ref(ex, A):
    if isinstance(A, BatchCsr):
        rows = _csr_row_ids(A.system(0))
        n = min(A.shape)
        hit = (rows == A.indices) & (rows < n)
        idx = jnp.where(hit, rows, 0)
        return jnp.stack(
            [
                jnp.zeros(n, A.dtype).at[idx].add(jnp.where(hit, A.values[b], 0.0))
                for b in range(A.num_batch)
            ]
        )
    if isinstance(A, BatchEll):
        m, k = A.col_idx.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, k))
        hit = A.col_idx == rows
        n = min(A.shape)
        return jnp.stack(
            [
                jnp.sum(jnp.where(hit, A.values[b], 0.0), axis=1)[:n]
                for b in range(A.num_batch)
            ]
        )
    raise TypeError(f"unknown batched format {type(A)}")


@batch_extract_diag_op.register("xla")
def _batch_extract_diag_xla(ex, A):
    if isinstance(A, BatchCsr):
        rows = _csr_row_ids(A.system(0))
        n = min(A.shape)
        hit = (rows == A.indices) & (rows < n)
        contrib = jnp.where(hit[None, :], A.values, 0.0)  # (nb, nnz)
        seg = jax.vmap(
            lambda c: jax.ops.segment_sum(
                c, jnp.where(hit, rows, n), num_segments=n + 1
            )[:n]
        )
        return seg(contrib)
    if isinstance(A, BatchEll):
        m, k = A.col_idx.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, k))
        hit = (A.col_idx == rows)[None, :, :]
        n = min(A.shape)
        return jnp.sum(jnp.where(hit, A.values, 0.0), axis=2)[:, :n]
    raise TypeError(f"unknown batched format {type(A)}")


class BatchScalarJacobi(BatchLinOp):
    """Per-system scalar Jacobi BatchLinOp: ``M^{-1} V[b] = inv_diag[b] * V[b]``."""

    def __init__(self, inv_diag: jax.Array):
        self.inv_diag = inv_diag  # (nb, n)

    @property
    def shape(self):
        n = self.inv_diag.shape[1]
        return (n, n)

    @property
    def num_batch(self) -> int:
        return self.inv_diag.shape[0]

    @property
    def dtype(self):
        return self.inv_diag.dtype

    @property
    def storage_bytes(self) -> int:
        return int(self.inv_diag.size) * self.inv_diag.dtype.itemsize

    def _apply(self, V: jax.Array, executor) -> jax.Array:
        return self.inv_diag.astype(V.dtype) * V


def batch_jacobi_preconditioner(
    A: BatchMatrixLike, executor=None
) -> BatchScalarJacobi:
    """Per-system scalar Jacobi: ``M^{-1} V[b] = V[b] / diag(A[b])``.

    The batched analogue of ``gko::batch::preconditioner::Jacobi`` (bs=1):
    one inverse-diagonal tensor ``(nb, n)``, one elementwise multiply per
    application — no cross-system coupling.  Returns a BatchLinOp reporting
    ``storage_bytes``.
    """
    d = batch_extract_diag_op(A, executor=executor)
    safe = jnp.where(jnp.abs(d) > 0, d, jnp.ones_like(d))
    inv = jnp.where(jnp.abs(d) > 0, 1.0 / safe, jnp.ones_like(d))
    return BatchScalarJacobi(inv)


def batch_block_jacobi_preconditioner(
    A: BatchMatrixLike,
    block_size: Optional[int] = None,
    *,
    adaptive=False,
    tau: Optional[float] = None,
    executor=None,
) -> Callable:
    """Per-system block-Jacobi — ``gko::batch::preconditioner::Jacobi``, bs > 1.

    Delegates to :func:`repro.precond.batch_block_jacobi`: the shared sparsity
    pattern yields one host-side slot table, per-system blocks are gathered
    and Gauss-Jordan-inverted in one batch, and ``adaptive`` selects a storage
    precision per (system, block) with the same condition-estimate rule as the
    single-system path (per-precision sub-batches span the whole batch).  The
    returned object is callable on ``(nb, n)`` and reports ``storage_bytes``.
    """
    from repro.precond import batch_block_jacobi

    return batch_block_jacobi(
        A,
        block_size,
        adaptive=adaptive,
        executor=executor,
        **({} if tau is None else {"tau": tau}),
    )


#: the batched identity preconditioner — a real BatchLinOp with
#: ``storage_bytes == 0``; remains callable (``batch_identity_preconditioner(V)
#: -> V``) for historical call sites.
batch_identity_preconditioner = BatchIdentity()


# =============================================================================
# Batched CG
# =============================================================================


def batch_cg(
    A: BatchMatrixLike,
    B: jax.Array,
    X0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Union[Callable, str]] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    history=None,
) -> BatchSolveResult:
    """Batched preconditioned CG (SPD systems), per-system stopping.

    ``B`` is ``(nb, n)`` — one right-hand side per system.  Converged systems
    freeze (their state rides through the loop unchanged) while the rest keep
    iterating; the loop exits when all have converged or ``max_iters`` hits.
    """
    ex = executor
    X, M = _setup(A, B, X0, M, ex, precond_opts)
    nb = B.shape[0]
    bnorm = ops.batch_norm2(B, executor=ex)
    thresh = stop.threshold(bnorm)  # (nb,)

    R = B - _apply(A, X, ex)
    Z = M(R)
    P = Z
    rz = ops.batch_dot(R, Z, executor=ex)
    rnorm = ops.batch_norm2(R, executor=ex)
    iters = jnp.zeros(nb, jnp.int32)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             batch=nb, dtype=rnorm.dtype)

    def cond(state):
        k, rnorm = state[6], state[7]
        return jnp.any(rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        X, R, Z, P, rz, iters, k, rnorm, hist = state
        active = rnorm > thresh  # (nb,)
        a2 = active[:, None]
        AP = _apply(A, P, ex)
        pAp = ops.batch_dot(P, AP, executor=ex)
        # guards only matter for frozen systems (whose update is discarded);
        # active SPD systems have pAp > 0 and rz > 0
        alpha = rz / jnp.where(pAp == 0, 1.0, pAp)
        Xn = ops.batch_axpy(alpha, P, X, executor=ex)
        # fused residual update + per-system ‖R‖² — the convergence-mask
        # reduction rides the same pass as the axpy (shared with single CG)
        Rn, rr = ops.batch_axpy_norm(-alpha, AP, R, executor=ex)
        Zn = M(Rn)
        rz_new = ops.batch_dot(Rn, Zn, executor=ex)
        beta = rz_new / jnp.where(rz == 0, 1.0, rz)
        Pn = ops.batch_axpy(beta, P, Zn, executor=ex)
        X = jnp.where(a2, Xn, X)
        R = jnp.where(a2, Rn, R)
        Z = jnp.where(a2, Zn, Z)
        P = jnp.where(a2, Pn, P)
        rz = jnp.where(active, rz_new, rz)
        rnorm = jnp.where(active, jnp.sqrt(rr), rnorm)
        iters = iters + active.astype(jnp.int32)
        # frozen systems keep re-recording their final norm — the history row
        # at iteration k is the batch's residual state after k+1 sweeps
        return (X, R, Z, P, rz, iters, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (X, R, Z, P, rz, iters, jnp.int32(0), rnorm, hist0)
    (X, R, Z, P, rz, iters, k, rnorm, hist) = jax.lax.while_loop(
        cond, body, state
    )
    return BatchSolveResult(X, iters, rnorm, rnorm <= thresh,
                            convergence.finalize(hist))


# =============================================================================
# Batched BiCGSTAB
# =============================================================================


def batch_bicgstab(
    A: BatchMatrixLike,
    B: jax.Array,
    X0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Union[Callable, str]] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    history=None,
) -> BatchSolveResult:
    """Batched preconditioned BiCGSTAB (general systems), per-system stopping."""
    ex = executor
    X, M = _setup(A, B, X0, M, ex, precond_opts)
    nb = B.shape[0]
    bnorm = ops.batch_norm2(B, executor=ex)
    thresh = stop.threshold(bnorm)
    eps = jnp.asarray(1e-30, B.dtype)

    R = B - _apply(A, X, ex)
    R_hat = R
    rho = ops.batch_dot(R_hat, R, executor=ex)
    P = R
    rnorm = ops.batch_norm2(R, executor=ex)
    iters = jnp.zeros(nb, jnp.int32)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             batch=nb, dtype=rnorm.dtype)

    def cond(state):
        k, rnorm = state[5], state[6]
        return jnp.any(rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        X, R, P, rho, iters, k, rnorm, hist = state
        active = rnorm > thresh
        a2 = active[:, None]
        P_hat = M(P)
        V = _apply(A, P_hat, ex)
        alpha = rho / (ops.batch_dot(R_hat, V, executor=ex) + eps)
        S = ops.batch_axpy(-alpha, V, R, executor=ex)
        S_hat = M(S)
        T = _apply(A, S_hat, ex)
        omega = ops.batch_dot(T, S, executor=ex) / (
            ops.batch_dot(T, T, executor=ex) + eps
        )
        Xn = X + alpha[:, None] * P_hat + omega[:, None] * S_hat
        # fused residual update + per-system ‖R‖² (same op as single BiCGSTAB)
        Rn, rr = ops.batch_axpy_norm(-omega, T, S, executor=ex)
        rho_new = ops.batch_dot(R_hat, Rn, executor=ex)
        beta = (rho_new / (rho + eps)) * (alpha / (omega + eps))
        Pn = Rn + beta[:, None] * (P - omega[:, None] * V)
        X = jnp.where(a2, Xn, X)
        R = jnp.where(a2, Rn, R)
        P = jnp.where(a2, Pn, P)
        rho = jnp.where(active, rho_new, rho)
        rnorm = jnp.where(active, jnp.sqrt(rr), rnorm)
        iters = iters + active.astype(jnp.int32)
        return (X, R, P, rho, iters, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (X, R, P, rho, iters, jnp.int32(0), rnorm, hist0)
    X, R, P, rho, iters, k, rnorm, hist = jax.lax.while_loop(cond, body, state)
    return BatchSolveResult(X, iters, rnorm, rnorm <= thresh,
                            convergence.finalize(hist))
