"""Masked batched Krylov solvers — gko::batch::solver::{Cg, Bicgstab}.

One launch solves the whole batch: every iteration advances all systems inside
a single ``lax.while_loop``, a per-system convergence mask freezes systems
whose residual is already under their threshold (their state is carried
through unchanged by ``where``), and the loop exits when every system has
converged or the iteration cap hits.  This is Ginkgo's batched-solver design:
thousands of small independent systems, one kernel launch, individual
stopping — not a fixed iteration count imposed batch-wide.

Every vector operation goes through the executor-dispatched batched BLAS-1 /
SpMV operations (:mod:`repro.batch.ops`), so one solver source serves the
reference / xla / pallas kernel spaces unchanged.

Per-system iteration counts and converged flags are reported in
:class:`BatchSolveResult` and match what a loop of single-system solves
produces: a system is counted as iterating exactly while its own residual
exceeds its own threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.batch import ops
from repro.batch.formats import BatchCsr, BatchEll
from repro.batch.linop import BatchIdentity, BatchLinOp
from repro.core import registry
from repro.observability import convergence
from repro.solvers.common import Stop
from repro.sparse.ops import _csr_row_ids

__all__ = [
    "BatchSolveResult",
    "BatchCgState",
    "BatchBicgstabState",
    "BatchScalarJacobi",
    "batch_cg",
    "batch_cg_init",
    "batch_cg_advance",
    "batch_bicgstab",
    "batch_bicgstab_init",
    "batch_bicgstab_advance",
    "batch_jacobi_preconditioner",
    "batch_block_jacobi_preconditioner",
    "batch_identity_preconditioner",
]

BatchMatrixLike = Union[
    BatchLinOp, BatchCsr, BatchEll, Callable[[jax.Array], jax.Array]
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchSolveResult:
    """Per-system outcome of one batched solve.

    Everything is per-system: ``x (nb, n)``, ``iterations (nb,) int32``,
    ``residual_norms (nb,)``, ``converged (nb,) bool``.
    """

    x: jax.Array
    iterations: jax.Array
    residual_norms: jax.Array
    converged: jax.Array
    #: per-iteration residual norms, shape ``(cap, nb)``, when the solve ran
    #: with ``history=`` (NaN in unfilled slots); None otherwise.
    history: Optional[jax.Array] = None

    @property
    def num_batch(self) -> int:
        return self.x.shape[0]


def _apply(A: BatchMatrixLike, X: jax.Array, executor) -> jax.Array:
    if isinstance(A, BatchLinOp):
        # formats and composed operators alike — executor threads down
        return A.apply(X, executor=executor)
    if callable(A) and not hasattr(A, "values"):
        return A(X)
    return ops.apply_batch(A, X, executor=executor)


def _setup(A, B, X0, M, executor=None, precond_opts=None):
    X = jnp.zeros_like(B) if X0 is None else X0
    if isinstance(M, str):
        opts = dict(precond_opts or {})
        if M == "identity":
            if opts:
                raise ValueError(
                    f"identity preconditioner takes no options, got {sorted(opts)}"
                )
            M = batch_identity_preconditioner
        elif M == "jacobi":
            M = batch_jacobi_preconditioner(A, executor=executor, **opts)
        elif M == "block_jacobi":
            M = batch_block_jacobi_preconditioner(A, executor=executor, **opts)
        else:
            raise KeyError(
                f"unknown batched preconditioner kind {M!r}; known: "
                "identity, jacobi, block_jacobi"
            )
    elif precond_opts:
        raise ValueError("precond_opts is only meaningful when M is a kind name")
    M = M or batch_identity_preconditioner
    return X, M


# =============================================================================
# Preconditioners
# =============================================================================

batch_extract_diag_op = registry.operation(
    "batch_extract_diagonal", "per-system diagonals of a batched matrix"
)


@batch_extract_diag_op.register("reference")
def _batch_extract_diag_ref(ex, A):
    if isinstance(A, BatchCsr):
        rows = _csr_row_ids(A.system(0))
        n = min(A.shape)
        hit = (rows == A.indices) & (rows < n)
        idx = jnp.where(hit, rows, 0)
        return jnp.stack(
            [
                jnp.zeros(n, A.dtype).at[idx].add(jnp.where(hit, A.values[b], 0.0))
                for b in range(A.num_batch)
            ]
        )
    if isinstance(A, BatchEll):
        m, k = A.col_idx.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, k))
        hit = A.col_idx == rows
        n = min(A.shape)
        return jnp.stack(
            [
                jnp.sum(jnp.where(hit, A.values[b], 0.0), axis=1)[:n]
                for b in range(A.num_batch)
            ]
        )
    raise TypeError(f"unknown batched format {type(A)}")


@batch_extract_diag_op.register("xla")
def _batch_extract_diag_xla(ex, A):
    if isinstance(A, BatchCsr):
        rows = _csr_row_ids(A.system(0))
        n = min(A.shape)
        hit = (rows == A.indices) & (rows < n)
        contrib = jnp.where(hit[None, :], A.values, 0.0)  # (nb, nnz)
        seg = jax.vmap(
            lambda c: jax.ops.segment_sum(
                c, jnp.where(hit, rows, n), num_segments=n + 1
            )[:n]
        )
        return seg(contrib)
    if isinstance(A, BatchEll):
        m, k = A.col_idx.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, k))
        hit = (A.col_idx == rows)[None, :, :]
        n = min(A.shape)
        return jnp.sum(jnp.where(hit, A.values, 0.0), axis=2)[:, :n]
    raise TypeError(f"unknown batched format {type(A)}")


class BatchScalarJacobi(BatchLinOp):
    """Per-system scalar Jacobi BatchLinOp: ``M^{-1} V[b] = inv_diag[b] * V[b]``."""

    def __init__(self, inv_diag: jax.Array):
        self.inv_diag = inv_diag  # (nb, n)

    @property
    def shape(self):
        n = self.inv_diag.shape[1]
        return (n, n)

    @property
    def num_batch(self) -> int:
        return self.inv_diag.shape[0]

    @property
    def dtype(self):
        return self.inv_diag.dtype

    @property
    def storage_bytes(self) -> int:
        return int(self.inv_diag.size) * self.inv_diag.dtype.itemsize

    def _apply(self, V: jax.Array, executor) -> jax.Array:
        return self.inv_diag.astype(V.dtype) * V


def batch_jacobi_preconditioner(
    A: BatchMatrixLike, executor=None
) -> BatchScalarJacobi:
    """Per-system scalar Jacobi: ``M^{-1} V[b] = V[b] / diag(A[b])``.

    The batched analogue of ``gko::batch::preconditioner::Jacobi`` (bs=1):
    one inverse-diagonal tensor ``(nb, n)``, one elementwise multiply per
    application — no cross-system coupling.  Returns a BatchLinOp reporting
    ``storage_bytes``.
    """
    d = batch_extract_diag_op(A, executor=executor)
    safe = jnp.where(jnp.abs(d) > 0, d, jnp.ones_like(d))
    inv = jnp.where(jnp.abs(d) > 0, 1.0 / safe, jnp.ones_like(d))
    return BatchScalarJacobi(inv)


def batch_block_jacobi_preconditioner(
    A: BatchMatrixLike,
    block_size: Optional[int] = None,
    *,
    adaptive=False,
    tau: Optional[float] = None,
    executor=None,
) -> Callable:
    """Per-system block-Jacobi — ``gko::batch::preconditioner::Jacobi``, bs > 1.

    Delegates to :func:`repro.precond.batch_block_jacobi`: the shared sparsity
    pattern yields one host-side slot table, per-system blocks are gathered
    and Gauss-Jordan-inverted in one batch, and ``adaptive`` selects a storage
    precision per (system, block) with the same condition-estimate rule as the
    single-system path (per-precision sub-batches span the whole batch).  The
    returned object is callable on ``(nb, n)`` and reports ``storage_bytes``.
    """
    from repro.precond import batch_block_jacobi

    return batch_block_jacobi(
        A,
        block_size,
        adaptive=adaptive,
        executor=executor,
        **({} if tau is None else {"tau": tau}),
    )


#: the batched identity preconditioner — a real BatchLinOp with
#: ``storage_bytes == 0``; remains callable (``batch_identity_preconditioner(V)
#: -> V``) for historical call sites.
batch_identity_preconditioner = BatchIdentity()


# =============================================================================
# Batched CG
# =============================================================================


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchCgState:
    """Full in-flight state of a masked batched CG sweep — a pytree, so it
    round-trips through ``jax.jit`` boundaries and lets a long-running caller
    (the continuous-batching serve engine) advance the loop in chunks,
    swapping converged rows for fresh systems between chunks."""

    X: jax.Array      # (nb, n) iterates
    R: jax.Array      # (nb, n) residuals
    Z: jax.Array      # (nb, n) preconditioned residuals
    P: jax.Array      # (nb, n) search directions
    rz: jax.Array     # (nb,)  <R, Z>
    iters: jax.Array  # (nb,)  per-system iteration counts
    k: jax.Array      # ()     global sweep counter
    rnorm: jax.Array  # (nb,)  per-system residual norms
    hist: jax.Array   # (cap, nb) residual history rows


def _empty_result(B: jax.Array, stop: Stop, history) -> BatchSolveResult:
    """nb == 0: nothing to launch — no dispatches, no while_loop."""
    stop.threshold(jnp.zeros((0,), B.dtype))  # still reject degenerate stops
    z = jnp.zeros((0,), B.dtype)
    hist = convergence.init(convergence.capacity(history, stop),
                            batch=0, dtype=B.dtype)
    return BatchSolveResult(B, jnp.zeros((0,), jnp.int32), z,
                            jnp.zeros((0,), bool), convergence.finalize(hist))


def batch_cg_init(
    A: BatchMatrixLike,
    B: jax.Array,
    X: jax.Array,
    *,
    M: Optional[Callable] = None,
    executor=None,
    history_cap: int = 0,
) -> BatchCgState:
    """Initial CG state for iterate ``X``: residual, first search direction,
    per-system norms — the op sequence :func:`batch_cg` has always issued
    before entering its while_loop, factored out so admit/refresh paths can
    rebuild individual rows with bitwise-identical arithmetic."""
    ex = executor
    M = M or batch_identity_preconditioner
    nb = B.shape[0]
    R = B - _apply(A, X, ex)
    Z = M(R)
    P = Z
    rz = ops.batch_dot(R, Z, executor=ex)
    rnorm = ops.batch_norm2(R, executor=ex)
    iters = jnp.zeros(nb, jnp.int32)
    hist0 = convergence.init(history_cap, batch=nb, dtype=rnorm.dtype)
    return BatchCgState(X, R, Z, P, rz, iters, jnp.int32(0), rnorm, hist0)


def batch_cg_advance(
    A: BatchMatrixLike,
    state: BatchCgState,
    thresh: jax.Array,
    *,
    stop: Stop = Stop(),
    M: Optional[Callable] = None,
    num_sweeps: Optional[int] = None,
    executor=None,
) -> BatchCgState:
    """Advance the masked CG while_loop from ``state``.

    Runs until every system satisfies ``rnorm <= thresh`` or the global sweep
    counter reaches ``stop.max_iters`` — or, when ``num_sweeps`` is given, for
    at most that many additional sweeps (the chunked-advance hook continuous
    batching uses to regain control between admissions).  The loop body is the
    historical :func:`batch_cg` body, unchanged."""
    ex = executor
    M = M or batch_identity_preconditioner
    k0 = state.k

    def cond(st: BatchCgState):
        go = jnp.any(st.rnorm > thresh) & (st.k < stop.max_iters)
        if num_sweeps is not None:
            go = go & (st.k - k0 < num_sweeps)
        return go

    def body(st: BatchCgState):
        X, R, Z, P = st.X, st.R, st.Z, st.P
        rz, iters, k, rnorm, hist = st.rz, st.iters, st.k, st.rnorm, st.hist
        active = rnorm > thresh  # (nb,)
        a2 = active[:, None]
        AP = _apply(A, P, ex)
        pAp = ops.batch_dot(P, AP, executor=ex)
        # guards only matter for frozen systems (whose update is discarded);
        # active SPD systems have pAp > 0 and rz > 0
        alpha = rz / jnp.where(pAp == 0, 1.0, pAp)
        Xn = ops.batch_axpy(alpha, P, X, executor=ex)
        # fused residual update + per-system ‖R‖² — the convergence-mask
        # reduction rides the same pass as the axpy (shared with single CG)
        Rn, rr = ops.batch_axpy_norm(-alpha, AP, R, executor=ex)
        Zn = M(Rn)
        rz_new = ops.batch_dot(Rn, Zn, executor=ex)
        beta = rz_new / jnp.where(rz == 0, 1.0, rz)
        Pn = ops.batch_axpy(beta, P, Zn, executor=ex)
        X = jnp.where(a2, Xn, X)
        R = jnp.where(a2, Rn, R)
        Z = jnp.where(a2, Zn, Z)
        P = jnp.where(a2, Pn, P)
        rz = jnp.where(active, rz_new, rz)
        rnorm = jnp.where(active, jnp.sqrt(rr), rnorm)
        iters = iters + active.astype(jnp.int32)
        # frozen systems keep re-recording their final norm — the history row
        # at iteration k is the batch's residual state after k+1 sweeps
        return BatchCgState(X, R, Z, P, rz, iters, k + 1, rnorm,
                            convergence.push(hist, k, rnorm))

    return jax.lax.while_loop(cond, body, state)


def batch_cg(
    A: BatchMatrixLike,
    B: jax.Array,
    X0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Union[Callable, str]] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    history=None,
) -> BatchSolveResult:
    """Batched preconditioned CG (SPD systems), per-system stopping.

    ``B`` is ``(nb, n)`` — one right-hand side per system.  Converged systems
    freeze (their state rides through the loop unchanged) while the rest keep
    iterating; the loop exits when all have converged or ``max_iters`` hits.
    An empty batch (``nb == 0``) returns immediately without issuing a single
    kernel launch — continuous batching hits this between bursts.
    """
    ex = executor
    if B.shape[0] == 0:
        return _empty_result(B, stop, history)
    X, M = _setup(A, B, X0, M, ex, precond_opts)
    bnorm = ops.batch_norm2(B, executor=ex)
    thresh = stop.threshold(bnorm)  # (nb,)
    state = batch_cg_init(
        A, B, X, M=M, executor=ex,
        history_cap=convergence.capacity(history, stop),
    )
    state = batch_cg_advance(A, state, thresh, stop=stop, M=M, executor=ex)
    return BatchSolveResult(state.X, state.iters, state.rnorm,
                            state.rnorm <= thresh,
                            convergence.finalize(state.hist))


# =============================================================================
# Batched BiCGSTAB
# =============================================================================


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchBicgstabState:
    """In-flight state of a masked batched BiCGSTAB sweep (pytree).

    ``R_hat`` (the shadow residual, fixed per system at admission) is carried
    in the state rather than closed over so a serve engine can refresh it row
    by row when a slot is re-seeded with a new system."""

    X: jax.Array       # (nb, n) iterates
    R: jax.Array       # (nb, n) residuals
    R_hat: jax.Array   # (nb, n) shadow residuals
    P: jax.Array       # (nb, n) search directions
    rho: jax.Array     # (nb,)  <R_hat, R>
    iters: jax.Array   # (nb,)  per-system iteration counts
    k: jax.Array       # ()     global sweep counter
    rnorm: jax.Array   # (nb,)  per-system residual norms
    hist: jax.Array    # (cap, nb) residual history rows


def batch_bicgstab_init(
    A: BatchMatrixLike,
    B: jax.Array,
    X: jax.Array,
    *,
    executor=None,
    history_cap: int = 0,
) -> BatchBicgstabState:
    """Initial BiCGSTAB state for iterate ``X`` — the pre-loop op sequence of
    :func:`batch_bicgstab`, factored out for row-wise admit/refresh."""
    ex = executor
    nb = B.shape[0]
    R = B - _apply(A, X, ex)
    R_hat = R
    rho = ops.batch_dot(R_hat, R, executor=ex)
    P = R
    rnorm = ops.batch_norm2(R, executor=ex)
    iters = jnp.zeros(nb, jnp.int32)
    hist0 = convergence.init(history_cap, batch=nb, dtype=rnorm.dtype)
    return BatchBicgstabState(X, R, R_hat, P, rho, iters, jnp.int32(0),
                              rnorm, hist0)


def batch_bicgstab_advance(
    A: BatchMatrixLike,
    state: BatchBicgstabState,
    thresh: jax.Array,
    *,
    stop: Stop = Stop(),
    M: Optional[Callable] = None,
    num_sweeps: Optional[int] = None,
    executor=None,
) -> BatchBicgstabState:
    """Advance the masked BiCGSTAB while_loop from ``state`` (see
    :func:`batch_cg_advance` for the chunked-advance contract)."""
    ex = executor
    M = M or batch_identity_preconditioner
    eps = jnp.asarray(1e-30, state.R.dtype)
    k0 = state.k

    def cond(st: BatchBicgstabState):
        go = jnp.any(st.rnorm > thresh) & (st.k < stop.max_iters)
        if num_sweeps is not None:
            go = go & (st.k - k0 < num_sweeps)
        return go

    def body(st: BatchBicgstabState):
        X, R, R_hat, P = st.X, st.R, st.R_hat, st.P
        rho, iters, k, rnorm, hist = st.rho, st.iters, st.k, st.rnorm, st.hist
        active = rnorm > thresh
        a2 = active[:, None]
        P_hat = M(P)
        V = _apply(A, P_hat, ex)
        alpha = rho / (ops.batch_dot(R_hat, V, executor=ex) + eps)
        S = ops.batch_axpy(-alpha, V, R, executor=ex)
        S_hat = M(S)
        T = _apply(A, S_hat, ex)
        omega = ops.batch_dot(T, S, executor=ex) / (
            ops.batch_dot(T, T, executor=ex) + eps
        )
        Xn = X + alpha[:, None] * P_hat + omega[:, None] * S_hat
        # fused residual update + per-system ‖R‖² (same op as single BiCGSTAB)
        Rn, rr = ops.batch_axpy_norm(-omega, T, S, executor=ex)
        rho_new = ops.batch_dot(R_hat, Rn, executor=ex)
        beta = (rho_new / (rho + eps)) * (alpha / (omega + eps))
        Pn = Rn + beta[:, None] * (P - omega[:, None] * V)
        X = jnp.where(a2, Xn, X)
        R = jnp.where(a2, Rn, R)
        P = jnp.where(a2, Pn, P)
        rho = jnp.where(active, rho_new, rho)
        rnorm = jnp.where(active, jnp.sqrt(rr), rnorm)
        iters = iters + active.astype(jnp.int32)
        return BatchBicgstabState(X, R, R_hat, P, rho, iters, k + 1, rnorm,
                                  convergence.push(hist, k, rnorm))

    return jax.lax.while_loop(cond, body, state)


def batch_bicgstab(
    A: BatchMatrixLike,
    B: jax.Array,
    X0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Union[Callable, str]] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    history=None,
) -> BatchSolveResult:
    """Batched preconditioned BiCGSTAB (general systems), per-system stopping.

    Empty batches (``nb == 0``) return immediately with no kernel launches.
    """
    ex = executor
    if B.shape[0] == 0:
        return _empty_result(B, stop, history)
    X, M = _setup(A, B, X0, M, ex, precond_opts)
    bnorm = ops.batch_norm2(B, executor=ex)
    thresh = stop.threshold(bnorm)
    state = batch_bicgstab_init(
        A, B, X, executor=ex,
        history_cap=convergence.capacity(history, stop),
    )
    state = batch_bicgstab_advance(A, state, thresh, stop=stop, M=M,
                                   executor=ex)
    return BatchSolveResult(state.X, state.iters, state.rnorm,
                            state.rnorm <= thresh,
                            convergence.finalize(state.hist))
