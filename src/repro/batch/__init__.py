"""repro.batch — batched linear algebra (gko::batch::* analogue).

Solve thousands of small independent sparse systems in one launch: batched
formats with a shared-sparsity-pattern fast path (:mod:`repro.batch.formats`),
executor-dispatched batched SpMV / BLAS-1 (:mod:`repro.batch.ops`), and masked
batched Krylov solvers whose per-system convergence mask freezes finished
systems inside one ``lax.while_loop`` (:mod:`repro.batch.solvers`).

The multi-device driver (batch axis sharded across the mesh) lives in
:mod:`repro.launch.batch_solve`.
"""

from repro.batch.linop import (
    BatchComposition,
    BatchIdentity,
    BatchLinOp,
    BatchMatrixFreeOp,
    BatchScaledIdentity,
    BatchSum,
)
from repro.batch.formats import (
    BatchCsr,
    BatchEll,
    batch_csr_from_dense,
    batch_csr_from_list,
    batch_ell_from_batch_csr,
    batch_ell_from_dense,
    batch_ell_from_list,
)
from repro.batch.ops import (
    apply_batch,
    batch_axpy,
    batch_dot,
    batch_norm2,
    batch_scal,
)
from repro.batch.solvers import (
    BatchBicgstabState,
    BatchCgState,
    BatchScalarJacobi,
    BatchSolveResult,
    batch_bicgstab,
    batch_bicgstab_advance,
    batch_bicgstab_init,
    batch_block_jacobi_preconditioner,
    batch_cg,
    batch_cg_advance,
    batch_cg_init,
    batch_identity_preconditioner,
    batch_jacobi_preconditioner,
)

__all__ = [
    "BatchLinOp",
    "BatchComposition",
    "BatchSum",
    "BatchScaledIdentity",
    "BatchMatrixFreeOp",
    "BatchIdentity",
    "BatchCsr",
    "BatchEll",
    "batch_csr_from_list",
    "batch_ell_from_list",
    "batch_csr_from_dense",
    "batch_ell_from_dense",
    "batch_ell_from_batch_csr",
    "apply_batch",
    "batch_dot",
    "batch_axpy",
    "batch_scal",
    "batch_norm2",
    "BatchSolveResult",
    "BatchCgState",
    "BatchBicgstabState",
    "BatchScalarJacobi",
    "batch_cg",
    "batch_cg_init",
    "batch_cg_advance",
    "batch_bicgstab",
    "batch_bicgstab_init",
    "batch_bicgstab_advance",
    "batch_jacobi_preconditioner",
    "batch_block_jacobi_preconditioner",
    "batch_identity_preconditioner",
]
