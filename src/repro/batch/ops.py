"""Executor-dispatched batched operations: SpMV per batched format + BLAS-1.

Same three-space contract as the single-system ops (:mod:`repro.sparse.ops`):

* reference — python-loop-over-systems semantics (the sequential oracle;
  Ginkgo's reference kernels iterate the batch in a for loop);
* xla       — one vectorized formulation over the whole batch (``vmap`` /
  broadcast einsum) the compiler fuses into a single launch;
* pallas    — registered from :mod:`repro.kernels.spmv_batch_ell` (batch on
  the outer grid axis; imported lazily by ``repro.kernels``).

All batched vectors are ``(nb, n)``; batched scalars are ``(nb,)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.batch.formats import BatchCsr, BatchEll
from repro.core import registry
from repro.sparse.ops import _csr_row_ids

__all__ = [
    "apply_batch",
    "batch_dot",
    "batch_axpy",
    "batch_axpy_norm",
    "batch_scal",
    "batch_norm2",
]

# =============================================================================
# Batched SpMV — CSR (shared pattern)
# =============================================================================

spmv_batch_csr = registry.operation(
    "spmv_batch_csr", "Y[b] = A[b] @ X[b] for shared-pattern batched CSR"
)


@spmv_batch_csr.register("reference")
def _spmv_batch_csr_ref(ex, A: BatchCsr, X: jax.Array) -> jax.Array:
    # one system at a time — sequential reference semantics
    rows = _csr_row_ids(A.system(0))
    outs = []
    for b in range(A.num_batch):
        y = jnp.zeros((A.shape[0],), dtype=jnp.result_type(A.values, X))
        outs.append(y.at[rows].add(A.values[b] * X[b, A.indices]))
    return jnp.stack(outs)


@spmv_batch_csr.register("xla")
def _spmv_batch_csr_xla(ex, A: BatchCsr, X: jax.Array) -> jax.Array:
    rows = _csr_row_ids(A.system(0))
    contrib = A.values * X[:, A.indices]  # (nb, nnz)
    seg = jax.vmap(
        lambda c: jax.ops.segment_sum(
            c, rows, num_segments=A.shape[0], indices_are_sorted=True
        )
    )
    return seg(contrib)


# =============================================================================
# Batched SpMV — ELL (shared column block)
# =============================================================================

spmv_batch_ell = registry.operation(
    "spmv_batch_ell", "Y[b] = A[b] @ X[b] for shared-pattern batched ELL"
)


@spmv_batch_ell.register("reference")
def _spmv_batch_ell_ref(ex, A: BatchEll, X: jax.Array) -> jax.Array:
    outs = []
    for b in range(A.num_batch):
        gathered = X[b][A.col_idx]  # (m, k)
        outs.append(jnp.sum(A.values[b] * gathered, axis=1))
    return jnp.stack(outs)


@spmv_batch_ell.register("xla")
def _spmv_batch_ell_xla(ex, A: BatchEll, X: jax.Array) -> jax.Array:
    gathered = X[:, A.col_idx]  # (nb, m, k) — shared indices, batched gather
    return jnp.einsum("bmk,bmk->bm", A.values, gathered)


# =============================================================================
# Batched BLAS-1 (row-wise over the batch axis)
# =============================================================================

batch_dot_op = registry.operation("batch_blas_dot")
batch_axpy_op = registry.operation("batch_blas_axpy")
batch_scal_op = registry.operation("batch_blas_scal")
batch_norm2_op = registry.operation("batch_blas_norm2")


@batch_dot_op.register("reference")
def _batch_dot_ref(ex, X, Y):
    return jnp.stack([jnp.vdot(X[b], Y[b]) for b in range(X.shape[0])])


@batch_dot_op.register("xla")
def _batch_dot_xla(ex, X, Y):
    return jnp.einsum("bn,bn->b", X, Y)


@batch_axpy_op.register("reference")
def _batch_axpy_ref(ex, alpha, X, Y):
    return jnp.stack([alpha[b] * X[b] + Y[b] for b in range(X.shape[0])])


@batch_axpy_op.register("xla")
def _batch_axpy_xla(ex, alpha, X, Y):
    return alpha[:, None] * X + Y


@batch_scal_op.register("reference")
def _batch_scal_ref(ex, alpha, X):
    return jnp.stack([alpha[b] * X[b] for b in range(X.shape[0])])


@batch_scal_op.register("xla")
def _batch_scal_xla(ex, alpha, X):
    return alpha[:, None] * X


@batch_norm2_op.register("reference")
def _batch_norm2_ref(ex, X):
    return jnp.stack(
        [jnp.sqrt(jnp.vdot(X[b], X[b]).real) for b in range(X.shape[0])]
    )


@batch_norm2_op.register("xla")
def _batch_norm2_xla(ex, X):
    return jnp.sqrt(jnp.einsum("bn,bn->b", X, X))


# =============================================================================
# apply_batch — gko::batch::BatchLinOp::apply
# =============================================================================

_BATCH_FORMAT_OP = {
    BatchCsr: spmv_batch_csr,
    BatchEll: spmv_batch_ell,
}


def apply_batch(A, X: jax.Array, *, executor=None) -> jax.Array:
    """``Y[b] = A[b] @ X[b]``: format-dispatch then executor-dispatch.

    Composed batched operators (``BatchSum``, ``BatchComposition``, ...)
    delegate to their own ``apply``; the format fast path keeps dispatching
    straight into the kernel registry.
    """
    try:
        op = _BATCH_FORMAT_OP[type(A)]
    except KeyError:
        from repro.batch.formats import BatchMatrixLinOp
        from repro.batch.linop import BatchLinOp

        # a BatchMatrixLinOp not in the table is an unregistered *format* —
        # its _apply would bounce right back here, so fail loudly instead
        if isinstance(A, BatchLinOp) and not isinstance(A, BatchMatrixLinOp):
            return A.apply(X, executor=executor)
        raise TypeError(
            f"no batched spmv registered for format {type(A)}"
        ) from None
    return op(A, X, executor=executor)


def batch_dot(X, Y, *, executor=None):
    return batch_dot_op(X, Y, executor=executor)


def batch_axpy(alpha, X, Y, *, executor=None):
    return batch_axpy_op(alpha, X, Y, executor=executor)


def batch_scal(alpha, X, *, executor=None):
    return batch_scal_op(alpha, X, executor=executor)


def batch_norm2(X, *, executor=None):
    return batch_norm2_op(X, executor=executor)


def batch_axpy_norm(alpha, X, Y, *, executor=None):
    """Fused ``(Z, ‖Z[b]‖²)`` with ``Z = alpha[:, None] * X + Y``.

    Delegates to the SAME ``axpy_norm`` operation the single-vector Krylov
    loops use (its implementations handle both 1-D and ``(nb, n)`` operands),
    so the batched convergence-mask reduction and the single-system stopping
    norm share one fused implementation per kernel space instead of
    recomputing the mask norm with separate dot launches.
    """
    from repro.sparse.ops import axpy_norm_op

    return axpy_norm_op(alpha, X, Y, executor=executor)
