"""Batched sparse formats — gko::batch::matrix::{Csr, Ell} analogues.

Ginkgo's batched functionality solves thousands of small independent sparse
systems in one kernel launch.  The dominant application pattern (chemistry
networks, cells of a discretized PDE) produces systems that share one sparsity
pattern and differ only in values, so both formats here store **one** index
structure and a value tensor with a leading batch axis — Ginkgo's
shared-pattern fast path made the storage invariant:

* :class:`BatchCsr` — shared ``indptr``/``indices``, values ``(nb, nnz)``;
* :class:`BatchEll` — shared ``col_idx (m, k)``, values ``(nb, m, k)``.

Conversion from a *heterogeneous* list of single-system matrices computes the
union sparsity pattern host-side (setup time, numpy — like ``convert_to``) and
fills the entries a system lacks with explicit zeros: SpMV and the solvers are
agnostic to which zeros are structural.

Both classes are frozen JAX pytrees: the batch axis of ``values`` is a normal
array axis, so the whole matrix shards across devices with a single
``NamedSharding`` on that axis (see :mod:`repro.launch.batch_solve`).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.batch.linop import BatchLinOp
from repro.sparse.formats import Csr, Ell, _nbytes

__all__ = [
    "BatchCsr",
    "BatchEll",
    "batch_csr_from_list",
    "batch_ell_from_list",
    "batch_csr_from_dense",
    "batch_ell_from_dense",
    "batch_ell_from_batch_csr",
]


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields)
    )
    return cls


class BatchMatrixLinOp(BatchLinOp):
    """Common BatchLinOp behavior for the batched formats.

    ``apply`` dispatches through the batched operation registry
    (:func:`repro.batch.ops.apply_batch`) — kernels untouched.
    """

    def _apply(self, X, executor):
        from repro.batch import ops

        return ops.apply_batch(self, X, executor=executor)

    def astype(self, dtype) -> "BatchMatrixLinOp":
        """Same shared structure, values cast (the mixed-precision hook)."""
        return dataclasses.replace(self, values=self.values.astype(dtype))


@dataclasses.dataclass(frozen=True)
class BatchCsr(BatchMatrixLinOp):
    """Batch of CSR matrices sharing one sparsity pattern.

    One index structure, stacked values — the storage Ginkgo's
    ``batch::matrix::Csr`` uses when ``num_stored_elems`` is uniform.
    """

    indptr: jax.Array  # (m+1,) int32 — shared
    indices: jax.Array  # (nnz,) int32 — shared
    values: jax.Array  # (nb, nnz)
    shape: Tuple[int, int]  # static, per-system

    @property
    def num_batch(self) -> int:
        return self.values.shape[0]

    @property
    def nnz(self) -> int:
        """Stored entries per system (shared pattern)."""
        return self.values.shape[1]

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def memory_bytes(self) -> int:
        return _nbytes(self.indptr, self.indices, self.values)

    def system(self, i: int) -> Csr:
        """Extract one system as a single-system ``Csr`` view."""
        return Csr(self.indptr, self.indices, self.values[i], self.shape)


_register(BatchCsr, ["indptr", "indices", "values"], ["shape"])


@dataclasses.dataclass(frozen=True)
class BatchEll(BatchMatrixLinOp):
    """Batch of ELL matrices sharing one column-index block.

    Padding follows the single-system convention: ``col_idx == 0`` with a zero
    value, so gathers stay in-bounds without predication on every system.
    """

    col_idx: jax.Array  # (m, max_nnz) int32 — shared
    values: jax.Array  # (nb, m, max_nnz)
    shape: Tuple[int, int]  # static, per-system

    @property
    def num_batch(self) -> int:
        return self.values.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.values.shape[2]

    @property
    def nnz(self) -> int:
        """Stored entries per system (``m * max_nnz``, incl. padding)."""
        return int(self.values.shape[1] * self.values.shape[2])

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def memory_bytes(self) -> int:
        return _nbytes(self.col_idx, self.values)

    def system(self, i: int) -> Ell:
        return Ell(self.col_idx, self.values[i], self.shape)


_register(BatchEll, ["col_idx", "values"], ["shape"])


# -- host-side constructors (setup-time, numpy) --------------------------------


def _check_uniform_shapes(mats: Sequence) -> Tuple[int, int]:
    if not mats:
        raise ValueError("cannot batch an empty list of matrices")
    shape = tuple(mats[0].shape)
    for i, m in enumerate(mats):
        if tuple(m.shape) != shape:
            raise ValueError(
                f"batched systems must share a shape: system 0 is {shape}, "
                f"system {i} is {tuple(m.shape)}"
            )
    return shape


def _shared_csr_pattern(mats: Sequence[Csr]) -> bool:
    p0, i0 = np.asarray(mats[0].indptr), np.asarray(mats[0].indices)
    return all(
        np.array_equal(np.asarray(m.indptr), p0)
        and np.array_equal(np.asarray(m.indices), i0)
        for m in mats[1:]
    )


def batch_csr_from_list(mats: Sequence[Csr]) -> BatchCsr:
    """Stack single-system CSR matrices into one BatchCsr.

    Identical patterns take the fast path (stack values, zero copies of the
    index arrays); heterogeneous patterns are rebuilt on the union pattern
    with explicit zeros for the entries a system lacks.
    """
    shape = _check_uniform_shapes(mats)
    if _shared_csr_pattern(mats):
        return BatchCsr(
            indptr=mats[0].indptr,
            indices=mats[0].indices,
            values=jnp.stack([m.values for m in mats]),
            shape=shape,
        )

    m_rows = shape[0]
    # union pattern: per row, the sorted union of every system's column set
    row_cols: List[np.ndarray] = []
    for r in range(m_rows):
        cols = [
            np.asarray(mat.indices)[
                int(np.asarray(mat.indptr)[r]) : int(np.asarray(mat.indptr)[r + 1])
            ]
            for mat in mats
        ]
        row_cols.append(np.unique(np.concatenate(cols)) if cols else np.zeros(0, np.int32))
    indptr = np.zeros(m_rows + 1, np.int64)
    indptr[1:] = np.cumsum([c.size for c in row_cols])
    indices = (
        np.concatenate(row_cols).astype(np.int32)
        if m_rows
        else np.zeros(0, np.int32)
    )
    dtype = np.asarray(mats[0].values).dtype
    values = np.zeros((len(mats), int(indptr[-1])), dtype)
    for b, mat in enumerate(mats):
        mp, mi, mv = (
            np.asarray(mat.indptr),
            np.asarray(mat.indices),
            np.asarray(mat.values),
        )
        for r in range(m_rows):
            lo, hi = int(indptr[r]), int(indptr[r + 1])
            pos = lo + np.searchsorted(indices[lo:hi], mi[mp[r] : mp[r + 1]])
            values[b, pos] = mv[mp[r] : mp[r + 1]]
    return BatchCsr(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(indices),
        values=jnp.asarray(values),
        shape=shape,
    )


def _shared_ell_pattern(mats: Sequence[Ell]) -> bool:
    c0 = np.asarray(mats[0].col_idx)
    return all(
        m.col_idx.shape == mats[0].col_idx.shape
        and np.array_equal(np.asarray(m.col_idx), c0)
        for m in mats[1:]
    )


def batch_ell_from_list(mats: Sequence[Ell]) -> BatchEll:
    """Stack single-system ELL matrices into one BatchEll.

    Identical column blocks take the fast path; otherwise each row's union
    column set (padded to the batch-wide max width) becomes the shared block.
    """
    shape = _check_uniform_shapes(mats)
    if _shared_ell_pattern(mats):
        return BatchEll(
            col_idx=mats[0].col_idx,
            values=jnp.stack([m.values for m in mats]),
            shape=shape,
        )

    m_rows = shape[0]
    dtype = np.asarray(mats[0].values).dtype
    # per-row union of stored columns across the batch; padding entries
    # (col 0, value 0) may enter the union as structural zeros — harmless,
    # they contribute nothing to SpMV
    row_cols = []
    for r in range(m_rows):
        cols = np.unique(
            np.concatenate([np.asarray(mat.col_idx)[r] for mat in mats])
        )
        row_cols.append(cols)
    k = max((c.size for c in row_cols), default=1)
    col_idx = np.zeros((m_rows, k), np.int32)
    values = np.zeros((len(mats), m_rows, k), dtype)
    for r in range(m_rows):
        cols = row_cols[r]
        col_idx[r, : cols.size] = cols
        for b, mat in enumerate(mats):
            mc = np.asarray(mat.col_idx)[r]
            mv = np.asarray(mat.values)[r]
            pos = np.searchsorted(cols, mc)
            # scatter-add so duplicate padding columns (col 0, value 0)
            # cannot clobber a real entry at column 0
            np.add.at(values[b, r], pos, mv)
    return BatchEll(
        col_idx=jnp.asarray(col_idx),
        values=jnp.asarray(values),
        shape=shape,
    )


def batch_csr_from_dense(stack: np.ndarray) -> BatchCsr:
    """(nb, m, n) dense stack -> BatchCsr on the union pattern."""
    from repro.sparse.formats import csr_from_dense

    return batch_csr_from_list([csr_from_dense(a) for a in np.asarray(stack)])


def batch_ell_from_dense(stack: np.ndarray) -> BatchEll:
    """(nb, m, n) dense stack -> BatchEll on the union pattern."""
    from repro.sparse.formats import ell_from_dense

    return batch_ell_from_list([ell_from_dense(a) for a in np.asarray(stack)])


def batch_ell_from_batch_csr(A: BatchCsr, max_nnz: int | None = None) -> BatchEll:
    """BatchCsr -> BatchEll (shared pattern is preserved by construction)."""
    indptr = np.asarray(A.indptr)
    indices = np.asarray(A.indices)
    values = np.asarray(A.values)  # (nb, nnz)
    m = A.shape[0]
    row_nnz = np.diff(indptr)
    k = int(max_nnz if max_nnz is not None else (row_nnz.max() if m else 0))
    k = max(k, 1)
    cols = np.zeros((m, k), np.int32)
    vals = np.zeros((A.num_batch, m, k), values.dtype)
    for r in range(m):
        n = row_nnz[r]
        if n > k:
            raise ValueError(f"row {r} has {n} nnz > max_nnz {k}")
        cols[r, :n] = indices[indptr[r] : indptr[r] + n]
        vals[:, r, :n] = values[:, indptr[r] : indptr[r] + n]
    return BatchEll(jnp.asarray(cols), jnp.asarray(vals), A.shape)
