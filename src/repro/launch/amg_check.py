"""AMG smoke driver: hierarchy report + iteration-cut gate vs block-Jacobi.

Builds a 2D Poisson system from :mod:`repro.sparse.gallery`, sets up the
smoothed-aggregation :class:`repro.precond.amg.Multigrid` hierarchy, and runs
preconditioned CG twice — ``M="amg"`` against the ``M="block_jacobi"``
baseline.  The run reports the hierarchy (per-level rows/nnz, operator
complexity) and both convergence histories, then ends with a greppable
``AMG-GATE: PASS|FAIL`` line — the CI smoke gate — asserting that

* both solves converged,
* the AMG hierarchy actually coarsened (more than one level), and
* AMG cut CG iterations by at least ``--iter-cut`` (default 3x; the full
  10^5-row benchmark in ``benchmarks/report.py`` pins the 5x headline).

Usage:
    python -m repro.launch.amg_check --smoke
    python -m repro.launch.amg_check --n-side 128 --cycle w --iter-cut 5
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import make_executor, use_executor
from repro.observability import trace
from repro.precond import make_preconditioner
from repro.solvers.common import Stop
from repro.solvers.krylov import cg
from repro.sparse import csr_from_arrays
from repro.sparse.gallery import poisson_2d

__all__ = ["run_amg_check", "main"]


def run_amg_check(
    n_side: int,
    *,
    cycle: str = "v",
    theta: float = 0.08,
    iter_cut: float = 3.0,
    max_iters: int = 2000,
    tol: float = 1e-6,
    executor=None,
) -> bool:
    ex = executor or make_executor("xla")
    indptr, indices, values, shape = poisson_2d(n_side)
    A = csr_from_arrays(indptr, indices, values, shape)
    rng = np.random.default_rng(0)
    b = rng.normal(size=shape[0]).astype(np.float32)
    stop = Stop(max_iters=max_iters, reduction_factor=tol)

    print(f"amg_check: poisson_2d({n_side}) -> {shape[0]} rows, "
          f"{indices.size} nnz, cycle={cycle}, theta={theta:g}")

    t0 = time.perf_counter()
    M_amg = make_preconditioner(A, "amg", executor=ex,
                                cycle=cycle, theta=theta)
    setup_s = time.perf_counter() - t0
    rows = [int(L.A.shape[0]) for L in M_amg.levels]
    nnzs = [int(np.asarray(L.A.indices).size) for L in M_amg.levels]
    complexity = sum(nnzs) / max(nnzs[0], 1)
    print(f"  hierarchy: {M_amg.num_levels} levels, rows {rows}, "
          f"operator complexity {complexity:.2f}, setup {setup_s:.2f} s")

    M_bj = make_preconditioner(A, "block_jacobi", executor=ex)

    res_bj = cg(A, b, stop=stop, M=M_bj, executor=ex)
    res_amg = cg(A, b, stop=stop, M=M_amg, executor=ex)
    it_bj = int(res_bj.iterations)
    it_amg = int(res_amg.iterations)
    ratio = it_bj / max(it_amg, 1)
    print(f"  block_jacobi-cg: {it_bj} iters, "
          f"rnorm {float(res_bj.residual_norm):.3e}, "
          f"converged {bool(res_bj.converged)}")
    print(f"  amg-cg:          {it_amg} iters, "
          f"rnorm {float(res_amg.residual_norm):.3e}, "
          f"converged {bool(res_amg.converged)}")
    print(f"  iteration cut: {ratio:.1f}x (gate: >= {iter_cut:g}x)")

    ok = (
        bool(res_bj.converged)
        and bool(res_amg.converged)
        and M_amg.num_levels > 1
        and ratio >= iter_cut
    )
    print(f"AMG-GATE: {'PASS' if ok else 'FAIL'}")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run (64x64 grid, 3x gate)")
    ap.add_argument("--n-side", type=int, default=128,
                    help="Poisson grid side (rows = n_side^2)")
    ap.add_argument("--cycle", default="v", choices=("v", "w"))
    ap.add_argument("--theta", type=float, default=0.08,
                    help="strength-of-connection threshold")
    ap.add_argument("--iter-cut", type=float, default=3.0,
                    help="gate: AMG must cut CG iterations by this factor")
    ap.add_argument("--max-iters", type=int, default=2000)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--executor", default="xla")
    trace.add_cli_flag(ap)
    args = ap.parse_args(argv)
    trace.enable_from_args(args)

    n_side = 64 if args.smoke else args.n_side
    ex = make_executor(args.executor)
    with use_executor(ex):
        ok = run_amg_check(
            n_side,
            cycle=args.cycle,
            theta=args.theta,
            iter_cut=args.iter_cut,
            max_iters=args.max_iters,
            tol=args.tol,
            executor=ex,
        )
    if args.trace and trace.export():
        print(f"  trace -> {args.trace}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
