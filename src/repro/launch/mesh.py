"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init, and
tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16, 16) = ("data", "model") — 256 chips (TPU v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
    axis composes with "data" for cross-pod data parallelism (gradient
    all-reduce crosses pods once per step over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, have {n}")
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
