"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init, and
tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import numpy as np
import jax

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "make_shard_mesh",
    "compat_make_mesh",
    "use_mesh",
    "shard_map",
]


def use_mesh(mesh: jax.sharding.Mesh):
    """Ambient-mesh context: ``jax.set_mesh`` on new jax; on older versions
    the ``Mesh`` object itself is the context manager that sets the ambient
    physical mesh."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def shard_map(f, mesh=None, **kw):
    """``jax.shard_map`` where available; the experimental version plus the
    ambient mesh on older jax (which requires an explicit mesh argument and
    spells ``check_vma`` as ``check_rep``)."""
    native = getattr(jax, "shard_map", None)
    if native is not None:
        return native(f, mesh=mesh, **kw) if mesh is not None else native(f, **kw)
    from jax.experimental.shard_map import shard_map as legacy

    if "check_vma" in kw:
        kw["check_rep"] = kw.pop("check_vma")
    # legacy shard_map's transpose mishandles symbolic-Zero cotangents (grads
    # of partially-used outputs) unless replication checking is off
    kw.setdefault("check_rep", False)
    if mesh is None:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
    return legacy(f, mesh=mesh, **kw)


def compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them.

    ``jax.sharding.AxisType`` only exists on newer jax; older versions default
    to the same auto-sharded behaviour, so omit the argument there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16, 16) = ("data", "model") — 256 chips (TPU v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
    axis composes with "data" for cross-pod data parallelism (gradient
    all-reduce crosses pods once per step over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_shard_mesh(num_shards: int, axis: str = "data") -> jax.sharding.Mesh:
    """1-D mesh over the first ``num_shards`` devices (distributed operators).

    Unlike :func:`make_host_mesh` this deliberately takes a device-count
    *subset*, so a partition over fewer parts than devices (e.g. 2 shards on
    an 8-device host platform) still maps one part per device.
    """
    devs = jax.devices()
    if num_shards > len(devs):
        raise ValueError(
            f"partition has {num_shards} parts but only {len(devs)} devices "
            "are available (set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=N for host-platform testing)"
        )
    return jax.sharding.Mesh(np.asarray(devs[:num_shards]), (axis,))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, have {n}")
    return compat_make_mesh((data, model), ("data", "model"))
