"""Persistent solve-service driver: continuous batching + setup cache.

Stands up :class:`repro.serve.SolveService`, replays a synthetic Poisson
request stream over a sparsity-pattern gallery (``repro.serve.traffic``),
and reports serving metrics: solves/sec, p50/p99 end-to-end latency (from
the sub-unit-bucketed ``serve_latency_s`` histogram), and setup-cache hit
rates per tier.

A warmup pass (one request per gallery pattern) absorbs jit compilation and
populates the pattern tier, as a long-running service would be; the measured
stream then runs against a warm cache.  The run ends with a greppable
``SERVE-GATE: PASS|FAIL`` line — the CI smoke gate — asserting that every
request converged, the cache actually hit, and p99 latency stayed under the
bound.

Usage:
    python -m repro.launch.solve_serve --smoke
    python -m repro.launch.solve_serve --requests 256 --rate-hz 200 \
        --gallery 4 --repeat-ratio 0.6 --slots 8 --p99-bound 0.5
"""

from __future__ import annotations

import argparse
import time

from repro.core import make_executor, use_executor
from repro.observability import metrics, trace
from repro.serve import ServeConfig, SolveService, TrafficConfig
from repro.serve.traffic import generate_traffic, pattern_gallery
from repro.serve.request import SolveRequest
from repro.solvers.common import Stop

__all__ = ["run_serve", "main"]


def _warmup(svc: SolveService, traffic_cfg: TrafficConfig) -> None:
    """One solve per gallery pattern: compiles closures, fills the cache."""
    import numpy as np

    rng = np.random.default_rng(traffic_cfg.seed + 97)
    ids = []
    for indptr, indices, make_values in pattern_gallery(traffic_cfg):
        req = SolveRequest(
            indptr=indptr, indices=indices, values=make_values()[2],
            b=rng.normal(size=traffic_cfg.n).astype(np.float32),
            shape=(traffic_cfg.n, traffic_cfg.n),
        )
        ids.append(svc.submit(req))
    svc.gather(ids, timeout=300.0)


def run_serve(
    config: ServeConfig,
    traffic_cfg: TrafficConfig,
    *,
    executor=None,
    pace: bool = True,
):
    """Warm up, replay the stream, and return ``(responses, wall_s)``."""
    traffic = generate_traffic(traffic_cfg)
    with SolveService(config, executor=executor) as svc:
        _warmup(svc, traffic_cfg)
        metrics.reset()  # measure the steady state, not compilation
        t0 = time.perf_counter()
        ids = []
        for gap, req in traffic:
            if pace and gap > 0:
                time.sleep(gap)
            ids.append(svc.submit(req))
        responses = svc.gather(ids, timeout=600.0)
        wall = time.perf_counter() - t0
    return responses, wall


def report(responses, wall: float, p99_bound: float) -> bool:
    num = len(responses)
    converged = sum(r.converged for r in responses)
    p_hits = sum(r.pattern_hit for r in responses)
    f_hits = sum(r.factors_hit for r in responses)
    iters = sum(r.iterations for r in responses)
    h = metrics.histogram("serve_latency_s")
    p50, p99 = h.quantile(0.5), h.quantile(0.99)
    rate = num / max(wall, 1e-9)

    print(f"solve_serve: {num} requests in {wall:.3f} s "
          f"({rate:.1f} solves/sec, {iters} total iterations)")
    print(f"  converged {converged}/{num}")
    print(f"  cache hits: pattern {p_hits}/{num}  factors {f_hits}/{num}")
    cache = {k: int(v) for k, v in sorted(metrics_cache_stats().items())}
    print(f"  cache counters: {cache}")
    print(f"  latency p50 = {_fmt_s(p50)}  p99 = {_fmt_s(p99)}  "
          f"(bound {p99_bound:g} s)")

    ok = (
        converged == num
        and p_hits > 0
        and p99 is not None
        and p99 < p99_bound
    )
    print(f"SERVE-GATE: {'PASS' if ok else 'FAIL'}")
    return ok


def metrics_cache_stats():
    out = {}
    for name in ("serve_cache_hits", "serve_cache_misses",
                 "serve_cache_evictions"):
        for tier in ("pattern", "values"):
            out[f"{name}_{tier}"] = metrics.counter(name, tier=tier).value
    return out


def _fmt_s(v) -> str:
    return "n/a" if v is None else f"{v * 1e3:.3g} ms"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small end-to-end run for CI (48 requests)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--rate-hz", type=float, default=200.0,
                    help="Poisson arrival rate of the synthetic stream")
    ap.add_argument("--gallery", type=int, default=4,
                    help="distinct sparsity patterns in the traffic")
    ap.add_argument("--repeat-ratio", type=float, default=0.6,
                    help="fraction of requests reusing a previous matrix")
    ap.add_argument("--n", type=int, default=24, help="rows per system")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=8,
                    help="batch slots per pattern lane")
    ap.add_argument("--chunk-sweeps", type=int, default=8,
                    help="masked sweeps per jitted advance chunk")
    ap.add_argument("--solver", default="cg", choices=("cg", "bicgstab"))
    ap.add_argument("--format", default="csr", choices=("csr", "ell"),
                    dest="fmt")
    ap.add_argument("--precond", default="block_jacobi",
                    choices=("block_jacobi", "none"))
    ap.add_argument("--block-size", type=int, default=4)
    ap.add_argument("--max-iters", type=int, default=500)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--p99-bound", type=float, default=2.0,
                    help="gate: p99 end-to-end latency must stay under this")
    ap.add_argument("--no-pace", action="store_true",
                    help="submit the whole stream at once (throughput mode)")
    ap.add_argument("--executor", default="xla")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="write the metrics registry snapshot here")
    trace.add_cli_flag(ap)
    args = ap.parse_args(argv)
    trace.enable_from_args(args)

    requests = 48 if args.smoke else args.requests
    gallery = min(args.gallery, 3) if args.smoke else args.gallery

    config = ServeConfig(
        slots=args.slots,
        chunk_sweeps=args.chunk_sweeps,
        solver=args.solver,
        fmt=args.fmt,
        precond=args.precond,
        block_size=args.block_size,
        stop=Stop(max_iters=args.max_iters, reduction_factor=args.tol),
    )
    traffic_cfg = TrafficConfig(
        num_requests=requests,
        rate_hz=args.rate_hz,
        gallery_size=gallery,
        repeat_ratio=args.repeat_ratio,
        n=args.n,
        seed=args.seed,
    )
    print(f"solve_serve: {requests} requests @ {args.rate_hz:g} Hz, "
          f"gallery={gallery} repeat={args.repeat_ratio:g}, "
          f"{args.solver}/{args.fmt}/{args.precond} slots={args.slots}, "
          f"seed={args.seed}, executor={args.executor}")

    ex = make_executor(args.executor)
    with use_executor(ex):
        responses, wall = run_serve(
            config, traffic_cfg, executor=ex, pace=not args.no_pace
        )
    ok = report(responses, wall, args.p99_bound)
    if args.metrics_jsonl:
        print(f"  metrics -> {metrics.export_jsonl(args.metrics_jsonl)}")
    if args.trace and trace.export():
        print(f"  trace -> {args.trace}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
