"""Inspect observability artifacts: traces, metrics, convergence histories.

The read side of :mod:`repro.observability` — a small CLI that turns the
artifacts the instrumented code writes into terminal-sized answers:

* ``trace <file>``    — summarize a Chrome trace: span table + a roofline
  aggregation of the dispatch events (count, bytes, wall, achieved GB/s
  per op x space x target);
* ``validate <file>`` — schema-check a trace file (the CI gate); exit 1 and
  print every problem when invalid;
* ``metrics <file>``  — render an exported metrics JSONL as an aligned table;
* ``solve``           — run a demo Krylov solve with ``history=`` telemetry
  on and plot the per-iteration residual norms as a text sparkline (also a
  one-command way to produce trace + metrics artifacts: ``--trace`` /
  ``--metrics``).

Usage:
    python -m repro.launch.inspect trace repro_trace.json
    python -m repro.launch.inspect validate repro_trace.json
    python -m repro.launch.inspect metrics metrics.jsonl
    python -m repro.launch.inspect solve --smoke --trace out.json \
        --metrics out.jsonl
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

__all__ = ["sparkline", "summarize_trace", "main"]

#: eight-level block ramp; one cell per residual sample.
SPARK_CHARS = "▁▂▃▄▅▆▇█"


def sparkline(values, *, log: bool = True, width: int = 72) -> str:
    """Render ``values`` as a text sparkline, log-scaled by default.

    Residual norms span many decades, so the log of each value is mapped onto
    the eight block characters; non-finite or non-positive values render as
    spaces.  When there are more samples than ``width`` the series is
    decimated by striding (first and last samples always kept).
    """
    import math

    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:
        stride = (len(vals) - 1) / (width - 1)
        vals = [vals[round(i * stride)] for i in range(width)]
    keyed = []
    for v in vals:
        if not math.isfinite(v) or (log and v <= 0.0):
            keyed.append(None)
        else:
            keyed.append(math.log10(v) if log else v)
    finite = [k for k in keyed if k is not None]
    if not finite:
        return " " * len(vals)
    lo, hi = min(finite), max(finite)
    span = hi - lo
    out = []
    for k in keyed:
        if k is None:
            out.append(" ")
        elif span == 0.0:
            out.append(SPARK_CHARS[-1])
        else:
            idx = int((k - lo) / span * (len(SPARK_CHARS) - 1))
            out.append(SPARK_CHARS[idx])
    return "".join(out)


def _fmt_table(rows: List[tuple], header: tuple) -> str:
    """Align ``rows`` of strings under ``header``."""
    all_rows = [header] + rows
    widths = [max(len(r[i]) for r in all_rows) for i in range(len(header))]
    lines = ["  ".join(h.ljust(w) for h, w in zip(header, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for r in rows:
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)


def summarize_trace(data) -> str:
    """Human summary of a Chrome trace object (or a path to one): per-name
    span totals, then a roofline aggregation of the ``dispatch`` events."""
    if isinstance(data, str):
        with open(data) as f:
            data = json.load(f)
    events = data.get("traceEvents", [])
    lines = [f"{len(events)} events"]

    # -- span table: total/self-less duration per (category, name) -----------
    spans: Dict[tuple, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        key = (ev.get("cat", ""), ev["name"])
        row = spans.setdefault(key, {"count": 0, "dur_us": 0.0})
        row["count"] += 1
        row["dur_us"] += float(ev.get("dur", 0.0))
    if spans:
        rows = [
            (cat, name, str(row["count"]), f"{row['dur_us'] / 1e3:.3f}")
            for (cat, name), row in sorted(
                spans.items(), key=lambda kv: -kv[1]["dur_us"]
            )
        ]
        lines.append("")
        lines.append(_fmt_table(rows, ("cat", "name", "count", "total_ms")))

    # -- roofline aggregation of dispatch events ------------------------------
    agg: Dict[tuple, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("cat") != "dispatch" or ev.get("ph") != "X":
            continue
        args = ev.get("args", {})
        key = (ev["name"], args.get("space", "?"), args.get("target", "?"))
        row = agg.setdefault(key, {"count": 0, "bytes": 0, "wall_us": 0.0})
        row["count"] += 1
        row["bytes"] += int(args.get("est_bytes", 0) or 0)
        row["wall_us"] += float(ev.get("dur", 0.0))
    if agg:
        rows = []
        for (op, space, target), row in sorted(agg.items()):
            wall_s = row["wall_us"] * 1e-6
            gbs = row["bytes"] / wall_s / 1e9 if wall_s > 0 else 0.0
            rows.append((op, space, target, str(row["count"]),
                         str(row["bytes"]), f"{gbs:.3f}"))
        lines.append("")
        lines.append("dispatch roofline (trace-time GB/s):")
        lines.append(_fmt_table(
            rows, ("op", "space", "target", "count", "est_bytes", "gbs")))
    return "\n".join(lines)


def _metrics_table(records: List[Dict[str, Any]]) -> str:
    rows = []
    for rec in records:
        labels = ",".join(
            f"{k}={v}" for k, v in sorted(rec.get("labels", {}).items())
        )
        if rec.get("kind") == "histogram":
            val = (
                f"n={rec['count']} mean={rec['mean']:.3g} "
                f"min={rec['min']:.3g} max={rec['max']:.3g}"
                if rec.get("count")
                else "n=0"
            )
        else:
            val = f"{rec.get('value', 0.0):.6g}"
        rows.append((rec.get("name", "?"), labels, rec.get("kind", "?"), val))
    if not rows:
        return "(no metrics recorded)"
    return _fmt_table(rows, ("metric", "labels", "kind", "value"))


# =============================================================================
# subcommands
# =============================================================================


def _cmd_trace(args) -> int:
    print(summarize_trace(args.file))
    return 0


def _cmd_validate(args) -> int:
    from repro.observability import trace as trace_mod

    errors = trace_mod.validate_trace(args.file)
    if errors:
        for e in errors:
            print(f"INVALID: {e}")
        print(f"trace-schema: FAIL ({args.file}: {len(errors)} problems)")
        return 1
    print(f"trace-schema: OK ({args.file})")
    return 0


def _cmd_metrics(args) -> int:
    from repro.observability import metrics as metrics_mod

    print(_metrics_table(metrics_mod.load_jsonl(args.file)))
    return 0


def _cmd_solve(args) -> int:
    # imports deferred: trace/validate/metrics must work without touching jax
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import sparse
    from repro.core import make_executor, use_executor
    from repro.launch.dist_solve import build_system
    from repro.observability import convergence, metrics, trace
    from repro.solvers import krylov
    from repro.solvers.common import Stop

    if args.trace:
        trace.enable(args.trace)

    n = 225 if args.smoke else args.n
    nonsym = args.solver in ("bicgstab", "cgs", "gmres")
    a, xstar, b = build_system(n, nonsym=nonsym)
    A = sparse.csr_from_dense(a)
    stop = Stop(max_iters=args.max_iters, reduction_factor=args.tol)
    fn = getattr(krylov, args.solver)

    ex = make_executor(args.executor)
    with use_executor(ex):
        with trace.span("solve", solver=args.solver, n=n):
            t0 = time.perf_counter()
            res = fn(A, jnp.asarray(b), stop=stop, executor=ex, history=True)
            jax.block_until_ready(res.x)
            wall = time.perf_counter() - t0

    hist = convergence.trim(res.history)
    err = float(np.abs(np.asarray(res.x) - xstar).max())
    print(
        f"inspect solve: {args.solver} n={n} executor={args.executor}  "
        f"{int(res.iterations)} iters in {wall * 1e3:.1f} ms, "
        f"residual {float(res.residual_norm):.3e}, error {err:.3e}"
    )
    if hist is not None and len(hist):
        lo, hi = float(np.nanmin(hist)), float(np.nanmax(hist))
        print(f"  residual history ({len(hist)} samples, log scale, "
              f"{hi:.2e} .. {lo:.2e}):")
        print(f"  {sparkline(hist)}")
    if args.metrics:
        metrics.export_jsonl(args.metrics)
        print(f"  metrics -> {args.metrics}")
    if args.trace and trace.export():
        print(f"  trace -> {args.trace}")
    ok = bool(res.converged) and hist is not None and len(hist) > 0
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.inspect", description=__doc__
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("trace", help="summarize a Chrome trace file")
    p.add_argument("file")
    p.set_defaults(fn=_cmd_trace)

    p = sub.add_parser("validate", help="schema-check a trace file (CI gate)")
    p.add_argument("file")
    p.set_defaults(fn=_cmd_validate)

    p = sub.add_parser("metrics", help="render a metrics JSONL as a table")
    p.add_argument("file")
    p.set_defaults(fn=_cmd_metrics)

    p = sub.add_parser(
        "solve", help="demo solve with convergence telemetry + sparkline"
    )
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--n", type=int, default=1024)
    p.add_argument("--solver", default="cg",
                   choices=("cg", "fcg", "bicgstab", "cgs", "gmres"))
    p.add_argument("--executor", default="xla")
    p.add_argument("--max-iters", type=int, default=500)
    p.add_argument("--tol", type=float, default=1e-6)
    p.add_argument("--trace", metavar="OUT_JSON", default=None)
    p.add_argument("--metrics", metavar="OUT_JSONL", default=None)
    p.set_defaults(fn=_cmd_solve)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
