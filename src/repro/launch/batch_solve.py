"""Sharded batched-solve driver: one launch, thousands of systems, N devices.

The batch axis is embarrassingly parallel — every system is independent — so
the driver shards it across the mesh's data axis with the existing mesh
utilities: the shared index structure (``col_idx`` / ``indptr``) replicates,
the value tensor and right-hand sides split on their leading batch axis, and
the masked batched solver runs unchanged under ``jit`` (GSPMD keeps every
per-system reduction local to its shard; the loop's ``any(active)`` is the
only cross-device collective, one bit per iteration).

Usage:
    python -m repro.launch.batch_solve --smoke
    python -m repro.launch.batch_solve --batch 512 --n 64 --solver bicgstab \
        --format csr --precond jacobi --executor xla
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import batch as batch_lib
from repro.core import make_executor, use_executor
from repro.observability import trace
from repro.launch.mesh import compat_make_mesh
from repro.solvers.common import Stop

__all__ = ["build_batch", "shard_batch", "solve_batch", "main"]


def build_batch(
    nb: int, n: int, *, fmt: str = "ell", nonsym: bool = False, seed: int = 0
):
    """``nb`` synthetic shifted-tridiagonal systems of size ``n``.

    The diagonal shift varies across the batch so per-system iteration counts
    differ — the convergence mask has real work to do.  ``nonsym`` adds a
    strictly-upper perturbation (BiCGSTAB territory).
    """
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    stack = np.zeros((nb, n, n), np.float32)
    for b in range(nb):
        a = stack[b]
        a[idx, idx] = 3.0 + 2.0 * (b % 8)
        a[idx[1:], idx[:-1]] = -1.0
        a[idx[:-1], idx[1:]] = -1.0
        if nonsym:
            a += np.triu(rng.normal(size=(n, n)).astype(np.float32) * 0.05, 1)
    xstar = rng.normal(size=(nb, n)).astype(np.float32)
    B = np.einsum("bmn,bn->bm", stack, xstar)
    if fmt == "ell":
        A = batch_lib.batch_ell_from_dense(stack)
    elif fmt == "csr":
        A = batch_lib.batch_csr_from_dense(stack)
    else:
        raise ValueError(f"unknown batched format {fmt!r} (ell | csr)")
    return A, jnp.asarray(B), xstar


def shard_batch(mesh, A, B):
    """Place the batch on the mesh: values/rhs split on the batch axis, the
    shared index structure replicated (it is identical for every system)."""
    batch_spec = NamedSharding(mesh, P("data", *([None] * (A.values.ndim - 1))))
    replicated = NamedSharding(mesh, P())
    leaves, treedef = jax.tree_util.tree_flatten(A)
    shardings = []
    for leaf in leaves:
        if leaf.ndim == A.values.ndim and leaf.shape[0] == A.values.shape[0]:
            shardings.append(batch_spec)
        else:
            shardings.append(replicated)
    A = jax.device_put(A, jax.tree_util.tree_unflatten(treedef, shardings))
    B = jax.device_put(B, NamedSharding(mesh, P("data", None)))
    return A, B


def solve_batch(
    A,
    B,
    *,
    solver: str = "cg",
    precond: str = "none",
    stop: Stop = Stop(),
    executor=None,
):
    fn = {"cg": batch_lib.batch_cg, "bicgstab": batch_lib.batch_bicgstab}[solver]
    M = (
        batch_lib.batch_jacobi_preconditioner(A, executor=executor)
        if precond == "jacobi"
        else None
    )
    return jax.jit(lambda B: fn(A, B, stop=stop, M=M, executor=executor))(B)


def report(res, xstar, wall: float) -> None:
    iters = np.asarray(res.iterations)
    conv = np.asarray(res.converged)
    rnorm = np.asarray(res.residual_norms)
    err = np.abs(np.asarray(res.x) - xstar).max()
    print(f"batch_solve: {res.num_batch} systems in {wall*1e3:.1f} ms")
    print(
        f"  converged {int(conv.sum())}/{conv.size}  "
        f"iterations min/median/max = {iters.min()}/{int(np.median(iters))}/"
        f"{iters.max()}  distinct counts = {len(np.unique(iters))}"
    )
    print(
        f"  residual max = {rnorm.max():.3e}  "
        f"error vs known solution = {err:.3e}"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small end-to-end run (64 systems)")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--n", type=int, default=64, help="rows per system")
    ap.add_argument("--solver", default="cg", choices=("cg", "bicgstab"))
    ap.add_argument("--format", default="ell", choices=("ell", "csr"),
                    dest="fmt")
    ap.add_argument("--precond", default="none", choices=("none", "jacobi"))
    ap.add_argument("--executor", default="xla",
                    help="executor kind or hardware target name")
    ap.add_argument("--max-iters", type=int, default=500)
    ap.add_argument("--tol", type=float, default=1e-6)
    trace.add_cli_flag(ap)
    args = ap.parse_args(argv)
    trace.enable_from_args(args)

    nb = 64 if args.smoke else args.batch
    n = 48 if args.smoke else args.n

    ndev = len(jax.devices())
    # the data axis carries the batch; pad nb up so it divides evenly
    if nb % ndev:
        nb += ndev - nb % ndev
    mesh = compat_make_mesh((ndev,), ("data",))
    print(f"batch_solve: {nb} x ({n}x{n}) {args.fmt} systems, "
          f"{args.solver}/{args.precond}, mesh data={ndev}, "
          f"executor={args.executor}")

    A, B, xstar = build_batch(
        nb, n, fmt=args.fmt, nonsym=(args.solver == "bicgstab")
    )
    A, B = shard_batch(mesh, A, B)
    stop = Stop(max_iters=args.max_iters, reduction_factor=args.tol)

    ex = make_executor(args.executor)
    with use_executor(ex):
        t0 = time.perf_counter()
        res = solve_batch(
            A, B, solver=args.solver, precond=args.precond, stop=stop,
            executor=ex,
        )
        jax.block_until_ready(res.x)
        wall = time.perf_counter() - t0
    report(res, xstar, wall)
    ok = bool(np.asarray(res.converged).all())
    if not ok:
        print("batch_solve: NOT all systems converged")
    if args.trace and trace.export():
        print(f"  trace -> {args.trace}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
