import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init): the dry-run — and only the dry-run — sees 512 host
placeholder devices so ``jax.make_mesh`` can build the production meshes.

Per cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs abstract params / optimizer state / batch / cache
     (ShapeDtypeStruct only — nothing is allocated),
  3. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...)``,
  4. ``.compile()`` — sharding mismatches, non-divisible layouts, or OOM
     surface here and are bugs in the framework,
  5. records ``compiled.memory_analysis()``, ``compiled.cost_analysis()`` and
     the collective-byte census parsed from the optimized HLO
     into ``experiments/dryrun/<cell>.json`` for the §Roofline analysis.

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--zero zero1|fsdp]
"""

import argparse
from repro.observability import trace
import dataclasses
import functools
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config, ARCH_IDS
from repro.distributed import sharding as shd
from repro.launch import costmodel
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh, use_mesh as mesh_lib_use_mesh
from repro.models import lm
from repro.optim import adamw, warmup_cosine_schedule

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

# per-chip hardware constants (TPU v5e) for the roofline terms
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9

_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(?P<dtype>[a-z][a-z0-9]+)\[(?P<dims>[\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
# bytes-on-the-wire multiplier per output byte (ring algorithms)
_WIRE_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        b = _DTYPE_BYTES.get(m.group("dtype"))
        if b is None:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(",") if dims else []:
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_census(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum output bytes of every collective op in the optimized HLO."""
    census: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("out"))
        entry = census.setdefault(op, {"count": 0, "bytes": 0.0})
        entry["count"] += 1
        entry["bytes"] += nbytes
    return census


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train, dense) / 6*N_active*D (MoE); 2*N*D decode."""
    shapes, _ = steps_lib.model_shapes_and_axes(cfg)
    n_total = sum(
        s.size for s in jax.tree_util.tree_leaves(shapes)
        if jnp.issubdtype(s.dtype, jnp.floating)
    )
    n_active = n_total
    if cfg.family == "moe":
        # subtract inactive routed-expert params (padded experts included)
        from repro.nn.moe import padded_experts

        per_expert = 3 * cfg.d_model * cfg.d_expert * cfg.n_layers
        n_active = n_total - (padded_experts(cfg) - cfg.top_k) * per_expert
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    return factor * n_active * tokens, n_total, n_active


def build_cell(arch: str, shape_name: str, *, multi_pod: bool, zero: str = "zero1",
               attn: str = "chunked", sp: bool = True, capacity: float = None,
               remat: str = "block", moe_dispatch: str = "gather"):
    """Returns (jitted_fn, example_args, mesh, cfg, shape).

    ``attn="dense"`` is the paper-faithful straightforward baseline (records
    the S^2 score materialization); ``"chunked"`` is the production portable
    path (flash algorithm in XLA) and the dry-run default — the Pallas flash
    kernel is the TPU-native backend validated in interpret mode.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cfg = dataclasses.replace(cfg, attn_impl=attn)
    if shape.kind == "train":
        # activation checkpointing on by default for the big train cells
        cfg = dataclasses.replace(cfg, remat=remat)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    if sp and shape.kind in ("train", "prefill") and shape.seq_len % 16 == 0:
        # sequence-parallel residual sharding (production default; the
        # non-SP baseline is recorded for the §Perf hillclimb cells)
        cfg = dataclasses.replace(cfg, sp_spec=(batch_axes, "model"))
    if cfg.family == "moe":
        # expert-parallel shard_map dispatch over the model axis
        cfg = dataclasses.replace(
            cfg, moe_spec=(batch_axes, "model"), moe_dispatch=moe_dispatch
        )
        if capacity is not None:
            cfg = dataclasses.replace(cfg, moe_capacity_factor=capacity)
    mesh = make_production_mesh(multi_pod=multi_pod)
    opt = adamw(warmup_cosine_schedule(3e-4, 2000, 100_000))

    shapes, axes, p_sh, opt_shapes, opt_sh = steps_lib.train_shardings(
        mesh, cfg, opt, zero=zero
    )

    if shape.kind == "train":
        batch = steps_lib.batch_struct(cfg, shape.global_batch, shape.seq_len)
        b_sh = shd.batch_shardings(mesh, batch)
        raw = steps_lib.make_train_step(cfg, opt)
        fn = jax.jit(
            raw,
            in_shardings=(p_sh, opt_sh, b_sh),
            out_shardings=(p_sh, opt_sh, None),
        )
        args = (shapes, opt_shapes, batch)
    elif shape.kind == "prefill":
        cache = steps_lib.cache_struct(cfg, shape.global_batch, shape.seq_len)
        c_sh = shd.cache_shardings(mesh, cache, lm.cache_axes(cfg))
        batch = steps_lib.batch_struct(cfg, shape.global_batch, shape.seq_len)
        batch.pop("labels")
        b_sh = shd.batch_shardings(mesh, batch)
        raw = steps_lib.make_prefill_step(cfg)
        fn = jax.jit(
            raw,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(None, c_sh),
        )
        args = (shapes, batch, cache)
    elif shape.kind == "decode":
        cache = steps_lib.cache_struct(cfg, shape.global_batch, shape.seq_len)
        c_sh = shd.cache_shardings(mesh, cache, lm.cache_axes(cfg))
        batch = steps_lib.batch_struct(cfg, shape.global_batch, 1)
        batch.pop("labels")
        b_sh = shd.batch_shardings(mesh, batch)
        length = jax.ShapeDtypeStruct((), jnp.int32)
        raw = steps_lib.make_decode_step(cfg)
        fn = jax.jit(
            raw,
            in_shardings=(p_sh, b_sh, None, c_sh),
            out_shardings=(None, c_sh),
        )
        args = (shapes, batch, length, cache)
    else:
        raise ValueError(shape.kind)
    return fn, raw, args, mesh, cfg, shape


def _peak_bytes(mem) -> Optional[float]:
    """Peak device memory: the direct stat on newer jax, else the
    argument+output+temp sum older CompiledMemoryStats exposes."""
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is not None:
        return float(peak)
    parts = [
        getattr(mem, a, 0) or 0
        for a in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes")
    ]
    return float(sum(parts)) if any(parts) else None


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, zero: str = "zero1",
             attn: str = "chunked", sp: bool = True, capacity: float = None,
             remat: str = "block", moe_dispatch: str = "gather",
             flash_cost: bool = False, tag: str = "",
             save: bool = True, verbose: bool = True) -> Dict:
    t0 = time.perf_counter()
    fn, raw_fn, args, mesh, cfg, shape = build_cell(
        arch, shape_name, multi_pod=multi_pod, zero=zero, attn=attn, sp=sp,
        capacity=capacity, remat=remat, moe_dispatch=moe_dispatch,
    )
    n_chips = mesh.size
    with mesh_lib_use_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        logical = costmodel.function_cost(raw_fn, *args)
        logical_flash = None
        if flash_cost and shape.kind in ("prefill", "decode"):
            # kernel-contract costing: trace under the Pallas executor so the
            # hot ops appear as pallas_call units (HBM traffic = BlockSpec io)
            from repro.core import PallasInterpretExecutor, use_executor

            with use_executor(PallasInterpretExecutor()):
                logical_flash = costmodel.function_cost(raw_fn, *args)

    mem = compiled.memory_analysis()
    cost = costmodel.hlo_cost_analysis(compiled)
    hlo = compiled.as_text()
    census = collective_census(hlo)

    # raw HLO cost analysis (recorded for reference) undercounts while-loop
    # bodies (counted once regardless of trip count — see costmodel.py), so
    # the roofline compute/memory terms come from the jaxpr walker instead.
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    coll_bytes = sum(
        e["bytes"] * _WIRE_FACTOR[op] for op, e in census.items()
    )

    mflops, n_total, n_active = model_flops(cfg, shape)
    compute_t = logical["flops"] / n_chips / PEAK_FLOPS
    # memory term uses the fusion-aware estimate; the unfused upper bound is
    # recorded alongside (see costmodel.py for both definitions)
    memory_t = logical["fused_bytes"] / n_chips / HBM_BW
    memory_t_unfused = logical["bytes"] / n_chips / HBM_BW
    collective_t = coll_bytes / ICI_BW

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": f"{'2x16x16' if multi_pod else '16x16'}",
        "chips": n_chips,
        "zero": zero,
        "attn": attn,
        "sp": sp,
        "tag": tag,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "logical_flops": logical["flops"] / n_chips,
            "logical_bytes_unfused": logical["bytes"] / n_chips,
            "logical_bytes_fused_est": logical["fused_bytes"] / n_chips,
            "hlo_flops_raw": hlo_flops,  # while bodies counted once — see costmodel
            "hlo_bytes_raw": hlo_bytes,
            "collective_bytes_wire": coll_bytes,
        },
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": _peak_bytes(mem),
        },
        "collectives": census,
        "roofline": {
            "compute_s": compute_t,
            "memory_s": memory_t,
            "memory_s_unfused": memory_t_unfused,
            "collective_s": collective_t,
            "bottleneck": max(
                ("compute", compute_t),
                ("memory", memory_t),
                ("collective", collective_t),
                key=lambda kv: kv[1],
            )[0],
        },
        "model_flops": {
            "total_params": n_total,
            "active_params": n_active,
            "model_flops_global": mflops,
            "model_flops_per_chip": mflops / n_chips,
            "useful_fraction": mflops / logical["flops"] if logical["flops"] else None,
        },
    }
    if logical_flash is not None:
        result["roofline_flash"] = {
            "compute_s": logical_flash["flops"] / n_chips / PEAK_FLOPS,
            "memory_s": logical_flash["fused_bytes"] / n_chips / HBM_BW,
        }
    if verbose:
        r = result["roofline"]
        print(
            f"[{arch} x {shape_name} x {result['mesh']}] compile {t_compile:.0f}s | "
            f"compute {r['compute_s']*1e3:.2f}ms memory {r['memory_s']*1e3:.2f}ms "
            f"collective {r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}-bound | "
            f"useful {result['model_flops']['useful_fraction']}"
        )
        print(f"  memory_analysis: {result['memory_analysis']}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = "_mp" if multi_pod else ""
        zsuffix = "" if zero == "zero1" else f"_{zero}"
        asuffix = "" if attn == "chunked" else f"_{attn}"
        tsuffix = f"_{tag}" if tag else ""
        path = os.path.join(
            OUT_DIR, f"{arch}__{shape_name}{suffix}{zsuffix}{asuffix}{tsuffix}.json"
        )
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero", default="zero1", choices=("none", "zero1", "fsdp"))
    ap.add_argument("--attn", default="chunked", choices=("dense", "chunked"))
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel residual sharding")
    ap.add_argument("--capacity", type=float, default=None,
                    help="MoE expert-parallel capacity factor")
    ap.add_argument("--remat", default="block", choices=("none", "block", "dots"))
    ap.add_argument("--flash-cost", action="store_true",
                    help="also cost the Pallas kernel-contract path (prefill/decode)")
    ap.add_argument("--moe-dispatch", default="gather", choices=("gather", "a2a"))
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    trace.add_cli_flag(ap)
    args = ap.parse_args()
    trace.enable_from_args(args)

    if args.all:
        failures = []
        for arch in ARCH_IDS:
            for shape_name in cells(arch):
                try:
                    run_cell(arch, shape_name, multi_pod=args.multi_pod,
                             zero=args.zero, attn=args.attn)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((arch, shape_name, repr(e)))
                    print(f"[{arch} x {shape_name}] FAILED: {e}")
        if failures:
            raise SystemExit(f"{len(failures)} cells failed: {failures}")
        print("ALL CELLS PASSED")
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        run_cell(args.arch.replace("-", "_"), args.shape,
                 multi_pod=args.multi_pod, zero=args.zero, attn=args.attn,
                 sp=not args.no_sp, capacity=args.capacity, remat=args.remat,
                 moe_dispatch=args.moe_dispatch,
                 flash_cost=args.flash_cost, tag=args.tag)
    if args.trace and trace.export():
        print(f"trace -> {args.trace}")


if __name__ == "__main__":
    main()
