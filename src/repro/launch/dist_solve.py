"""Distributed-solve driver: one Krylov solve sharded across the devices.

The distributed twin of ``repro.launch.batch_solve``: build a sparse SPD (or
perturbed nonsymmetric) system, row-partition it over the available devices
(:class:`repro.distributed.Partition` + :class:`DistCsr`/:class:`DistEll`),
and hand it to the UNCHANGED solver entry point — ``krylov.cg`` notices the
distributed operand and runs the whole iteration under ``shard_map`` (local
SpMV + halo exchange, psum reductions).  The run is checked against the
single-device solve: same iteration count (±1), matching solution.

Usage:
    python -m repro.launch.dist_solve --smoke
    python -m repro.launch.dist_solve --n 4096 --solver cg --format csr \
        --precond block_jacobi --shards 8 --executor xla

On a CPU host, force virtual devices first:
    XLA_FLAGS=--xla_force_host_platform_device_count=8
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import sparse
from repro.core import make_executor, use_executor
from repro.distributed import DistCsr, DistEll, Partition
from repro.observability import trace
from repro.solvers import krylov
from repro.solvers.common import Stop

__all__ = ["build_system", "main"]


def build_system(n: int, *, nonsym: bool = False, seed: int = 0):
    """2-D five-point stencil on the largest square grid fitting ``n`` rows,
    padded with a shifted-diagonal tail so any ``n`` works; SPD by
    construction, optionally perturbed strictly-upper for the nonsymmetric
    solvers."""
    rng = np.random.default_rng(seed)
    side = max(1, int(np.sqrt(n)))
    a = np.zeros((n, n), np.float32)
    idx = np.arange(n)
    a[idx, idx] = 4.0
    for r in range(n):
        i, j = divmod(r, side)
        if j > 0:
            a[r, r - 1] = -1.0
        if j < side - 1 and r + 1 < n:
            a[r, r + 1] = -1.0
        if i > 0:
            a[r, r - side] = -1.0
        if r + side < n:
            a[r, r + side] = -1.0
    if nonsym:
        mask = rng.random((n, n)) < min(1.0, 8.0 / n)
        a += np.triu(np.where(mask, 0.05, 0.0), 1).astype(np.float32)
    xstar = rng.normal(size=n).astype(np.float32)
    return a, xstar, (a @ xstar).astype(np.float32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small end-to-end run with parity check")
    ap.add_argument("--n", type=int, default=1024, help="global rows")
    ap.add_argument("--solver", default="cg",
                    choices=("cg", "fcg", "bicgstab", "cgs", "gmres"))
    ap.add_argument("--format", default="csr", choices=("csr", "ell"),
                    dest="fmt")
    ap.add_argument("--precond", default="none",
                    choices=("none", "jacobi", "block_jacobi"))
    ap.add_argument("--shards", type=int, default=0,
                    help="parts (default: all devices)")
    ap.add_argument("--executor", default="xla",
                    help="executor kind or hardware target name")
    ap.add_argument("--max-iters", type=int, default=500)
    ap.add_argument("--tol", type=float, default=1e-6)
    trace.add_cli_flag(ap)
    args = ap.parse_args(argv)
    trace.enable_from_args(args)

    n = 225 if args.smoke else args.n
    ndev = len(jax.devices())
    shards = args.shards or ndev
    if shards > ndev:
        print(f"dist_solve: clamping --shards {shards} to {ndev} devices")
        shards = ndev

    nonsym = args.solver in ("bicgstab", "cgs", "gmres")
    a, xstar, b = build_system(n, nonsym=nonsym)
    A = sparse.csr_from_dense(a) if args.fmt == "csr" else sparse.ell_from_dense(a)
    part = Partition.uniform(n, shards)
    dist_cls = DistCsr if args.fmt == "csr" else DistEll
    Ad = dist_cls.from_matrix(A, part)
    print(
        f"dist_solve: n={n} {args.fmt} nnz={Ad.nnz} over {shards} shards "
        f"(sizes {min(part.part_sizes)}..{max(part.part_sizes)}, halo cols "
        f"{min(Ad.num_halo_cols)}..{max(Ad.num_halo_cols)}), "
        f"{args.solver}/{args.precond}, executor={args.executor}"
    )

    stop = Stop(max_iters=args.max_iters, reduction_factor=args.tol)
    fn = getattr(krylov, args.solver)
    M = None if args.precond == "none" else args.precond
    ex = make_executor(args.executor)
    with use_executor(ex):
        single = fn(A, jnp.asarray(b), stop=stop, M=M, executor=ex)
        t0 = time.perf_counter()
        res = fn(Ad, jnp.asarray(b), stop=stop, M=M, executor=ex)
        jax.block_until_ready(res.x)
        wall = time.perf_counter() - t0

    err = np.abs(np.asarray(res.x) - xstar).max()
    diff = np.abs(np.asarray(res.x) - np.asarray(single.x)).max()
    iters_d, iters_s = int(res.iterations), int(single.iterations)
    print(
        f"  distributed: {iters_d} iters, residual {float(res.residual_norm):.3e}, "
        f"{wall*1e3:.1f} ms   single-device: {iters_s} iters"
    )
    print(f"  error vs known solution = {err:.3e}, vs single-device = {diff:.3e}")

    # block-Jacobi is block-LOCAL per shard: when shard boundaries split a
    # block, the distributed preconditioner differs from the single-device
    # one and iteration counts legitimately diverge — only the solutions
    # must still agree
    same_preconditioner = args.precond != "block_jacobi" or shards == 1
    iters_ok = abs(iters_d - iters_s) <= 1 if same_preconditioner else True
    ok = bool(res.converged) and iters_ok and diff < 1e-3
    if not ok:
        print("dist_solve: PARITY FAILURE")
    if args.trace and trace.export():
        print(f"  trace -> {args.trace}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
