"""Serving driver: batched prefill + decode with KV/state caches.

Greedy decoding over batched synthetic prompts; demonstrates the serving
contract every architecture implements (prefill fills the cache at offset 0,
decode_step appends one token), including the attention-free (RWKV) and
hybrid (Zamba2) recurrent-state paths.

Usage:
    python -m repro.launch.serve --arch rwkv6-3b --smoke --prompt-len 32 \
        --gen-len 32 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.observability import trace
from repro.launch import steps as steps_lib
from repro.models import lm


def serve(cfg, *, batch: int, prompt_len: int, gen_len: int, seed: int = 0,
          greedy: bool = True, temperature: float = 1.0):
    rng = np.random.default_rng(seed)
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    s_max = prompt_len + gen_len

    feed = {}
    if cfg.frontend == "stub_embeddings":
        feed["embeds"] = jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.d_model)).astype(np.float32)
        )
    else:
        feed["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, prompt_len)), jnp.int32
        )

    prefill_fn = jax.jit(steps_lib.make_prefill_step(cfg))
    decode_fn = jax.jit(steps_lib.make_decode_step(cfg))

    cache = lm.init_cache(cfg, batch, s_max)
    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, feed, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(seed)

    def sample(logits, key):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(
            jnp.int32
        )

    tokens = sample(logits, key)  # (B,)
    generated = [tokens]
    t0 = time.perf_counter()
    for t in range(prompt_len, prompt_len + gen_len - 1):
        key, sub = jax.random.split(key)
        step_feed = {}
        if cfg.frontend == "stub_embeddings":
            # stub frontend: embed the sampled token through the LM embedding
            step_feed["embeds"] = lm.embed(
                params["embedding"], tokens[:, None]
            ).astype(jnp.dtype(cfg.dtype))
        else:
            step_feed["tokens"] = tokens[:, None]
        logits, cache = decode_fn(params, step_feed, jnp.int32(t), cache)
        tokens = sample(logits, sub)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    out = jnp.stack(generated, axis=1)  # (B, gen_len)
    tok_s = batch * (gen_len - 1) / max(t_decode, 1e-9)
    print(
        f"[serve] {cfg.name}: prefill {batch}x{prompt_len} in {t_prefill*1e3:.0f}ms; "
        f"decode {gen_len-1} steps at {tok_s:.1f} tok/s"
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="rng seed for prompts and sampling")
    trace.add_cli_flag(ap)
    args = ap.parse_args()
    trace.enable_from_args(args)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    serve(
        cfg,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen_len=args.gen_len,
        seed=args.seed,
        greedy=args.temperature == 0.0,
        temperature=max(args.temperature, 1e-3),
    )
    if args.trace and trace.export():
        print(f"trace -> {args.trace}")


if __name__ == "__main__":
    main()
