"""Step factories: train_step / prefill_step / decode_step + their shardings.

These are the functions the launcher jits and the dry-run lowers.  Everything
configuration-dependent is closed over (static); everything data-dependent is
an argument (traced).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.launch.mesh import shard_map
from repro.models import lm
from repro.optim.adamw import AdamWState


def make_train_step(cfg, optimizer):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True
        )(params, cfg, batch)
        params, opt_state, stats = optimizer.update(params, grads, opt_state)
        return params, opt_state, {**metrics, **stats}

    return train_step


def make_grad_accum_train_step(cfg, optimizer, num_microbatches: int):
    """Microbatched gradient accumulation via lax.scan (compute/comm overlap:
    XLA schedules microbatch i+1's compute against microbatch i's gradient
    reduction)."""

    def train_step(params, opt_state, batch):
        def split(x):
            b = x.shape[0]
            return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

        micro = {k: split(v) for k, v in batch.items()}

        def acc_fn(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
                params, cfg, mb
            )
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, grads)
            return (g_acc, loss_acc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss_sum), _ = jax.lax.scan(acc_fn, (zeros, 0.0), micro)
        grads = jax.tree_util.tree_map(lambda g: g / num_microbatches, grads)
        params, opt_state, stats = optimizer.update(params, grads, opt_state)
        stats = dict(stats)
        stats["loss"] = loss_sum / num_microbatches
        return params, opt_state, stats

    return train_step


def make_compressed_dp_train_step(cfg, optimizer, data_axis: str = "data"):
    """Explicit-DP train step with int8 error-feedback gradient compression.

    The cross-replica gradient reduction — the collective that crosses the
    slowest links (DCN between pods) at 1000-node scale — runs on an int8
    payload via :func:`repro.optim.compressed_psum`; quantization error is
    carried per replica in an error-feedback state (leading device axis,
    sharded over the data axis).

    Params/optimizer state are replicated (pure DP; compose with TP by
    nesting inside the model's sharded ops as usual).

    Returns ``train_step(params, opt_state, err_state, batch)`` and
    ``init_err_state(params, num_replicas)``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.optim.compression import compressed_psum

    def init_err_state(params, num_replicas: int):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros((num_replicas,) + p.shape, jnp.float32), params
        )

    def train_step(params, opt_state, err_state, batch):
        def body(params, opt_state, err_stacked, batch_l):
            err_l = jax.tree_util.tree_map(lambda e: e[0], err_stacked)
            (loss, metrics), grads = jax.value_and_grad(
                lm.loss_fn, has_aux=True
            )(params, cfg, batch_l)
            grads, err_l = compressed_psum(grads, err_l, data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            params, opt_state, stats = optimizer.update(params, grads, opt_state)
            err_stacked = jax.tree_util.tree_map(lambda e: e[None], err_l)
            stats = dict(stats)
            stats["loss"] = loss
            return params, opt_state, err_stacked, stats

        replicated = jax.tree_util.tree_map(lambda _: P(), params)
        opt_rep = jax.tree_util.tree_map(lambda _: P(), opt_state)
        err_specs = jax.tree_util.tree_map(lambda _: P(data_axis), err_state)
        batch_specs = {k: P(data_axis) for k in batch}
        stats_specs = {k: P() for k in
                       ("loss", "lr", "grad_norm", "param_norm")}
        return shard_map(
            body,
            in_specs=(replicated, opt_rep, err_specs, batch_specs),
            out_specs=(replicated, opt_rep, err_specs, stats_specs),
            check_vma=False,  # optimizer math is replica-identical by
            # construction (same compressed grads everywhere)
        )(params, opt_state, err_state, batch)

    return train_step, init_err_state


def make_prefill_step(cfg):
    def prefill_step(params, batch, cache):
        logits, cache = lm.prefill(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            cache=cache,
        )
        # serving returns the last position's logits (next-token distribution)
        return logits[:, -1, :], cache

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, batch, length, cache):
        logits, cache = lm.decode_step(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            length=length,
            cache=cache,
        )
        return logits[:, -1, :], cache

    return decode_step


# =============================================================================
# shapes + shardings for a (cfg, shape, mesh) cell
# =============================================================================

def model_shapes_and_axes(cfg):
    """Abstract param shapes + logical axes without materializing anything."""
    box = {}

    def f():
        params, axes = lm.init_model(jax.random.PRNGKey(0), cfg)
        box["axes"] = axes
        return params

    shapes = jax.eval_shape(f)
    return shapes, box["axes"]


def opt_state_shapes(optimizer, param_shapes):
    return jax.eval_shape(optimizer.init, param_shapes)


def batch_struct(cfg, global_batch: int, seq_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    toks = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    out = {"labels": toks}
    if cfg.frontend == "stub_embeddings":
        out["embeds"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    else:
        out["tokens"] = toks
    return out


def cache_struct(cfg, batch: int, s_max: int):
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, s_max))


def train_shardings(mesh, cfg, optimizer, *, zero: str = "zero1"):
    """(param_sh, opt_sh) trees for the cell."""
    shapes, axes = model_shapes_and_axes(cfg)
    p_sh = shd.param_shardings(mesh, shapes, axes, zero="fsdp" if zero == "fsdp" else "none")
    opt_shapes = opt_state_shapes(optimizer, shapes)
    m_zero = "zero1" if zero in ("zero1", "fsdp") else "none"
    mu_sh = shd.moment_shardings(mesh, opt_shapes.mu, axes, zero=m_zero)
    nu_sh = shd.moment_shardings(mesh, opt_shapes.nu, axes, zero=m_zero)
    opt_sh = AdamWState(step=shd.replicated(mesh), mu=mu_sh, nu=nu_sh)
    return shapes, axes, p_sh, opt_shapes, opt_sh
