"""repro.launch — mesh, steps, dry-run, training and serving drivers.

NOTE: import ``repro.launch.dryrun`` only as a __main__ entry point — it sets
XLA_FLAGS for 512 placeholder devices before jax initializes.
"""
