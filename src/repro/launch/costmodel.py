"""Jaxpr-level cost model: exact FLOP/byte totals with scan trip counts.

Why not ``compiled.cost_analysis()`` alone: XLA's HLO cost analysis counts a
while-loop body ONCE regardless of trip count (verified in
tests/launch/test_costmodel.py), which undercounts every scan-based model by
~n_layers x.  This walker traverses the jaxpr instead, recursing into
scan bodies with explicit ``length`` multipliers, giving exact *logical*
totals:

* flops: 2*M*N*K per dot_general (batch included), 1/elem for elementwise,
  1/elem for reductions;
* bytes: sum of operand+result sizes per equation — a fusion-blind upper
  proxy for HBM traffic (same blindness as HLO bytes-accessed, but with
  correct trip counts).

The dry-run divides by chip count for per-device terms (exact for evenly
sharded programs; replicated compute makes real per-chip numbers higher —
noted per cell).  Collective bytes still come from the optimized HLO census
(dryrun.collective_census), which is per-device and partition-aware.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import numpy as np
from jax.extend import core as jcore

# pure layout ops: no flops; usually folded into consumers on TPU (fused
# traffic estimate: 0), but counted in the unfused upper bound
LAYOUT_OPS = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "copy", "device_put", "iota", "stop_gradient",
    "bitcast_convert_type", "slice", "rev",
}
# data-movement ops: no flops, but genuinely move memory even when fused
MOVEMENT_OPS = {
    "gather", "scatter", "scatter-add", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "pad",
}

TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "sin", "cos", "rsqrt",
                  "sqrt", "erf", "cbrt", "log1p", "expm1", "pow"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0  # unfused: every eqn's operands + results
    fused_bytes: float = 0.0  # fusion estimate: elementwise -> output-only
    transcendentals: float = 0.0

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(
            self.flops + o.flops,
            self.bytes + o.bytes,
            self.fused_bytes + o.fused_bytes,
            self.transcendentals + o.transcendentals,
        )

    def __mul__(self, k: float) -> "Cost":
        return Cost(
            self.flops * k,
            self.bytes * k,
            self.fused_bytes * k,
            self.transcendentals * k,
        )


def _aval_bytes(aval) -> float:
    if not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64) * np.dtype(aval.dtype).itemsize)


def _eqn_io_bytes(eqn) -> float:
    total = 0.0
    for v in eqn.invars:
        if isinstance(v, jcore.Literal):
            continue
        total += _aval_bytes(v.aval)
    for v in eqn.outvars:
        total += _aval_bytes(v.aval)
    return total


def _eqn_out_bytes(eqn) -> float:
    return float(sum(_aval_bytes(v.aval) for v in eqn.outvars))


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = np.prod([lhs.shape[i] for i in lb], dtype=np.float64) if lb else 1.0
    contract = np.prod([lhs.shape[i] for i in lc], dtype=np.float64) if lc else 1.0
    m = np.prod(
        [d for i, d in enumerate(lhs.shape) if i not in set(lc) | set(lb)],
        dtype=np.float64,
    )
    n = np.prod(
        [d for i, d in enumerate(rhs.shape) if i not in set(rc) | set(rb)],
        dtype=np.float64,
    )
    return float(2.0 * batch * m * n * contract)


def _out_elems(eqn) -> float:
    return float(
        sum(np.prod(v.aval.shape, dtype=np.float64) for v in eqn.outvars
            if hasattr(v.aval, "shape"))
    )


def _subjaxpr_cost(params_value) -> Cost:
    if params_value is None:
        return Cost()
    if hasattr(params_value, "jaxpr"):  # ClosedJaxpr
        return jaxpr_cost(params_value.jaxpr)
    return jaxpr_cost(params_value)


def jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            inner = _subjaxpr_cost(eqn.params["jaxpr"])
            total = total + inner * float(eqn.params["length"])
        elif name == "while":
            # trip count is data-dependent; count the body once and flag via
            # transcendentals? -> body once (documented; solver loops only)
            total = total + _subjaxpr_cost(eqn.params["body_jaxpr"])
            total = total + _subjaxpr_cost(eqn.params["cond_jaxpr"])
        elif name == "cond":
            branches = eqn.params["branches"]
            costs = [_subjaxpr_cost(b) for b in branches]
            worst = max(costs, key=lambda c: c.flops + c.bytes, default=Cost())
            total = total + worst
        elif name in ("jit", "pjit", "closed_call", "core_call", "xla_call",
                      "custom_vjp_call", "custom_jvp_call", "remat2", "checkpoint",
                      "custom_vjp_call_jaxpr", "named_call"):
            sub = (
                eqn.params.get("jaxpr")
                or eqn.params.get("call_jaxpr")
                or eqn.params.get("fun_jaxpr")
            )
            total = total + _subjaxpr_cost(sub)
        elif name == "pallas_call":
            # hand-written kernel: HBM traffic is the call's visible io (the
            # kernel's VMEM-resident intermediates never touch HBM); flops =
            # body flops x grid steps
            inner = _subjaxpr_cost(eqn.params.get("jaxpr"))
            grid = getattr(eqn.params.get("grid_mapping"), "grid", ()) or ()
            steps = float(np.prod([g for g in grid if isinstance(g, int)] or [1]))
            io = _eqn_io_bytes(eqn)
            total = total + Cost(
                flops=inner.flops * steps,
                bytes=io,
                fused_bytes=io,
                transcendentals=inner.transcendentals * steps,
            )
        elif name == "shard_map":
            inner = _subjaxpr_cost(eqn.params.get("jaxpr"))
            mesh = eqn.params.get("mesh")
            n = getattr(mesh, "size", 1) or 1
            total = total + inner * float(n)
        elif name == "dot_general":
            io = _eqn_io_bytes(eqn)
            total = total + Cost(flops=_dot_flops(eqn), bytes=io, fused_bytes=io)
        elif name == "ragged_dot":
            lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
            m, kdim = lhs.shape
            n = rhs.shape[-1]
            io = _eqn_io_bytes(eqn)
            total = total + Cost(flops=float(2 * m * kdim * n), bytes=io,
                                 fused_bytes=io)
        elif name in ("conv_general_dilated",):
            # rare here; approximate with dot-equivalent on output elems
            out = _out_elems(eqn)
            k = np.prod(eqn.invars[1].aval.shape, dtype=np.float64)
            io = _eqn_io_bytes(eqn)
            total = total + Cost(flops=float(2 * out * k), bytes=io, fused_bytes=io)
        elif name in LAYOUT_OPS:
            total = total + Cost(bytes=_eqn_io_bytes(eqn), fused_bytes=0.0)
        elif name in MOVEMENT_OPS:
            io = _eqn_io_bytes(eqn)
            total = total + Cost(bytes=io, fused_bytes=io)
        elif name in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                      "reduce_and", "reduce_or", "argmax", "argmin",
                      "cumsum", "cumlogsumexp", "cummax", "cumprod"):
            in_elems = float(
                sum(np.prod(v.aval.shape, dtype=np.float64) for v in eqn.invars
                    if not isinstance(v, jcore.Literal) and hasattr(v.aval, "shape"))
            )
            io = _eqn_io_bytes(eqn)
            total = total + Cost(flops=in_elems, bytes=io, fused_bytes=io)
        elif name in ("sort",):
            n = _out_elems(eqn)
            io = _eqn_io_bytes(eqn)
            total = total + Cost(
                flops=float(n * max(np.log2(max(n, 2)), 1)), bytes=io,
                fused_bytes=io,
            )
        elif name in TRANSCENDENTAL:
            n = _out_elems(eqn)
            total = total + Cost(flops=n, bytes=_eqn_io_bytes(eqn),
                                 fused_bytes=_eqn_out_bytes(eqn),
                                 transcendentals=n)
        else:
            # default: elementwise — 1 flop per output element; fused traffic
            # = output only (operand reads fuse with producers on TPU)
            total = total + Cost(flops=_out_elems(eqn), bytes=_eqn_io_bytes(eqn),
                                 fused_bytes=_eqn_out_bytes(eqn))
    return total


def function_cost(fn, *args, **kwargs) -> Dict[str, float]:
    """Trace ``fn`` abstractly (ShapeDtypeStruct args ok) and walk its jaxpr.

    A fresh wrapper defeats jax's trace cache: dispatch decisions inside
    ``fn`` may depend on ambient context (the executor contextvar), which is
    not part of the cache key.
    """
    jaxpr = jax.make_jaxpr(lambda *a, **k: fn(*a, **k))(*args, **kwargs)
    c = jaxpr_cost(jaxpr.jaxpr)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "fused_bytes": c.fused_bytes,
        "transcendentals": c.transcendentals,
    }


def hlo_cost_analysis(compiled) -> Dict[str, float]:
    """Version-tolerant ``compiled.cost_analysis()``.

    jax <= 0.4.x returns a one-element list of dicts (per device assignment);
    newer jax returns the dict directly.  Either way: a flat dict (possibly
    empty).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
