"""Training driver: data -> sharded train_step -> checkpoints, fault-tolerant.

Runs anywhere: on this CPU container with ``--smoke`` (reduced config, visible
loss decrease against the synthetic chain's entropy floor), on a real pod with
the full config.  Wiring demonstrated here:

* deterministic resumable data (repro.data),
* pjit train step with logical-axis shardings (repro.distributed.sharding),
* async atomic checkpoints + exact resume (step, data state) (repro.checkpoint),
* preemption checkpoint-and-exit, straggler monitor, restart supervisor
  (repro.runtime).

Usage:
    python -m repro.launch.train --arch smollm-135m --smoke --steps 60
    python -m repro.launch.train --arch smollm-135m --smoke --steps 60 \
        --resume --ckpt-dir /tmp/ckpt   # restart path
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.observability import trace
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, DataIterator, entropy_floor
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw, warmup_cosine_schedule
from repro.runtime import PreemptionHandler, StragglerMonitor


def build_state(cfg, opt, mesh, ckpt: Optional[CheckpointManager], data_cfg):
    """Init or restore (params, opt_state, data_iter, start_step)."""
    shapes, axes = steps_lib.model_shapes_and_axes(cfg)
    p_sh = shd.param_shardings(mesh, shapes, axes)

    data_iter = DataIterator(data_cfg)
    if ckpt is not None and ckpt.latest_step() is not None:
        opt_shapes = steps_lib.opt_state_shapes(opt, shapes)
        target = {"params": shapes, "opt": opt_shapes}
        shardings = {"params": p_sh, "opt": jax.tree_util.tree_map(
            lambda _: shd.replicated(mesh), opt_shapes)}
        tree, meta = ckpt.restore(target=target, shardings=shardings)
        data_iter.restore(meta["data"])
        print(f"[train] restored step {meta['step']} from {ckpt.directory}")
        return tree["params"], tree["opt"], data_iter, int(meta["step"])

    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, p_sh)
    opt_state = opt.init(params)
    return params, opt_state, data_iter, 0


def train(
    cfg,
    *,
    steps: int,
    global_batch: int,
    seq_len: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    resume: bool = False,
    data_shards: int = 1,
    mesh=None,
    log_every: int = 10,
    preemption: Optional[PreemptionHandler] = None,
    stop_at_step: Optional[int] = None,  # simulate an interruption (tests)
):
    mesh = mesh or make_host_mesh(1, 1)
    opt = adamw(warmup_cosine_schedule(3e-3, max(steps // 10, 1), steps),
                weight_decay=0.01)
    data_cfg = DataConfig(
        vocab=cfg.vocab,
        seq_len=seq_len,
        global_batch=global_batch,
        num_shards=data_shards,
        seed=17,
        stub_embed_dim=cfg.d_model if cfg.frontend == "stub_embeddings" else 0,
    )
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if not resume and ckpt is not None and ckpt.latest_step() is not None:
        raise SystemExit(
            f"{ckpt_dir} already has checkpoints; pass --resume to continue"
        )

    params, opt_state, data_iter, start = build_state(cfg, opt, mesh, ckpt, data_cfg)
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt), donate_argnums=(0, 1))
    monitor = StragglerMonitor(window=50, factor=4.0)

    losses = []
    t_start = time.perf_counter()
    with mesh:
        for step in range(start, steps):
            batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
            monitor.start_step()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            if monitor.end_step():
                print(f"[train] step {step}: straggler alarm "
                      f"(median {monitor.median*1e3:.0f}ms)")
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm "
                    f"{float(metrics['grad_norm']):.2f}"
                )
            want_ckpt = ckpt is not None and (
                (step + 1) % ckpt_every == 0 or step == steps - 1
            )
            if preemption is not None and preemption.preempted:
                if ckpt is not None:
                    ckpt.save(step + 1, {"params": params, "opt": opt_state},
                              metadata={"step": step + 1, "data": data_iter.state()},
                              block=True)
                    print(f"[train] preempted — checkpointed step {step+1}, exiting")
                return params, losses
            if want_ckpt:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          metadata={"step": step + 1, "data": data_iter.state()})
            if stop_at_step is not None and step + 1 >= stop_at_step:
                if ckpt is not None:
                    ckpt.wait()
                print(f"[train] stopped at step {step + 1} (requested)")
                return params, losses
    if ckpt is not None:
        ckpt.wait()
    dt = time.perf_counter() - t_start
    tok_s = (steps - start) * global_batch * seq_len / max(dt, 1e-9)
    print(f"[train] done: {steps - start} steps in {dt:.1f}s ({tok_s:.0f} tok/s); "
          f"final loss {losses[-1]:.4f} (entropy floor {entropy_floor(data_cfg):.4f})")
    return params, losses


def train_deq(*, steps: int, batch: int, lr: float = 3e-2,
              log_every: int = 5) -> bool:
    """Train the deep-equilibrium regression model end to end.

    Every forward is a batched GMRES solve; every backward an adjoint solve
    through the ``Transpose`` combinator.  Returns True when the loss
    strictly decreased from first to last logged value (the DEQ-GATE
    criterion).
    """
    from repro.models import deq as deq_lib

    cfg = deq_lib.DeqConfig()
    params = deq_lib.init_deq(jax.random.PRNGKey(0), cfg)
    opt = adamw(lambda _: jnp.asarray(lr, jnp.float32),
                weight_decay=0.0, clip_norm=None)
    opt_state = opt.init(params)
    batch_data = deq_lib.synthetic_batch(0, batch, cfg)

    @jax.jit
    def step_fn(params, opt_state, batch_data):
        loss, grads = jax.value_and_grad(deq_lib.deq_loss)(params, batch_data, cfg)
        params, opt_state, _ = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    losses = []
    for step in range(steps):
        params, opt_state, loss = step_fn(params, opt_state, batch_data)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"[deq] step {step:4d} loss {losses[-1]:.6f}")
    decreased = losses[-1] < losses[0]
    print(f"DEQ-GATE: {'PASS' if decreased else 'FAIL'} "
          f"(loss {losses[0]:.6f} -> {losses[-1]:.6f})")
    return decreased


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--model", default="lm", choices=["lm", "deq"])
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    trace.add_cli_flag(ap)
    args = ap.parse_args()
    trace.enable_from_args(args)

    if args.model == "deq":
        steps = min(args.steps, 30) if args.smoke else args.steps
        ok = train_deq(steps=steps, batch=args.global_batch)
        raise SystemExit(0 if ok else 1)

    if args.arch is None:
        ap.error("--arch is required for --model lm")
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    handler = PreemptionHandler().install()
    train(
        cfg,
        steps=args.steps,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        resume=args.resume,
        preemption=handler,
    )
    if args.trace and trace.export():
        print(f"trace -> {args.trace}")


if __name__ == "__main__":
    main()
