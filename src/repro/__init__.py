"""repro — Ginkgo's platform-portability design as a multi-pod JAX framework.

Subpackages:
  core         the paper's contribution: executors, op registry, coop groups
  sparse       COO/CSR/ELL/SELL-P + executor-dispatched SpMV
  solvers      CG/FCG/BiCGSTAB/CGS/GMRES + Jacobi/block-Jacobi/ParILU
  kernels      Pallas TPU kernels (flash attention, spmv, rmsnorm, ssd, rwkv6)
  nn, models   layer library + the 10 assigned architectures
  configs      architecture/shape configuration system
  data, optim, checkpoint, runtime   training substrate
  distributed  sharding rules, collective matmuls
  launch       mesh, dry-run, train/serve drivers, roofline cost model
"""

__version__ = "0.1.0"
