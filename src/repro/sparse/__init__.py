"""repro.sparse — Ginkgo's sparse formats and SpMV, executor-dispatched."""

from repro.sparse.formats import (
    Coo,
    Csr,
    Dense,
    Ell,
    Sellp,
    convert,
    coo_from_dense,
    csr_from_arrays,
    csr_from_dense,
    csr_host_arrays,
    csr_slice_rows_host,
    ell_from_csr_host,
    ell_from_dense,
    sellp_from_csr_host,
    sellp_from_dense,
)
from repro.sparse.ops import apply, axpy, dot, norm2, scal, to_dense

__all__ = [
    "Coo",
    "Csr",
    "Dense",
    "Ell",
    "Sellp",
    "convert",
    "csr_host_arrays",
    "csr_slice_rows_host",
    "coo_from_dense",
    "csr_from_dense",
    "csr_from_arrays",
    "ell_from_dense",
    "ell_from_csr_host",
    "sellp_from_dense",
    "sellp_from_csr_host",
    "apply",
    "to_dense",
    "dot",
    "axpy",
    "scal",
    "norm2",
]
