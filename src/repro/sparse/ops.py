"""Executor-dispatched sparse operations (SpMV per format) + BLAS-1 kernels.

Reference space = sequential-semantics oracle (straightforward scatter/gather).
XLA space       = segment-sum / one-shot vectorized formulations the compiler
                  can fuse (Ginkgo's "OpenMP" slot).
Pallas space    = registered from ``repro.kernels.spmv_sellp`` / ``..._ell``
                  (hardware-native; imported lazily by ``repro.kernels``).

``apply(A, x)`` mirrors ``gko::LinOp::apply`` — dispatch on format type, then on
executor kernel space.
"""

from __future__ import annotations

import contextlib
import contextvars

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import registry
from repro.sparse.formats import Coo, Csr, Dense, Ell, Sellp, csr_from_arrays

__all__ = [
    "apply",
    "to_dense",
    "dot",
    "axpy",
    "scal",
    "norm2",
    "distributed_blas",
    "spmv_dot",
    "axpy_norm",
    "dot_batch",
    "has_fused_ops",
    "spgemm",
    "sptranspose",
]

# =============================================================================
# SpMV — COO
# =============================================================================

spmv_coo = registry.operation(
    "spmv_coo", "y = A @ x for sorted COO (scatter-add semantics)"
)


@spmv_coo.register("reference")
def _spmv_coo_ref(ex, A: Coo, x: jax.Array) -> jax.Array:
    m = A.shape[0]
    y = jnp.zeros((m,) + x.shape[1:], dtype=jnp.result_type(A.values, x))
    contrib = A.values[:, None] * x[A.col_idx] if x.ndim == 2 else A.values * x[A.col_idx]
    return y.at[A.row_idx].add(contrib)


@spmv_coo.register("xla")
def _spmv_coo_xla(ex, A: Coo, x: jax.Array) -> jax.Array:
    # segment-sum over sorted rows; indices_are_sorted lets XLA lower a
    # contiguous scatter (the TPU-friendly form of the paper's COO kernel,
    # which on GPUs uses atomicAdd — no TPU analogue, see DESIGN.md).
    contrib = A.values[:, None] * x[A.col_idx] if x.ndim == 2 else A.values * x[A.col_idx]
    return jax.ops.segment_sum(
        contrib, A.row_idx, num_segments=A.shape[0], indices_are_sorted=True
    )


# =============================================================================
# SpMV — CSR
# =============================================================================

spmv_csr = registry.operation("spmv_csr", "y = A @ x for CSR")


def _csr_row_ids(A: Csr) -> jax.Array:
    nnz = A.values.shape[0]
    return (
        jnp.searchsorted(A.indptr, jnp.arange(nnz, dtype=jnp.int32), side="right")
        .astype(jnp.int32)
        - 1
    )


@spmv_csr.register("reference")
def _spmv_csr_ref(ex, A: Csr, x: jax.Array) -> jax.Array:
    rows = _csr_row_ids(A)
    y = jnp.zeros((A.shape[0],) + x.shape[1:], dtype=jnp.result_type(A.values, x))
    contrib = A.values[:, None] * x[A.indices] if x.ndim == 2 else A.values * x[A.indices]
    return y.at[rows].add(contrib)


@spmv_csr.register("xla")
def _spmv_csr_xla(ex, A: Csr, x: jax.Array) -> jax.Array:
    rows = _csr_row_ids(A)
    contrib = A.values[:, None] * x[A.indices] if x.ndim == 2 else A.values * x[A.indices]
    return jax.ops.segment_sum(
        contrib, rows, num_segments=A.shape[0], indices_are_sorted=True
    )


# =============================================================================
# SpMV — ELL
# =============================================================================

spmv_ell = registry.operation("spmv_ell", "y = A @ x for ELLPACK")


@spmv_ell.register("reference")
def _spmv_ell_ref(ex, A: Ell, x: jax.Array) -> jax.Array:
    # gather x per (row, k) then reduce over k — padding contributes 0.
    gathered = x[A.col_idx]  # (m, k) or (m, k, nrhs)
    if x.ndim == 2:
        return jnp.einsum("mk,mkr->mr", A.values, gathered)
    return jnp.sum(A.values * gathered, axis=1)


@spmv_ell.register("xla")
def _spmv_ell_xla(ex, A: Ell, x: jax.Array) -> jax.Array:
    return _spmv_ell_ref(ex, A, x)


# =============================================================================
# SpMV — SELL-P
# =============================================================================

spmv_sellp = registry.operation("spmv_sellp", "y = A @ x for SELL-P")


@spmv_sellp.register("reference")
def _spmv_sellp_ref(ex, A: Sellp, x: jax.Array) -> jax.Array:
    """Oracle: direct readback of the slice layout, one slice at a time.

    Python loop over slices (static count) — sequential reference semantics,
    mirroring Ginkgo's reference kernel.
    """
    if x.ndim != 1:
        raise NotImplementedError("reference SELL-P spmv is single-rhs")
    m = A.shape[0]
    C = A.slice_size
    y = jnp.zeros((m,), dtype=jnp.result_type(A.values, x))
    import numpy as np

    slice_sets = np.asarray(A.slice_sets)
    for s in range(A.num_slices):
        lo, hi = int(slice_sets[s]), int(slice_sets[s + 1])
        width = hi - lo
        block_v = A.values[lo * C : hi * C].reshape(width, C)
        block_c = A.col_idx[lo * C : hi * C].reshape(width, C)
        contrib = (block_v * x[block_c]).sum(axis=0)  # (C,)
        rows = jnp.arange(C) + s * C
        y = y.at[rows].add(jnp.where(rows < m, contrib, 0.0))
    return y


@spmv_sellp.register("xla")
def _spmv_sellp_xla(ex, A: Sellp, x: jax.Array) -> jax.Array:
    """Vectorized: one flat gather + segment reduction into rows.

    Element t of the flat buffer belongs to slice s(t), local column j, local
    row r = t % C; its output row is s*C + r.  We compute output rows with a
    searchsorted over slice_sets (flat index // C gives the column-set index).
    """
    if x.ndim != 1:
        raise NotImplementedError("xla SELL-P spmv is single-rhs")
    C = A.slice_size
    total = A.values.shape[0]
    t = jnp.arange(total, dtype=jnp.int32)
    colset = t // C  # global column-set index in [0, slice_sets[-1])
    s = (
        jnp.searchsorted(A.slice_sets, colset, side="right").astype(jnp.int32) - 1
    )
    r = t % C
    out_row = s * C + r
    contrib = A.values * x[A.col_idx]
    y = jax.ops.segment_sum(contrib, out_row, num_segments=A.num_slices * C)
    return y[: A.shape[0]]


# =============================================================================
# Dense apply + to_dense
# =============================================================================

spmv_dense = registry.operation("spmv_dense", "y = A @ x (dense)")


@spmv_dense.register("reference")
def _spmv_dense_ref(ex, A: Dense, x: jax.Array) -> jax.Array:
    return A.values @ x


@spmv_dense.register("xla")
def _spmv_dense_xla(ex, A: Dense, x: jax.Array) -> jax.Array:
    return A.values @ x


to_dense_op = registry.operation("sparse_to_dense", "densify any format")


@to_dense_op.register("reference")
def _to_dense_ref(ex, A) -> jax.Array:
    if isinstance(A, Dense):
        return A.values
    if isinstance(A, Coo):
        out = jnp.zeros(A.shape, A.values.dtype)
        return out.at[A.row_idx, A.col_idx].add(A.values)
    if isinstance(A, Csr):
        rows = _csr_row_ids(A)
        out = jnp.zeros(A.shape, A.values.dtype)
        return out.at[rows, A.indices].add(A.values)
    if isinstance(A, Ell):
        m, k = A.values.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, k))
        out = jnp.zeros(A.shape, A.values.dtype)
        return out.at[rows, A.col_idx].add(A.values)
    if isinstance(A, Sellp):
        x = jnp.eye(A.shape[1], dtype=A.values.dtype)
        cols = [_spmv_sellp_ref(ex, A, x[:, j]) for j in range(A.shape[1])]
        return jnp.stack(cols, axis=1)
    raise TypeError(f"unknown format {type(A)}")


# =============================================================================
# apply — gko::LinOp::apply
# =============================================================================

_FORMAT_OP = {
    Coo: spmv_coo,
    Csr: spmv_csr,
    Ell: spmv_ell,
    Sellp: spmv_sellp,
    Dense: spmv_dense,
}


def apply(A, x: jax.Array, *, executor=None) -> jax.Array:
    """``A.apply(x)``: format-dispatch then executor-dispatch.

    Composed / non-format LinOps (``Sum``, ``Composition``, solvers, ...)
    delegate to their own ``apply`` — this function stays the single entry
    point for "apply any operator" while the format fast path below keeps
    dispatching straight into the kernel registry.
    """
    try:
        op = _FORMAT_OP[type(A)]
    except KeyError:
        from repro.core.linop import LinOp
        from repro.sparse.formats import MatrixLinOp

        # a MatrixLinOp not in the table is an unregistered *format* — its
        # _apply would bounce right back here, so fail loudly instead
        if isinstance(A, LinOp) and not isinstance(A, MatrixLinOp):
            return A.apply(x, executor=executor)
        raise TypeError(f"no spmv registered for format {type(A)}") from None
    m, n = A.shape
    if m == 0 or n == 0:
        # degenerate operand: no kernel may launch (zero-size grids) and the
        # padding convention (col 0) has no column 0 to gather — the product
        # is empty or zero by definition
        return jnp.zeros((m,) + x.shape[1:], dtype=jnp.result_type(A.dtype, x))
    return op(A, x, executor=executor)


def to_dense(A, *, executor=None) -> jax.Array:
    if 0 in A.shape:
        return jnp.zeros(A.shape, A.dtype)
    return to_dense_op(A, executor=executor)


# =============================================================================
# BLAS-1 kernels used by the Krylov solvers (Ginkgo registers these per backend)
# =============================================================================

dot_op = registry.operation("blas_dot")
axpy_op = registry.operation("blas_axpy")
scal_op = registry.operation("blas_scal")
norm2_op = registry.operation("blas_norm2")


@dot_op.register("reference")
def _dot_ref(ex, x, y):
    return jnp.vdot(x, y)


@dot_op.register("xla")
def _dot_xla(ex, x, y):
    return jnp.vdot(x, y)


@axpy_op.register("reference")
def _axpy_ref(ex, alpha, x, y):
    return alpha * x + y


@axpy_op.register("xla")
def _axpy_xla(ex, alpha, x, y):
    return alpha * x + y


@scal_op.register("reference")
def _scal_ref(ex, alpha, x):
    return alpha * x


@scal_op.register("xla")
def _scal_xla(ex, alpha, x):
    return alpha * x


@norm2_op.register("reference")
def _norm2_ref(ex, x):
    return jnp.sqrt(jnp.vdot(x, x).real)


@norm2_op.register("xla")
def _norm2_xla(ex, x):
    return jnp.sqrt(jnp.vdot(x, x).real)


# =============================================================================
# Fused apply-with-reduction ops (arXiv:2011.08879 §kernels)
# =============================================================================
#
# Ginkgo's hand-tuned kernels fuse the reduction into the apply so the Krylov
# hot path streams each vector through HBM once instead of three times:
#
# * ``spmv_dot_*``  — SpMV that emits ``w · y`` in the same pass (CG's
#   ``p·Ap``, BiCGSTAB's ``r̂·v``);
# * ``axpy_norm``   — ``z = alpha*x + y`` plus ``z·z`` (the residual update
#   and the stopping-criterion norm, one pass).
#
# These are OPTIONAL ops: solvers probe :func:`has_fused_ops` (capability
# probe on the registry) and gracefully fall back to the unfused path when a
# backend doesn't advertise them.  The reference/xla implementations below are
# deliberately the *literal unfused composition*, so enabling the fused path
# on those spaces is bitwise-neutral — the fallback-parity contract the tests
# pin.  The pallas space registers truly fused kernels from
# ``repro.kernels.spmv_dot`` / ``repro.kernels.axpy_norm``.

spmv_dot_csr_op = registry.operation(
    "spmv_dot_csr", "(y, w·y) = (A @ x, fused dot) for CSR"
)
spmv_dot_ell_op = registry.operation(
    "spmv_dot_ell", "(y, w·y) = (A @ x, fused dot) for ELLPACK"
)
axpy_norm_op = registry.operation(
    "axpy_norm", "(z, z·z) with z = alpha*x + y, fused"
)


@spmv_dot_csr_op.register("reference")
def _spmv_dot_csr_ref(ex, A: Csr, x, w):
    y = _spmv_csr_ref(ex, A, x)
    return y, jnp.vdot(w, y)


@spmv_dot_csr_op.register("xla")
def _spmv_dot_csr_xla(ex, A: Csr, x, w):
    y = _spmv_csr_xla(ex, A, x)
    return y, jnp.vdot(w, y)


@spmv_dot_ell_op.register("reference")
def _spmv_dot_ell_ref(ex, A: Ell, x, w):
    y = _spmv_ell_ref(ex, A, x)
    return y, jnp.vdot(w, y)


@spmv_dot_ell_op.register("xla")
def _spmv_dot_ell_xla(ex, A: Ell, x, w):
    y = _spmv_ell_xla(ex, A, x)
    return y, jnp.vdot(w, y)


def _axpy_norm_impl(ex, alpha, x, y):
    # shared 1-D / batched (nb, n) formulation: the batched solvers reuse this
    # exact op, so single and batched paths share one fused implementation
    if jnp.ndim(x) == 2:
        a = alpha[:, None] if jnp.ndim(alpha) == 1 else alpha
        z = a * x + y
        return z, jnp.einsum("bn,bn->b", z, z)
    z = alpha * x + y
    return z, jnp.vdot(z, z)


axpy_norm_op.register("reference")(_axpy_norm_impl)
_axpy_norm_xla = axpy_norm_op.register("xla")(_axpy_norm_impl)


_FUSED_SPMV_OP = {Csr: spmv_dot_csr_op, Ell: spmv_dot_ell_op}


def has_fused_ops(A, *, executor=None) -> bool:
    """Capability probe: can this executor serve the fused iteration ops for
    operand ``A``?  False for formats/operators without a fused SpMV (solvers
    then keep the unfused path — graceful degradation, never an error)."""
    from repro.core.executor import current_executor

    op = _FUSED_SPMV_OP.get(type(A))
    if op is None:
        return False
    ex = executor if executor is not None else current_executor()
    return op.supports(ex) and axpy_norm_op.supports(ex)


def spmv_dot(A, x, w=None, *, executor=None):
    """Fused SpMV + dot: ``(y, w·y)`` with ``w`` defaulting to ``x``.

    Under the distributed-reduction context the dot partial is masked and
    ``psum``'d like every reduction (the SpMV output stays shard-local); the
    solver layer normally disables the fused path per shard instead, but the
    wrapper stays correct either way.
    """
    w = x if w is None else w
    op = _FUSED_SPMV_OP[type(A)]
    ctx = _DIST_BLAS.get()
    if ctx is None:
        return op(A, x, w, executor=executor)
    axis_name, mask = ctx
    y = apply(A, x, executor=executor)
    local = dot_op(_masked(w, mask), _masked(y, mask), executor=executor)
    return y, jax.lax.psum(local, axis_name)


def axpy_norm(alpha, x, y, *, executor=None):
    """Fused axpy + squared-norm: ``(z, ‖z‖²)`` with ``z = alpha*x + y``."""
    ctx = _DIST_BLAS.get()
    if ctx is None:
        return axpy_norm_op(alpha, x, y, executor=executor)
    axis_name, mask = ctx
    z = axpy_op(alpha, x, y, executor=executor)
    zm = _masked(z, mask)
    local = dot_op(zm, zm, executor=executor)
    return z, jax.lax.psum(local, axis_name)


# -- the distributed-reduction context ----------------------------------------
#
# Inside a ``shard_map`` body, a vector is one padded shard of the global
# vector: ``dot``/``norm2`` must reduce locally (still executor-dispatched)
# and then ``psum`` over the mesh axis, with padding slots masked out of the
# operands.  The distributed solver layer (:mod:`repro.distributed.solvers`)
# opens this context around the UNCHANGED solver source — the Krylov methods
# never learn whether their reductions are local or global, exactly Ginkgo's
# ``distributed::Vector`` story.  ``axpy``/``scal`` are elementwise and need
# no collective.

_DIST_BLAS: contextvars.ContextVar = contextvars.ContextVar(
    "repro_distributed_blas", default=None
)


@contextlib.contextmanager
def distributed_blas(axis_name: str, mask=None):
    """Make ``dot``/``norm2`` global over ``axis_name`` (psum of the local
    partial) with padding slots of the shard masked by ``mask`` (bool,
    broadcastable; ``None`` = no padding)."""
    token = _DIST_BLAS.set((axis_name, mask))
    try:
        yield
    finally:
        _DIST_BLAS.reset(token)


def _masked(x, mask):
    # zero the padding slots so a ragged partition never double-counts them
    # (the padded-shard bug); lazy import keeps the layering one-directional
    # everywhere outside this trace-time hook.
    from repro.distributed.sharding import zero_shard_padding

    return zero_shard_padding(x, mask)


def dot(x, y, *, executor=None):
    ctx = _DIST_BLAS.get()
    if ctx is None:
        return dot_op(x, y, executor=executor)
    axis_name, mask = ctx
    # mask BOTH operands: 0 * non-finite padding would still be NaN
    local = dot_op(_masked(x, mask), _masked(y, mask), executor=executor)
    return jax.lax.psum(local, axis_name)


def axpy(alpha, x, y, *, executor=None):
    return axpy_op(alpha, x, y, executor=executor)


def scal(alpha, x, *, executor=None):
    return scal_op(alpha, x, executor=executor)


def norm2(x, *, executor=None):
    ctx = _DIST_BLAS.get()
    if ctx is None:
        return norm2_op(x, executor=executor)
    axis_name, mask = ctx
    xm = _masked(x, mask)
    # local sum of squares through the dispatched dot, global psum, one sqrt —
    # bit-for-bit the shape Stop.threshold expects from a global norm
    local = dot_op(xm, xm, executor=executor)
    return jnp.sqrt(jax.lax.psum(local, axis_name).real)


# =============================================================================
# Sparse-sparse composition: SpGEMM and sparse transpose
# =============================================================================
#
# ``gko::Csr::apply(Csr)`` — the setup-path workhorse behind algebraic
# multigrid's Galerkin triple product R·A·P.  Unlike the SpMV hot path, the
# *structure* of the result is data-dependent (row nnz of C = A·B is unknown
# until computed), so every space runs a host-side structure pass:
#
#   1. row-nnz upper-bound pass — expand each a_ik into the length of B's row
#      k (the classical "symbolic" upper bound, before duplicate merging);
#   2. numeric expansion — produce the (row, col, a_ik·b_kj) triplets (this is
#      the flop-carrying pass; the pallas space runs it as a tiled kernel in
#      ``repro.kernels.spgemm``);
#   3. coalesce — sort triplets by (row, col), merge duplicates, build indptr.
#
# All three spaces share steps 1 and 3 bit-for-bit, so the output *structure*
# is identical across executors (the conformance contract); only step 2's
# arithmetic differs in summation order, covered by the usual float tolerance.
# Structural nonzeros are kept even when numerically zero — Ginkgo semantics,
# and what keeps the pattern a pure function of the operand patterns (the
# property the serve-cache pattern tier relies on).

spgemm_op = registry.operation(
    "spgemm", "C = A @ B for CSR pairs (sparse-sparse composition)"
)
sptranspose_op = registry.operation(
    "sptranspose", "B = A^T for CSR (sorted column-major permutation)"
)


def _empty_csr(m: int, n: int, dtype) -> Csr:
    return csr_from_arrays(
        np.zeros(m + 1, np.int64), np.zeros(0, np.int32),
        np.zeros(0, dtype), (m, n),
    )


def _spgemm_maps(A: Csr, B: Csr):
    """Host structure pass: expansion maps for C = A·B.

    Returns ``(rows_a, b_start, b_len, K)`` where entry t of A contributes
    products against ``b_len[t]`` entries of B starting at ``b_start[t]``,
    lands in output row ``rows_a[t]``, and ``K`` is the padded expansion
    width (max B-row nnz reached by A's column indices).
    """
    ai = np.asarray(A.indptr)
    ac = np.asarray(A.indices)
    bi = np.asarray(B.indptr)
    rows_a = np.repeat(np.arange(A.shape[0], dtype=np.int64), np.diff(ai))
    b_start = bi[ac]
    b_len = np.diff(bi)[ac]
    K = int(b_len.max()) if b_len.size else 0
    return rows_a, b_start, b_len, K


def _coalesce_host(rows, cols, vals, m: int):
    """Sort (row, col, val) triplets, merge duplicate coordinates, build CSR.

    The shared accumulate pass: every space funnels its expanded triplets
    through this exact routine, which is what makes the output structure
    bitwise-identical across executors.
    """
    if rows.size == 0:
        return (
            np.zeros(m + 1, np.int64),
            np.zeros(0, np.int32),
            np.zeros(0, vals.dtype),
        )
    order = np.lexsort((cols, rows))
    r, c, v = rows[order], cols[order], vals[order]
    head = np.ones(r.size, bool)
    head[1:] = (r[1:] != r[:-1]) | (c[1:] != c[:-1])
    starts = np.flatnonzero(head)
    out_v = np.add.reduceat(v, starts)
    out_r, out_c = r[starts], c[starts]
    indptr = np.zeros(m + 1, np.int64)
    indptr[1:] = np.cumsum(np.bincount(out_r, minlength=m))
    return indptr, out_c.astype(np.int32), out_v


def _finalize_spgemm(rows_a, K, valid, cols, prod, m, n) -> Csr:
    """Pull the expanded (possibly padded) triplets to host and coalesce."""
    vmask = np.asarray(valid).ravel()
    rows_f = np.repeat(rows_a, K)[vmask]
    cols_f = np.asarray(cols).ravel()[vmask]
    vals_f = np.asarray(prod).ravel()[vmask]
    indptr, out_c, out_v = _coalesce_host(rows_f, cols_f, vals_f, m)
    return csr_from_arrays(indptr, out_c, out_v, (m, n))


@spgemm_op.register("reference")
def _spgemm_ref(ex, A: Csr, B: Csr) -> Csr:
    """Oracle: sequential per-row merge, mirroring Ginkgo's reference kernel."""
    m, _ = A.shape
    n = B.shape[1]
    ai = np.asarray(A.indptr)
    ac = np.asarray(A.indices)
    av = np.asarray(A.values)
    bi = np.asarray(B.indptr)
    bc = np.asarray(B.indices)
    bv = np.asarray(B.values)
    dtype = np.result_type(av.dtype, bv.dtype)
    indptr = np.zeros(m + 1, np.int64)
    out_cols: list = []
    out_vals: list = []
    for i in range(m):
        row_c: list = []
        row_v: list = []
        for t in range(int(ai[i]), int(ai[i + 1])):
            k = int(ac[t])
            s0, s1 = int(bi[k]), int(bi[k + 1])
            row_c.append(bc[s0:s1])
            row_v.append(av[t] * bv[s0:s1])
        if row_c:
            cat_c = np.concatenate(row_c)
            cat_v = np.concatenate(row_v)
            uniq, inv = np.unique(cat_c, return_inverse=True)
            acc = np.zeros(uniq.size, dtype)
            np.add.at(acc, inv, cat_v)
            out_cols.append(uniq.astype(np.int32))
            out_vals.append(acc)
            indptr[i + 1] = indptr[i] + uniq.size
        else:
            indptr[i + 1] = indptr[i]
    cols = np.concatenate(out_cols) if out_cols else np.zeros(0, np.int32)
    vals = np.concatenate(out_vals) if out_vals else np.zeros(0, dtype)
    return csr_from_arrays(indptr, cols, vals, (m, n))


@spgemm_op.register("xla")
def _spgemm_xla(ex, A: Csr, B: Csr) -> Csr:
    """One-shot expansion: gather B's rows padded to width K, multiply on
    device, coalesce on host.  The device pass is a single fused
    gather-multiply the compiler vectorizes; K is the max B-row width so the
    expansion is rectangular (the predication-free padding idiom)."""
    m, _ = A.shape
    n = B.shape[1]
    rows_a, b_start, b_len, K = _spgemm_maps(A, B)
    if K == 0 or rows_a.size == 0:
        return _empty_csr(m, n, np.result_type(A.dtype, B.dtype))
    q = np.arange(K)
    valid = q[None, :] < b_len[:, None]  # (nnzA, K) host bool
    idx = jnp.asarray(np.where(valid, b_start[:, None] + q[None, :], 0))
    prod = A.values[:, None] * B.values[idx]
    cols = B.indices[idx]
    return _finalize_spgemm(rows_a, K, valid, cols, prod, m, n)


@sptranspose_op.register("reference")
def _sptranspose_ref(ex, A: Csr) -> Csr:
    """Oracle: host lexsort of the swapped triplet (Csr.transpose semantics)."""
    m, n = A.shape
    ai = np.asarray(A.indptr)
    cols = np.asarray(A.indices)
    rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(ai))
    order = np.lexsort((rows, cols))
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(np.bincount(cols, minlength=n))
    return csr_from_arrays(
        indptr, rows[order].astype(np.int32),
        np.asarray(A.values)[order], (n, m),
    )


@sptranspose_op.register("xla")
def _sptranspose_xla(ex, A: Csr) -> Csr:
    """Device transpose: nnz is invariant so every array keeps a static
    shape — the whole permutation (lexsort + bincount) stays on device and
    is jit-traceable."""
    m, n = A.shape
    rows = _csr_row_ids(A)
    order = jnp.lexsort((rows, A.indices))
    counts = jnp.bincount(A.indices, length=n)
    t_indptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts).astype(jnp.int32)]
    )
    return Csr(
        indptr=t_indptr,
        indices=rows[order].astype(jnp.int32),
        values=A.values[order],
        shape=(n, m),
    )


def spgemm(A: Csr, B: Csr, *, executor=None) -> Csr:
    """``C = A @ B`` for CSR operands — executor-dispatched SpGEMM.

    Output rows are column-sorted and duplicate-free; structural nonzeros are
    kept even when numerically zero, so the result pattern is a pure function
    of the operand patterns.
    """
    if not isinstance(A, Csr) or not isinstance(B, Csr):
        raise TypeError(
            f"spgemm needs CSR operands, got {type(A).__name__} × "
            f"{type(B).__name__}"
        )
    m, k = A.shape
    k2, n = B.shape
    if k != k2:
        raise ValueError(f"spgemm shape mismatch: {A.shape} @ {B.shape}")
    if m == 0 or n == 0 or k == 0 or A.nnz == 0 or B.nnz == 0:
        return _empty_csr(m, n, np.result_type(A.dtype, B.dtype))
    return spgemm_op(A, B, executor=executor)


def sptranspose(A: Csr, *, executor=None) -> Csr:
    """``B = Aᵀ`` for CSR — executor-dispatched sparse transpose."""
    if not isinstance(A, Csr):
        raise TypeError(f"sptranspose needs a CSR operand, got {type(A).__name__}")
    m, n = A.shape
    if m == 0 or n == 0 or A.nnz == 0:
        return _empty_csr(n, m, A.dtype)
    return sptranspose_op(A, executor=executor)


def dot_batch(pairs, *, executor=None):
    """Batched dot products: ``[(x₁,y₁), ...] -> (len(pairs),)`` scalars.

    The communication-avoiding reduction: under the distributed context the
    local partials are stacked and reduced in ONE ``psum`` instead of one
    collective per dot — the enabler for pipelined Krylov methods, whose
    recurrences are restructured precisely so their dots batch here.  Outside
    the context it is just the stacked local dots.
    """
    ctx = _DIST_BLAS.get()
    if ctx is None:
        return jnp.stack(
            [dot_op(x, y, executor=executor) for x, y in pairs]
        )
    axis_name, mask = ctx
    local = jnp.stack(
        [
            dot_op(_masked(x, mask), _masked(y, mask), executor=executor)
            for x, y in pairs
        ]
    )
    return jax.lax.psum(local, axis_name)
