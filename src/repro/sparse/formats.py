"""Sparse matrix formats — COO, CSR, ELL, SELL-P (Ginkgo's format set).

Each format is a frozen JAX pytree (device arrays + static metadata) so it can
flow through ``jit`` / ``pjit`` and be sharded.  Construction/conversion happens
host-side in numpy (setup time, like Ginkgo's ``convert_to``); the `apply`
(SpMV) path is executor-dispatched (see :mod:`repro.sparse.ops`).

TPU adaptations (DESIGN.md §2):

* ELL stores row-major ``(m, max_nnz)`` blocks; padding uses column 0 with a
  zero value so gathers stay in-bounds without predication.
* SELL-P uses slice size ``C = 8`` (one sublane) by default instead of
  Ginkgo's GPU default 64, and pads each slice's column count to a multiple of
  ``stride_factor`` so slice-local blocks stay vector-aligned.  Values are laid
  out per-slice column-major — ``(cols_in_slice, C)`` contiguous per slice —
  exactly Ginkgo's layout, flattened into one buffer with ``slice_sets``
  offsets.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linop import LinOp

__all__ = [
    "Coo",
    "Csr",
    "Ell",
    "Sellp",
    "Dense",
    "convert",
    "csr_host_arrays",
    "csr_slice_rows_host",
]


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields)
    )
    return cls


def _nbytes(*arrays: jax.Array) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in arrays)


class MatrixLinOp(LinOp):
    """Common LinOp behavior for every sparse/dense format.

    ``apply`` keeps dispatching through the operation registry and the
    executor's kernel-space chain (:func:`repro.sparse.ops.apply`) — the
    format classes gaining a LinOp face changes nothing below the dispatch
    layer.  Formats carry no ``executor`` field (they are sharded pytrees);
    the executor threads in from the apply call or the ambient context.
    """

    def _apply(self, b, executor):
        from repro.sparse import ops

        return ops.apply(self, b, executor=executor)

    def astype(self, dtype) -> "MatrixLinOp":
        """Same structure, values cast to ``dtype`` (indices untouched).

        The mixed-precision hook: ``A.astype(jnp.float32)`` is the reduced-
        precision operator the IR inner solve runs against.
        """
        return dataclasses.replace(self, values=self.values.astype(dtype))

    def transpose(self):
        """Transpose via the host CSR hub (setup time, concrete values only).

        Dense/Coo/Csr override with direct (and tracer-safe) paths; the
        padded formats route through :func:`csr_host_arrays` and rebuild in
        their own format, so ``Transpose(A)`` works for every format.
        """
        indptr, indices, values = csr_host_arrays(self)
        m, n = self.shape
        t_indptr, t_indices, t_values = _transpose_host(
            indptr, indices, values, m, n
        )
        tT = convert(
            Csr(
                indptr=jnp.asarray(t_indptr, jnp.int32),
                indices=jnp.asarray(t_indices, jnp.int32),
                values=jnp.asarray(t_values),
                shape=(n, m),
            ),
            type(self),
        )
        return tT


@dataclasses.dataclass(frozen=True)
class Dense(MatrixLinOp):
    """Row-major dense matrix (gko::matrix::Dense)."""

    values: jax.Array  # (m, n)

    @property
    def shape(self) -> Tuple[int, int]:
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def nnz(self) -> int:
        """Stored entries (dense stores every entry)."""
        return int(self.values.size)

    @property
    def memory_bytes(self) -> int:
        return _nbytes(self.values)

    def transpose(self) -> "Dense":
        return Dense(self.values.T)


_register(Dense, ["values"], [])


@dataclasses.dataclass(frozen=True)
class Coo(MatrixLinOp):
    """Coordinate format; row indices kept sorted (Ginkgo requires sorted COO)."""

    row_idx: jax.Array  # (nnz,) int32, sorted
    col_idx: jax.Array  # (nnz,) int32
    values: jax.Array  # (nnz,)
    shape: Tuple[int, int]  # static

    @property
    def nnz(self) -> int:
        return self.values.shape[0]

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def memory_bytes(self) -> int:
        return _nbytes(self.row_idx, self.col_idx, self.values)

    def transpose(self) -> "Coo":
        """Transpose: swap indices, restore row order.

        Structure work is host-side (indices must be concrete); the values
        are permuted on-device, so a ``Coo`` built inside a trace from a
        static pattern and *traced* values transposes cleanly (the implicit-
        layer backward relies on this).
        """
        r = np.asarray(self.col_idx)
        c = np.asarray(self.row_idx)
        order = np.lexsort((c, r))
        return Coo(
            row_idx=jnp.asarray(r[order], jnp.int32),
            col_idx=jnp.asarray(c[order], jnp.int32),
            values=jnp.take(self.values, jnp.asarray(order), axis=0),
            shape=(self.shape[1], self.shape[0]),
        )


_register(Coo, ["row_idx", "col_idx", "values"], ["shape"])


@dataclasses.dataclass(frozen=True)
class Csr(MatrixLinOp):
    """Compressed sparse row."""

    indptr: jax.Array  # (m+1,) int32
    indices: jax.Array  # (nnz,) int32
    values: jax.Array  # (nnz,)
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.values.shape[0]

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def memory_bytes(self) -> int:
        return _nbytes(self.indptr, self.indices, self.values)

    def transpose(self) -> "Csr":
        """Transpose via the sorted triplet.

        Structure work (the permutation) is host-side and needs concrete
        ``indptr``/``indices``; the values are permuted on-device with a
        single gather, so a ``Csr`` built inside a trace from a static
        pattern and *traced* values transposes cleanly — the implicit-layer
        backward (``Transpose(A)`` under ``jit``) relies on this.
        """
        indptr = np.asarray(self.indptr, np.int64)
        indices = np.asarray(self.indices, np.int64)
        m = self.shape[0]
        rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
        tr, tc = indices, rows  # swapped
        order = np.lexsort((tc, tr))
        t_indptr = np.zeros(self.shape[1] + 1, np.int64)
        np.add.at(t_indptr, tr + 1, 1)
        return Csr(
            indptr=jnp.asarray(np.cumsum(t_indptr), jnp.int32),
            indices=jnp.asarray(tc[order], jnp.int32),
            values=jnp.take(self.values, jnp.asarray(order), axis=0),
            shape=(self.shape[1], self.shape[0]),
        )


_register(Csr, ["indptr", "indices", "values"], ["shape"])


@dataclasses.dataclass(frozen=True)
class Ell(MatrixLinOp):
    """ELLPACK: fixed ``max_nnz`` entries per row, zero-padded.

    Padding entries have ``col_idx == 0`` and ``value == 0`` (in-bounds gather,
    zero contribution) — the predication-free TPU idiom.
    """

    col_idx: jax.Array  # (m, max_nnz) int32
    values: jax.Array  # (m, max_nnz)
    shape: Tuple[int, int]

    @property
    def max_nnz(self) -> int:
        return self.values.shape[1]

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def nnz(self) -> int:
        """Stored entries ``m * max_nnz`` (Ginkgo's num_stored_elements:
        padding is read by the kernel, so it is what memory bounds see)."""
        return int(self.values.size)

    @property
    def memory_bytes(self) -> int:
        return _nbytes(self.col_idx, self.values)


_register(Ell, ["col_idx", "values"], ["shape"])


@dataclasses.dataclass(frozen=True)
class Sellp(MatrixLinOp):
    """SELL-P (sliced ELL with padding) — Ginkgo's GPU throughput format.

    Rows are grouped into slices of ``slice_size`` (C).  Each slice stores its
    own padded column count (a multiple of ``stride_factor``); slice ``i``'s
    values occupy ``slice_sets[i]*C : slice_sets[i+1]*C`` of the flat buffers,
    laid out column-major within the slice (column-contiguous groups of C).

    ``slice_cols`` (static-shaped device array) and ``slice_sets`` are part of
    the pytree; ``max_slice_cols`` is static so Pallas grids can size to it.
    """

    col_idx: jax.Array  # (total_padded_nnz,) int32
    values: jax.Array  # (total_padded_nnz,)
    slice_sets: jax.Array  # (num_slices+1,) int32 — column offsets per slice
    slice_cols: jax.Array  # (num_slices,) int32 — padded cols per slice
    shape: Tuple[int, int]
    slice_size: int  # static (C)
    stride_factor: int  # static
    max_slice_cols: int  # static — max(slice_cols), for grid sizing

    @property
    def num_slices(self) -> int:
        return self.slice_cols.shape[0]

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def nnz(self) -> int:
        """Stored (slice-padded) entries — what the kernels stream."""
        return int(self.values.size)

    @property
    def memory_bytes(self) -> int:
        return _nbytes(self.col_idx, self.values, self.slice_sets, self.slice_cols)

    def transpose(self) -> "Sellp":
        """Transpose preserving this matrix's slice layout parameters."""
        indptr, indices, values = csr_host_arrays(self)
        m, n = self.shape
        t_indptr, t_indices, t_values = _transpose_host(
            indptr, indices, values, m, n
        )
        return sellp_from_csr_host(
            t_indptr, t_indices, t_values, (n, m),
            slice_size=self.slice_size, stride_factor=self.stride_factor,
        )


_register(
    Sellp,
    ["col_idx", "values", "slice_sets", "slice_cols"],
    ["shape", "slice_size", "stride_factor", "max_slice_cols"],
)


def _transpose_host(
    indptr: np.ndarray, indices: np.ndarray, values: np.ndarray, m: int, n: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Transpose a host CSR triplet of an ``(m, n)`` matrix (setup time)."""
    rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
    order = np.lexsort((rows, indices))
    t_indptr = np.zeros(n + 1, np.int64)
    np.add.at(t_indptr, indices + 1, 1)
    return np.cumsum(t_indptr), rows[order], values[order]


# -- host-side constructors (setup-time, numpy) --------------------------------


def coo_from_dense(a: np.ndarray, dtype=None) -> Coo:
    a = np.asarray(a)
    r, c = np.nonzero(a)
    order = np.lexsort((c, r))
    r, c = r[order], c[order]
    v = a[r, c]
    return Coo(
        row_idx=jnp.asarray(r, jnp.int32),
        col_idx=jnp.asarray(c, jnp.int32),
        values=jnp.asarray(v, dtype or a.dtype),
        shape=a.shape,
    )


def csr_from_dense(a: np.ndarray, dtype=None) -> Csr:
    a = np.asarray(a)
    m = a.shape[0]
    r, c = np.nonzero(a)
    order = np.lexsort((c, r))
    r, c = r[order], c[order]
    v = a[r, c]
    indptr = np.zeros(m + 1, np.int32)
    np.add.at(indptr, r + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return Csr(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(c, jnp.int32),
        values=jnp.asarray(v, dtype or a.dtype),
        shape=a.shape,
    )


def csr_from_arrays(indptr, indices, values, shape) -> Csr:
    return Csr(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(indices, jnp.int32),
        values=jnp.asarray(values),
        shape=tuple(shape),
    )


def ell_from_csr_host(indptr, indices, values, shape, max_nnz=None) -> Ell:
    """Host-side CSR -> ELL."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    values = np.asarray(values)
    m, _ = shape
    row_nnz = np.diff(indptr)
    k = int(max_nnz if max_nnz is not None else (row_nnz.max() if m else 0))
    k = max(k, 1)
    bad = np.flatnonzero(row_nnz > k)
    if bad.size:
        raise ValueError(
            f"row {int(bad[0])} has {int(row_nnz[bad[0]])} nnz > max_nnz {k}"
        )
    cols = np.zeros((m, k), np.int32)
    vals = np.zeros((m, k), values.dtype)
    # vectorized scatter: entry t of the CSR stream lands at
    # (row[t], t - indptr[row[t]])
    rows = np.repeat(np.arange(m, dtype=np.int64), row_nnz)
    pos = np.arange(indices.shape[0], dtype=np.int64) - indptr[:-1][rows]
    cols[rows, pos] = indices
    vals[rows, pos] = values
    return Ell(jnp.asarray(cols), jnp.asarray(vals), tuple(shape))


def ell_from_dense(a: np.ndarray, dtype=None) -> Ell:
    c = csr_from_dense(a, dtype)
    return ell_from_csr_host(
        np.asarray(c.indptr), np.asarray(c.indices), np.asarray(c.values), c.shape
    )


def sellp_from_csr_host(
    indptr,
    indices,
    values,
    shape,
    slice_size: int = 8,
    stride_factor: int = 8,
) -> Sellp:
    """Host-side CSR -> SELL-P with Ginkgo's slice layout."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    values = np.asarray(values)
    m, _ = shape
    C = slice_size
    # an empty matrix gets zero slices — not one phantom padded slice whose
    # (col 0, value 0) entries would gather out of bounds from an empty x
    num_slices = (m + C - 1) // C
    row_nnz = np.diff(indptr) if m else np.zeros(0, np.int64)

    slice_cols = np.zeros(num_slices, np.int32)
    for s in range(num_slices):
        rows = row_nnz[s * C : min((s + 1) * C, m)]
        w = int(rows.max()) if rows.size else 0
        # pad to stride_factor (Ginkgo's stride alignment), at least one column
        w = max(w, 1)
        slice_cols[s] = ((w + stride_factor - 1) // stride_factor) * stride_factor

    slice_sets = np.zeros(num_slices + 1, np.int32)
    slice_sets[1:] = np.cumsum(slice_cols)
    total = int(slice_sets[-1]) * C

    cols = np.zeros(total, np.int32)
    vals = np.zeros(total, values.dtype)
    for s in range(num_slices):
        base = slice_sets[s] * C
        for r in range(C):
            row = s * C + r
            if row >= m:
                continue
            n = row_nnz[row]
            src = slice(indptr[row], indptr[row] + n)
            # column-major within slice: entry (col j, row r) at base + j*C + r
            dst = base + np.arange(n) * C + r
            cols[dst] = indices[src]
            vals[dst] = values[src]
    return Sellp(
        col_idx=jnp.asarray(cols),
        values=jnp.asarray(vals),
        slice_sets=jnp.asarray(slice_sets),
        slice_cols=jnp.asarray(slice_cols),
        shape=tuple(shape),
        slice_size=C,
        stride_factor=stride_factor,
        max_slice_cols=int(slice_cols.max()) if num_slices else 0,
    )


def sellp_from_dense(a: np.ndarray, slice_size=8, stride_factor=8) -> Sellp:
    c = csr_from_dense(a)
    return sellp_from_csr_host(
        np.asarray(c.indptr),
        np.asarray(c.indices),
        np.asarray(c.values),
        c.shape,
        slice_size=slice_size,
        stride_factor=stride_factor,
    )


# -- host-side conversion between formats (gko ConvertibleTo) ------------------


def csr_host_arrays(A) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``(indptr, indices, values)`` numpy triplet for any format (host-side).

    Setup-time extraction (Ginkgo's ``convert_to`` hub format): explicit
    stored zeros in the padded formats (ELL / SELL-P padding slots) are
    dropped — they are storage artifacts, not matrix entries.
    """
    if isinstance(A, Csr):
        return (
            np.asarray(A.indptr, np.int64),
            np.asarray(A.indices, np.int64),
            np.asarray(A.values),
        )
    if isinstance(A, Coo):
        r = np.asarray(A.row_idx)
        c = np.asarray(A.col_idx)
        v = np.asarray(A.values)
        m = A.shape[0]
        indptr = np.zeros(m + 1, np.int64)
        np.add.at(indptr, r + 1, 1)
        return np.cumsum(indptr), c.astype(np.int64), v
    if isinstance(A, Dense):
        a = np.asarray(A.values)
        r, c = np.nonzero(a)
        m = a.shape[0]
        indptr = np.zeros(m + 1, np.int64)
        np.add.at(indptr, r + 1, 1)
        return np.cumsum(indptr), c.astype(np.int64), a[r, c]
    if isinstance(A, Ell):
        cols = np.asarray(A.col_idx)
        vals = np.asarray(A.values)
        keep = vals != 0
        m = A.shape[0]
        counts = keep.sum(axis=1)
        indptr = np.zeros(m + 1, np.int64)
        indptr[1:] = np.cumsum(counts)
        return indptr, cols[keep].astype(np.int64), vals[keep]
    if isinstance(A, Sellp):
        m = A.shape[0]
        C = A.slice_size
        slice_sets = np.asarray(A.slice_sets)
        cols = np.asarray(A.col_idx)
        vals = np.asarray(A.values)
        rows_c, rows_v = [[] for _ in range(m)], [[] for _ in range(m)]
        for s in range(A.num_slices):
            lo, hi = int(slice_sets[s]), int(slice_sets[s + 1])
            width = hi - lo
            bc = cols[lo * C : hi * C].reshape(width, C)
            bv = vals[lo * C : hi * C].reshape(width, C)
            for r in range(min(C, m - s * C)):
                keep = bv[:, r] != 0
                rows_c[s * C + r].extend(bc[keep, r].tolist())
                rows_v[s * C + r].extend(bv[keep, r].tolist())
        counts = np.array([len(rc) for rc in rows_c], np.int64)
        indptr = np.zeros(m + 1, np.int64)
        indptr[1:] = np.cumsum(counts)
        indices = (
            np.asarray([c for rc in rows_c for c in rc], np.int64)
            if indptr[-1]
            else np.zeros(0, np.int64)
        )
        values = (
            np.asarray([v for rv in rows_v for v in rv], vals.dtype)
            if indptr[-1]
            else np.zeros(0, vals.dtype)
        )
        return indptr, indices, values
    raise TypeError(f"cannot extract a CSR triplet from {type(A)}")


def csr_slice_rows_host(
    indptr: np.ndarray,
    indices: np.ndarray,
    values: np.ndarray,
    lo: int,
    hi: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Row block ``[lo, hi)`` of a host CSR triplet (setup time).

    The partition-aware split primitive behind the distributed formats: the
    returned triplet is a self-contained CSR over ``hi - lo`` rows (indptr
    rebased to 0), with column indices untouched (still global) and per-row
    entry order preserved.
    """
    indptr = np.asarray(indptr)
    if not (0 <= lo <= hi <= len(indptr) - 1):
        raise ValueError(
            f"row range [{lo}, {hi}) outside [0, {len(indptr) - 1})"
        )
    start, stop = int(indptr[lo]), int(indptr[hi])
    return (
        (indptr[lo : hi + 1] - start).astype(np.int64),
        np.asarray(indices)[start:stop].astype(np.int64),
        np.asarray(values)[start:stop],
    )


_CONVERT_TARGETS = {
    "coo": Coo,
    "csr": Csr,
    "ell": Ell,
    "sellp": Sellp,
    "dense": Dense,
}


def convert(A, target, **kwargs):
    """Convert any format to another — Ginkgo's ``ConvertibleTo`` surface.

    ``target`` is a format class or name (``"coo"`` / ``"csr"`` / ``"ell"`` /
    ``"sellp"`` / ``"dense"``); ``kwargs`` forward to the target constructor
    (``slice_size`` / ``stride_factor`` for SELL-P, ``max_nnz`` for ELL).
    Conversion routes host-side through the CSR triplet (setup time) and
    drops explicit stored zeros, matching the from-dense constructors.
    """
    if isinstance(target, str):
        try:
            target = _CONVERT_TARGETS[target.lower()]
        except KeyError:
            raise KeyError(
                f"unknown format {target!r}; known: {sorted(_CONVERT_TARGETS)}"
            ) from None
    if type(A) is target and not kwargs:
        return A
    indptr, indices, values = csr_host_arrays(A)
    m, n = A.shape
    if target is Csr:
        return Csr(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(indices, jnp.int32),
            values=jnp.asarray(values),
            shape=(m, n),
        )
    if target is Coo:
        rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
        return Coo(
            row_idx=jnp.asarray(rows, jnp.int32),
            col_idx=jnp.asarray(indices, jnp.int32),
            values=jnp.asarray(values),
            shape=(m, n),
        )
    if target is Ell:
        return ell_from_csr_host(indptr, indices, values, (m, n), **kwargs)
    if target is Sellp:
        return sellp_from_csr_host(indptr, indices, values, (m, n), **kwargs)
    if target is Dense:
        rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
        out = np.zeros((m, n), values.dtype if values.size else np.dtype(A.dtype))
        np.add.at(out, (rows, indices), values)
        return Dense(jnp.asarray(out))
    raise TypeError(f"unknown conversion target {target!r}")
