"""repro.sparse.gallery — parameterized matrix generators (host CSR).

The realistic-matrix corpus the solver stack is exercised on: 2D/3D Poisson
finite-difference stencils, anisotropic diffusion, the diagonally dominant
banded family the serve traffic generator draws from, seeded power-law graph
Laplacians (irregular row-length distributions, the spectra graph solvers
see), and nonsymmetric convection-diffusion with upwind/centered
discretizations and a mesh-Péclet knob (the workloads CG is *unsafe* on —
GMRES/BiCGSTAB territory).  Every generator returns host CSR arrays
``(indptr, indices, values, shape)`` — ``repro.sparse.csr_from_arrays`` turns
them into a device :class:`Csr`; the serve layer consumes the host arrays
directly (its requests travel as numpy).

These are the PDE-like spectra where Krylov iteration counts grow with √κ —
the matrices the AMG preconditioner (:mod:`repro.precond.amg`) exists for —
generated vectorized so the 10⁵–10⁶-row sizes the benchmarks use build in
milliseconds, not minutes.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "BANDED_OFFSETS",
    "HostCsr",
    "anisotropic_2d",
    "convection_diffusion_2d",
    "poisson_2d",
    "poisson_3d",
    "power_law_laplacian",
    "spd_banded",
]

#: (indptr, indices, values, shape) — the host-side CSR quadruple
HostCsr = Tuple[np.ndarray, np.ndarray, np.ndarray, Tuple[int, int]]

#: off-diagonal offset sets for :func:`spd_banded` — each a distinct sparsity
#: pattern (the serve traffic gallery indexes into this tuple)
BANDED_OFFSETS = (
    (1,),
    (1, 2),
    (1, 3),
    (1, 2, 4),
    (2,),
    (1, 2, 3),
    (1, 5),
    (3,),
)


def _coo_to_csr(
    rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, n: int
) -> HostCsr:
    """Sorted-duplicate-free COO triplets -> host CSR arrays."""
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(np.bincount(rows, minlength=n))
    return indptr, cols.astype(np.int32), vals.astype(np.float32), (n, n)


def poisson_2d(n_side: int) -> HostCsr:
    """5-point 2D Poisson stencil on an ``n_side`` × ``n_side`` grid.

    The canonical SPD model problem: diag 4, four ``-1`` neighbors,
    Dirichlet boundary.  κ grows like ``n_side²`` — unpreconditioned CG needs
    O(``n_side``) iterations, AMG O(1).
    """
    n = n_side * n_side
    idx = np.arange(n)
    gi, gj = idx // n_side, idx % n_side
    rows = [idx]
    cols = [idx]
    vals = [np.full(n, 4.0, np.float32)]
    for di, dj in ((-1, 0), (1, 0), (0, -1), (0, 1)):
        ni, nj = gi + di, gj + dj
        m = (ni >= 0) & (ni < n_side) & (nj >= 0) & (nj < n_side)
        rows.append(idx[m])
        cols.append((ni * n_side + nj)[m])
        vals.append(np.full(int(m.sum()), -1.0, np.float32))
    return _coo_to_csr(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n
    )


def poisson_3d(n_side: int) -> HostCsr:
    """7-point 3D Poisson stencil on an ``n_side``³ grid (diag 6)."""
    n = n_side ** 3
    idx = np.arange(n)
    gi = idx // (n_side * n_side)
    gj = (idx // n_side) % n_side
    gk = idx % n_side
    rows = [idx]
    cols = [idx]
    vals = [np.full(n, 6.0, np.float32)]
    for di, dj, dk in (
        (-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)
    ):
        ni, nj, nk = gi + di, gj + dj, gk + dk
        m = (
            (ni >= 0) & (ni < n_side)
            & (nj >= 0) & (nj < n_side)
            & (nk >= 0) & (nk < n_side)
        )
        rows.append(idx[m])
        cols.append(((ni * n_side + nj) * n_side + nk)[m])
        vals.append(np.full(int(m.sum()), -1.0, np.float32))
    return _coo_to_csr(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n
    )


def anisotropic_2d(n_side: int, epsilon: float = 0.01) -> HostCsr:
    """Anisotropic diffusion ``-u_xx - ε u_yy`` on a 2D grid.

    ``epsilon`` ≪ 1 makes the y-coupling weak — the strength-of-connection
    filter in AMG aggregation must drop the weak direction, which is exactly
    what :func:`repro.precond.amg.strength_mask`'s θ-threshold tests probe.
    """
    n = n_side * n_side
    eps = np.float32(epsilon)
    idx = np.arange(n)
    gi, gj = idx // n_side, idx % n_side
    rows = [idx]
    cols = [idx]
    vals = [np.full(n, 2.0 * (1.0 + eps), np.float32)]
    # x-direction (strong): weight -1; y-direction (weak): weight -epsilon
    for (di, dj), w in (
        ((0, -1), -1.0), ((0, 1), -1.0), ((-1, 0), -eps), ((1, 0), -eps)
    ):
        ni, nj = gi + di, gj + dj
        m = (ni >= 0) & (ni < n_side) & (nj >= 0) & (nj < n_side)
        rows.append(idx[m])
        cols.append((ni * n_side + nj)[m])
        vals.append(np.full(int(m.sum()), w, np.float32))
    return _coo_to_csr(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n
    )


def convection_diffusion_2d(
    n_side: int,
    peclet: float = 1.0,
    *,
    scheme: str = "upwind",
    velocity: Tuple[float, float] = (1.0, 0.5),
) -> HostCsr:
    """Nonsymmetric convection-diffusion ``-Δu + w·∇u`` on an ``n_side``² grid.

    ``peclet`` is the mesh Péclet number ``Pe = |w| h / (2ε)`` — the knob that
    moves the spectrum from diffusion-dominated (symmetric-ish, ``Pe ≪ 1``)
    to convection-dominated (strongly nonsymmetric, ``Pe ≫ 1``).  Rows are
    scaled by ``h²/ε`` so entries stay O(1) at every size.

    ``scheme="upwind"`` uses first-order upwind convection: an M-matrix,
    (weakly) diagonally dominant at any Péclet — the robust discretization.
    ``scheme="centered"`` uses central differences: second-order accurate but
    loses diagonal dominance past ``Pe = 1`` (the classic oscillatory regime),
    which is exactly the stress the nonsymmetric solvers need exercised.

    Either way the matrix is NOT symmetric (``velocity`` ≠ 0): ``cg``/``fcg``
    are wrong on it and must refuse (see the solver symmetry guard); use
    ``gmres``/``bicgstab``/``cgs``.
    """
    if scheme not in ("upwind", "centered"):
        raise ValueError(
            f"unknown scheme {scheme!r} (expected 'upwind' or 'centered')"
        )
    wx, wy = float(velocity[0]), float(velocity[1])
    wmag = float(np.hypot(wx, wy))
    if wmag == 0.0:
        raise ValueError("velocity must be nonzero for a convective term")
    # per-direction mesh Péclet: gamma_d = w_d * h / (2 eps)
    gx = float(peclet) * wx / wmag
    gy = float(peclet) * wy / wmag

    n = n_side * n_side
    idx = np.arange(n)
    gi, gj = idx // n_side, idx % n_side
    if scheme == "centered":
        diag = np.full(n, 4.0, np.float64)
        # (di, dj) -> stencil weight; +dj is +x (east), +di is +y (north)
        weights = {
            (0, 1): -1.0 + gx,
            (0, -1): -1.0 - gx,
            (1, 0): -1.0 + gy,
            (-1, 0): -1.0 - gy,
        }
    else:  # upwind, first order — donor cell against the flow direction
        diag = np.full(n, 4.0 + 2.0 * (abs(gx) + abs(gy)), np.float64)
        weights = {
            (0, 1): -1.0 - (2.0 * -gx if gx < 0 else 0.0),
            (0, -1): -1.0 - (2.0 * gx if gx > 0 else 0.0),
            (1, 0): -1.0 - (2.0 * -gy if gy < 0 else 0.0),
            (-1, 0): -1.0 - (2.0 * gy if gy > 0 else 0.0),
        }
    rows = [idx]
    cols = [idx]
    vals = [diag]
    for (di, dj), w in weights.items():
        ni, nj = gi + di, gj + dj
        m = (ni >= 0) & (ni < n_side) & (nj >= 0) & (nj < n_side)
        rows.append(idx[m])
        cols.append((ni * n_side + nj)[m])
        vals.append(np.full(int(m.sum()), w, np.float64))
    return _coo_to_csr(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n
    )


def power_law_laplacian(
    n: int,
    *,
    exponent: float = 2.5,
    min_degree: int = 2,
    shift: float = 1e-2,
    seed: int = 0,
) -> HostCsr:
    """Shifted graph Laplacian ``L + shift·I`` of a seeded power-law graph.

    Degrees are drawn from a Pareto tail with index ``exponent - 1`` (so the
    degree distribution decays like ``d^-exponent``, the scale-free regime)
    and wired by a configuration model: stub pairing, self-loops and
    duplicate edges dropped.  Unlike the stencils, row lengths are wildly
    irregular — a few hub rows with O(√n) entries next to degree-2 leaves —
    which is the load-imbalance stress ELL padding and SpMV row-splitting
    heuristics exist for.

    ``L = D - A`` is symmetric positive *semi*-definite (constant vector in
    the kernel); the ``shift`` makes it SPD so CG/AMG apply cleanly.
    Deterministic for a given ``seed``.
    """
    if n < 2:
        raise ValueError(f"need at least 2 vertices, got {n}")
    rng = np.random.default_rng(seed)
    deg = min_degree + np.floor(rng.pareto(exponent - 1.0, size=n)).astype(
        np.int64
    )
    deg = np.minimum(deg, n - 1)
    stubs = np.repeat(np.arange(n, dtype=np.int64), deg)
    if stubs.size % 2:
        stubs = stubs[:-1]
    rng.shuffle(stubs)
    u, v = stubs[0::2], stubs[1::2]
    keep = u != v  # drop self-loops
    u, v = u[keep], v[keep]
    # canonicalize + dedupe parallel edges from the stub pairing
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    edges = np.unique(lo * n + hi)
    lo, hi = edges // n, edges % n
    rows = np.concatenate([lo, hi, np.arange(n, dtype=np.int64)])
    cols = np.concatenate([hi, lo, np.arange(n, dtype=np.int64)])
    final_deg = np.bincount(np.concatenate([lo, hi]), minlength=n)
    vals = np.concatenate([
        np.full(lo.size * 2, -1.0, np.float64),
        final_deg.astype(np.float64) + float(shift),
    ])
    return _coo_to_csr(rows, cols, vals, n)


def spd_banded(
    n: int,
    offsets: Tuple[int, ...],
    shift: float,
    rng: np.random.Generator,
) -> HostCsr:
    """Diagonally dominant SPD banded matrix (the serve-traffic family).

    Distinct ``offsets`` tuples give distinct sparsity patterns; ``shift``
    and the random diagonal jitter vary the values within a pattern.
    """
    a = np.zeros((n, n), np.float32)
    idx = np.arange(n)
    a[idx, idx] = shift + rng.uniform(0.0, 0.5, size=n).astype(np.float32)
    for off in offsets:
        w = np.float32(-1.0 / off)
        a[idx[off:], idx[:-off]] = w
        a[idx[:-off], idx[off:]] = w
    # diagonal dominance keeps every draw SPD
    a[idx, idx] += np.abs(a).sum(axis=1).astype(np.float32)
    nz = a != 0
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(nz.sum(axis=1))
    indices = np.nonzero(nz)[1].astype(np.int32)
    values = a[nz].astype(np.float32)
    return indptr, indices, values, (n, n)
