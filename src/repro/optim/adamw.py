"""AdamW optimizer + LR schedules + global-norm clipping (no optax — substrate
is built in-repo per the framework scope).

Functional, optax-like contract::

    opt = adamw(schedule, weight_decay=0.1, clip_norm=1.0)
    state = opt.init(params)
    params, state, stats = opt.update(params, grads, state)

State is a registered pytree (checkpointable, shardable: moments inherit the
parameter sharding under pjit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


# -- schedules -------------------------------------------------------------------

def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
) -> Schedule:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule


def warmup_linear_schedule(peak_lr: float, warmup_steps: int, total_steps: int) -> Schedule:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        return jnp.where(step < warmup_steps, warm, peak_lr * (1 - prog))

    return schedule


# -- optimizer ---------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: jax.Array  # scalar int32
    mu: Any  # first moment (params-shaped)
    nu: Any  # second moment (params-shaped)


class Optimizer(NamedTuple):
    init: Callable[[Any], AdamWState]
    update: Callable[..., Tuple[Any, AdamWState, Dict[str, jax.Array]]]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda x: (x * scale).astype(x.dtype), tree), norm


def adamw(
    schedule: Schedule,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
    moment_dtype=jnp.float32,
) -> Optimizer:
    def init(params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(params, grads, state: AdamWState):
        stats: Dict[str, jax.Array] = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        stats["grad_norm"] = gnorm

        step = state.step + 1
        lr = schedule(step)
        stats["lr"] = lr
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(moment_dtype)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            mu_hat = mu / bc1
            nu_hat = nu / bc2
            step_val = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * p.astype(
                moment_dtype
            )
            return (p.astype(moment_dtype) - lr * step_val).astype(p.dtype), mu, nu

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state.mu)
        flat_nu = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        stats["param_norm"] = global_norm(new_p)
        return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), stats

    return Optimizer(init=init, update=update)
