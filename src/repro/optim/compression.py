"""Int8 gradient compression with error feedback — the DP bandwidth optimization.

At 1000+ node scale the data-parallel gradient all-reduce crosses the slowest
links (DCN between pods); 4x compression (f32 -> int8, or 2x from bf16) on that
axis is a standard distributed-optimization trick.  We implement the classic
error-feedback scheme (1-bit Adam lineage):

    q, scale = quantize(g + e)          # per-tensor symmetric int8
    e        = (g + e) - dequantize(q)  # residual carried to the next step
    g_sync   = all_reduce(q) * scale    # collective runs on int8 payload

``compressed_psum`` is the shard_map building block (used by the explicit-DP
train step and tested under an 8-device subprocess); pjit paths can wrap the
gradient tree with ``compress_tree``/``decompress_tree`` around their reduction.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compress one tensor: returns (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def init_error_state(grads: Any) -> Any:
    return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads: Any, err_state: Any):
    """Tree-wise EF compression. Returns ((q_tree, scale_tree), new_err_state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = ef_compress(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (
        (treedef.unflatten(qs), treedef.unflatten(scales)),
        treedef.unflatten(errs),
    )


def decompress_tree(q_tree: Any, scale_tree: Any, like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda q, s, g: dequantize_int8(q, s, g.dtype), q_tree, scale_tree, like
    )


def compressed_psum(grads: Any, err_state: Any, axis_name: str):
    """shard_map building block: EF-compressed mean-reduce over ``axis_name``.

    The int8 payload is what crosses the network; scales are reduced with a max
    (conservative — every shard dequantizes with the same scale, so the sum is
    exact in the quantized domain).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        amax_local = jnp.max(jnp.abs(corrected))
        amax = jax.lax.pmax(amax_local, axis_name)  # shared scale
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_e = corrected - q.astype(jnp.float32) * scale
        # int8 payload summed on the wire (accumulate in int32)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (qsum.astype(jnp.float32) * scale / n).astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten(
        [o[1] for o in out]
    )
