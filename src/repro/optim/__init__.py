"""repro.optim — AdamW, schedules, clipping, gradient compression."""

from repro.optim.adamw import (
    AdamWState,
    Optimizer,
    adamw,
    clip_by_global_norm,
    constant_schedule,
    global_norm,
    warmup_cosine_schedule,
    warmup_linear_schedule,
)
from repro.optim.compression import (
    compress_tree,
    compressed_psum,
    decompress_tree,
    dequantize_int8,
    ef_compress,
    init_error_state,
    quantize_int8,
)

__all__ = [
    "AdamWState",
    "Optimizer",
    "adamw",
    "clip_by_global_norm",
    "constant_schedule",
    "global_norm",
    "warmup_cosine_schedule",
    "warmup_linear_schedule",
    "compress_tree",
    "compressed_psum",
    "decompress_tree",
    "dequantize_int8",
    "ef_compress",
    "init_error_state",
    "quantize_int8",
]
