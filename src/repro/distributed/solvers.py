"""Distributed Krylov solves — the solver source runs UNCHANGED per shard.

``dist_solve`` is what the solver entry points (:mod:`repro.solvers.krylov`)
delegate to when handed a distributed operator: it wraps ONE ``shard_map``
over the mesh data axis around the ordinary solver function, giving it

* the matrix's per-shard local operator (local SpMV + halo exchange,
  :meth:`~repro.distributed.matrix.DistLinOp.local_operator`);
* a shard-local preconditioner (:mod:`repro.distributed.precond`);
* the distributed BLAS context
  (:func:`repro.sparse.ops.distributed_blas`), under which every ``dot`` /
  ``norm2`` the solver issues reduces locally through the dispatched kernels
  and then ``psum``-s over the axis, padding masked.

Because the stopping criterion consumes exactly those psum'd norms, ``Stop``
behaves bit-for-bit like the single-device solve (modulo reduction-order
float drift) — Ginkgo's promise that ``solver::Cg`` neither knows nor cares
whether its operator is ``matrix::Csr`` or ``distributed::Matrix``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.matrix import DATA_AXIS, shard_specs
from repro.distributed.precond import dist_preconditioner
from repro.solvers.common import SolveResult, Stop

__all__ = ["dist_solve"]

#: jitted shard_map closures keyed on everything the closure bakes in
#: (solver, operator/preconditioner structure incl. static partition, stop,
#: executor, options, part count) — without this every distributed solve
#: would rebuild the closure and pay a full retrace + XLA compile.  jit's own
#: cache still handles shape/dtype changes of the array arguments.
_JIT_CACHE = {}


def dist_solve(
    solver_fn,
    A,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M=None,
    precond_opts: Optional[dict] = None,
    executor=None,
    **options,
) -> SolveResult:
    """Run ``solver_fn`` (cg / bicgstab / gmres / ...) sharded over ``A``'s
    partition.  ``b`` / ``x0`` are ordinary global vectors; the result is the
    single-device-shaped :class:`SolveResult` with a global ``x``.
    """
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_shard_mesh, shard_map
    from repro.sparse import ops as sparse_ops

    part = A.partition
    Md = dist_preconditioner(A, M, executor=executor, **(precond_opts or {}))
    # static branch: history changes the shard_map output arity, and the
    # option value is part of the _JIT_CACHE key, so each setting compiles
    # its own closure
    want_history = bool(options.get("history"))

    bp = part.pad(b)
    xp = part.pad(x0) if x0 is not None else jnp.zeros_like(bp)
    mask = jnp.asarray(part.pad_mask)

    a_leaves, a_tree = jax.tree_util.tree_flatten(A)
    m_leaves, m_tree = jax.tree_util.tree_flatten(Md)

    key = (
        solver_fn,
        a_tree,
        m_tree,
        stop,
        executor,
        tuple(sorted(options.items())),
        part.num_parts,
    )
    fn = _JIT_CACHE.get(key)
    if fn is None:
        mesh = make_shard_mesh(part.num_parts, DATA_AXIS)

        def body(a_ls, m_ls, b_l, x0_l, mask_l):
            A_shard = jax.tree_util.tree_unflatten(a_tree, a_ls)
            M_shard = jax.tree_util.tree_unflatten(m_tree, m_ls)
            Aop = A_shard.local_operator(executor=executor)
            Ml = (
                M_shard.local_operator(executor=executor)
                if M_shard is not None
                else None
            )
            with sparse_ops.distributed_blas(DATA_AXIS, mask_l[0]):
                res = solver_fn(
                    Aop,
                    b_l[0],
                    x0_l[0],
                    stop=stop,
                    M=Ml,
                    executor=executor,
                    **options,
                )
            # scalars pick up a length-1 shard axis so every output can use
            # the same sharded out_spec (their psum'd values agree across
            # shards)
            outs = (
                res.x[None],
                res.iterations[None],
                res.residual_norm[None],
                res.converged[None],
            )
            if want_history:
                # the residual norms the solver recorded are the psum'd
                # global norms — every shard holds an identical copy
                outs = outs + (res.history[None],)
            return outs

        vec = P(DATA_AXIS, None)
        out_specs = (vec, P(DATA_AXIS), P(DATA_AXIS), P(DATA_AXIS))
        if want_history:
            out_specs = out_specs + (P(DATA_AXIS, None),)
        fn = jax.jit(
            shard_map(
                body,
                mesh=mesh,
                in_specs=(
                    shard_specs(a_leaves),
                    shard_specs(m_leaves),
                    vec,
                    vec,
                    vec,
                ),
                out_specs=out_specs,
            )
        )
        _JIT_CACHE[key] = fn
    outs = fn(a_leaves, m_leaves, bp, xp, mask)
    xs, iters, rnorm, conv = outs[:4]
    hist = outs[4][0] if want_history else None
    return SolveResult(part.unpad(xs), iters[0], rnorm[0], conv[0], hist)
