"""Row partitions — gko::experimental::distributed::Partition for this repo.

A :class:`Partition` splits the global row range ``[0, n)`` into one
*contiguous* range per part (device).  It is pure host-side setup metadata —
a frozen, hashable tuple of offsets — so it can ride along as static pytree
metadata on every distributed operator and be part of ``jit`` cache keys.

Ginkgo's distributed ``Partition`` supports arbitrary range-to-part maps;
this repo restricts to contiguous ranges in part order (range ``p`` belongs
to part ``p``), which is what mesh-axis sharding produces and what keeps the
padded shard layout (below) a single reshape.

Padded shard layout: every part is padded to ``max_part_size`` (``Lmax``) so
shards have identical shapes under ``shard_map``.  ``pad_index`` /
``unpad_index`` are the host-precomputed gather maps between the global
``(n,)`` vector and the padded ``(P, Lmax)`` shard stack; padding slots are
filled with zeros and masked out of every cross-shard reduction (see
:func:`repro.distributed.sharding.zero_shard_padding`).
"""

from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Sequence, Tuple

import numpy as np
import jax.numpy as jnp

__all__ = ["Partition"]


@dataclasses.dataclass(frozen=True)
class Partition:
    """Contiguous row ranges per part: part ``p`` owns ``[offsets[p], offsets[p+1])``."""

    offsets: Tuple[int, ...]  # (P+1,) non-decreasing, offsets[0] == 0

    def __post_init__(self):
        offs = tuple(int(o) for o in self.offsets)
        object.__setattr__(self, "offsets", offs)
        if len(offs) < 2:
            raise ValueError(f"partition needs at least one part, got {offs}")
        if offs[0] != 0:
            raise ValueError(f"partition offsets must start at 0, got {offs}")
        if any(b < a for a, b in zip(offs, offs[1:])):
            raise ValueError(f"partition offsets must be non-decreasing: {offs}")

    # -- construction ----------------------------------------------------------
    @classmethod
    def uniform(cls, n: int, num_parts: int) -> "Partition":
        """Balanced contiguous split: the first ``n % num_parts`` parts get one
        extra row (ragged when ``n % num_parts != 0``)."""
        if num_parts < 1:
            raise ValueError(f"num_parts must be >= 1, got {num_parts}")
        base, rem = divmod(int(n), num_parts)
        sizes = [base + (1 if p < rem else 0) for p in range(num_parts)]
        return cls.from_part_sizes(sizes)

    @classmethod
    def from_part_sizes(cls, sizes: Sequence[int]) -> "Partition":
        offs = [0]
        for s in sizes:
            if s < 0:
                raise ValueError(f"part sizes must be >= 0, got {tuple(sizes)}")
            offs.append(offs[-1] + int(s))
        return cls(tuple(offs))

    @classmethod
    def from_mesh_axis(cls, mesh, n: int, axis: str = "data") -> "Partition":
        """Partition ``n`` rows over a mesh axis (one part per axis slot)."""
        return cls.uniform(n, mesh.shape[axis])

    # -- shape queries ---------------------------------------------------------
    @property
    def num_parts(self) -> int:
        return len(self.offsets) - 1

    @property
    def global_size(self) -> int:
        return self.offsets[-1]

    @property
    def part_sizes(self) -> Tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.offsets, self.offsets[1:]))

    @property
    def max_part_size(self) -> int:
        """``Lmax`` — the padded per-shard length."""
        return max(self.part_sizes)

    def range_of(self, part: int) -> Tuple[int, int]:
        return (self.offsets[part], self.offsets[part + 1])

    # -- index maps (host-side numpy) ------------------------------------------
    def part_of(self, rows) -> np.ndarray:
        """Owning part of each global row (empty parts own nothing)."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.global_size):
            raise IndexError(f"rows out of range [0, {self.global_size})")
        return (
            np.searchsorted(self._offsets_np, rows, side="right").astype(np.int64)
            - 1
        )

    def to_local(self, rows) -> Tuple[np.ndarray, np.ndarray]:
        """Global rows -> (part, local index within the part)."""
        p = self.part_of(rows)
        return p, np.asarray(rows) - self._offsets_np[p]

    def to_global(self, part, local) -> np.ndarray:
        """(part, local index) -> global row."""
        part = np.asarray(part, np.int64)
        local = np.asarray(local, np.int64)
        sizes = np.asarray(self.part_sizes, np.int64)
        if (local < 0).any() or (local >= sizes[part]).any():
            raise IndexError("local index out of its part's range")
        return self._offsets_np[part] + local

    def padded_index(self, rows) -> np.ndarray:
        """Global rows -> flat index into the padded ``(P*Lmax,)`` layout.

        This is the coordinate system halo maps gather from after an
        ``all_gather`` of the padded shards.
        """
        p, l = self.to_local(rows)
        return p * self.max_part_size + l

    @cached_property
    def _offsets_np(self) -> np.ndarray:
        return np.asarray(self.offsets, np.int64)

    @cached_property
    def pad_mask(self) -> np.ndarray:
        """(P, Lmax) bool — True on real slots, False on padding."""
        from repro.distributed.sharding import shard_pad_mask

        return shard_pad_mask(self.part_sizes, self.max_part_size)

    @cached_property
    def _pad_gather(self) -> np.ndarray:
        """(P, Lmax) int — global row per slot; padding -> n (zero sentinel)."""
        n, L = self.global_size, self.max_part_size
        idx = self._offsets_np[:-1, None] + np.arange(L, dtype=np.int64)[None, :]
        return np.where(self.pad_mask, idx, n)

    @cached_property
    def _unpad_gather(self) -> np.ndarray:
        """(n,) int — padded flat slot of each global row."""
        return self.padded_index(np.arange(self.global_size, dtype=np.int64))

    # -- padded shard stack <-> global vector (device, jittable) ---------------
    def pad(self, x) -> jnp.ndarray:
        """Global ``(n, ...)`` -> padded ``(P, Lmax, ...)``, padding zeroed."""
        x = jnp.asarray(x)
        zero = jnp.zeros((1,) + x.shape[1:], x.dtype)
        return jnp.concatenate([x, zero], axis=0)[self._pad_gather]

    def unpad(self, xp) -> jnp.ndarray:
        """Padded ``(P, Lmax, ...)`` -> global ``(n, ...)``."""
        xp = jnp.asarray(xp)
        flat = xp.reshape((self.num_parts * self.max_part_size,) + xp.shape[2:])
        return flat[self._unpad_gather]
