"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / ZeRO).

Models annotate parameters with *logical* axes ("embed", "mlp", "heads",
"vocab", "expert", ...); this module turns those into ``NamedSharding``s for a
concrete mesh.  The rules:

* tensor-parallel ("model" mesh axis): mlp hidden, attention heads, kv heads,
  vocab, experts — first annotated dim that divides evenly gets the axis;
* data-parallel: dims annotated "batch" shard over ("pod", "data");
* ZeRO-1: optimizer moments additionally shard a large replicated dim over
  "data" (params stay replicated across data; the update induces the ZeRO-1
  reduce-scatter/all-gather pair);
* FSDP mode (``zero="fsdp"``): parameters themselves shard "embed" over
  "data" — a §Perf lever for memory-bound cells.

Every assignment is divisibility-checked; non-divisible dims fall back to
replication (e.g. minicpm3's vocab 73448 on a 16-wide model axis).

Axes trees are arbitrary pytrees whose leaves are tuples of logical-axis names
(or None); the walkers below pair them with shape trees structurally.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axes eligible for the tensor-parallel mesh axis, in priority order;
# "kv_seq" is the sequence-parallel fallback for KV caches whose head count
# does not divide the model axis (e.g. granite kv=8 on a 16-wide axis)
MODEL_AXES = ("expert", "mlp", "heads", "kv_heads", "kv_seq", "vocab")
# logical axes eligible for ZeRO sharding of moments / FSDP of params
ZERO_AXES = ("embed", "expert_mlp", "mlp", "heads", "vocab")


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` where available; psum(1) inside older jax's
    collective bodies (same value, both resolve at trace time)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)



# -- padded-shard reduction hygiene --------------------------------------------
#
# Distributed vectors are padded to a uniform per-shard length (``Lmax``) so
# every shard has the same shape under ``shard_map``; the padding slots MUST
# be excluded from any cross-shard reduction (``psum`` dot / norm), or a
# ragged partition double-counts whatever happens to sit in them — the
# classic padded-shard bug.  The distributed BLAS layer routes every
# reduction operand through :func:`zero_shard_padding` so a reduction is
# correct even when padding slots hold garbage (e.g. after an operator that
# writes the full padded shard).


def shard_pad_mask(part_sizes: Sequence[int], max_size: int) -> np.ndarray:
    """(P, max_size) bool mask — True on real slots, False on padding."""
    sizes = np.asarray(part_sizes, np.int64)
    if max_size < (int(sizes.max()) if sizes.size else 0):
        raise ValueError(
            f"max_size {max_size} smaller than largest part {sizes.max()}"
        )
    return np.arange(max_size, dtype=np.int64)[None, :] < sizes[:, None]


def zero_shard_padding(x: jax.Array, mask) -> jax.Array:
    """Zero the padding slots of a (possibly poisoned) padded shard.

    ``mask`` is this shard's slice of :func:`shard_pad_mask` (bool,
    broadcastable against ``x`` on the trailing shard axis); ``None`` means
    "no padding" and returns ``x`` unchanged.
    """
    if mask is None:
        return x
    return jnp.where(mask, x, jnp.zeros((), x.dtype))


def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)
    )


def _mesh_axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel mesh axes ("pod","data") or ("data",)."""
    return tuple(n for n in ("pod", "data") if n in mesh.shape)


def spec_for_leaf(
    shape: Sequence[int],
    axes: Optional[Tuple[Optional[str], ...]],
    mesh: Mesh,
    *,
    zero: str = "none",  # "none" | "zero1" | "fsdp"
) -> P:
    if axes is None:
        return P()
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} rank != shape {shape}")
    assign: list = [None] * len(shape)

    daxes = data_axes(mesh)
    dsize = _mesh_axis_size(mesh, daxes) if daxes else 1
    model_size = mesh.shape.get("model", 1)
    model_used = False
    data_used = False

    # 0) batch dims -> data axes
    for i, ax in enumerate(axes):
        if ax == "batch" and dsize > 1 and shape[i] % dsize == 0:
            assign[i] = daxes if len(daxes) > 1 else daxes[0]
            data_used = True
            break

    # 1) tensor parallel: highest-priority eligible divisible dim
    if model_size > 1:
        for logical in MODEL_AXES:
            if model_used:
                break
            for i, ax in enumerate(axes):
                if ax == logical and assign[i] is None and shape[i] % model_size == 0:
                    assign[i] = "model"
                    model_used = True
                    break

    # 2) ZeRO/FSDP: shard one more big dim over the data axes
    if zero in ("zero1", "fsdp") and dsize > 1 and not data_used:
        for logical in ZERO_AXES:
            placed = False
            for i, ax in enumerate(axes):
                if ax == logical and assign[i] is None and shape[i] % dsize == 0:
                    assign[i] = daxes if len(daxes) > 1 else daxes[0]
                    placed = True
                    break
            if placed:
                break
    return P(*assign)


def _walk(mesh: Mesh, shapes, axes_tree, *, zero: str):
    flat_axes, treedef = jax.tree_util.tree_flatten(axes_tree, is_leaf=_is_axes_leaf)
    flat_shapes = treedef.flatten_up_to(shapes)
    out = [
        NamedSharding(mesh, spec_for_leaf(s.shape, a, mesh, zero=zero))
        for s, a in zip(flat_shapes, flat_axes)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(mesh: Mesh, shapes, axes_tree, *, zero: str = "none"):
    """shapes: pytree of ShapeDtypeStruct (eval_shape); axes_tree: logical axes."""
    return _walk(mesh, shapes, axes_tree, zero=zero)


def moment_shardings(mesh: Mesh, shapes, axes_tree, *, zero: str = "zero1"):
    """Optimizer-moment shardings (ZeRO-1 by default)."""
    return _walk(mesh, shapes, axes_tree, zero=zero)


def cache_shardings(mesh: Mesh, shapes, axes_tree):
    return _walk(mesh, shapes, axes_tree, zero="none")


def batch_spec(mesh: Mesh, batch_size: int, extra_dims: int = 1) -> P:
    """Shard the leading batch dim over as many data axes as divide it."""
    daxes = data_axes(mesh)
    full = _mesh_axis_size(mesh, daxes) if daxes else 1
    if daxes and full > 1 and batch_size % full == 0:
        lead = daxes if len(daxes) > 1 else daxes[0]
        return P(lead, *([None] * extra_dims))
    if "data" in mesh.shape and mesh.shape["data"] > 1 and batch_size % mesh.shape["data"] == 0:
        return P("data", *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def batch_shardings(mesh: Mesh, batch: Dict) -> Dict:
    """NamedShardings for a data batch dict ({tokens|embeds, labels})."""
    return {
        k: NamedSharding(mesh, batch_spec(mesh, v.shape[0], v.ndim - 1))
        for k, v in batch.items()
    }


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def tree_replicated(mesh: Mesh, tree):
    return jax.tree_util.tree_map(lambda _: replicated(mesh), tree)
