"""Mesh-sharded matrix formats — gko::experimental::distributed::Matrix.

A distributed matrix row-partitions a square operator ``A`` into one shard
per part of a :class:`~repro.distributed.partition.Partition`.  Each shard
stores TWO blocks (exactly Ginkgo's local/non-local decomposition):

* the **local** block — columns inside the shard's own row range, with
  column indices rebased to the shard, applied against the shard's own
  ``x`` chunk with no communication;
* the **halo** (non-local) block — columns owned by other shards, compressed
  onto the shard's *halo column set* (the unique remote columns it touches),
  applied against the gathered remote entries.

SpMV is then ``y_p = A_pp x_p + A_halo_p gather(x)[halo_cols_p]`` under
``shard_map`` over the mesh data axis: one ``all_gather`` of the padded
``x`` shards per apply, followed by the host-precomputed halo-column gather.
Both block SpMVs dispatch through the ordinary format registry, so every
shard's local kernel still resolves tile geometry via
``Executor.launch_config`` — the per-target tuning tables apply per shard.

Shards are padded to uniform shapes (rows to ``Lmax``, nnz/halo widths to the
per-matrix maxima) so the whole matrix is one stacked pytree with a leading
part axis — shardable with a single ``P("data", ...)`` spec.  Padding follows
the repo's predication-free convention: index 0 + value 0 (in-bounds gather,
zero contribution).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.linop import LinOp, MatrixFreeOp
from repro.distributed.partition import Partition
from repro.sparse.formats import (
    Csr,
    Ell,
    csr_host_arrays,
    csr_slice_rows_host,
)

__all__ = ["DistLinOp", "DistCsr", "DistEll", "split_by_rows", "shard_specs"]

#: the mesh axis every distributed operator shards over
DATA_AXIS = "data"


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields)
    )
    return cls


def shard_specs(tree):
    """PartitionSpec pytree sharding every leaf's leading part axis."""
    from jax.sharding import PartitionSpec as P

    return jax.tree_util.tree_map(
        lambda l: P(DATA_AXIS, *([None] * (l.ndim - 1))), tree
    )


# =============================================================================
# Host-side split (setup time, numpy) — Ginkgo's build_local_nonlocal
# =============================================================================


def split_by_rows(indptr, indices, values, partition: Partition) -> List[dict]:
    """Split a host CSR triplet into per-part local + halo blocks.

    Returns one dict per part with keys ``local`` (CSR triplet over the
    shard's square diagonal block, columns rebased), ``halo`` (CSR triplet
    whose columns index into ``halo_cols``), and ``halo_cols`` (sorted unique
    global columns this part needs from other parts).
    """
    indptr = np.asarray(indptr, np.int64)
    parts = []
    for p in range(partition.num_parts):
        lo, hi = partition.range_of(p)
        ip, j, v = csr_slice_rows_host(indptr, indices, values, lo, hi)
        rows = np.repeat(np.arange(hi - lo, dtype=np.int64), np.diff(ip))
        is_local = (j >= lo) & (j < hi)

        def _triplet(sel, cols):
            counts = np.zeros(hi - lo + 1, np.int64)
            np.add.at(counts, rows[sel] + 1, 1)
            return (np.cumsum(counts), cols, v[sel])

        halo_cols = np.unique(j[~is_local])
        parts.append(
            {
                "local": _triplet(is_local, j[is_local] - lo),
                "halo": _triplet(
                    ~is_local, np.searchsorted(halo_cols, j[~is_local])
                ),
                "halo_cols": halo_cols,
            }
        )
    return parts


def _stack_csr(triplets, n_rows_pad: int, pad_nnz: int):
    """Stack per-part CSR triplets into padded (P, ...) arrays."""
    P = len(triplets)
    indptr = np.zeros((P, n_rows_pad + 1), np.int32)
    indices = np.zeros((P, pad_nnz), np.int32)
    values = None
    for p, (ip, j, v) in enumerate(triplets):
        if values is None:
            values = np.zeros((P, pad_nnz), v.dtype)
        rows = len(ip) - 1
        indptr[p, : rows + 1] = ip
        indptr[p, rows + 1 :] = ip[-1]  # padding rows are empty
        indices[p, : len(j)] = j
        values[p, : len(v)] = v
    return indptr, indices, values


def _ell_arrays(ip, j, v, n_rows_pad: int, k: int):
    """One part's CSR triplet -> padded row-major ELL arrays."""
    cols = np.zeros((n_rows_pad, k), np.int32)
    vals = np.zeros((n_rows_pad, k), v.dtype)
    for r in range(len(ip) - 1):
        a, b = ip[r], ip[r + 1]
        cols[r, : b - a] = j[a:b]
        vals[r, : b - a] = v[a:b]
    return cols, vals


# =============================================================================
# The distributed LinOp base
# =============================================================================


class DistLinOp(LinOp):
    """Base of the mesh-sharded operators (gko::experimental::distributed).

    Subclasses are stacked pytrees whose array leaves carry a leading part
    axis; ``local_operator`` builds the per-shard operator INSIDE a
    ``shard_map`` body (leaves sliced to leading size 1), and the global
    ``_apply`` wraps exactly that body in ``shard_map`` over the data axis —
    so ``A @ x`` on a replicated global vector and a sharded solver iteration
    run the same per-shard code.
    """

    is_distributed = True
    axis_name = DATA_AXIS

    # -- subclass surface: per-shard apply pieces ------------------------------
    def _local_blocks(self, executor):
        """(local_block, halo_block_or_None, halo_map) for THIS shard."""
        raise NotImplementedError

    def local_operator(self, executor=None) -> LinOp:
        part = self.partition
        Lmax = part.max_part_size
        local, halo, halo_map = self._local_blocks(executor)

        def matvec(x_l):
            from repro.sparse import ops as sparse_ops

            y = sparse_ops.apply(local, x_l, executor=executor)
            if halo is not None:
                xg = jax.lax.all_gather(x_l, self.axis_name, tiled=True)
                y = y + sparse_ops.apply(halo, xg[halo_map], executor=executor)
            return y

        return MatrixFreeOp(matvec, shape=(Lmax, Lmax), dtype=self.dtype)

    # -- the global apply (replicated global vector in / out) ------------------
    def _apply(self, x, executor):
        from repro.launch.mesh import make_shard_mesh, shard_map
        from jax.sharding import PartitionSpec as P

        part = self.partition
        mesh = make_shard_mesh(part.num_parts, self.axis_name)
        leaves, treedef = jax.tree_util.tree_flatten(self)
        xp = part.pad(x)

        def body(shard_leaves, x_l):
            shard = jax.tree_util.tree_unflatten(treedef, shard_leaves)
            op = shard.local_operator(executor=executor)
            return op.apply(x_l[0])[None]

        vec_spec = P(self.axis_name, *([None] * (xp.ndim - 1)))
        yp = shard_map(
            body,
            mesh=mesh,
            in_specs=(shard_specs(leaves), vec_spec),
            out_specs=vec_spec,
        )(leaves, xp)
        return part.unpad(yp)

    # -- common reporting ------------------------------------------------------
    @property
    def dtype(self):
        return self.local_values.dtype

    @property
    def memory_bytes(self) -> int:
        return sum(
            int(l.size) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(self)
        )

    @property
    def num_halo_cols(self) -> Tuple[int, ...]:
        """Per-part halo-column-set sizes (communication volume metric)."""
        return self._halo_counts

    def astype(self, dtype) -> "DistLinOp":
        return dataclasses.replace(
            self,
            local_values=self.local_values.astype(dtype),
            halo_values=self.halo_values.astype(dtype),
        )


def _halo_map_padded(parts, partition: Partition) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Stack per-part halo column sets as padded-global gather indices."""
    counts = tuple(len(p["halo_cols"]) for p in parts)
    h_max = max(counts) if counts else 0
    halo_map = np.zeros((partition.num_parts, h_max), np.int32)
    for p, info in enumerate(parts):
        cols = info["halo_cols"]
        # padded-global coordinates: what an all_gather of padded x shards
        # yields; padding entries point at slot 0 and pair with zero values
        halo_map[p, : len(cols)] = partition.padded_index(cols)
    return halo_map, counts


# =============================================================================
# DistCsr
# =============================================================================


@dataclasses.dataclass(frozen=True)
class DistCsr(DistLinOp):
    """Row-partitioned CSR: per-shard local + halo CSR blocks."""

    local_indptr: jax.Array  # (P, Lmax+1) i32
    local_indices: jax.Array  # (P, K_loc) i32, shard-local columns
    local_values: jax.Array  # (P, K_loc)
    halo_indptr: jax.Array  # (P, Lmax+1) i32
    halo_indices: jax.Array  # (P, K_halo) i32, into the halo column set
    halo_values: jax.Array  # (P, K_halo)
    halo_map: jax.Array  # (P, H_max) i32, padded-global gather indices
    shape: Tuple[int, int]  # static (n, n)
    nnz: int  # static — true nonzeros (flops metric)
    partition: Partition  # static
    _halo_counts: Tuple[int, ...]  # static — true halo sizes per part

    @classmethod
    def from_matrix(cls, A, partition: Partition) -> "DistCsr":
        indptr, indices, values, n = _square_host_csr(A, partition)
        parts = split_by_rows(indptr, indices, values, partition)
        Lmax = partition.max_part_size
        k_loc = max(1, max(len(p["local"][2]) for p in parts))
        k_halo = max(1, max(len(p["halo"][2]) for p in parts))
        li, lj, lv = _stack_csr([p["local"] for p in parts], Lmax, k_loc)
        hi_, hj, hv = _stack_csr([p["halo"] for p in parts], Lmax, k_halo)
        halo_map, counts = _halo_map_padded(parts, partition)
        return cls(
            local_indptr=jnp.asarray(li),
            local_indices=jnp.asarray(lj),
            local_values=jnp.asarray(lv),
            halo_indptr=jnp.asarray(hi_),
            halo_indices=jnp.asarray(hj),
            halo_values=jnp.asarray(hv),
            halo_map=jnp.asarray(halo_map),
            shape=(n, n),
            nnz=int(len(values)),
            partition=partition,
            _halo_counts=counts,
        )

    def local_block(self, p: int) -> Csr:
        """Part ``p``'s padded square diagonal block as a plain Csr."""
        L = self.partition.max_part_size
        return Csr(
            self.local_indptr[p], self.local_indices[p], self.local_values[p],
            shape=(L, L),
        )

    def _local_blocks(self, executor):
        L = self.partition.max_part_size
        h_max = self.halo_map.shape[-1]
        local = Csr(
            self.local_indptr[0], self.local_indices[0], self.local_values[0],
            shape=(L, L),
        )
        if h_max == 0:
            return local, None, None
        halo = Csr(
            self.halo_indptr[0], self.halo_indices[0], self.halo_values[0],
            shape=(L, h_max),
        )
        return local, halo, self.halo_map[0]


_register(
    DistCsr,
    [
        "local_indptr", "local_indices", "local_values",
        "halo_indptr", "halo_indices", "halo_values", "halo_map",
    ],
    ["shape", "nnz", "partition", "_halo_counts"],
)


# =============================================================================
# DistEll
# =============================================================================


@dataclasses.dataclass(frozen=True)
class DistEll(DistLinOp):
    """Row-partitioned ELL: per-shard local + halo ELL blocks.

    Padding entries use the format's own (col 0, value 0) convention in both
    the shard-local and halo-column index spaces.
    """

    local_col_idx: jax.Array  # (P, Lmax, k_loc) i32
    local_values: jax.Array  # (P, Lmax, k_loc)
    halo_col_idx: jax.Array  # (P, Lmax, k_halo) i32, into the halo column set
    halo_values: jax.Array  # (P, Lmax, k_halo)
    halo_map: jax.Array  # (P, H_max) i32
    shape: Tuple[int, int]
    nnz: int
    partition: Partition
    _halo_counts: Tuple[int, ...]

    @classmethod
    def from_matrix(cls, A, partition: Partition) -> "DistEll":
        indptr, indices, values, n = _square_host_csr(A, partition)
        parts = split_by_rows(indptr, indices, values, partition)
        Lmax = partition.max_part_size

        def max_row_nnz(key):
            return max(
                1,
                max(
                    (int(np.diff(p[key][0]).max()) if len(p[key][0]) > 1 else 0)
                    for p in parts
                ),
            )

        k_loc, k_halo = max_row_nnz("local"), max_row_nnz("halo")
        lc = np.zeros((partition.num_parts, Lmax, k_loc), np.int32)
        lv = np.zeros((partition.num_parts, Lmax, k_loc), values.dtype)
        hc = np.zeros((partition.num_parts, Lmax, k_halo), np.int32)
        hv = np.zeros((partition.num_parts, Lmax, k_halo), values.dtype)
        for p, info in enumerate(parts):
            lc[p], lv[p] = _ell_arrays(*info["local"], Lmax, k_loc)
            hc[p], hv[p] = _ell_arrays(*info["halo"], Lmax, k_halo)
        halo_map, counts = _halo_map_padded(parts, partition)
        return cls(
            local_col_idx=jnp.asarray(lc),
            local_values=jnp.asarray(lv),
            halo_col_idx=jnp.asarray(hc),
            halo_values=jnp.asarray(hv),
            halo_map=jnp.asarray(halo_map),
            shape=(n, n),
            nnz=int(len(values)),
            partition=partition,
            _halo_counts=counts,
        )

    def local_block(self, p: int) -> Ell:
        L = self.partition.max_part_size
        return Ell(self.local_col_idx[p], self.local_values[p], shape=(L, L))

    def _local_blocks(self, executor):
        L = self.partition.max_part_size
        h_max = self.halo_map.shape[-1]
        local = Ell(self.local_col_idx[0], self.local_values[0], shape=(L, L))
        if h_max == 0:
            return local, None, None
        halo = Ell(self.halo_col_idx[0], self.halo_values[0], shape=(L, h_max))
        return local, halo, self.halo_map[0]


_register(
    DistEll,
    ["local_col_idx", "local_values", "halo_col_idx", "halo_values", "halo_map"],
    ["shape", "nnz", "partition", "_halo_counts"],
)


def _square_host_csr(A, partition: Partition):
    """Validate + extract the host CSR triplet of a square operand."""
    m, n = A.shape
    if m != n:
        raise ValueError(
            f"distributed formats row-partition SQUARE operators, got {A.shape}"
        )
    if partition.global_size != n:
        raise ValueError(
            f"partition covers {partition.global_size} rows but A has {n}"
        )
    indptr, indices, values = csr_host_arrays(A)
    return indptr, indices, values, n
