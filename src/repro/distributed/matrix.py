"""Mesh-sharded matrix formats — gko::experimental::distributed::Matrix.

A distributed matrix row-partitions a square operator ``A`` into one shard
per part of a :class:`~repro.distributed.partition.Partition`.  Each shard
stores TWO blocks (exactly Ginkgo's local/non-local decomposition):

* the **local** block — columns inside the shard's own row range, with
  column indices rebased to the shard, applied against the shard's own
  ``x`` chunk with no communication;
* the **halo** (non-local) block — columns owned by other shards, compressed
  onto the shard's *halo column set* (the unique remote columns it touches),
  applied against the gathered remote entries.

The local block is further split row-wise at partition time into an
**interior** class (rows touching no halo column) and a **boundary** class
(rows that do): the apply issues the halo ``all_gather`` first and runs the
interior SpMV while the collective is in flight — halo-exchange/compute
overlap, with the row classification decided once on the host.

SpMV is then ``y_p = A_int_p x_p + A_bnd_p x_p + A_halo_p
gather(x)[halo_cols_p]`` under ``shard_map`` over the mesh data axis: one
``all_gather`` of the padded ``x`` shards per apply, followed by the
host-precomputed halo-column gather.
Both block SpMVs dispatch through the ordinary format registry, so every
shard's local kernel still resolves tile geometry via
``Executor.launch_config`` — the per-target tuning tables apply per shard.

Shards are padded to uniform shapes (rows to ``Lmax``, nnz/halo widths to the
per-matrix maxima) so the whole matrix is one stacked pytree with a leading
part axis — shardable with a single ``P("data", ...)`` spec.  Padding follows
the repo's predication-free convention: index 0 + value 0 (in-bounds gather,
zero contribution).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.linop import LinOp, MatrixFreeOp
from repro.distributed.partition import Partition
from repro.sparse.formats import (
    Csr,
    Ell,
    csr_host_arrays,
    csr_slice_rows_host,
)

__all__ = ["DistLinOp", "DistCsr", "DistEll", "split_by_rows", "shard_specs"]

#: the mesh axis every distributed operator shards over
DATA_AXIS = "data"


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields)
    )
    return cls


def shard_specs(tree):
    """PartitionSpec pytree sharding every leaf's leading part axis."""
    from jax.sharding import PartitionSpec as P

    return jax.tree_util.tree_map(
        lambda l: P(DATA_AXIS, *([None] * (l.ndim - 1))), tree
    )


# =============================================================================
# Host-side split (setup time, numpy) — Ginkgo's build_local_nonlocal
# =============================================================================


def split_by_rows(indptr, indices, values, partition: Partition) -> List[dict]:
    """Split a host CSR triplet into per-part local + halo blocks.

    Returns one dict per part with keys ``local`` (CSR triplet over the
    shard's square diagonal block, columns rebased), ``halo`` (CSR triplet
    whose columns index into ``halo_cols``), and ``halo_cols`` (sorted unique
    global columns this part needs from other parts).

    The local block is additionally classified row-wise for the
    overlap-capable formats: ``interior`` holds the local entries of rows
    that touch NO halo column (computable before any communication lands)
    and ``boundary`` the local entries of rows that do.  The two are
    row-disjoint and together exactly the ``local`` triplet — the
    compute/communication overlap split, decided once at partition time.
    """
    indptr = np.asarray(indptr, np.int64)
    parts = []
    for p in range(partition.num_parts):
        lo, hi = partition.range_of(p)
        ip, j, v = csr_slice_rows_host(indptr, indices, values, lo, hi)
        rows = np.repeat(np.arange(hi - lo, dtype=np.int64), np.diff(ip))
        is_local = (j >= lo) & (j < hi)

        def _triplet(sel, cols):
            counts = np.zeros(hi - lo + 1, np.int64)
            np.add.at(counts, rows[sel] + 1, 1)
            return (np.cumsum(counts), cols, v[sel])

        has_halo = np.zeros(hi - lo, bool)
        has_halo[rows[~is_local]] = True
        is_int = is_local & ~has_halo[rows]
        is_bnd = is_local & has_halo[rows]
        halo_cols = np.unique(j[~is_local])
        parts.append(
            {
                "local": _triplet(is_local, j[is_local] - lo),
                "interior": _triplet(is_int, j[is_int] - lo),
                "boundary": _triplet(is_bnd, j[is_bnd] - lo),
                "halo": _triplet(
                    ~is_local, np.searchsorted(halo_cols, j[~is_local])
                ),
                "halo_cols": halo_cols,
            }
        )
    return parts


def _stack_csr(triplets, n_rows_pad: int, pad_nnz: int):
    """Stack per-part CSR triplets into padded (P, ...) arrays."""
    P = len(triplets)
    indptr = np.zeros((P, n_rows_pad + 1), np.int32)
    indices = np.zeros((P, pad_nnz), np.int32)
    values = None
    for p, (ip, j, v) in enumerate(triplets):
        if values is None:
            values = np.zeros((P, pad_nnz), v.dtype)
        rows = len(ip) - 1
        indptr[p, : rows + 1] = ip
        indptr[p, rows + 1 :] = ip[-1]  # padding rows are empty
        indices[p, : len(j)] = j
        values[p, : len(v)] = v
    return indptr, indices, values


def _ell_arrays(ip, j, v, n_rows_pad: int, k: int):
    """One part's CSR triplet -> padded row-major ELL arrays."""
    cols = np.zeros((n_rows_pad, k), np.int32)
    vals = np.zeros((n_rows_pad, k), v.dtype)
    for r in range(len(ip) - 1):
        a, b = ip[r], ip[r + 1]
        cols[r, : b - a] = j[a:b]
        vals[r, : b - a] = v[a:b]
    return cols, vals


# =============================================================================
# The distributed LinOp base
# =============================================================================


class DistLinOp(LinOp):
    """Base of the mesh-sharded operators (gko::experimental::distributed).

    Subclasses are stacked pytrees whose array leaves carry a leading part
    axis; ``local_operator`` builds the per-shard operator INSIDE a
    ``shard_map`` body (leaves sliced to leading size 1), and the global
    ``_apply`` wraps exactly that body in ``shard_map`` over the data axis —
    so ``A @ x`` on a replicated global vector and a sharded solver iteration
    run the same per-shard code.
    """

    is_distributed = True
    axis_name = DATA_AXIS

    #: ordered value-array field names (first one defines the dtype)
    _value_fields: Tuple[str, ...] = ()

    # -- subclass surface: per-shard apply pieces ------------------------------
    def _local_blocks(self, executor):
        """(interior, boundary_or_None, halo_block_or_None, halo_map) for THIS
        shard.  ``boundary``/``halo`` are ``None`` when the shard touches no
        remote column (then ``interior`` is the whole diagonal block)."""
        raise NotImplementedError

    def local_operator(self, executor=None) -> LinOp:
        part = self.partition
        Lmax = part.max_part_size
        interior, boundary, halo, halo_map = self._local_blocks(executor)

        def matvec(x_l):
            from repro.sparse import ops as sparse_ops

            if halo is None:
                return sparse_ops.apply(interior, x_l, executor=executor)
            # issue the collective FIRST, then the interior SpMV: interior
            # rows touch no halo column, so XLA's latency-hiding scheduler is
            # free to run that matvec while the all_gather is in flight; only
            # the boundary/halo contributions wait on the gathered x.
            xg = jax.lax.all_gather(x_l, self.axis_name, tiled=True)
            y = sparse_ops.apply(interior, x_l, executor=executor)
            y = y + sparse_ops.apply(boundary, x_l, executor=executor)
            return y + sparse_ops.apply(halo, xg[halo_map], executor=executor)

        return MatrixFreeOp(matvec, shape=(Lmax, Lmax), dtype=self.dtype)

    # -- the global apply (replicated global vector in / out) ------------------
    def _apply(self, x, executor):
        from repro.launch.mesh import make_shard_mesh, shard_map
        from jax.sharding import PartitionSpec as P

        part = self.partition
        mesh = make_shard_mesh(part.num_parts, self.axis_name)
        leaves, treedef = jax.tree_util.tree_flatten(self)
        xp = part.pad(x)

        def body(shard_leaves, x_l):
            shard = jax.tree_util.tree_unflatten(treedef, shard_leaves)
            op = shard.local_operator(executor=executor)
            return op.apply(x_l[0])[None]

        vec_spec = P(self.axis_name, *([None] * (xp.ndim - 1)))
        yp = shard_map(
            body,
            mesh=mesh,
            in_specs=(shard_specs(leaves), vec_spec),
            out_specs=vec_spec,
        )(leaves, xp)
        return part.unpad(yp)

    # -- common reporting ------------------------------------------------------
    @property
    def dtype(self):
        return getattr(self, self._value_fields[0]).dtype

    @property
    def memory_bytes(self) -> int:
        return sum(
            int(l.size) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(self)
        )

    @property
    def num_halo_cols(self) -> Tuple[int, ...]:
        """Per-part halo-column-set sizes (communication volume metric)."""
        return self._halo_counts

    def astype(self, dtype) -> "DistLinOp":
        return dataclasses.replace(
            self,
            **{
                f: getattr(self, f).astype(dtype)
                for f in self._value_fields
            },
        )


def _halo_map_padded(parts, partition: Partition) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Stack per-part halo column sets as padded-global gather indices."""
    counts = tuple(len(p["halo_cols"]) for p in parts)
    h_max = max(counts) if counts else 0
    halo_map = np.zeros((partition.num_parts, h_max), np.int32)
    for p, info in enumerate(parts):
        cols = info["halo_cols"]
        # padded-global coordinates: what an all_gather of padded x shards
        # yields; padding entries point at slot 0 and pair with zero values
        halo_map[p, : len(cols)] = partition.padded_index(cols)
    return halo_map, counts


# =============================================================================
# DistCsr
# =============================================================================


@dataclasses.dataclass(frozen=True)
class DistCsr(DistLinOp):
    """Row-partitioned CSR: per-shard interior + boundary + halo CSR blocks.

    The diagonal (local) block is stored split by row class — ``int_*`` for
    rows touching no halo column, ``bnd_*`` for rows that do — so the apply
    can run the interior SpMV while the halo ``all_gather`` is in flight.
    """

    int_indptr: jax.Array  # (P, Lmax+1) i32
    int_indices: jax.Array  # (P, K_int) i32, shard-local columns
    int_values: jax.Array  # (P, K_int)
    bnd_indptr: jax.Array  # (P, Lmax+1) i32
    bnd_indices: jax.Array  # (P, K_bnd) i32, shard-local columns
    bnd_values: jax.Array  # (P, K_bnd)
    halo_indptr: jax.Array  # (P, Lmax+1) i32
    halo_indices: jax.Array  # (P, K_halo) i32, into the halo column set
    halo_values: jax.Array  # (P, K_halo)
    halo_map: jax.Array  # (P, H_max) i32, padded-global gather indices
    shape: Tuple[int, int]  # static (n, n)
    nnz: int  # static — true nonzeros (flops metric)
    partition: Partition  # static
    _halo_counts: Tuple[int, ...]  # static — true halo sizes per part

    _value_fields = ("int_values", "bnd_values", "halo_values")

    @classmethod
    def from_matrix(cls, A, partition: Partition) -> "DistCsr":
        indptr, indices, values, n = _square_host_csr(A, partition)
        parts = split_by_rows(indptr, indices, values, partition)
        Lmax = partition.max_part_size
        k_int = max(1, max(len(p["interior"][2]) for p in parts))
        k_bnd = max(1, max(len(p["boundary"][2]) for p in parts))
        k_halo = max(1, max(len(p["halo"][2]) for p in parts))
        ii, ij, iv = _stack_csr([p["interior"] for p in parts], Lmax, k_int)
        bi, bj, bv = _stack_csr([p["boundary"] for p in parts], Lmax, k_bnd)
        hi_, hj, hv = _stack_csr([p["halo"] for p in parts], Lmax, k_halo)
        halo_map, counts = _halo_map_padded(parts, partition)
        return cls(
            int_indptr=jnp.asarray(ii),
            int_indices=jnp.asarray(ij),
            int_values=jnp.asarray(iv),
            bnd_indptr=jnp.asarray(bi),
            bnd_indices=jnp.asarray(bj),
            bnd_values=jnp.asarray(bv),
            halo_indptr=jnp.asarray(hi_),
            halo_indices=jnp.asarray(hj),
            halo_values=jnp.asarray(hv),
            halo_map=jnp.asarray(halo_map),
            shape=(n, n),
            nnz=int(len(values)),
            partition=partition,
            _halo_counts=counts,
        )

    def local_block(self, p: int) -> Csr:
        """Part ``p``'s padded square diagonal block as a plain Csr.

        Re-merges the interior/boundary row classes (row-disjoint by
        construction) into one CSR on the host — the shape the per-shard
        preconditioner generators expect.
        """
        L = self.partition.max_part_size
        iip = np.asarray(self.int_indptr[p], np.int64)
        bip = np.asarray(self.bnd_indptr[p], np.int64)
        ij = np.asarray(self.int_indices[p])[: iip[-1]]
        iv = np.asarray(self.int_values[p])[: iip[-1]]
        bj = np.asarray(self.bnd_indices[p])[: bip[-1]]
        bv = np.asarray(self.bnd_values[p])[: bip[-1]]
        rows = np.concatenate(
            [
                np.repeat(np.arange(L, dtype=np.int64), np.diff(iip)),
                np.repeat(np.arange(L, dtype=np.int64), np.diff(bip)),
            ]
        )
        order = np.argsort(rows, kind="stable")
        indptr = np.cumsum(
            np.concatenate([[0], np.diff(iip) + np.diff(bip)])
        ).astype(np.int32)
        return Csr(
            jnp.asarray(indptr),
            jnp.asarray(np.concatenate([ij, bj])[order]),
            jnp.asarray(np.concatenate([iv, bv])[order]),
            shape=(L, L),
        )

    def _local_blocks(self, executor):
        L = self.partition.max_part_size
        h_max = self.halo_map.shape[-1]
        interior = Csr(
            self.int_indptr[0], self.int_indices[0], self.int_values[0],
            shape=(L, L),
        )
        if h_max == 0:
            return interior, None, None, None
        boundary = Csr(
            self.bnd_indptr[0], self.bnd_indices[0], self.bnd_values[0],
            shape=(L, L),
        )
        halo = Csr(
            self.halo_indptr[0], self.halo_indices[0], self.halo_values[0],
            shape=(L, h_max),
        )
        return interior, boundary, halo, self.halo_map[0]


_register(
    DistCsr,
    [
        "int_indptr", "int_indices", "int_values",
        "bnd_indptr", "bnd_indices", "bnd_values",
        "halo_indptr", "halo_indices", "halo_values", "halo_map",
    ],
    ["shape", "nnz", "partition", "_halo_counts"],
)


# =============================================================================
# DistEll
# =============================================================================


@dataclasses.dataclass(frozen=True)
class DistEll(DistLinOp):
    """Row-partitioned ELL: per-shard interior + boundary + halo ELL blocks.

    Padding entries use the format's own (col 0, value 0) convention in both
    the shard-local and halo-column index spaces.  As in :class:`DistCsr`,
    the diagonal block is split row-wise into interior (no halo columns in
    the row) and boundary classes so the interior SpMV overlaps the halo
    ``all_gather``; each class carries its own ELL width (``k_int`` /
    ``k_bnd``), so the split often *shrinks* stored bytes when boundary rows
    are the long ones.
    """

    int_col_idx: jax.Array  # (P, Lmax, k_int) i32
    int_values: jax.Array  # (P, Lmax, k_int)
    bnd_col_idx: jax.Array  # (P, Lmax, k_bnd) i32
    bnd_values: jax.Array  # (P, Lmax, k_bnd)
    halo_col_idx: jax.Array  # (P, Lmax, k_halo) i32, into the halo column set
    halo_values: jax.Array  # (P, Lmax, k_halo)
    halo_map: jax.Array  # (P, H_max) i32
    shape: Tuple[int, int]
    nnz: int
    partition: Partition
    _halo_counts: Tuple[int, ...]

    _value_fields = ("int_values", "bnd_values", "halo_values")

    @classmethod
    def from_matrix(cls, A, partition: Partition) -> "DistEll":
        indptr, indices, values, n = _square_host_csr(A, partition)
        parts = split_by_rows(indptr, indices, values, partition)
        Lmax = partition.max_part_size

        def max_row_nnz(key):
            return max(
                1,
                max(
                    (int(np.diff(p[key][0]).max()) if len(p[key][0]) > 1 else 0)
                    for p in parts
                ),
            )

        k_int, k_bnd = max_row_nnz("interior"), max_row_nnz("boundary")
        k_halo = max_row_nnz("halo")
        ic = np.zeros((partition.num_parts, Lmax, k_int), np.int32)
        iv = np.zeros((partition.num_parts, Lmax, k_int), values.dtype)
        bc = np.zeros((partition.num_parts, Lmax, k_bnd), np.int32)
        bv = np.zeros((partition.num_parts, Lmax, k_bnd), values.dtype)
        hc = np.zeros((partition.num_parts, Lmax, k_halo), np.int32)
        hv = np.zeros((partition.num_parts, Lmax, k_halo), values.dtype)
        for p, info in enumerate(parts):
            ic[p], iv[p] = _ell_arrays(*info["interior"], Lmax, k_int)
            bc[p], bv[p] = _ell_arrays(*info["boundary"], Lmax, k_bnd)
            hc[p], hv[p] = _ell_arrays(*info["halo"], Lmax, k_halo)
        halo_map, counts = _halo_map_padded(parts, partition)
        return cls(
            int_col_idx=jnp.asarray(ic),
            int_values=jnp.asarray(iv),
            bnd_col_idx=jnp.asarray(bc),
            bnd_values=jnp.asarray(bv),
            halo_col_idx=jnp.asarray(hc),
            halo_values=jnp.asarray(hv),
            halo_map=jnp.asarray(halo_map),
            shape=(n, n),
            nnz=int(len(values)),
            partition=partition,
            _halo_counts=counts,
        )

    def local_block(self, p: int) -> Ell:
        # interior and boundary are row-disjoint; concatenating along the
        # width axis re-merges them (the inactive class contributes only
        # (col 0, value 0) padding slots — zero by the ELL convention)
        L = self.partition.max_part_size
        return Ell(
            jnp.concatenate([self.int_col_idx[p], self.bnd_col_idx[p]], axis=1),
            jnp.concatenate([self.int_values[p], self.bnd_values[p]], axis=1),
            shape=(L, L),
        )

    def _local_blocks(self, executor):
        L = self.partition.max_part_size
        h_max = self.halo_map.shape[-1]
        interior = Ell(self.int_col_idx[0], self.int_values[0], shape=(L, L))
        if h_max == 0:
            return interior, None, None, None
        boundary = Ell(self.bnd_col_idx[0], self.bnd_values[0], shape=(L, L))
        halo = Ell(self.halo_col_idx[0], self.halo_values[0], shape=(L, h_max))
        return interior, boundary, halo, self.halo_map[0]


_register(
    DistEll,
    [
        "int_col_idx", "int_values", "bnd_col_idx", "bnd_values",
        "halo_col_idx", "halo_values", "halo_map",
    ],
    ["shape", "nnz", "partition", "_halo_counts"],
)


def _square_host_csr(A, partition: Partition):
    """Validate + extract the host CSR triplet of a square operand."""
    m, n = A.shape
    if m != n:
        raise ValueError(
            f"distributed formats row-partition SQUARE operators, got {A.shape}"
        )
    if partition.global_size != n:
        raise ValueError(
            f"partition covers {partition.global_size} rows but A has {n}"
        )
    indptr, indices, values = csr_host_arrays(A)
    return indptr, indices, values, n
