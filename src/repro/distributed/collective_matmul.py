"""Ring collective matmul — explicit compute/communication overlap for TP.

The TP MLP's second matmul produces partial sums that must be reduced across
the "model" axis.  A monolithic ``psum`` serializes compute then communication;
the ring formulation (Wang et al., "Overlap communication with dependent
computation via decomposition") splits the reduction into ``axis_size`` chunked
steps where each step's ``ppermute`` overlaps the next step's partial matmul —
XLA's async collective-permute machinery schedules them concurrently.

``ring_reduce_scatter_matmul``: computes ``y = sum_r x_r @ w_r`` reduce-
scattered over the axis (each shard ends with its output-row chunk), one
matmul + one ppermute per step.

``ring_all_gather_matmul``: computes ``y_local = x_full @ w_local`` where x is
row-sharded, gathering x chunks around the ring while accumulating partial
products — the all-gather never materializes the full x.

Both are shard_map bodies: use under ``jax.shard_map`` with the "model" axis
manual.  Correctness is asserted against the dense equivalent in
tests/distributed (8-device subprocess).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import axis_size


def ring_reduce_scatter_matmul(
    x: jax.Array,  # (m, k_local) — this shard's contraction slice
    w: jax.Array,  # (k_local, n) — this shard's weight slice
    axis_name: str,
) -> jax.Array:
    """Returns (m, n / axis_size): the reduce-scattered product chunk.

    Equivalent to ``psum(x @ w)[:, rank*chunk:(rank+1)*chunk]`` with the
    reduction decomposed into a ring so each ppermute overlaps the next
    partial matmul.
    """
    size = axis_size(axis_name)
    rank = jax.lax.axis_index(axis_name)
    n = w.shape[1]
    if n % size:
        raise ValueError(f"output dim {n} not divisible by axis size {size}")
    chunk = n // size
    perm = [(i, (i + 1) % size) for i in range(size)]

    def chunk_of(i):
        # the accumulator destined for shard r sits at shard q = r + 1 + i
        # (mod size) at step i, so shard q contributes chunk r = q - 1 - i;
        # it arrives at its owner exactly on the last step
        idx = (rank - 1 - i) % size
        return jax.lax.dynamic_slice_in_dim(w, idx * chunk, chunk, axis=1)

    acc = x @ chunk_of(0)  # partial product for neighbour's chunk
    for i in range(1, size):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + x @ chunk_of(i)
    return acc  # (m, chunk) — this shard's rows of the reduced product


def ring_all_gather_matmul(
    x: jax.Array,  # (m_local, k) — row shard of x
    w: jax.Array,  # (k, n_local) — column shard of w
    axis_name: str,
) -> jax.Array:
    """Returns (m_local * size, n_local) = all_gather(x) @ w, gathered via ring.

    Each step matmuls the chunk currently held and forwards it — the full x is
    never resident; communication hides behind the running matmul.
    """
    size = axis_size(axis_name)
    rank = jax.lax.axis_index(axis_name)
    m_local = x.shape[0]
    out = jnp.zeros((m_local * size, w.shape[1]), x.dtype)
    perm = [(i, (i + 1) % size) for i in range(size)]

    chunk_x = x
    for i in range(size):
        src = (rank - i) % size  # whose rows we currently hold
        out = jax.lax.dynamic_update_slice_in_dim(
            out, (chunk_x @ w).astype(out.dtype), src * m_local, axis=0
        )
        if i + 1 < size:
            chunk_x = jax.lax.ppermute(chunk_x, axis_name, perm)
    return out
