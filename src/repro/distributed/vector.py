"""Mesh-sharded vectors — gko::experimental::distributed::Vector.

A :class:`DistVector` is the padded shard stack of a global vector: shape
``(P, Lmax)`` with one row per part of the partition and padding slots zeroed
(see :class:`~repro.distributed.partition.Partition`).  BLAS-1 runs under
``shard_map`` over the data axis: ``axpy``/``scal`` are purely shard-local,
``dot``/``norm2`` reduce locally through the executor-dispatched kernels and
then ``psum`` — with padding masked via
:func:`repro.distributed.sharding.zero_shard_padding`, so a ragged partition
never double-counts (the padded-shard bug this module's tests pin).

These are the same reduction semantics the distributed solvers get from
``repro.sparse.ops.distributed_blas``; the module-level functions here are
the standalone surface (parity tests, drivers, benchmarks).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partition import Partition

__all__ = [
    "DistVector",
    "dist_dot",
    "dist_norm2",
    "dist_axpy",
    "dist_scal",
]


@dataclasses.dataclass(frozen=True)
class DistVector:
    """Padded shard stack of a global vector (+ its partition)."""

    local: jax.Array  # (P, Lmax); padding slots zero by construction
    partition: Partition  # static

    @classmethod
    def from_global(cls, x, partition: Partition) -> "DistVector":
        return cls(local=partition.pad(x), partition=partition)

    def to_global(self) -> jax.Array:
        return self.partition.unpad(self.local)

    @property
    def shape(self) -> Tuple[int]:
        return (self.partition.global_size,)

    @property
    def dtype(self):
        return self.local.dtype


jax.tree_util.register_dataclass(
    DistVector, data_fields=["local"], meta_fields=["partition"]
)


def _check_same_partition(x: DistVector, y: DistVector):
    if x.partition != y.partition:
        # two stacks can agree in shape while laying out different global
        # rows per slot — pairing them would be silently wrong, not an error
        raise ValueError(
            f"DistVector partitions differ ({x.partition.offsets} vs "
            f"{y.partition.offsets}); repartition one operand first"
        )


def _shard_map_blas(partition: Partition, body, *operands):
    """Run a per-shard BLAS body over the partition's mesh.

    ``body(mask_l, *shard_operands)`` receives this shard's pad mask plus the
    operands sliced to leading part-axis size 1; scalar results come back
    stacked ``(P,)`` (identical across shards after the psum).
    """
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_shard_mesh, shard_map
    from repro.distributed.matrix import DATA_AXIS

    mesh = make_shard_mesh(partition.num_parts, DATA_AXIS)
    mask = jnp.asarray(partition.pad_mask)
    args = (mask,) + operands
    specs = tuple(P(DATA_AXIS, *([None] * (a.ndim - 1))) for a in args)
    return shard_map(body, mesh=mesh, in_specs=specs, out_specs=P(DATA_AXIS))(
        *args
    )


def dist_dot(x: DistVector, y: DistVector, *, executor=None) -> jax.Array:
    """Global ``<x, y>`` via per-shard dispatched dot + ``psum``."""
    from repro.sparse import ops as sparse_ops
    from repro.distributed.matrix import DATA_AXIS

    _check_same_partition(x, y)

    def body(m_l, x_l, y_l):
        with sparse_ops.distributed_blas(DATA_AXIS, m_l[0]):
            return sparse_ops.dot(x_l[0], y_l[0], executor=executor)[None]

    return _shard_map_blas(x.partition, body, x.local, y.local)[0]


def dist_norm2(x: DistVector, *, executor=None) -> jax.Array:
    """Global ``||x||_2`` via per-shard masked sum of squares + ``psum``."""
    from repro.sparse import ops as sparse_ops
    from repro.distributed.matrix import DATA_AXIS

    def body(m_l, x_l):
        with sparse_ops.distributed_blas(DATA_AXIS, m_l[0]):
            return sparse_ops.norm2(x_l[0], executor=executor)[None]

    return _shard_map_blas(x.partition, body, x.local)[0]


def dist_axpy(alpha, x: DistVector, y: DistVector, *, executor=None) -> DistVector:
    """``alpha * x + y`` — shard-local, no communication."""
    from repro.sparse import ops as sparse_ops

    _check_same_partition(x, y)
    return dataclasses.replace(
        y, local=sparse_ops.axpy(alpha, x.local, y.local, executor=executor)
    )


def dist_scal(alpha, x: DistVector, *, executor=None) -> DistVector:
    """``alpha * x`` — shard-local, no communication."""
    from repro.sparse import ops as sparse_ops

    return dataclasses.replace(
        x, local=sparse_ops.scal(alpha, x.local, executor=executor)
    )
