"""repro.distributed — mesh-sharded operators, vectors and solves.

The gko::experimental::distributed analogue (arXiv:2006.16852): a
:class:`Partition` of the row space, row-partitioned matrix formats
(:class:`DistCsr` / :class:`DistEll`) whose SpMV is local-block SpMV plus a
halo exchange under ``shard_map``, padded sharded vectors
(:class:`DistVector`) with ``psum`` reductions, shard-local preconditioners,
and :func:`dist_solve` — which runs the UNCHANGED Krylov solver source per
shard.  Plus the older layers: logical-axis sharding rules
(:mod:`~repro.distributed.sharding`) and explicit ring collectives
(:mod:`~repro.distributed.collective_matmul`).
"""

from repro.distributed import collective_matmul, sharding
from repro.distributed.matrix import DistCsr, DistEll, DistLinOp, split_by_rows
from repro.distributed.partition import Partition
from repro.distributed.precond import (
    DistBlockJacobi,
    DistScalarJacobi,
    dist_block_jacobi,
    dist_preconditioner,
    dist_scalar_jacobi,
)
from repro.distributed.solvers import dist_solve
from repro.distributed.vector import (
    DistVector,
    dist_axpy,
    dist_dot,
    dist_norm2,
    dist_scal,
)

__all__ = [
    "sharding",
    "collective_matmul",
    "Partition",
    "DistLinOp",
    "DistCsr",
    "DistEll",
    "DistVector",
    "DistScalarJacobi",
    "DistBlockJacobi",
    "split_by_rows",
    "dist_preconditioner",
    "dist_scalar_jacobi",
    "dist_block_jacobi",
    "dist_solve",
    "dist_dot",
    "dist_norm2",
    "dist_axpy",
    "dist_scal",
]
