"""repro.distributed — sharding rules + explicit collective algorithms."""

from repro.distributed import collective_matmul, sharding

__all__ = ["sharding", "collective_matmul"]
