"""Shard-local preconditioners for distributed solves.

Distributed block-Jacobi is block-local BY CONSTRUCTION: blocks never
straddle a shard boundary, so each shard generates its preconditioner from
its own padded diagonal block and applies it with zero communication — the
standard distributed Jacobi semantics (and exactly how Ginkgo applies
``preconditioner::Jacobi`` to a ``distributed::Matrix``: on the local block).

Generation is host-side per part, reusing the single-device generators
(:func:`repro.solvers.common.jacobi_preconditioner`,
:func:`repro.precond.block_jacobi`) on each shard's padded local block;
padding rows carry a zero diagonal, which both generators regularize to an
identity action — harmless, since padded residual slots are zero and every
cross-shard reduction is masked anyway.

``adaptive`` storage: an explicit storage dtype is supported (uniform across
shards, so the stacked pytree stays rectangular); the per-block
condition-rule ``adaptive=True`` is rejected — it would pick different
precision-class splits per shard and the stack would go ragged.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.linop import LinOp
from repro.distributed.partition import Partition

__all__ = ["DistScalarJacobi", "DistBlockJacobi", "dist_preconditioner"]


@dataclasses.dataclass(frozen=True)
class DistScalarJacobi(LinOp):
    """Stacked per-shard scalar Jacobi: ``M^-1 v = inv_diag * v`` per shard."""

    inv_diag: jax.Array  # (P, Lmax)
    partition: Partition  # static

    is_distributed = True

    @property
    def shape(self):
        n = self.partition.global_size
        return (n, n)

    @property
    def dtype(self):
        return self.inv_diag.dtype

    @property
    def storage_bytes(self) -> int:
        return int(self.inv_diag.size) * self.inv_diag.dtype.itemsize

    def local_operator(self, executor=None) -> LinOp:
        from repro.solvers.common import ScalarJacobi

        return ScalarJacobi(self.inv_diag[0])

    def _apply(self, v, executor):
        # global-vector apply (outside shard_map): purely diagonal, so pad /
        # multiply / unpad needs no collective at all
        part = self.partition
        return part.unpad(self.inv_diag.astype(v.dtype) * part.pad(v))


jax.tree_util.register_dataclass(
    DistScalarJacobi, data_fields=["inv_diag"], meta_fields=["partition"]
)


@dataclasses.dataclass(frozen=True)
class DistBlockJacobi(LinOp):
    """Stacked per-shard block-Jacobi (uniform storage precision).

    Each shard applies a plain :class:`repro.precond.BlockJacobi` built from
    its slice of the stacked inverted blocks — the apply dispatches through
    the ``block_jacobi_apply`` kernel family like the single-device path.
    """

    inv_blocks: jax.Array  # (P, nb, bs, bs) in the storage dtype
    gather_idx: jax.Array  # (P, nb, bs) i32
    scatter_idx: jax.Array  # (P, Lmax) i32
    partition: Partition  # static
    block_size: int  # static

    is_distributed = True

    @property
    def shape(self):
        n = self.partition.global_size
        return (n, n)

    @property
    def dtype(self):
        return self.inv_blocks.dtype

    @property
    def storage_bytes(self) -> int:
        return int(self.inv_blocks.size) * self.inv_blocks.dtype.itemsize

    def local_operator(self, executor=None) -> LinOp:
        from repro.precond import BlockJacobi

        return BlockJacobi(
            inv_blocks=(self.inv_blocks[0],),
            gather_idx=self.gather_idx[0],
            scatter_idx=self.scatter_idx[0],
            n=self.partition.max_part_size,
            block_size=self.block_size,
            num_blocks=self.inv_blocks.shape[1],
            executor=executor,
        )

    def _apply(self, v, executor):
        # global-vector apply (outside shard_map): block-diagonal, so every
        # shard's apply is independent — pad, batched small matvec over the
        # stacked inverted blocks, unpad.  (The sharded solver path instead
        # applies per shard through the block_jacobi_apply kernel family.)
        part = self.partition
        vp = part.pad(v)  # (P, Lmax)
        nparts, nb, bs = self.gather_idx.shape
        vpad = jnp.concatenate(
            [vp, jnp.zeros((nparts, 1), vp.dtype)], axis=1
        )  # slot Lmax = the zero-pad slot gather_idx points at
        g = jnp.take_along_axis(
            vpad, self.gather_idx.reshape(nparts, nb * bs), axis=1
        ).reshape(nparts, nb, bs)
        y = jnp.einsum(
            "pnij,pnj->pni", self.inv_blocks.astype(vp.dtype), g
        ).reshape(nparts, nb * bs)
        return part.unpad(jnp.take_along_axis(y, self.scatter_idx, axis=1))


jax.tree_util.register_dataclass(
    DistBlockJacobi,
    data_fields=["inv_blocks", "gather_idx", "scatter_idx"],
    meta_fields=["partition", "block_size"],
)


def dist_scalar_jacobi(A, *, adaptive: Union[bool, str] = False, executor=None):
    """Per-shard scalar Jacobi from a distributed matrix's local blocks."""
    from repro.solvers.common import jacobi_preconditioner

    if adaptive is True:
        # per-shard range checks could pick fp16 on one shard and bf16 on
        # another; jnp.stack would then silently promote to f32, defeating
        # the storage reduction — demand an explicit uniform dtype instead
        raise ValueError(
            "distributed scalar Jacobi needs a uniform storage precision "
            "across shards: pass an explicit dtype (adaptive='float16') "
            "instead of adaptive=True"
        )
    inv = jnp.stack(
        [
            jacobi_preconditioner(
                A.local_block(p), executor=executor, adaptive=adaptive
            ).inv_diag
            for p in range(A.partition.num_parts)
        ]
    )
    return DistScalarJacobi(inv_diag=inv, partition=A.partition)


def dist_block_jacobi(
    A,
    block_size: int = None,
    *,
    adaptive: Union[bool, str] = False,
    executor=None,
):
    """Per-shard block-Jacobi from a distributed matrix's local blocks."""
    from repro.precond import block_jacobi

    if adaptive is True:
        raise ValueError(
            "distributed block-Jacobi needs a uniform storage precision "
            "across shards: pass an explicit dtype (adaptive='float16') "
            "instead of adaptive=True"
        )
    per_part = [
        block_jacobi(
            A.local_block(p),
            block_size=block_size,
            adaptive=adaptive,
            executor=executor,
        )
        for p in range(A.partition.num_parts)
    ]
    # uniform blocks + uniform (or no) adaptive class => exactly one stacked
    # precision tensor per part, all the same shape
    assert all(len(bj.inv_blocks) == 1 for bj in per_part)
    return DistBlockJacobi(
        inv_blocks=jnp.stack([bj.inv_blocks[0] for bj in per_part]),
        gather_idx=jnp.stack([bj.gather_idx for bj in per_part]),
        scatter_idx=jnp.stack([bj.scatter_idx for bj in per_part]),
        partition=A.partition,
        block_size=per_part[0].block_size,
    )


def dist_preconditioner(A, kind, *, executor=None, **opts):
    """Resolve a distributed solve's ``M=`` argument.

    ``None`` / ``"identity"`` -> no preconditioner; ``"jacobi"`` /
    ``"block_jacobi"`` generate shard-locally from ``A``'s local blocks;
    an already-distributed LinOp passes through.  A non-distributed LinOp or
    bare callable is rejected — it could not apply shard-locally.
    """
    from repro.core.linop import Identity

    if kind is None or isinstance(kind, Identity):
        if opts:
            raise ValueError(
                f"identity preconditioner takes no options, got {sorted(opts)}"
            )
        return None
    if isinstance(kind, str):
        if kind == "identity":
            return dist_preconditioner(A, None, executor=executor, **opts)
        if kind == "jacobi":
            return dist_scalar_jacobi(A, executor=executor, **opts)
        if kind == "block_jacobi":
            return dist_block_jacobi(A, executor=executor, **opts)
        raise ValueError(
            f"unknown distributed preconditioner kind {kind!r} "
            "(identity | jacobi | block_jacobi)"
        )
    if getattr(kind, "is_distributed", False):
        if opts:
            raise ValueError(
                "precond_opts is only meaningful when M is a kind name"
            )
        m_part = getattr(kind, "partition", None)
        if m_part is not None and m_part != A.partition:
            # a partition mismatch would either crash with an opaque shape
            # error inside the shard_map body or — with equal part counts but
            # different offsets — silently apply shard inverses to the wrong
            # rows; refuse loudly instead
            raise ValueError(
                f"preconditioner partition {m_part.offsets} does not match "
                f"the matrix partition {A.partition.offsets}; regenerate the "
                "preconditioner against this matrix"
            )
        return kind
    raise TypeError(
        f"{type(kind).__name__} cannot precondition a distributed solve: "
        "pass a kind name ('jacobi' / 'block_jacobi') or a distributed "
        "preconditioner built against the matrix's partition"
    )
