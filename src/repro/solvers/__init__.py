"""repro.solvers — Krylov subspace solvers (Ginkgo's solver set), executor-agnostic."""

from repro.solvers.common import (
    LinearOperator,
    ScalarJacobi,
    block_jacobi_preconditioner,
    SolveResult,
    Stop,
    identity_preconditioner,
    jacobi_preconditioner,
)
from repro.solvers.krylov import bicgstab, cg, cgs, fcg, gmres
from repro.solvers.parilu import parilu_factorize, parilu_preconditioner, parilu_setup

__all__ = [
    "LinearOperator",
    "ScalarJacobi",
    "SolveResult",
    "Stop",
    "jacobi_preconditioner",
    "block_jacobi_preconditioner",
    "identity_preconditioner",
    "cg",
    "fcg",
    "bicgstab",
    "cgs",
    "gmres",
    "parilu_factorize",
    "parilu_preconditioner",
    "parilu_setup",
]
