"""repro.solvers — Krylov subspace solvers (Ginkgo's solver set), executor-agnostic.

Every solver function has a factory-style LinOp twin (``CgSolver`` etc.) so a
generated solver composes as an operator — the Ginkgo solver-as-preconditioner
pattern — and :mod:`repro.solvers.ir` builds mixed-precision iterative
refinement on top of that interface.
"""

from repro.solvers.common import (
    LinearOperator,
    ScalarJacobi,
    block_jacobi_preconditioner,
    SolveResult,
    Stop,
    identity_preconditioner,
    jacobi_preconditioner,
)
from repro.solvers.krylov import (
    BicgstabSolver,
    CgSolver,
    CgsSolver,
    FcgSolver,
    GmresSolver,
    KrylovSolver,
    PipelinedCgSolver,
    bicgstab,
    cg,
    cgs,
    fcg,
    gmres,
)
from repro.solvers.ir import IrSolver, ir, mixed_precision_ir
from repro.solvers.parilu import (
    ParILU,
    parilu_factorize,
    parilu_preconditioner,
    parilu_setup,
)

__all__ = [
    "LinearOperator",
    "ScalarJacobi",
    "SolveResult",
    "Stop",
    "jacobi_preconditioner",
    "block_jacobi_preconditioner",
    "identity_preconditioner",
    "cg",
    "fcg",
    "bicgstab",
    "cgs",
    "gmres",
    "ir",
    "mixed_precision_ir",
    "KrylovSolver",
    "CgSolver",
    "FcgSolver",
    "BicgstabSolver",
    "CgsSolver",
    "GmresSolver",
    "PipelinedCgSolver",
    "IrSolver",
    "ParILU",
    "parilu_factorize",
    "parilu_preconditioner",
    "parilu_setup",
]
