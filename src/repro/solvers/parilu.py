"""ParILU (Chow–Patel) incomplete factorization + iterative triangular solves.

Ginkgo's preconditioner stack beyond (block-)Jacobi is built on the *parallel*
ILU family: instead of the inherently sequential IKJ factorization, ParILU
iterates fixed-point sweeps over the nonzeros

    l_ij = (a_ij - sum_{k<j} l_ik u_kj) / u_jj     (i > j)
    u_ij =  a_ij - sum_{k<i} l_ik u_kj             (i <= j)

where every sweep updates all entries in parallel — a perfect fit for a
vector machine.  The triangular solves applying M^-1 = (LU)^-1 are likewise
replaced by fixed-sweep Jacobi iterations (Ginkgo does the same on GPUs:
exact triangular solves serialize; a handful of sweeps preconditions just as
well).  TPU adaptation (DESIGN.md): the per-nonzero dependency lists are
precomputed host-side into fixed-width padded index tables so each sweep is
two gathers + a segment contraction — no atomics, no sequential loops.

setup (host, numpy): sparsity analysis of S(L), S(U), intersection tables
sweeps (device, jnp): vectorized fixed-point updates
apply (device, jnp): Jacobi triangular sweeps
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linop import LinOp
from repro.sparse.formats import Csr

__all__ = [
    "ParILU",
    "batch_parilu_apply",
    "parilu_setup",
    "parilu_factorize",
    "parilu_preconditioner",
]


@dataclasses.dataclass(frozen=True)
class ParILUStructure:
    """Host-precomputed sparsity structure (static shapes for the sweeps)."""

    # L strict-lower entries (unit diagonal implied)
    l_rows: np.ndarray
    l_cols: np.ndarray
    # U upper (incl. diagonal) entries
    u_rows: np.ndarray
    u_cols: np.ndarray
    # per-A-nonzero metadata
    a_rows: np.ndarray
    a_cols: np.ndarray
    is_lower: np.ndarray  # (nnz,) bool: strictly lower -> L slot else U slot
    slot: np.ndarray  # (nnz,) index into l_vals or u_vals
    # fixed-width dependency tables: for A-nonzero t, the k-intersection
    # contributions l_ik * u_kj; width-padded with sentinel 0-entries
    dep_l: np.ndarray  # (nnz, K) indices into l_vals (+1 shifted; 0 = zero pad)
    dep_u: np.ndarray  # (nnz, K) indices into u_vals (+1 shifted; 0 = zero pad)
    u_diag_slot: np.ndarray  # (n,) slot of u_jj in u_vals
    n: int


def parilu_setup(A: Csr) -> ParILUStructure:
    indptr = np.asarray(A.indptr)
    indices = np.asarray(A.indices)
    n = A.shape[0]

    # per-row column sets of the L / U patterns (= A's pattern split)
    rows_of = [indices[indptr[i]: indptr[i + 1]] for i in range(n)]
    l_pat = {}  # (i, k) -> L slot
    u_pat = {}  # (k, j) -> U slot
    l_rows, l_cols, u_rows, u_cols = [], [], [], []
    for i in range(n):
        for j in rows_of[i]:
            if i > j:
                l_pat[(i, j)] = len(l_rows)
                l_rows.append(i)
                l_cols.append(j)
            else:
                u_pat[(i, j)] = len(u_rows)
                u_rows.append(i)
                u_cols.append(j)
    u_diag_slot = np.array([u_pat[(j, j)] for j in range(n)], np.int32)

    a_rows, a_cols, is_lower, slot = [], [], [], []
    deps = []
    for i in range(n):
        for j in rows_of[i]:
            a_rows.append(i)
            a_cols.append(j)
            lower = i > j
            is_lower.append(lower)
            slot.append(l_pat[(i, j)] if lower else u_pat[(i, j)])
            kmax = min(i, j)  # k < min(i, j) for lower; k < i <= j for upper
            dep = [
                (l_pat[(i, k)], u_pat[(k, j)])
                for k in rows_of[i]
                if k < kmax and (i, k) in l_pat and (k, j) in u_pat
            ]
            deps.append(dep)

    K = max((len(d) for d in deps), default=0)
    K = max(K, 1)
    nnz = len(a_rows)
    dep_l = np.zeros((nnz, K), np.int32)  # 0 = padding (points at zero slot)
    dep_u = np.zeros((nnz, K), np.int32)
    for t, dep in enumerate(deps):
        for q, (ls, us) in enumerate(dep):
            dep_l[t, q] = ls + 1  # shift: 0 reserved for padding
            dep_u[t, q] = us + 1

    return ParILUStructure(
        l_rows=np.asarray(l_rows, np.int32),
        l_cols=np.asarray(l_cols, np.int32),
        u_rows=np.asarray(u_rows, np.int32),
        u_cols=np.asarray(u_cols, np.int32),
        a_rows=np.asarray(a_rows, np.int32),
        a_cols=np.asarray(a_cols, np.int32),
        is_lower=np.asarray(is_lower, bool),
        slot=np.asarray(slot, np.int32),
        dep_l=dep_l,
        dep_u=dep_u,
        u_diag_slot=u_diag_slot,
        n=n,
    )


def parilu_factorize(
    A: Csr, structure: ParILUStructure = None, sweeps: int = 5
) -> Tuple[jax.Array, jax.Array, ParILUStructure]:
    """Run the fixed-point sweeps; returns (l_vals, u_vals, structure)."""
    st = structure or parilu_setup(A)
    a_vals = A.values  # CSR order == (a_rows, a_cols) construction order
    dtype = a_vals.dtype

    is_lower = jnp.asarray(st.is_lower)
    slot = jnp.asarray(st.slot)
    dep_l = jnp.asarray(st.dep_l)
    dep_u = jnp.asarray(st.dep_u)
    u_diag_slot = jnp.asarray(st.u_diag_slot)
    a_cols = jnp.asarray(st.a_cols)

    nl, nu = len(st.l_rows), len(st.u_rows)

    # initial guess (Chow-Patel): L/U take A's values on their patterns.
    # Scatter guards: an entry belonging to the other factor writes past the
    # end (mode="drop") so the two value arrays never alias.
    l0 = jnp.zeros(nl, dtype).at[
        jnp.where(is_lower, slot, nl)
    ].set(jnp.where(is_lower, a_vals, 0), mode="drop")
    u0 = jnp.zeros(nu, dtype).at[
        jnp.where(is_lower, nu, slot)
    ].set(jnp.where(is_lower, 0, a_vals), mode="drop")

    def sweep(_, carry):
        l_vals, u_vals = carry
        l_pad = jnp.concatenate([jnp.zeros(1, dtype), l_vals])
        u_pad = jnp.concatenate([jnp.zeros(1, dtype), u_vals])
        corr = jnp.sum(l_pad[dep_l] * u_pad[dep_u], axis=1)  # (nnz,)
        s = a_vals - corr
        u_jj = u_vals[u_diag_slot[a_cols]]
        u_jj = jnp.where(jnp.abs(u_jj) > 0, u_jj, jnp.ones_like(u_jj))
        new_l = l_vals.at[jnp.where(is_lower, slot, nl)].set(
            jnp.where(is_lower, s / u_jj, 0.0), mode="drop"
        )
        new_u = u_vals.at[jnp.where(is_lower, nu, slot)].set(
            jnp.where(is_lower, 0.0, s), mode="drop"
        )
        return new_l, new_u

    l_vals, u_vals = jax.lax.fori_loop(0, sweeps, sweep, (l0, u0))
    return l_vals, u_vals, st


def _jacobi_lower_solve(st, l_vals, b, sweeps, dtype):
    """Solve (I + L) x = b approximately: x <- b - L x, fixed sweeps."""
    rows = jnp.asarray(st.l_rows)
    cols = jnp.asarray(st.l_cols)

    def body(_, x):
        lx = jnp.zeros_like(b).at[rows].add(l_vals * x[cols])
        return b - lx

    return jax.lax.fori_loop(0, sweeps, body, b)


def _jacobi_upper_solve(st, u_vals, b, sweeps, dtype):
    """Solve U x = b approximately: x <- D^-1 (b - (U - D) x)."""
    rows = jnp.asarray(st.u_rows)
    cols = jnp.asarray(st.u_cols)
    diag = u_vals[jnp.asarray(st.u_diag_slot)]
    safe = jnp.where(jnp.abs(diag) > 0, diag, jnp.ones_like(diag))
    off = jnp.where(jnp.asarray(st.u_rows == st.u_cols), 0.0, u_vals)

    def body(_, x):
        ux = jnp.zeros_like(b).at[rows].add(off * x[cols])
        return (b - ux) / safe

    return jax.lax.fori_loop(0, sweeps, body, b / safe)


def batch_parilu_apply(
    st: ParILUStructure,
    l_vals: jax.Array,
    u_vals: jax.Array,
    B: jax.Array,
    sweeps: int = 8,
) -> jax.Array:
    """Batched ``M⁻¹ B ≈ U⁻¹ (I + L)⁻¹ B`` over per-system factors.

    ``l_vals``/``u_vals`` are ``(nb, nl)`` / ``(nb, nu)`` stacks sharing one
    :class:`ParILUStructure`, ``B`` is ``(nb, n)``.  Each row runs the same
    Jacobi triangular sweeps as the solo :class:`ParILU` apply — every scatter
    and gather is row-independent, which is what lets the serve engine batch
    cached factors across solve slots.
    """
    l_rows = jnp.asarray(st.l_rows)
    l_cols = jnp.asarray(st.l_cols)
    u_rows = jnp.asarray(st.u_rows)
    u_cols = jnp.asarray(st.u_cols)
    diag = jnp.take_along_axis(
        u_vals, jnp.asarray(st.u_diag_slot)[None, :], axis=1
    )  # (nb, n)
    safe = jnp.where(jnp.abs(diag) > 0, diag, jnp.ones_like(diag))
    off = jnp.where(jnp.asarray(st.u_rows == st.u_cols)[None, :], 0.0, u_vals)

    def lower(_, x):
        lx = jnp.zeros_like(B).at[:, l_rows].add(l_vals * x[:, l_cols])
        return B - lx

    y = jax.lax.fori_loop(0, sweeps, lower, B)

    def upper(_, x):
        ux = jnp.zeros_like(y).at[:, u_rows].add(off * x[:, u_cols])
        return (y - ux) / safe

    return jax.lax.fori_loop(0, sweeps, upper, y / safe)


class ParILU(LinOp):
    """Generated ParILU preconditioner as a LinOp:
    ``M^-1 v ~= U^-1 (I + L)^-1 v`` via Jacobi triangular sweeps.

    ``storage_bytes`` reports the factor-value storage (L strict-lower +
    U upper entries) — the footprint the preconditioner owns beyond A.
    """

    def __init__(self, structure: ParILUStructure, l_vals, u_vals, solve_sweeps: int, dtype):
        self.structure = structure
        self.l_vals = l_vals
        self.u_vals = u_vals
        self.solve_sweeps = solve_sweeps
        self._dtype = dtype

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.structure.n, self.structure.n)

    @property
    def dtype(self):
        return self._dtype

    @property
    def storage_bytes(self) -> int:
        return sum(
            int(v.size) * v.dtype.itemsize for v in (self.l_vals, self.u_vals)
        )

    def _apply(self, v: jax.Array, executor) -> jax.Array:
        y = _jacobi_lower_solve(
            self.structure, self.l_vals, v, self.solve_sweeps, self._dtype
        )
        return _jacobi_upper_solve(
            self.structure, self.u_vals, y, self.solve_sweeps, self._dtype
        )


def parilu_preconditioner(
    A: Csr,
    *,
    factor_sweeps: int = 5,
    solve_sweeps: int = 8,
    structure: ParILUStructure = None,
) -> ParILU:
    """M^-1 v  ~=  U^-1 (I + L)^-1 v with iterative sweeps throughout."""
    l_vals, u_vals, st = parilu_factorize(A, structure, sweeps=factor_sweeps)
    return ParILU(st, l_vals, u_vals, solve_sweeps, A.values.dtype)
