"""Iterative refinement / Richardson iteration — gko::solver::Ir.

The outer loop is the textbook refinement

    r_k = b - A x_k          (outer precision — f64 under ``jax_enable_x64``)
    d_k = S(r_k)             (inner solver: any LinOp approximating A^{-1})
    x_{k+1} = x_k + d_k

with everything unified by the LinOp interface: the inner solver ``S`` can be
a relaxation scalar (plain Richardson via
:class:`~repro.core.linop.ScaledIdentity`), a preconditioner, or — the
Ginkgo pattern this module exists for — a *generated Krylov solver over a
reduced-precision copy of A*.  That is mixed-precision iterative refinement:
the inner CG streams f32 (or 16-bit) operator data, cutting memory traffic
roughly in half, while the outer residual is evaluated against the full-
precision operator, recovering the full-precision solution (the adaptive-
precision playbook of arXiv:2006.16852 applied to the solver itself).

The inner tolerance is budgeted from the storage dtype's unit roundoff
(:func:`repro.precond.unit_roundoff` — the same table the adaptive
block-Jacobi rule uses): solving the correction equation much below
``sqrt(u_inner)`` buys nothing because the inner operator itself is only
accurate to ``u_inner``.
"""

from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.linop import LinOp, ScaledIdentity, as_linop
from repro.observability import convergence
from repro.solvers.common import MatrixLike, SolveResult, Stop
from repro.solvers.krylov import CgSolver
from repro.sparse import ops as blas

__all__ = ["ir", "mixed_precision_ir", "IrSolver"]


def ir(
    A: MatrixLike,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    inner: Optional[Union[LinOp, Callable]] = None,
    inner_dtype=None,
    relaxation: float = 1.0,
    executor=None,
    history=None,
) -> SolveResult:
    """Iterative-refinement / Richardson outer loop.

    ``inner`` is any LinOp (or callable) approximating ``A^{-1}`` — a
    preconditioner, a generated solver (:class:`~repro.solvers.krylov.CgSolver`
    over a low-precision copy of A), anything.  ``inner=None`` degenerates to
    plain Richardson ``x += relaxation * r``.

    ``inner_dtype`` casts the residual down before the inner apply and the
    correction back up after it — the precision boundary of mixed-precision
    IR.  The outer residual, norms, and ``x`` stay in ``b``'s dtype
    throughout; ``iterations`` counts outer sweeps.
    """
    Aop = as_linop(A)
    x = jnp.zeros_like(b) if x0 is None else x0
    if inner is None:
        inner = ScaledIdentity(relaxation, b.shape[0], dtype=b.dtype)
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)

    def correction(r):
        r_in = r.astype(inner_dtype) if inner_dtype is not None else r
        # thread the outer executor down the inner subtree (bare callables
        # have no executor to thread)
        d = inner.apply(r_in, executor=ex) if isinstance(inner, LinOp) else inner(r_in)
        return d.astype(b.dtype)

    # the residual rides in the loop state: one full-precision apply per
    # sweep (A.apply(-1.0, x, 1.0, b) — the advanced-apply residual form)
    def cond(state):
        x, r, k, rnorm, hist = state
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, r, k, _, hist = state
        x = x + correction(r)
        r = Aop.apply(-1.0, x, 1.0, b, executor=ex)
        rnorm = blas.norm2(r, executor=ex)
        return x, r, k + 1, rnorm, convergence.push(hist, k, rnorm)

    r0 = Aop.apply(-1.0, x, 1.0, b, executor=ex)
    rnorm0 = blas.norm2(r0, executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=rnorm0.dtype)
    state = (x, r0, jnp.int32(0), rnorm0, hist0)
    x, r, k, rnorm, hist = jax.lax.while_loop(cond, body, state)
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


def mixed_precision_ir(
    A: MatrixLike,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    inner_dtype=jnp.float32,
    inner_solver: type = CgSolver,
    inner_stop: Optional[Stop] = None,
    inner_opts: Optional[dict] = None,
    executor=None,
    history=None,
) -> SolveResult:
    """Mixed-precision IR: a reduced-precision inner Krylov solve under a
    full-precision outer residual.

    The inner operator is ``A.astype(inner_dtype)`` (structure shared, values
    cast — :meth:`repro.sparse.formats.MatrixLinOp.astype`), solved by
    ``inner_solver`` (default CG) to a tolerance budgeted at
    ``sqrt(unit_roundoff(inner_dtype))`` — tighter is wasted, the inner
    operator is only accurate to ``u_inner``.  Under ``jax_enable_x64`` with
    f64 data this converges to the f64 tolerance while the inner iterations
    stream half the bytes.
    """
    from repro.precond import unit_roundoff

    astype = getattr(A, "astype", None)
    if astype is None:
        raise TypeError(
            f"mixed_precision_ir needs an operator with astype() to build the "
            f"reduced-precision inner copy; {type(A).__name__} has none — "
            "pass an explicit inner solver to ir() instead"
        )
    A_low = astype(inner_dtype)
    if inner_stop is None:
        u_inner = unit_roundoff(inner_dtype)
        inner_stop = Stop(max_iters=200, reduction_factor=u_inner**0.5)
    inner = inner_solver(
        A_low, stop=inner_stop, executor=executor, **(inner_opts or {})
    )
    return ir(
        A,
        b,
        x0,
        stop=stop,
        inner=inner,
        inner_dtype=inner_dtype,
        executor=executor,
        history=history,
    )


class IrSolver(LinOp):
    """Generated IR solver as a LinOp (``inner=`` / ``relaxation=`` forward).

    ``IrSolver(A, inner=CgSolver(A.astype(jnp.float32), ...))`` composes like
    any other operator — IR itself can precondition, or be refined again.
    """

    def __init__(
        self,
        A: MatrixLike,
        *,
        stop: Stop = Stop(),
        inner=None,
        inner_dtype=None,
        relaxation: float = 1.0,
        executor=None,
        history=None,
    ):
        self.A = as_linop(A)
        self.stop = stop
        self.inner = inner
        self.inner_dtype = inner_dtype
        self.relaxation = relaxation
        self.executor = executor
        self.history = history

    @property
    def shape(self):
        return getattr(self.A, "shape", None)

    @property
    def dtype(self):
        return getattr(self.A, "dtype", None)

    def solve(self, b: jax.Array, x0=None, *, executor=None) -> SolveResult:
        ex = executor if executor is not None else self.executor
        return ir(
            self.A,
            b,
            x0,
            stop=self.stop,
            inner=self.inner,
            inner_dtype=self.inner_dtype,
            relaxation=self.relaxation,
            executor=ex,
            history=self.history,
        )

    def _apply(self, b: jax.Array, executor) -> jax.Array:
        return self.solve(b, executor=executor).x
