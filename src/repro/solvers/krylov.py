"""Krylov solvers: CG, FCG, BiCGSTAB, GMRES(m) — Ginkgo's solver set.

All solvers:

* are pure-functional and jittable (``lax.while_loop`` / ``lax.fori_loop``);
* perform every vector operation through executor-dispatched BLAS-1 /
  SpMV kernels (:mod:`repro.sparse.ops`) — the algorithm never names a backend;
* distribute under ``pjit`` by sharding A (rows) and the vectors; the dot
  products lower to global all-reduces under GSPMD.

Each function also has a factory-style LinOp twin (``CgSolver``,
``GmresSolver``, ...): ``CgSolver(A, stop=...)`` is a
:class:`~repro.core.linop.LinOp` whose apply *solves*, so a solver can
precondition another solver — ``cg(A2, b, M=CgSolver(A, ...))`` is
inner-outer Krylov, Ginkgo's solver-as-preconditioner pattern.

Precision note: the paper evaluates in IEEE754 double precision; on this CPU
container f64 requires ``jax_enable_x64``.  Solvers are dtype-polymorphic —
benchmarks run f32 by default and f64 under ``with jax.experimental.enable_x64()``.
"""

from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.linop import LinOp, as_linop
from repro.observability import convergence
from repro.solvers.common import (
    MatrixLike,
    SolveResult,
    Stop,
    ensure_symmetric,
    identity_preconditioner,
)
from repro.sparse import ops as blas

__all__ = [
    "cg",
    "fcg",
    "bicgstab",
    "cgs",
    "gmres",
    "CgSolver",
    "FcgSolver",
    "BicgstabSolver",
    "CgsSolver",
    "GmresSolver",
    "PipelinedCgSolver",
]

#: a preconditioner argument: a LinOp / callable ``v -> M^{-1} v`` or a kind
#: name (``"jacobi"`` / ``"block_jacobi"`` / ``"parilu"`` / ``"amg"`` /
#: ``"identity"``)
#: that :func:`repro.precond.make_preconditioner` resolves against ``A`` — the
#: string path is how the ``adaptive`` storage knob threads through the
#: solvers: ``cg(A, b, M="block_jacobi", precond_opts={"adaptive": True})``.
Precond = Union[LinOp, Callable, str]


def _dist_route(solver_fn, A, b, x0, *, stop, M, precond_opts, executor, **options):
    """Delegate to the sharded solve when ``A`` is a distributed operator.

    The distributed layer re-enters ``solver_fn`` with the per-shard local
    operator (not distributed), so the delegation happens exactly once.
    """
    from repro.distributed.solvers import dist_solve

    return dist_solve(
        solver_fn,
        A,
        b,
        x0,
        stop=stop,
        M=M,
        precond_opts=precond_opts,
        executor=executor,
        **options,
    )


def _resolve_precond(A, M, executor, precond_opts):
    if isinstance(M, str):
        from repro.precond import make_preconditioner

        return make_preconditioner(A, M, executor=executor, **(precond_opts or {}))
    if precond_opts:
        raise ValueError("precond_opts is only meaningful when M is a kind name")
    return M if M is not None else identity_preconditioner


def _setup(A, b, x0, M, executor, precond_opts=None):
    Aop = as_linop(A)
    op = lambda v: Aop.apply(v, executor=executor)  # noqa: E731
    x = jnp.zeros_like(b) if x0 is None else x0
    M = _resolve_precond(A, M, executor, precond_opts)
    if isinstance(M, LinOp):
        # thread the solver's executor down the preconditioner subtree too —
        # A and M must dispatch in the same kernel space (bare callables have
        # no executor to thread)
        Mop = M
        M = lambda v: Mop.apply(v, executor=executor)  # noqa: E731
    return op, x, M


def cg(
    A: MatrixLike,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Precond] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    fused: Optional[bool] = None,
    pipeline: bool = False,
    history=None,
    strict: bool = True,
) -> SolveResult:
    """Preconditioned conjugate gradient (SPD systems).

    ``strict=True`` (the default) runs a cheap seeded symmetry probe on
    concrete format operands and raises instead of silently producing
    garbage on nonsymmetric A; ``strict=False`` is the escape hatch.

    ``history=True`` (or an int capacity) records per-iteration residual
    norms into a jit-safe ring buffer surfaced as ``SolveResult.history``
    (see :mod:`repro.observability.convergence`); the default ``None`` adds
    nothing to the compiled loop.

    ``fused`` selects the apply-with-reduction formulation (SpMV + dot and
    axpy + norm fused into single kernel launches).  The default ``None``
    means "use it when the executor advertises the fused ops for this
    format" — the optional-op capability probe; ``False`` forces the
    portable unfused loop, ``True`` asks for fusion but still degrades
    gracefully when the ops are unavailable.  In the reference/xla kernel
    spaces the fused ops are the literal unfused composition, so both
    settings are bitwise identical there.

    ``pipeline=True`` runs the communication-avoiding (Ghysels–Vanroose)
    variant instead: all three recurrence dot products are batched into one
    reduction per iteration (a single ``psum`` under the distributed
    context).  Pipelining reassociates the recurrences, so iteration counts
    may differ by a step or two from classic CG.
    """
    if getattr(A, "is_distributed", False):
        # shard-local re-entry must not probe: local row blocks of a
        # symmetric global matrix are not themselves symmetric
        return _dist_route(cg, A, b, x0, stop=stop, M=M,
                           precond_opts=precond_opts, executor=executor,
                           fused=fused, pipeline=pipeline, history=history,
                           strict=False)
    ensure_symmetric(A, solver="cg", strict=strict)
    if pipeline:
        return _pipelined_cg(A, b, x0, stop=stop, M=M,
                             precond_opts=precond_opts, executor=executor,
                             history=history)
    want_fused = True if fused is None else bool(fused)
    if want_fused and blas.has_fused_ops(A, executor=executor):
        return _cg_fused(A, b, x0, stop=stop, M=M,
                         precond_opts=precond_opts, executor=executor,
                         history=history)
    op, x, M = _setup(A, b, x0, M, executor, precond_opts)
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)

    r = b - op(x)
    z = M(r)
    p = z
    rz = blas.dot(r, z, executor=ex)
    rnorm0 = blas.norm2(r, executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=rnorm0.dtype)

    def cond(state):
        x, r, z, p, rz, k, rnorm, hist = state
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, r, z, p, rz, k, _, hist = state
        Ap = op(p)
        alpha = rz / blas.dot(p, Ap, executor=ex)
        x = blas.axpy(alpha, p, x, executor=ex)
        r = blas.axpy(-alpha, Ap, r, executor=ex)
        z = M(r)
        rz_new = blas.dot(r, z, executor=ex)
        beta = rz_new / rz
        p = blas.axpy(beta, p, z, executor=ex)
        rnorm = blas.norm2(r, executor=ex)
        return (x, r, z, p, rz_new, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (x, r, z, p, rz, jnp.int32(0), rnorm0, hist0)
    x, r, z, p, rz, k, rnorm, hist = jax.lax.while_loop(cond, body, state)
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


def _cg_fused(A, b, x0, *, stop, M, precond_opts, executor, history=None):
    """CG on the fused-reduction ops: 2 reduction launches per iteration.

    Every iteration issues exactly one ``spmv_dot`` (Ap and p·Ap in a single
    pass over A) and one ``axpy_norm`` (r-update and ‖r‖² in a single pass
    over the vectors) — versus SpMV + 2 dots + norm as four separate
    reduction launches in the portable loop.  With the identity
    preconditioner the ``r·z`` dot *is* the fused ‖r‖², so the loop carries
    no standalone dot at all.
    """
    Aop = as_linop(A)
    op = lambda v: Aop.apply(v, executor=executor)  # noqa: E731
    x = jnp.zeros_like(b) if x0 is None else x0
    Mres = _resolve_precond(A, M, executor, precond_opts)
    # detect identity BEFORE the lambda wrap _setup applies — with identity M
    # the fused ‖r‖² doubles as r·z and the loop carries no standalone dot
    identity_M = Mres is identity_preconditioner
    if isinstance(Mres, LinOp):
        Mop = Mres
        Mfn = lambda v: Mop.apply(v, executor=executor)  # noqa: E731
    else:
        Mfn = Mres
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)

    r = b - op(x)
    z = Mfn(r)
    p = z
    rz = blas.dot(r, z, executor=ex)
    rnorm0 = blas.norm2(r, executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=rnorm0.dtype)

    def cond(state):
        x, r, z, p, rz, k, rnorm, hist = state
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, r, z, p, rz, k, _, hist = state
        Ap, pAp = blas.spmv_dot(A, p, executor=ex)
        alpha = rz / pAp
        x = blas.axpy(alpha, p, x, executor=ex)
        r, rr = blas.axpy_norm(-alpha, Ap, r, executor=ex)
        if identity_M:
            # z = r and r·z = ‖r‖² — the fused norm doubles as the CG dot
            z, rz_new = r, rr
        else:
            z = Mfn(r)
            rz_new = blas.dot(r, z, executor=ex)
        beta = rz_new / rz
        p = blas.axpy(beta, p, z, executor=ex)
        rnorm = jnp.sqrt(rr.real)
        return (x, r, z, p, rz_new, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (x, r, z, p, rz, jnp.int32(0), rnorm0, hist0)
    x, r, z, p, rz, k, rnorm, hist = jax.lax.while_loop(cond, body, state)
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


def _pipelined_cg(A, b, x0, *, stop, M, precond_opts, executor, history=None):
    """Pipelined (Ghysels–Vanroose) preconditioned CG — one reduction/iteration.

    Classic CG needs two dependent dot products per iteration (``p·Ap``
    before the updates, ``r·z`` after), each a separate global reduction.
    The pipelined recurrences carry the auxiliary vectors ``u = M r``,
    ``w = A u``, ``z/q/s/p`` so that all three scalars (γ = r·u, δ = w·u,
    ‖r‖²) are computable from the *same* state — one
    :func:`repro.sparse.ops.dot_batch` call, which under the distributed
    reduction context is a single fused ``psum`` per iteration.

    The reassociated recurrences change rounding, so iteration counts may
    drift by ±1–2 versus classic CG; the converged solution is the same to
    solver tolerance.
    """
    op, x, Mfn = _setup(A, b, x0, M, executor, precond_opts)
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)
    dtype = b.dtype

    r = b - op(x)
    u = Mfn(r)
    w = op(u)
    d0 = blas.dot_batch([(r, u), (w, u), (r, r)], executor=ex)
    gam, delta, rr = d0[0], d0[1], d0[2]
    zeros = jnp.zeros_like(b)
    one = jnp.ones((), dtype)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=jnp.sqrt(rr.real).dtype)

    def cond(state):
        rr, k = state[10], state[13]
        return (jnp.sqrt(rr.real) > thresh) & (k < stop.max_iters)

    def body(state):
        (x, r, u, w, z, q, s, p, gam, delta, rr,
         gam_old, alpha_old, k, hist) = state
        beta = jnp.where(k == 0, jnp.zeros((), gam.dtype), gam / gam_old)
        # at k == 0 beta = 0, so the denominator reduces to delta
        alpha = gam / (delta - beta * gam / alpha_old)
        mv = Mfn(w)
        nv = op(mv)
        z = blas.axpy(beta, z, nv, executor=ex)
        q = blas.axpy(beta, q, mv, executor=ex)
        s = blas.axpy(beta, s, w, executor=ex)
        p = blas.axpy(beta, p, u, executor=ex)
        x = blas.axpy(alpha, p, x, executor=ex)
        r = blas.axpy(-alpha, s, r, executor=ex)
        u = blas.axpy(-alpha, q, u, executor=ex)
        w = blas.axpy(-alpha, z, w, executor=ex)
        d = blas.dot_batch([(r, u), (w, u), (r, r)], executor=ex)
        hist = convergence.push(hist, k, jnp.sqrt(d[2].real))
        return (x, r, u, w, z, q, s, p, d[0], d[1], d[2],
                gam, alpha, k + 1, hist)

    state = (x, r, u, w, zeros, zeros, zeros, zeros,
             gam, delta, rr, one, one, jnp.int32(0), hist0)
    out = jax.lax.while_loop(cond, body, state)
    x, rr, k = out[0], out[10], out[13]
    rnorm = jnp.sqrt(rr.real)
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(out[14]))


def fcg(
    A: MatrixLike,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Precond] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    history=None,
    strict: bool = True,
) -> SolveResult:
    """Flexible CG (Ginkgo's FCG): Polak–Ribière beta = r'(r - r_prev)/rz_prev,
    robust to non-constant preconditioners.

    Like :func:`cg`, ``strict=True`` probes concrete operands for symmetry
    and raises on nonsymmetric A instead of silently diverging."""
    if getattr(A, "is_distributed", False):
        return _dist_route(fcg, A, b, x0, stop=stop, M=M,
                           precond_opts=precond_opts, executor=executor,
                           history=history, strict=False)
    ensure_symmetric(A, solver="fcg", strict=strict)
    op, x, M = _setup(A, b, x0, M, executor, precond_opts)
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)

    r = b - op(x)
    z = M(r)
    p = z
    rz = blas.dot(r, z, executor=ex)
    rnorm0 = blas.norm2(r, executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=rnorm0.dtype)

    def cond(state):
        k, rnorm = state[6], state[7]
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, r, r_prev, z, p, rz, k, _, hist = state
        Ap = op(p)
        alpha = rz / blas.dot(p, Ap, executor=ex)
        x = blas.axpy(alpha, p, x, executor=ex)
        r_new = blas.axpy(-alpha, Ap, r, executor=ex)
        z = M(r_new)
        # flexible beta uses the difference with the previous residual
        rz_new = blas.dot(r_new, z, executor=ex)
        beta = blas.dot(z, r_new - r, executor=ex) / rz
        p = blas.axpy(beta, p, z, executor=ex)
        rnorm = blas.norm2(r_new, executor=ex)
        return (x, r_new, r, z, p, rz_new, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (x, r, r, z, p, rz, jnp.int32(0), rnorm0, hist0)
    out = jax.lax.while_loop(cond, body, state)
    x, r, r_prev, z, p, rz, k, rnorm, hist = out
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


def bicgstab(
    A: MatrixLike,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Precond] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    fused: Optional[bool] = None,
    history=None,
) -> SolveResult:
    """Preconditioned BiCGSTAB (general nonsymmetric systems).

    ``fused`` works as in :func:`cg`: ``None`` probes the executor for the
    fused apply-with-reduction ops and uses them when available.
    """
    if getattr(A, "is_distributed", False):
        return _dist_route(bicgstab, A, b, x0, stop=stop, M=M,
                           precond_opts=precond_opts, executor=executor,
                           fused=fused, history=history)
    want_fused = True if fused is None else bool(fused)
    if want_fused and blas.has_fused_ops(A, executor=executor):
        return _bicgstab_fused(A, b, x0, stop=stop, M=M,
                               precond_opts=precond_opts, executor=executor,
                               history=history)
    op, x, M = _setup(A, b, x0, M, executor, precond_opts)
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)
    eps = jnp.asarray(1e-30, b.dtype)

    r = b - op(x)
    r_hat = r
    rho = blas.dot(r_hat, r, executor=ex)
    p = r
    rnorm0 = blas.norm2(r, executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=rnorm0.dtype)

    def cond(state):
        x, r, p, rho, k, rnorm, hist = state
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, r, p, rho, k, _, hist = state
        p_hat = M(p)
        v = op(p_hat)
        alpha = rho / (blas.dot(r_hat, v, executor=ex) + eps)
        s = blas.axpy(-alpha, v, r, executor=ex)
        s_hat = M(s)
        t = op(s_hat)
        omega = blas.dot(t, s, executor=ex) / (blas.dot(t, t, executor=ex) + eps)
        x = x + alpha * p_hat + omega * s_hat
        r_new = blas.axpy(-omega, t, s, executor=ex)
        rho_new = blas.dot(r_hat, r_new, executor=ex)
        beta = (rho_new / (rho + eps)) * (alpha / (omega + eps))
        p = r_new + beta * (p - omega * v)
        rnorm = blas.norm2(r_new, executor=ex)
        return (x, r_new, p, rho_new, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (x, r, p, rho, jnp.int32(0), rnorm0, hist0)
    x, r, p, rho, k, rnorm, hist = jax.lax.while_loop(cond, body, state)
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


def _bicgstab_fused(A, b, x0, *, stop, M, precond_opts, executor,
                    history=None):
    """BiCGSTAB on the fused ops: both SpMVs carry their follow-up dot
    (``r̂·v`` and ``s·t``) and the final residual update carries ‖r‖²,
    collapsing five reduction launches per iteration into three (the ``t·t``
    and ``r̂·r`` dots remain standalone).  For real dtypes ``s·t`` equals the
    portable loop's ``t·s`` bitwise, preserving fallback parity."""
    op, x, M = _setup(A, b, x0, M, executor, precond_opts)
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)
    eps = jnp.asarray(1e-30, b.dtype)

    r = b - op(x)
    r_hat = r
    rho = blas.dot(r_hat, r, executor=ex)
    p = r
    rnorm0 = blas.norm2(r, executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=rnorm0.dtype)

    def cond(state):
        x, r, p, rho, k, rnorm, hist = state
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, r, p, rho, k, _, hist = state
        p_hat = M(p)
        v, rhv = blas.spmv_dot(A, p_hat, w=r_hat, executor=ex)
        alpha = rho / (rhv + eps)
        s = blas.axpy(-alpha, v, r, executor=ex)
        s_hat = M(s)
        t, ts = blas.spmv_dot(A, s_hat, w=s, executor=ex)
        omega = ts / (blas.dot(t, t, executor=ex) + eps)
        x = x + alpha * p_hat + omega * s_hat
        r_new, rr = blas.axpy_norm(-omega, t, s, executor=ex)
        rho_new = blas.dot(r_hat, r_new, executor=ex)
        beta = (rho_new / (rho + eps)) * (alpha / (omega + eps))
        p = r_new + beta * (p - omega * v)
        rnorm = jnp.sqrt(rr.real)
        return (x, r_new, p, rho_new, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (x, r, p, rho, jnp.int32(0), rnorm0, hist0)
    x, r, p, rho, k, rnorm, hist = jax.lax.while_loop(cond, body, state)
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


def cgs(
    A: MatrixLike,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    stop: Stop = Stop(),
    M: Optional[Precond] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    history=None,
) -> SolveResult:
    """Conjugate Gradient Squared (Sonneveld) — the paper's solver set's
    transpose-free nonsymmetric method."""
    if getattr(A, "is_distributed", False):
        return _dist_route(cgs, A, b, x0, stop=stop, M=M,
                           precond_opts=precond_opts, executor=executor,
                           history=history)
    op, x, M = _setup(A, b, x0, M, executor, precond_opts)
    ex = executor
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)
    eps = jnp.asarray(1e-30, b.dtype)

    r = b - op(x)
    r_hat = r
    rho = blas.dot(r_hat, r, executor=ex)
    u = r
    p = r
    rnorm0 = blas.norm2(r, executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=rnorm0.dtype)

    def cond(state):
        k, rnorm = state[5], state[6]
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, r, u, p, rho, k, _, hist = state
        p_hat = M(p)
        v = op(p_hat)
        alpha = rho / (blas.dot(r_hat, v, executor=ex) + eps)
        q = u - alpha * v
        uq_hat = M(u + q)
        x = x + alpha * uq_hat
        r = r - alpha * op(uq_hat)
        rho_new = blas.dot(r_hat, r, executor=ex)
        beta = rho_new / (rho + eps)
        u = r + beta * q
        p = u + beta * (q + beta * p)
        rnorm = blas.norm2(r, executor=ex)
        return (x, r, u, p, rho_new, k + 1, rnorm,
                convergence.push(hist, k, rnorm))

    state = (x, r, u, p, rho, jnp.int32(0), rnorm0, hist0)
    x, r, u, p, rho, k, rnorm, hist = jax.lax.while_loop(cond, body, state)
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


def gmres(
    A: MatrixLike,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    restart: int = 30,
    stop: Stop = Stop(),
    M: Optional[Precond] = None,
    precond_opts: Optional[dict] = None,
    executor=None,
    history=None,
) -> SolveResult:
    """Restarted GMRES(m) with modified Gram-Schmidt Arnoldi + Givens rotations.

    Right-preconditioned: solves A M^{-1} u = b, x = M^{-1} u, so the true
    residual is available without extra applies.

    ``history=`` records the true residual norm once per restart *cycle*
    (slot ``k // m``), not per inner Arnoldi step — the inner steps only
    track the rotated-rhs estimate.
    """
    if getattr(A, "is_distributed", False):
        return _dist_route(gmres, A, b, x0, stop=stop, M=M,
                           precond_opts=precond_opts, executor=executor,
                           restart=restart, history=history)
    op, x, M = _setup(A, b, x0, M, executor, precond_opts)
    ex = executor
    n = b.shape[0]
    m = restart
    dtype = b.dtype
    bnorm = blas.norm2(b, executor=ex)
    thresh = stop.threshold(bnorm)
    eps = jnp.asarray(1e-30, dtype)

    def arnoldi_cycle(x):
        """One restart cycle. Returns (x_new, rnorm_new)."""
        r = b - op(x)
        beta = blas.norm2(r, executor=ex)
        V = jnp.zeros((m + 1, n), dtype)
        V = V.at[0].set(r / (beta + eps))
        H = jnp.zeros((m + 1, m), dtype)
        # Givens coefficients and the rotated rhs g
        cs = jnp.zeros(m, dtype)
        sn = jnp.zeros(m, dtype)
        g = jnp.zeros(m + 1, dtype).at[0].set(beta)

        def step(j, carry):
            V, H, cs, sn, g, done = carry
            w = op(M(V[j]))
            # modified Gram-Schmidt against all m+1 basis vectors; rows > j are
            # zero so the extra dots are no-ops (keeps shapes static).
            def mgs(i, wh):
                w, h = wh
                hij = jnp.where(i <= j, blas.dot(V[i], w, executor=ex), 0.0)
                w = w - hij * V[i]
                return w, h.at[i].set(hij)

            w, hcol = jax.lax.fori_loop(0, m + 1, mgs, (w, jnp.zeros(m + 1, dtype)))
            hj1 = blas.norm2(w, executor=ex)
            hcol = hcol.at[j + 1].set(hj1)
            V = V.at[j + 1].set(w / (hj1 + eps))

            # apply existing Givens rotations to the new column
            def rot(i, h):
                hi = cs[i] * h[i] + sn[i] * h[i + 1]
                hi1 = -sn[i] * h[i] + cs[i] * h[i + 1]
                h = h.at[i].set(jnp.where(i < j, hi, h[i]))
                return h.at[i + 1].set(jnp.where(i < j, hi1, h[i + 1]))

            hcol = jax.lax.fori_loop(0, m, rot, hcol)

            # new rotation to zero hcol[j+1]
            denom = jnp.sqrt(hcol[j] ** 2 + hcol[j + 1] ** 2) + eps
            c, s = hcol[j] / denom, hcol[j + 1] / denom
            hcol = hcol.at[j].set(c * hcol[j] + s * hcol[j + 1]).at[j + 1].set(0.0)
            cs = cs.at[j].set(c)
            sn = sn.at[j].set(s)
            g_j1 = -s * g[j]
            g = g.at[j + 1].set(g_j1).at[j].set(c * g[j])

            H = H.at[:, j].set(hcol)
            done = done | (jnp.abs(g_j1) <= thresh)
            return V, H, cs, sn, g, done

        # run all m steps (static shape); 'done' only gates the outer loop —
        # redundant inner steps are numerically harmless (rotations freeze g).
        V, H, cs, sn, g, done = jax.lax.fori_loop(
            0, m, step, (V, H, cs, sn, g, jnp.asarray(False))
        )

        # back-substitution on the m×m triangular system H y = g
        def back(i_rev, y):
            i = m - 1 - i_rev
            num = g[i] - jnp.dot(H[i, :], y)
            return y.at[i].set(num / (H[i, i] + eps))

        y = jax.lax.fori_loop(0, m, back, jnp.zeros(m, dtype))
        dx = V[:m].T @ y
        x_new = x + M(dx)
        rnorm = blas.norm2(b - op(x_new), executor=ex)
        return x_new, rnorm

    def cond(state):
        x, k, rnorm, hist = state
        return (rnorm > thresh) & (k < stop.max_iters)

    def body(state):
        x, k, _, hist = state
        x, rnorm = arnoldi_cycle(x)
        return x, k + m, rnorm, convergence.push(hist, k // m, rnorm)

    r0 = blas.norm2(b - op(x), executor=ex)
    hist0 = convergence.init(convergence.capacity(history, stop),
                             dtype=r0.dtype)
    x, k, rnorm, hist = jax.lax.while_loop(
        cond, body, (x, jnp.int32(0), r0, hist0)
    )
    return SolveResult(x, k, rnorm, rnorm <= thresh,
                       convergence.finalize(hist))


# =============================================================================
# Factory-style solver LinOps — gko::solver::Cg::Factory ... ::generate(A)
# =============================================================================


class KrylovSolver(LinOp):
    """A generated solver as a LinOp: ``apply(b)`` *solves* ``A x = b``.

    This is Ginkgo's factory pattern collapsed to one step: a Ginkgo solver
    factory ``generate(A)``-s a solver object that IS a LinOp, so solvers
    compose anywhere an operator is expected — as the ``M`` of an outer
    Krylov method (inner-outer iteration), as the inner solve of iterative
    refinement (:mod:`repro.solvers.ir`), or inside
    :class:`~repro.core.linop.Composition` chains.

    String preconditioners resolve at construction (generation time, like
    Ginkgo's ``generate``), so the host-side setup work never re-runs inside
    a jitted apply.  ``solve(b)`` returns the full :class:`SolveResult`;
    ``apply(b)`` returns only ``x`` (the LinOp face).
    """

    _fn: Callable = None  # bound per subclass
    _requires_spd: bool = False  # CG-family subclasses probe at generation

    def __init__(
        self,
        A: MatrixLike,
        *,
        stop: Stop = Stop(),
        M: Optional[Precond] = None,
        precond_opts: Optional[dict] = None,
        executor=None,
        **options,
    ):
        self.A = as_linop(A)
        self.stop = stop
        if self._requires_spd:
            # generation-time symmetry probe (Ginkgo generates eagerly, so
            # failing here is the earliest loud failure point); the solve-time
            # check is skipped since generation already vetted the operand
            ensure_symmetric(A, solver=type(self).__name__,
                             strict=options.get("strict", True))
            options["strict"] = False
        if getattr(self.A, "is_distributed", False):
            # generation-time resolution for distributed operands goes through
            # the shard-local generators (a global M cannot apply per shard)
            from repro.distributed.precond import dist_preconditioner

            self.M = dist_preconditioner(
                self.A, M, executor=executor, **(precond_opts or {})
            )
        else:
            self.M = _resolve_precond(A, M, executor, precond_opts)
        self.executor = executor
        self.options = options

    @property
    def shape(self):
        return getattr(self.A, "shape", None)

    @property
    def dtype(self):
        return getattr(self.A, "dtype", None)

    def solve(self, b: jax.Array, x0=None, *, executor=None) -> SolveResult:
        ex = executor if executor is not None else self.executor
        return type(self)._fn(
            self.A, b, x0, stop=self.stop, M=self.M, executor=ex, **self.options
        )

    def _apply(self, b: jax.Array, executor) -> jax.Array:
        return self.solve(b, executor=executor).x


class CgSolver(KrylovSolver):
    """Generated CG solver (SPD) as a LinOp."""

    _fn = staticmethod(cg)
    _requires_spd = True


class PipelinedCgSolver(KrylovSolver):
    """Generated communication-avoiding CG solver as a LinOp.

    ``PipelinedCgSolver(A, stop=...)`` is :class:`CgSolver` with
    ``pipeline=True`` baked into the generated options: every iteration
    performs a single batched reduction (one ``psum`` under the distributed
    context) instead of two dependent dots — the latency-bound regime's
    solver of choice at scale."""

    _fn = staticmethod(cg)
    _requires_spd = True

    def __init__(self, A, **kw):
        super().__init__(A, pipeline=True, **kw)


class FcgSolver(KrylovSolver):
    """Generated flexible-CG solver as a LinOp."""

    _fn = staticmethod(fcg)
    _requires_spd = True


class BicgstabSolver(KrylovSolver):
    """Generated BiCGSTAB solver as a LinOp."""

    _fn = staticmethod(bicgstab)


class CgsSolver(KrylovSolver):
    """Generated CGS solver as a LinOp."""

    _fn = staticmethod(cgs)


class GmresSolver(KrylovSolver):
    """Generated GMRES(m) solver as a LinOp (``restart=`` forwards)."""

    _fn = staticmethod(gmres)

    def __init__(self, A, *, restart: int = 30, **kw):
        super().__init__(A, restart=restart, **kw)
