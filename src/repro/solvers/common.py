"""Shared solver machinery: LinearOperator, results, stopping criteria.

Solvers are written against executor-dispatched BLAS-1/SpMV operations and
``jax.lax`` control flow only, so one solver source serves every executor
(the paper's separation of algorithm from kernels) and distributes under
``pjit`` by sharding the operands (dots become global collectives under GSPMD).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro import sparse
from repro.sparse.formats import Coo, Csr, Dense, Ell, Sellp
from repro.core import registry

MatrixLike = Union[Coo, Csr, Ell, Sellp, Dense, Callable[[jax.Array], jax.Array]]

__all__ = [
    "LinearOperator",
    "SolveResult",
    "Stop",
    "jacobi_preconditioner",
    "identity_preconditioner",
]


class LinearOperator:
    """gko::LinOp analogue: anything that can apply() to a vector."""

    def __init__(self, A: MatrixLike, executor=None):
        self.A = A
        self.executor = executor

    def __call__(self, x: jax.Array) -> jax.Array:
        if callable(self.A) and not hasattr(self.A, "values"):
            return self.A(x)
        return sparse.apply(self.A, x, executor=self.executor)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveResult:
    x: jax.Array
    iterations: jax.Array  # int32
    residual_norm: jax.Array
    converged: jax.Array  # bool


@dataclasses.dataclass(frozen=True)
class Stop:
    """Combined stopping criterion (gko::stop::Combined).

    Converged when ||r|| <= max(reduction_factor * ||b||, abs_tol), or stopped
    when iterations reach max_iters.
    """

    max_iters: int = 1000
    reduction_factor: float = 1e-6
    abs_tol: float = 0.0

    def threshold(self, bnorm: jax.Array) -> jax.Array:
        if self.reduction_factor == 0.0 and self.abs_tol == 0.0:
            # Without this check an abs_tol-only criterion mistyped as
            # (0.0, 0.0) silently yields threshold 0.0 — a solver that can
            # never converge and always burns max_iters.
            raise ValueError(
                "degenerate stopping criterion: reduction_factor=0.0 with "
                "abs_tol=0.0 can never be satisfied; set abs_tol > 0 for "
                "absolute-tolerance-only stopping or reduction_factor > 0 "
                "for relative stopping"
            )
        return jnp.maximum(self.reduction_factor * bnorm, self.abs_tol)


# -- preconditioners -----------------------------------------------------------

extract_diag_op = registry.operation("extract_diagonal")


@extract_diag_op.register("reference")
def _extract_diag_ref(ex, A):
    if isinstance(A, Dense):
        return jnp.diagonal(A.values)
    if isinstance(A, Csr):
        nnz = A.values.shape[0]
        rows = (
            jnp.searchsorted(A.indptr, jnp.arange(nnz, dtype=jnp.int32), side="right")
            - 1
        )
        n = min(A.shape)
        hit = (rows == A.indices) & (rows < n)
        return jnp.zeros(n, A.values.dtype).at[jnp.where(hit, rows, 0)].add(
            jnp.where(hit, A.values, 0.0)
        )
    if isinstance(A, Coo):
        n = min(A.shape)
        hit = A.row_idx == A.col_idx
        return jnp.zeros(n, A.values.dtype).at[jnp.where(hit, A.row_idx, 0)].add(
            jnp.where(hit, A.values, 0.0)
        )
    if isinstance(A, Ell):
        m, k = A.values.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, k))
        hit = A.col_idx == rows
        return jnp.sum(jnp.where(hit, A.values, 0.0), axis=1)[: min(A.shape)]
    # Fallback (Sellp): densify — reference semantics are allowed to be slow.
    return jnp.diagonal(sparse.to_dense(A, executor=ex))


@extract_diag_op.register("xla")
def _extract_diag_xla(ex, A):
    return _extract_diag_ref(ex, A)


def jacobi_preconditioner(A: MatrixLike, executor=None) -> Callable:
    """Scalar Jacobi: M^{-1} v = v / diag(A) (gko::preconditioner::Jacobi, bs=1)."""
    d = extract_diag_op(A, executor=executor)
    safe = jnp.where(jnp.abs(d) > 0, d, jnp.ones_like(d))
    inv = jnp.where(jnp.abs(d) > 0, 1.0 / safe, jnp.ones_like(d))

    def apply_m(v: jax.Array) -> jax.Array:
        return inv * v

    return apply_m


extract_diag_blocks_op = registry.operation("extract_diag_blocks")


@extract_diag_blocks_op.register("reference")
def _extract_blocks_ref(ex, A, block_size: int):
    """(nblocks, bs, bs) diagonal blocks; trailing block zero-padded.

    Reference semantics densify (correct for every format); a format-aware
    gather is the natural optimization for huge systems.
    """
    dense = sparse.to_dense(A, executor=ex)
    n = dense.shape[0]
    nb = -(-n // block_size)
    pad = nb * block_size - n
    if pad:
        dense = jnp.pad(dense, ((0, pad), (0, pad)))
    rows = dense.reshape(nb, block_size, nb * block_size)
    blocks = jnp.stack(
        [jax.lax.dynamic_slice_in_dim(rows[i], i * block_size, block_size, axis=1)
         for i in range(nb)]
    )
    return blocks


@extract_diag_blocks_op.register("xla")
def _extract_blocks_xla(ex, A, block_size: int):
    return _extract_blocks_ref(ex, A, block_size)


def block_jacobi_preconditioner(
    A: MatrixLike, block_size: Optional[int] = None, executor=None
) -> Callable:
    """Block-Jacobi (gko::preconditioner::Jacobi with block size > 1):
    M^{-1} = blockdiag(A_11^{-1}, A_22^{-1}, ...) — Ginkgo's flagship
    preconditioner for the solver benchmarks.

    ``block_size=None`` takes the executor's cooperative-subgroup width from
    the hardware table (Ginkgo tunes Jacobi storage to the subwarp size).
    Singular/padded blocks fall back to identity on their zero rows via a
    diagonal ridge before inversion.
    """
    if block_size is None:
        from repro.core.executor import current_executor

        ex = executor if executor is not None else current_executor()
        block_size = ex.hw.subgroup_size
    n = A.shape[0] if hasattr(A, "shape") else A.values.shape[0]
    blocks = extract_diag_blocks_op(A, block_size, executor=executor)
    nb = blocks.shape[0]
    # regularize zero diagonal entries (padding / structurally empty rows)
    diag = jnp.diagonal(blocks, axis1=1, axis2=2)
    ridge = jnp.where(jnp.abs(diag) > 0, 0.0, 1.0)
    blocks = blocks + jax.vmap(jnp.diag)(ridge)
    inv_blocks = jnp.linalg.inv(blocks)  # (nb, bs, bs)

    def apply_m(v: jax.Array) -> jax.Array:
        pad = nb * block_size - v.shape[0]
        vp = jnp.pad(v, (0, pad)) if pad else v
        y = jnp.einsum("bij,bj->bi", inv_blocks, vp.reshape(nb, block_size))
        return y.reshape(-1)[: v.shape[0]]

    return apply_m


def identity_preconditioner(v: jax.Array) -> jax.Array:
    return v
