"""Shared solver machinery: results, stopping criteria, scalar preconditioners.

Solvers are written against executor-dispatched BLAS-1/SpMV operations and
``jax.lax`` control flow only, so one solver source serves every executor
(the paper's separation of algorithm from kernels) and distributes under
``pjit`` by sharding the operands (dots become global collectives under GSPMD).

Operators are unified under :mod:`repro.core.linop`: formats, preconditioners,
and solver factories are all LinOps composing through one ``apply``.
:class:`LinearOperator` survives only as a deprecated back-compat shim over
:func:`repro.core.linop.as_linop`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.core.linop import Identity, LinOp, as_linop
from repro.sparse.formats import Coo, Csr, Dense, Ell, Sellp

MatrixLike = Union[
    LinOp, Coo, Csr, Ell, Sellp, Dense, Callable[[jax.Array], jax.Array]
]

__all__ = [
    "LinearOperator",
    "SolveResult",
    "Stop",
    "ScalarJacobi",
    "probe_symmetry",
    "ensure_symmetric",
    "jacobi_preconditioner",
    "block_jacobi_preconditioner",
    "identity_preconditioner",
]


class LinearOperator(LinOp):
    """Deprecated back-compat shim — use the operand directly, or
    :func:`repro.core.linop.as_linop`.

    Every sparse format, preconditioner, and solver factory is now itself a
    :class:`~repro.core.linop.LinOp`; wrapping one in ``LinearOperator`` adds
    nothing.  The class delegates to ``as_linop`` so existing call sites keep
    the historical behavior (format -> registry-dispatched SpMV, callable ->
    matrix-free apply).
    """

    def __init__(self, A: MatrixLike, executor=None):
        warnings.warn(
            "repro.solvers.common.LinearOperator is deprecated: formats, "
            "preconditioners and solvers are LinOps — pass them directly "
            "(or use repro.core.linop.as_linop for bare callables)",
            DeprecationWarning,
            stacklevel=2,
        )
        self.A = A
        self.op = as_linop(A)
        self.executor = executor

    @property
    def shape(self):
        return getattr(self.op, "shape", None)

    @property
    def dtype(self):
        return getattr(self.op, "dtype", None)

    def _apply(self, x: jax.Array, executor) -> jax.Array:
        ex = executor if executor is not None else self.executor
        return self.op.apply(x, executor=ex)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveResult:
    x: jax.Array
    iterations: jax.Array  # int32
    residual_norm: jax.Array
    converged: jax.Array  # bool
    #: per-iteration residual norms when the solve ran with ``history=``
    #: (a fixed-capacity ring buffer, NaN in unfilled slots — see
    #: :mod:`repro.observability.convergence`); None otherwise.
    history: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class Stop:
    """Combined stopping criterion (gko::stop::Combined).

    Converged when ||r|| <= max(reduction_factor * ||b||, abs_tol), or stopped
    when iterations reach max_iters.
    """

    max_iters: int = 1000
    reduction_factor: float = 1e-6
    abs_tol: float = 0.0

    def threshold(self, bnorm: jax.Array) -> jax.Array:
        if self.reduction_factor == 0.0 and self.abs_tol == 0.0:
            # Without this check an abs_tol-only criterion mistyped as
            # (0.0, 0.0) silently yields threshold 0.0 — a solver that can
            # never converge and always burns max_iters.
            raise ValueError(
                "degenerate stopping criterion: reduction_factor=0.0 with "
                "abs_tol=0.0 can never be satisfied; set abs_tol > 0 for "
                "absolute-tolerance-only stopping or reduction_factor > 0 "
                "for relative stopping"
            )
        return jnp.maximum(self.reduction_factor * bnorm, self.abs_tol)


# -- preconditioners -----------------------------------------------------------

extract_diag_op = registry.operation("extract_diagonal")


@extract_diag_op.register("reference")
def _extract_diag_ref(ex, A):
    if isinstance(A, Dense):
        return jnp.diagonal(A.values)
    if isinstance(A, Csr):
        nnz = A.values.shape[0]
        rows = (
            jnp.searchsorted(A.indptr, jnp.arange(nnz, dtype=jnp.int32), side="right")
            - 1
        )
        n = min(A.shape)
        hit = (rows == A.indices) & (rows < n)
        return jnp.zeros(n, A.values.dtype).at[jnp.where(hit, rows, 0)].add(
            jnp.where(hit, A.values, 0.0)
        )
    if isinstance(A, Coo):
        n = min(A.shape)
        hit = A.row_idx == A.col_idx
        return jnp.zeros(n, A.values.dtype).at[jnp.where(hit, A.row_idx, 0)].add(
            jnp.where(hit, A.values, 0.0)
        )
    if isinstance(A, Ell):
        m, k = A.values.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, k))
        hit = A.col_idx == rows
        return jnp.sum(jnp.where(hit, A.values, 0.0), axis=1)[: min(A.shape)]
    # Fallback (Sellp): densify — reference semantics are allowed to be slow.
    from repro.sparse import ops as sparse_ops

    return jnp.diagonal(sparse_ops.to_dense(A, executor=ex))


@extract_diag_op.register("xla")
def _extract_diag_xla(ex, A):
    return _extract_diag_ref(ex, A)


class ScalarJacobi(LinOp):
    """Scalar Jacobi LinOp: ``M^{-1} v = inv_diag * v``.

    ``inv_diag`` may be held in a reduced storage precision (the adaptive
    knob); the apply upcasts to the vector's dtype, so reduced precision only
    shrinks the stored footprint, never the arithmetic.
    """

    def __init__(self, inv_diag: jax.Array):
        self.inv_diag = inv_diag

    @property
    def shape(self):
        n = self.inv_diag.shape[0]
        return (n, n)

    @property
    def dtype(self):
        return self.inv_diag.dtype

    @property
    def storage_bytes(self) -> int:
        return int(self.inv_diag.size) * self.inv_diag.dtype.itemsize

    def _apply(self, v: jax.Array, executor) -> jax.Array:
        return self.inv_diag.astype(v.dtype) * v

    def transpose(self) -> "ScalarJacobi":
        # Diagonal operators are symmetric: M^{-T} = M^{-1}.
        return self


def probe_symmetry(A, *, seed: int = 0, rtol: float = 1e-4) -> Optional[bool]:
    """Cheap seeded two-vector symmetry probe: is ``u^T A v == v^T A u``?

    Returns ``True``/``False`` for concrete square real-dtype format operands,
    ``None`` when the question cannot be answered cheaply (traced values under
    ``jit``/``vmap``, matrix-free operators, non-square or complex operands).
    The probe runs entirely in host numpy so it leaves no trace in any
    executor's dispatch log — launch-count pins never see it.

    A single random pair catches every nonsymmetric matrix outside a measure-
    zero set; the tolerance is relative to ``|u|^T |A| |v|`` so cancellation-
    heavy but symmetric operands do not false-positive.
    """
    values = getattr(A, "values", None)
    shape = getattr(A, "shape", None)
    if values is None or shape is None or shape[0] != shape[1]:
        return None
    if isinstance(values, jax.core.Tracer):
        return None
    if jnp.issubdtype(jnp.asarray(values).dtype, jnp.complexfloating):
        return None
    try:
        from repro.sparse.formats import csr_host_arrays

        indptr, indices, vals = csr_host_arrays(A)
    except Exception:
        return None
    import numpy as np

    n = shape[0]
    rng = np.random.default_rng(seed)
    u = rng.standard_normal(n)
    v = rng.standard_normal(n)
    vals = np.asarray(vals, dtype=np.float64)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    cols = np.asarray(indices, dtype=np.int64)
    uAv = float(np.sum(u[rows] * vals * v[cols]))
    vAu = float(np.sum(v[rows] * vals * u[cols]))
    scale = float(np.sum(np.abs(u[rows]) * np.abs(vals) * np.abs(v[cols])))
    return abs(uAv - vAu) <= rtol * max(scale, 1.0)


def ensure_symmetric(A, *, solver: str, strict: bool = True, seed: int = 0) -> None:
    """Raise a clear error when an SPD-only solver receives a nonsymmetric A.

    ``cg``/``fcg`` silently diverge or converge to garbage on nonsymmetric
    operators; this guard turns that silent failure into a loud one at
    factory/generation time.  ``strict=False`` is the escape hatch for users
    who know their operator is symmetric in exact arithmetic (or accept the
    risk).  Probes that cannot decide (traced values, matrix-free A) pass.
    """
    if not strict:
        return
    sym = probe_symmetry(A, seed=seed)
    if sym is False:
        raise ValueError(
            f"{solver} requires a symmetric (SPD) operator, but a seeded "
            "symmetry probe found u^T A v != v^T A u. CG-family iterations "
            "silently produce garbage on nonsymmetric systems - use gmres, "
            "bicgstab, or cgs instead, or pass strict=False if the operator "
            "is symmetric in exact arithmetic."
        )


def jacobi_preconditioner(
    A: MatrixLike, executor=None, *, adaptive: Union[bool, str] = False
) -> Callable:
    """Scalar Jacobi: M^{-1} v = v / diag(A) (gko::preconditioner::Jacobi, bs=1).

    ``adaptive=True`` stores the inverse diagonal in the cheapest 16-bit
    precision whose range fits (fp16, else bf16); a dtype forces that storage.
    Arithmetic stays in the vector's precision either way.
    """
    d = extract_diag_op(A, executor=executor)
    safe = jnp.where(jnp.abs(d) > 0, d, jnp.ones_like(d))
    inv = jnp.where(jnp.abs(d) > 0, 1.0 / safe, jnp.ones_like(d))
    if adaptive is True:
        maxabs = float(jnp.max(jnp.abs(inv))) if inv.size else 0.0
        inv = inv.astype(jnp.float16 if maxabs < 65504.0 else jnp.bfloat16)
    elif adaptive:
        inv = inv.astype(jnp.dtype(adaptive))
    return ScalarJacobi(inv)


def block_jacobi_preconditioner(
    A: MatrixLike,
    block_size: Optional[int] = None,
    executor=None,
    *,
    blocks=None,
    adaptive: Union[bool, str] = False,
    tau: Optional[float] = None,
) -> Callable:
    """Block-Jacobi (gko::preconditioner::Jacobi with block size > 1):
    M^{-1} = blockdiag(A_11^{-1}, A_22^{-1}, ...) — Ginkgo's flagship
    preconditioner.

    Delegates to :mod:`repro.precond.block_jacobi`: host-side block discovery
    (``blocks`` pins explicit pointers, e.g. from
    :func:`repro.precond.natural_blocks`), format-aware extraction, batched
    Gauss-Jordan inversion, and an executor-dispatched apply.
    ``block_size=None`` takes the executor's cooperative-subgroup width from
    the hardware table (Ginkgo tunes Jacobi storage to the subwarp size);
    ``adaptive`` selects per-block storage precision (see
    :func:`repro.precond.block_jacobi`).  The returned object is callable and
    reports ``storage_bytes`` / ``precision_counts``.
    """
    from repro.precond import block_jacobi as _block_jacobi

    return _block_jacobi(
        A,
        block_size,
        blocks=blocks,
        adaptive=adaptive,
        executor=executor,
        **({} if tau is None else {"tau": tau}),
    )


#: the identity preconditioner — a real LinOp (``storage_bytes == 0``), not a
#: bare function, so benchmark code reads storage/shape uniformly across every
#: ``M=``.  Remains callable (``identity_preconditioner(v) -> v``) for all
#: historical call sites.
identity_preconditioner = Identity()
