"""repro.checkpoint — async, atomic, elastic checkpointing."""

from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
