"""Async, atomic, elastic checkpointing (no orbax — built in-repo).

Layout::

    <dir>/step_00001000.tmp/    (written)
    <dir>/step_00001000/        (atomic rename on commit)
        manifest.json           tree structure, shapes, dtypes, user metadata
        arrays.npz              flattened leaves keyed by tree path

Properties the 1000-node story needs:

* **Atomicity** — readers only ever see committed (renamed) directories; a
  preempted writer leaves only a ``.tmp`` that the next run garbage-collects.
* **Async** — ``save()`` snapshots leaves to host memory synchronously (cheap)
  and writes in a background thread; ``wait()`` joins before the next save or
  exit.  Training never blocks on the filesystem.
* **Elasticity** — arrays are stored unsharded (logical content); ``restore``
  takes target shardings and ``device_put``s onto *any* mesh, so a job can
  restart on a different topology (test: save on (2,2), restore on (4,)).
  On a real multi-host pod each process would write its addressable shards
  (path scheme includes a process suffix — single-process here, noted).
* **keep_k** — older committed checkpoints are pruned after each commit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_elem_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_elem_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep_k: int = 3):
        self.directory = directory
        self.keep_k = keep_k
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        self._gc_tmp()

    # -- public ------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None, *,
             block: bool = False) -> None:
        """Snapshot ``tree`` and write asynchronously."""
        self.wait()  # one in-flight save at a time
        # synchronous host snapshot (device -> host copy); structure preserved
        flat = {k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()}
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "keys": sorted(flat.keys()),
            "treedef": str(treedef),
            "metadata": metadata or {},
            "process_count": jax.process_count(),
            "process_index": jax.process_index(),
        }
        t = threading.Thread(
            target=self._write, args=(step, flat, manifest), daemon=True
        )
        self._thread = t
        t.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(steps)

    def restore(
        self,
        step: Optional[int] = None,
        *,
        target: Any = None,
        shardings: Any = None,
    ):
        """Restore a checkpoint.

        ``target``: a pytree prototype (structure + dtypes) to restore into.
        ``shardings``: optional matching pytree of ``jax.sharding.Sharding`` —
        the elastic-restart path (any mesh shape).
        Returns (tree, metadata).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._step_dir(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}

        if target is None:
            return flat, manifest["metadata"]

        target_flat = _flatten_with_paths(target)
        missing = set(target_flat) - set(flat)
        if missing:
            raise KeyError(f"checkpoint {step} missing keys: {sorted(missing)[:5]}...")
        shard_flat = _flatten_with_paths(shardings) if shardings is not None else {}
        leaves = []
        for key in target_flat:
            arr = flat[key]
            proto = target_flat[key]
            if hasattr(proto, "dtype"):
                arr = arr.astype(proto.dtype)
            if key in shard_flat and shard_flat[key] is not None:
                leaves.append(jax.device_put(arr, shard_flat[key]))
            else:
                leaves.append(jax.device_put(arr))
        # rebuild in target structure
        treedef = jax.tree_util.tree_structure(target)
        paths = list(target_flat.keys())
        order = {k: i for i, k in enumerate(paths)}
        flat_target_leaves = [None] * len(paths)
        for i, key in enumerate(target_flat):
            flat_target_leaves[order[key]] = leaves[i]
        tree = jax.tree_util.tree_unflatten(treedef, flat_target_leaves)
        return tree, manifest["metadata"]

    # -- internals ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _write(self, step: int, flat, manifest) -> None:
        try:
            final = self._step_dir(step)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=2)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # the commit point
            self._prune()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_k] if self.keep_k else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def _gc_tmp(self) -> None:
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)
