"""Quickstart: the executor model end-to-end in five minutes.

Demonstrates the paper's core idea on three payloads:
  1. sparse solve (Ginkgo's own domain): one CG source, three executors;
  2. the LinOp hierarchy: shifted systems, matrix-free operators,
     solver-as-preconditioner, and mixed-precision iterative refinement —
     all through one ``apply`` interface;
  3. an LM forward (the framework built on the same design): one model,
     three executors, identical logits.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro import solvers, sparse
from repro.core import (
    MatrixFreeOp,
    PallasInterpretExecutor,
    ReferenceExecutor,
    ScaledIdentity,
    Sum,
    XlaExecutor,
    use_executor,
)
from repro.configs import get_smoke_config
from repro.models import lm


def sparse_demo():
    print("=== 1. Krylov solve: one algorithm, three executors ===")
    n = 128
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i:
            a[i, i - 1] = a[i - 1, i] = -1.0
    xstar = np.linspace(-1, 1, n).astype(np.float32)
    b = jnp.asarray(a @ xstar)

    # SELL-P: the paper's GPU throughput format, TPU-adapted (8-row slices)
    A = sparse.sellp_from_dense(a)
    for ex in (ReferenceExecutor(), XlaExecutor(), PallasInterpretExecutor()):
        with use_executor(ex):
            res = solvers.cg(A, b, stop=solvers.Stop(max_iters=300, reduction_factor=1e-6))
        err = float(jnp.abs(res.x - xstar).max())
        print(f"  {ex.name:40s} iters={int(res.iterations):3d} "
              f"resnorm={float(res.residual_norm):.2e} err={err:.2e}")


def linop_demo():
    print("=== 2. LinOp hierarchy: compose, refine, precondition ===")
    n = 128
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i:
            a[i, i - 1] = a[i - 1, i] = -1.0
    A = sparse.csr_from_dense(a)
    xstar = np.linspace(-1, 1, n).astype(np.float32)

    with use_executor(XlaExecutor()):
        # shifted system A + 0.5 I without touching A's storage
        sigma = 0.5
        shifted = Sum(A, ScaledIdentity(sigma, n))
        b = jnp.asarray((a + sigma * np.eye(n, dtype=np.float32)) @ xstar)
        res = solvers.cg(shifted, b, stop=solvers.Stop(max_iters=300,
                                                       reduction_factor=1e-6))
        print(f"  shifted  A+{sigma}I   iters={int(res.iterations):3d} "
              f"err={float(jnp.abs(res.x - xstar).max()):.2e}")

        # the same stencil matrix-free: no stored matrix at all
        def stencil(v):
            return 4.0 * v - jnp.pad(v[1:], (0, 1)) - jnp.pad(v[:-1], (1, 0))

        b2 = jnp.asarray(a @ xstar)
        res = solvers.cg(MatrixFreeOp(stencil, shape=(n, n), dtype=jnp.float32),
                         b2, stop=solvers.Stop(max_iters=300,
                                               reduction_factor=1e-6))
        print(f"  matrix-free       iters={int(res.iterations):3d} "
              f"err={float(jnp.abs(res.x - xstar).max()):.2e}")

        # a generated solver IS a LinOp: GMRES preconditions CG (a
        # tolerance-stopped inner solve is a variable preconditioner — on
        # ill-conditioned systems use fcg as the outer method instead)
        inner = solvers.GmresSolver(
            A, restart=8, stop=solvers.Stop(max_iters=8, reduction_factor=1e-2))
        res = solvers.cg(A, b2, M=inner,
                         stop=solvers.Stop(max_iters=100, reduction_factor=1e-6))
        print(f"  cg + gmres inner  iters={int(res.iterations):3d} "
              f"err={float(jnp.abs(res.x - xstar).max()):.2e}")

        # mixed-precision IR: f32 inner CG under an f64 outer residual
        from jax import experimental as jax_experimental

        with jax_experimental.enable_x64(True):
            A64 = sparse.csr_from_dense(a.astype(np.float64))
            b64 = jnp.asarray(a.astype(np.float64) @ np.linspace(-1, 1, n))
            res = solvers.mixed_precision_ir(
                A64, b64, stop=solvers.Stop(max_iters=50,
                                            reduction_factor=1e-12))
            print(f"  mixed-prec IR     sweeps={int(res.iterations):2d} "
                  f"resnorm={float(res.residual_norm):.2e} "
                  f"(f32 inner, f64 outer)")


def lm_demo():
    print("=== 3. LM forward: same model code, three executors ===")
    cfg = get_smoke_config("granite_8b")
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)), jnp.int32
    )
    outs = {}
    for ex in (ReferenceExecutor(), XlaExecutor(), PallasInterpretExecutor()):
        with use_executor(ex):
            logits, _ = lm.forward(params, cfg, tokens=tokens)
        outs[ex.name] = np.asarray(logits)
        print(f"  {ex.name:40s} logits[0,0,:3] = {np.asarray(logits)[0,0,:3]}")
    names = list(outs)
    spread = max(
        np.abs(outs[a] - outs[names[0]]).max() for a in names[1:]
    )
    print(f"  max cross-executor deviation: {spread:.2e}")


if __name__ == "__main__":
    sparse_demo()
    linop_demo()
    lm_demo()
