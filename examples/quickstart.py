"""Quickstart: the executor model end-to-end in five minutes.

Demonstrates the paper's core idea on both payloads:
  1. sparse solve (Ginkgo's own domain): one CG source, three executors;
  2. an LM forward (the framework built on the same design): one model,
     three executors, identical logits.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro import solvers, sparse
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    use_executor,
)
from repro.configs import get_smoke_config
from repro.models import lm


def sparse_demo():
    print("=== 1. Krylov solve: one algorithm, three executors ===")
    n = 128
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i:
            a[i, i - 1] = a[i - 1, i] = -1.0
    xstar = np.linspace(-1, 1, n).astype(np.float32)
    b = jnp.asarray(a @ xstar)

    # SELL-P: the paper's GPU throughput format, TPU-adapted (8-row slices)
    A = sparse.sellp_from_dense(a)
    for ex in (ReferenceExecutor(), XlaExecutor(), PallasInterpretExecutor()):
        with use_executor(ex):
            res = solvers.cg(A, b, stop=solvers.Stop(max_iters=300, reduction_factor=1e-6))
        err = float(jnp.abs(res.x - xstar).max())
        print(f"  {ex.name:40s} iters={int(res.iterations):3d} "
              f"resnorm={float(res.residual_norm):.2e} err={err:.2e}")


def lm_demo():
    print("=== 2. LM forward: same model code, three executors ===")
    cfg = get_smoke_config("granite_8b")
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)), jnp.int32
    )
    outs = {}
    for ex in (ReferenceExecutor(), XlaExecutor(), PallasInterpretExecutor()):
        with use_executor(ex):
            logits, _ = lm.forward(params, cfg, tokens=tokens)
        outs[ex.name] = np.asarray(logits)
        print(f"  {ex.name:40s} logits[0,0,:3] = {np.asarray(logits)[0,0,:3]}")
    names = list(outs)
    spread = max(
        np.abs(outs[a] - outs[names[0]]).max() for a in names[1:]
    )
    print(f"  max cross-executor deviation: {spread:.2e}")


if __name__ == "__main__":
    sparse_demo()
    lm_demo()
