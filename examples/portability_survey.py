"""Portability survey (paper §6 analogue): run the SpMV format suite on every
executor and report the fraction of the bandwidth bound each achieves — the
paper's performance-portability metric, reproduced end-to-end.

Run: PYTHONPATH=src python examples/portability_survey.py
"""

import os
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_stream import run as stream_run
from benchmarks.common import matrix_suite, time_fn
from repro import sparse
from repro.core import ReferenceExecutor, XlaExecutor, use_executor

BOUND_DIVISOR = {"coo": 6.0, "csr": 4.0, "ell": 4.0, "sellp": 4.0}


def main():
    print("measuring machine bandwidth (stream)...")
    bw = stream_run(sizes=(1 << 22,))
    print(f"peak measured bandwidth: {bw/1e9:.2f} GB/s\n")

    suite = {k: v for k, v in list(matrix_suite(small=True).items())[:5]}
    rng = np.random.default_rng(0)
    print(f"{'matrix':14s} {'format':7s} {'executor':10s} "
          f"{'GFLOP/s':>9s} {'frac-of-bound':>14s}")
    for mat_name, a in suite.items():
        nnz = int((a != 0).sum())
        x = jnp.asarray(rng.normal(size=(a.shape[1],)).astype(np.float32))
        for fmt, build in (
            ("csr", sparse.csr_from_dense),
            ("ell", sparse.ell_from_dense),
            ("sellp", sparse.sellp_from_dense),
        ):
            A = build(a)
            for ex_name, ex in (("reference", ReferenceExecutor()),
                                ("xla", XlaExecutor())):
                with use_executor(ex):
                    fn = jax.jit(lambda x, A=A: sparse.apply(A, x))
                    t = time_fn(fn, x, warmup=1, repeats=3)
                gflops = 2 * nnz / t / 1e9
                bound = bw / BOUND_DIVISOR[fmt] / 1e9
                print(f"{mat_name:14s} {fmt:7s} {ex_name:10s} "
                      f"{gflops:9.3f} {gflops/bound:14.2f}")


if __name__ == "__main__":
    main()
