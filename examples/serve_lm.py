"""Serving example: batched prefill + decode across architecture families —
including the attention-free RWKV6 (recurrent state instead of KV cache) and
the hybrid Zamba2 (SSM state + shared-attention cache).

Run: PYTHONPATH=src python examples/serve_lm.py
"""

from repro.configs import get_smoke_config
from repro.launch.serve import serve

for arch in ("smollm_135m", "rwkv6_3b", "zamba2_2_7b", "musicgen_large"):
    cfg = get_smoke_config(arch)
    out = serve(cfg, batch=4, prompt_len=16, gen_len=16)
    print(f"  {arch}: generated token matrix {out.shape}\n")
