"""End-to-end training driver example: train a reduced smollm-135m on the
synthetic Markov-chain data for a few hundred steps, with checkpointing and
fault tolerance, and watch the loss approach the data's entropy floor.

This is the assignment's "train a ~100M model for a few hundred steps"
end-to-end driver, scaled to the CPU container via the smoke config; on a
real pod the same code runs the full config on a sharded mesh
(see repro.launch.train for the mesh/sharding path).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.configs import get_smoke_config
from repro.launch.train import train
from repro.runtime import PreemptionHandler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config("smollm_135m")
    handler = PreemptionHandler().install()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, losses = train(
            cfg,
            steps=args.steps,
            global_batch=args.global_batch,
            seq_len=args.seq_len,
            ckpt_dir=ckpt_dir,
            ckpt_every=100,
            preemption=handler,
        )
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
