"""Per-architecture smoke tests: reduced config, forward + one train step on
CPU, asserting output shapes and finiteness (assignment requirement f)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.optim import adamw, constant_schedule


def make_batch(cfg, rng, B=2, S=16):
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "stub_embeddings":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        )
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(rng, arch):
    cfg = get_smoke_config(arch)
    params, axes = lm.init_model(jax.random.PRNGKey(42), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)

    logits, _ = lm.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds")
    )
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    opt = adamw(constant_schedule(1e-3))
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
            params, cfg, batch
        )
        params, state, stats = opt.update(params, grads, state)
        return params, state, loss

    params2, state2, loss = step(params, state, batch)
    assert np.isfinite(float(loss))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda acc, pair: acc + float(jnp.abs(pair).max()),
        jax.tree_util.tree_map(lambda a, b: a - b, params, params2),
        0.0,
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expected = {
        "qwen2_moe_a2_7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                n_kv_heads=16, d_ff=1408, vocab=151936,
                                n_experts=60, top_k=4),
        "olmoe_1b_7b": dict(n_layers=16, d_model=2048, n_heads=16,
                            n_kv_heads=16, d_ff=1024, vocab=50304,
                            n_experts=64, top_k=8),
        "granite_8b": dict(n_layers=36, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, vocab=49152),
        "minicpm3_4b": dict(n_layers=62, d_model=2560, n_heads=40,
                            d_ff=6400, vocab=73448),
        "smollm_135m": dict(n_layers=30, d_model=576, n_heads=9,
                            n_kv_heads=3, d_ff=1536, vocab=49152),
        "yi_9b": dict(n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
                      d_ff=11008, vocab=64000),
        "rwkv6_3b": dict(n_layers=32, d_model=2560, d_ff=8960, vocab=65536),
        "musicgen_large": dict(n_layers=48, d_model=2048, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=2048),
        "zamba2_2_7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab=32000,
                            ssm_state=64),
        "pixtral_12b": dict(n_layers=40, d_model=5120, n_heads=32,
                            n_kv_heads=8, d_ff=14336, vocab=131072),
    }[arch]
    for key, val in expected.items():
        assert getattr(cfg, key) == val, (arch, key, getattr(cfg, key), val)


def test_param_counts_plausible():
    """Full-config parameter counts land near the published sizes."""
    import repro.launch.steps as steps_lib

    approx = {
        "smollm_135m": (0.13e9, 0.15e9),
        "granite_8b": (7.5e9, 8.6e9),
        "yi_9b": (8.0e9, 9.5e9),
        "pixtral_12b": (11.0e9, 13.0e9),
        "rwkv6_3b": (2.7e9, 3.5e9),
        "olmoe_1b_7b": (6.5e9, 7.5e9),
        "minicpm3_4b": (3.6e9, 4.6e9),
    }
    for arch, (lo, hi) in approx.items():
        cfg = get_config(arch)
        shapes, _ = steps_lib.model_shapes_and_axes(cfg)
        n = sum(
            s.size for s in jax.tree_util.tree_leaves(shapes)
            if jnp.issubdtype(s.dtype, jnp.floating)
        )
        assert lo <= n <= hi, (arch, n)
