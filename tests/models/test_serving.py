"""Serving-path correctness: prefill + token-by-token decode must reproduce
the full forward pass for every architecture family (KV caches, MLA latent
cache, RWKV recurrent state, Mamba conv+SSM state, zamba shared-attn cache)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import lm

FAMILIES = [
    "granite_8b",      # dense GQA
    "minicpm3_4b",     # MLA latent cache
    "qwen2_moe_a2_7b", # MoE
    "rwkv6_3b",        # attention-free recurrent
    "zamba2_2_7b",     # hybrid mamba + shared attention
    "musicgen_large",  # stub frontend + sinusoidal positions
]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_full_forward(rng, arch):
    cfg = get_smoke_config(arch)
    params, _ = lm.init_model(jax.random.PRNGKey(7), cfg)
    B, S, Sm, pre = 2, 10, 16, 6

    tokens = embeds = None
    if cfg.frontend == "stub_embeddings":
        embeds = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    else:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    full_logits, _ = lm.forward(params, cfg, tokens=tokens, embeds=embeds)
    cache = lm.init_cache(cfg, B, Sm)
    tk = tokens[:, :pre] if tokens is not None else None
    em = embeds[:, :pre] if embeds is not None else None
    pre_logits, cache = lm.prefill(params, cfg, tokens=tk, embeds=em, cache=cache)

    scale = max(np.abs(np.asarray(full_logits)).max(), 1.0)
    assert np.abs(np.asarray(pre_logits - full_logits[:, :pre])).max() / scale < 1e-4

    outs = []
    for t in range(pre, S):
        tk = tokens[:, t : t + 1] if tokens is not None else None
        em = embeds[:, t : t + 1] if embeds is not None else None
        lg, cache = lm.decode_step(
            params, cfg, tokens=tk, embeds=em, length=jnp.int32(t), cache=cache
        )
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    assert np.abs(np.asarray(dec - full_logits[:, pre:])).max() / scale < 1e-3


def test_bf16_decode_consistency(rng):
    """The bf16 production dtype keeps carry dtypes consistent end-to-end."""
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config("zamba2_2_7b"), dtype="bfloat16")
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    cache = lm.init_cache(cfg, 1, 8)
    toks = jnp.zeros((1, 1), jnp.int32)
    logits, cache = lm.decode_step(
        params, cfg, tokens=toks, length=jnp.int32(0), cache=cache
    )
    assert np.isfinite(np.asarray(logits, np.float32)).all()
