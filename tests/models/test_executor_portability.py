"""The paper's headline property: one model source, N executors, same result.

This is the LM-framework analogue of Ginkgo running the same solver on the
Reference / OpenMP / CUDA / HIP backends — here Reference / XLA / Pallas
(interpret), asserted numerically identical within fp tolerance, with dispatch
telemetry proving each executor used its own kernel space.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    use_executor,
)
from repro.models import lm

ARCHS = ["granite_8b", "rwkv6_3b", "zamba2_2_7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_same_logits_across_executors(rng, arch):
    cfg = get_smoke_config(arch)
    params, _ = lm.init_model(jax.random.PRNGKey(3), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    outs = {}
    for ex in (ReferenceExecutor(), XlaExecutor(), PallasInterpretExecutor()):
        with use_executor(ex):
            logits, _ = lm.forward(params, cfg, tokens=tokens)
        outs[ex.name] = np.asarray(logits)

    base = outs.pop("ReferenceExecutor(cpu_reference)")
    for name, got in outs.items():
        np.testing.assert_allclose(got, base, atol=5e-3, err_msg=name)


def test_pallas_executor_uses_pallas_kernels(rng):
    """Dispatch telemetry: the pallas executor's hot ops run in pallas space."""
    from repro.core import registry

    cfg = get_smoke_config("granite_8b")
    ex = PallasInterpretExecutor()
    op = registry.operation("nn_attention")
    assert op.space_used(ex) == "pallas"
    assert registry.operation("nn_rmsnorm").space_used(ex) == "pallas"
    assert registry.operation("nn_ssd_scan").space_used(ex) == "pallas"
    # ...while the xla executor stays in its own space
    assert op.space_used(XlaExecutor()) == "xla"


def test_solver_portability(rng):
    """Paper payload: the same CG source runs on all executors."""
    from repro import solvers, sparse

    n = 48
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i:
            a[i, i - 1] = a[i - 1, i] = -1.0
    xstar = rng.normal(size=n).astype(np.float32)
    b = jnp.asarray(a @ xstar)
    A_ell = sparse.ell_from_dense(a)
    stop = solvers.Stop(max_iters=200, reduction_factor=1e-6)

    sols = []
    for ex in (ReferenceExecutor(), XlaExecutor(), PallasInterpretExecutor()):
        with use_executor(ex):
            res = solvers.cg(A_ell, b, stop=stop)
        assert bool(res.converged), ex.name
        sols.append(np.asarray(res.x))
    for s in sols[1:]:
        np.testing.assert_allclose(s, sols[0], atol=1e-3)
