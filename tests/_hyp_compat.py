"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Re-exports ``given`` / ``settings`` / ``st`` from the real hypothesis when
available.  Otherwise provides a minimal emulation of the strategy surface
this suite uses (``integers``, ``sampled_from``, ``floats``): each ``@given``
test expands into a seeded, deterministic ``pytest.mark.parametrize`` sweep
(endpoints first, then uniform samples), so the property tests keep running —
with less adversarial search than real hypothesis, but far better than
skipping whole modules.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    import pytest

    _DEFAULT_EXAMPLES = 12

    class _Strategy:
        def __init__(self, sample, edges=()):
            self._sample = sample
            self._edges = tuple(edges)

        def examples(self, rnd, n):
            out = list(self._edges[:n])
            while len(out) < n:
                out.append(self._sample(rnd))
            return out

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda r: r.randint(min_value, max_value),
                edges=(min_value, max_value),
            )

        @staticmethod
        def sampled_from(items):
            items = list(items)
            return _Strategy(lambda r: r.choice(items), edges=items)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda r: r.uniform(min_value, max_value),
                edges=(min_value, max_value),
            )

    class settings:  # noqa: N801
        def __init__(self, max_examples=_DEFAULT_EXAMPLES, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._hyp_max_examples = self.max_examples
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            return None

        @staticmethod
        def load_profile(*_a, **_k):
            return None

    def given(**strats):
        keys = sorted(strats)

        def deco(fn):
            n = getattr(fn, "_hyp_max_examples", _DEFAULT_EXAMPLES)
            rnd = random.Random(zlib.crc32(fn.__name__.encode()))
            per_key = {k: strats[k].examples(rnd, n) for k in keys}
            cases, seen = [], set()
            for i in range(n):
                case = tuple(per_key[k][i] for k in keys)
                if case in seen:
                    continue
                seen.add(case)
                cases.append(case if len(keys) > 1 else case[0])
            return pytest.mark.parametrize(",".join(keys), cases)(fn)

        return deco
