import os
import sys

import numpy as np
import pytest

# make tests/_hyp_compat.py importable from nested test dirs
sys.path.insert(0, os.path.dirname(__file__))

# real hypothesis when installed, the deterministic shim otherwise — the
# shim's register_profile/load_profile are no-ops, so this is unconditional
from _hyp_compat import settings  # noqa: E402

# CI profile: small example counts, no deadline (CPU-only container)
settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
