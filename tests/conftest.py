import numpy as np
import pytest

from hypothesis import settings

# CI profile: small example counts, no deadline (CPU-only container)
settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
