"""Block-Jacobi preconditioner subsystem: correctness, adaptivity, portability.

Covers the acceptance criteria of the adaptive-precision block-Jacobi:

* the true block inverse solves an exactly block-diagonal SPD system in ONE
  CG iteration (the old diagonal-only approximation demonstrably cannot);
* adaptive storage reduces ``storage_bytes`` versus all-fp32 while CG
  iteration counts stay within 10% on the benchmark-style fixture;
* the three kernel spaces (reference / xla / pallas-interpret) agree on the
  apply to mixed-precision tolerance;
* the apply kernel family resolves geometry through the launch-configuration
  subsystem like every other family.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sparse, solvers
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    registry,
    tuning,
    use_executor,
)
from repro.core import params as hw_params
from repro.precond import (
    batch_block_jacobi,
    block_jacobi,
    invert_blocks,
    natural_blocks,
    uniform_block_ptrs,
)

STOP = solvers.Stop(max_iters=500, reduction_factor=1e-6)


def block_spd(n, bs, coupling=0.0, cond_spread=False, seed=8):
    """Block-structured SPD fixture; optionally with off-block coupling and a
    per-block conditioning spread (so adaptive selection mixes precisions)."""
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), np.float32)
    for bi, s in enumerate(range(0, n, bs)):
        blk = rng.normal(size=(bs, bs)).astype(np.float32)
        blk = blk @ blk.T + 4 * np.eye(bs, dtype=np.float32)
        if cond_spread and bi % 2 == 1:
            # stretch one direction: condition number grows ~scale^2
            scale = np.linspace(1.0, 40.0, bs).astype(np.float32)
            blk = blk * np.sqrt(scale[:, None] * scale[None, :])
        a[s : s + bs, s : s + bs] = blk
    for i in range(n - bs):
        a[i, i + bs] = a[i + bs, i] = coupling
    return a


# -----------------------------------------------------------------------------
# the correctness gap the diagonal-only predecessor had
# -----------------------------------------------------------------------------


def test_block_diagonal_system_one_cg_iteration():
    """On an exactly block-diagonal SPD system, block-Jacobi IS the inverse:
    CG must converge in a single iteration.  The scale-only (diagonal)
    approximation fails this — it needs many iterations — which is exactly
    the gap this subsystem closes."""
    n, bs = 96, 4
    a = block_spd(n, bs)
    rng = np.random.default_rng(1)
    xstar = rng.normal(size=n).astype(np.float32)
    b = (a @ xstar).astype(np.float32)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        M = solvers.block_jacobi_preconditioner(A, block_size=bs)
        res = solvers.cg(A, jnp.asarray(b), stop=STOP, M=M)
        assert bool(res.converged)
        assert int(res.iterations) == 1, (
            f"true block inverse must solve a block-diagonal system in one "
            f"iteration, took {int(res.iterations)}"
        )
        np.testing.assert_allclose(np.asarray(res.x), xstar, atol=1e-3)

        # the diagonal-only approximation (scalar Jacobi — what the old
        # implementation effectively was on non-diagonal blocks) cannot
        scalar = solvers.cg(
            A, jnp.asarray(b), stop=STOP, M=solvers.jacobi_preconditioner(A)
        )
        assert int(scalar.iterations) > 1


def test_apply_equals_exact_block_inverse():
    n, bs = 64, 8
    a = block_spd(n, bs)
    v = np.random.default_rng(2).normal(size=n).astype(np.float32)
    want = np.linalg.solve(a, v)
    for fmt in ("csr", "ell", "sellp", "coo", "dense"):
        A = (
            sparse.Dense(jnp.asarray(a))
            if fmt == "dense"
            else getattr(sparse, f"{fmt}_from_dense")(a)
        )
        M = block_jacobi(A, block_size=bs, executor=XlaExecutor())
        got = np.asarray(M(jnp.asarray(v)))
        np.testing.assert_allclose(got, want, atol=1e-4, err_msg=fmt)


def test_gauss_jordan_matches_linalg_inv():
    rng = np.random.default_rng(5)
    blocks = rng.normal(size=(20, 6, 6)).astype(np.float32)
    blocks += 4 * np.eye(6, dtype=np.float32)
    got = np.asarray(invert_blocks(jnp.asarray(blocks)))
    want = np.linalg.inv(blocks)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_gauss_jordan_pivots_zero_diagonal():
    """[[0, 1], [1, 0]] is nonsingular but has a zero diagonal — partial
    pivoting must invert it (a ridge-regularized fallback would not)."""
    b = jnp.asarray([[[0.0, 1.0], [1.0, 0.0]]], jnp.float32)
    got = np.asarray(invert_blocks(b))[0]
    np.testing.assert_allclose(got, [[0.0, 1.0], [1.0, 0.0]], atol=1e-6)


def test_singular_block_degrades_to_identity():
    """Rank-deficient blocks must fall back to identity — not a finite but
    wrong 'inverse' computed with a substituted pivot.  [[1,1],[1,1]] is the
    canonical trap: elimination finds a zero pivot mid-way."""
    eye3 = np.eye(3, dtype=np.float32)
    got = np.asarray(invert_blocks(jnp.zeros((1, 3, 3), jnp.float32)))[0]
    np.testing.assert_array_equal(got, eye3)
    rank1 = jnp.asarray([[[1.0, 1.0], [1.0, 1.0]]], jnp.float32)
    got = np.asarray(invert_blocks(rank1))[0]
    np.testing.assert_array_equal(got, np.eye(2, dtype=np.float32))
    # and a healthy block in the same batch is still inverted properly
    both = jnp.asarray(
        [[[1.0, 1.0], [1.0, 1.0]], [[2.0, 0.0], [0.0, 4.0]]], jnp.float32
    )
    got = np.asarray(invert_blocks(both))
    np.testing.assert_array_equal(got[0], np.eye(2, dtype=np.float32))
    np.testing.assert_allclose(got[1], [[0.5, 0.0], [0.0, 0.25]], atol=1e-6)


def test_natural_block_discovery():
    """Supervariable agglomeration recovers the true block partition of a
    block-diagonal sparsity pattern."""
    n, bs = 48, 4
    a = block_spd(n, bs)
    ptrs = natural_blocks(sparse.csr_from_dense(a), max_block_size=8)
    np.testing.assert_array_equal(ptrs, uniform_block_ptrs(n, bs))


def test_non_divisible_n_padded_block():
    a = block_spd(50, 5, coupling=0.1)  # 50 % 4 != 0 with bs=4
    rng = np.random.default_rng(3)
    xstar = rng.normal(size=50).astype(np.float32)
    b = (a @ xstar).astype(np.float32)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        M = solvers.block_jacobi_preconditioner(A, block_size=4)
        res = solvers.cg(A, jnp.asarray(b), stop=STOP, M=M)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), xstar, atol=1e-3)


# -----------------------------------------------------------------------------
# adaptive precision — the PR's acceptance criteria
# -----------------------------------------------------------------------------


def _bench_fixture():
    """Benchmark-style fixture: blocked SPD, weak coupling, mixed per-block
    conditioning (half the blocks are well-conditioned, half stretched)."""
    n, bs = 128, 8
    a = block_spd(n, bs, coupling=0.05, cond_spread=True)
    rng = np.random.default_rng(11)
    xstar = rng.normal(size=n).astype(np.float32)
    return a, bs, xstar, (a @ xstar).astype(np.float32)


def test_adaptive_reduces_storage_within_iteration_budget():
    """Acceptance: adaptive block-Jacobi stores strictly fewer bytes than
    all-fp32 while CG takes no more than 10% extra iterations."""
    a, bs, xstar, b = _bench_fixture()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        full = solvers.block_jacobi_preconditioner(A, block_size=bs)
        adap = solvers.block_jacobi_preconditioner(A, block_size=bs, adaptive=True)
        assert adap.storage_bytes < full.storage_bytes, (
            f"adaptive {adap.storage_bytes}B must undercut fp32 "
            f"{full.storage_bytes}B ({adap.precision_counts})"
        )
        r_full = solvers.cg(A, jnp.asarray(b), stop=STOP, M=full)
        r_adap = solvers.cg(A, jnp.asarray(b), stop=STOP, M=adap)
    assert bool(r_full.converged) and bool(r_adap.converged)
    k_full, k_adap = int(r_full.iterations), int(r_adap.iterations)
    assert k_adap <= int(np.ceil(1.10 * k_full)), (
        f"adaptive CG took {k_adap} iterations vs fp32's {k_full} "
        f"(>10% regression); classes: {adap.precision_counts}"
    )
    np.testing.assert_allclose(np.asarray(r_adap.x), xstar, atol=2e-3)


def test_adaptive_selects_mixed_classes():
    """The conditioning spread must produce a genuine precision mix — an
    all-or-nothing selection would mean the rule is degenerate."""
    a, bs, _, _ = _bench_fixture()
    A = sparse.csr_from_dense(a)
    M = block_jacobi(A, block_size=bs, adaptive=True, executor=XlaExecutor())
    dtypes = dict(M.precision_counts)
    assert len(dtypes) >= 2, f"expected a precision mix, got {dtypes}"
    assert sum(dtypes.values()) == M.num_blocks


def test_adaptive_spaces_agree_mixed_precision():
    """Acceptance: reference / xla / pallas-interpret agree on the adaptive
    apply to mixed-precision tolerance."""
    a, bs, _, _ = _bench_fixture()
    A = sparse.csr_from_dense(a)
    v = jnp.asarray(np.random.default_rng(7).normal(size=a.shape[0]).astype(np.float32))
    outs = {}
    for cls in (ReferenceExecutor, XlaExecutor, PallasInterpretExecutor):
        ex = cls()
        M = block_jacobi(A, block_size=bs, adaptive=True, executor=ex)
        outs[cls.__name__] = np.asarray(M(v))
        # the dispatch layer must have served the apply op
        assert ex.dispatch_log["block_jacobi_apply"] > 0
    ref = outs.pop("ReferenceExecutor")
    for name, got in outs.items():
        # fp16 storage bounds the element error at ~2^-11 * |y|
        np.testing.assert_allclose(got, ref, atol=5e-3, err_msg=name)


def test_forced_storage_dtype():
    a, bs, _, _ = _bench_fixture()
    A = sparse.csr_from_dense(a)
    M = block_jacobi(A, block_size=bs, adaptive="bfloat16", executor=XlaExecutor())
    assert M.storage_dtypes == ("bfloat16",)
    assert M.storage_bytes == M.num_blocks * bs * bs * 2


def test_bs1_matches_scalar_jacobi():
    rng = np.random.default_rng(9)
    a = block_spd(48, 4, coupling=0.1)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        m1 = solvers.jacobi_preconditioner(A)
        m2 = solvers.block_jacobi_preconditioner(A, block_size=1)
        v = jnp.asarray(rng.normal(size=48).astype(np.float32))
        np.testing.assert_allclose(np.asarray(m1(v)), np.asarray(m2(v)), rtol=1e-5)


def test_scalar_jacobi_adaptive_storage():
    a = block_spd(64, 4)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        full = solvers.jacobi_preconditioner(A)
        adap = solvers.jacobi_preconditioner(A, adaptive=True)
        v = jnp.asarray(np.random.default_rng(0).normal(size=64).astype(np.float32))
        assert adap.storage_bytes < full.storage_bytes
        got, want = np.asarray(adap(v)), np.asarray(full(v))
        assert got.dtype == np.float32  # arithmetic precision is preserved
        np.testing.assert_allclose(got, want, atol=2e-3)


# -----------------------------------------------------------------------------
# batched variant
# -----------------------------------------------------------------------------


def test_batch_block_jacobi_matches_single_loop():
    from repro import batch as batch_lib

    ns, n, bs = 5, 40, 4
    stack = np.stack([block_spd(n, bs, seed=20 + i) for i in range(ns)])
    A = batch_lib.batch_csr_from_dense(stack)
    V = np.random.default_rng(4).normal(size=(ns, n)).astype(np.float32)
    M = batch_block_jacobi(A, block_size=bs, executor=XlaExecutor())
    got = np.asarray(M(jnp.asarray(V)))
    want = np.stack([np.linalg.solve(stack[i], V[i]) for i in range(ns)])
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_batch_adaptive_reduces_storage_and_converges():
    from repro import batch as batch_lib
    from repro.batch import batch_cg

    ns, n, bs = 6, 48, 4
    stack = np.stack(
        [block_spd(n, bs, coupling=0.05, seed=30 + i) for i in range(ns)]
    )
    A = batch_lib.batch_ell_from_dense(stack)
    rng = np.random.default_rng(5)
    Xstar = rng.normal(size=(ns, n)).astype(np.float32)
    B = jnp.asarray(np.einsum("sij,sj->si", stack, Xstar))
    full = batch_block_jacobi(A, block_size=bs, executor=XlaExecutor())
    adap = batch_block_jacobi(A, block_size=bs, adaptive=True, executor=XlaExecutor())
    assert adap.storage_bytes < full.storage_bytes
    r_full = batch_cg(A, B, stop=STOP, M=full)
    r_adap = batch_cg(
        A, B, stop=STOP, M="block_jacobi",
        precond_opts={"block_size": bs, "adaptive": True},
    )
    assert bool(r_full.converged.all()) and bool(r_adap.converged.all())
    k_full = np.asarray(r_full.iterations)
    k_adap = np.asarray(r_adap.iterations)
    assert (k_adap <= np.ceil(1.10 * k_full) + 1).all(), (k_full, k_adap)
    np.testing.assert_allclose(np.asarray(r_adap.x), Xstar, atol=2e-3)


def test_batch_empty_row_matches_formats_and_single():
    """A system with a structurally empty row: BatchEll's q==0 padding slot is
    indistinguishable from a real col-0 entry, so the empty-row identity
    fallback must act on gathered *values* — BatchCsr, BatchEll, and the
    single-system path all have to agree (only the empty row degrades, not
    its whole block)."""
    from repro import batch as batch_lib

    n, bs = 8, 4
    a = block_spd(n, bs, seed=40)
    a[0, :] = 0.0
    a[:, 0] = 0.0
    stack = a[None]  # one system is enough
    V = np.random.default_rng(1).normal(size=(1, n)).astype(np.float32)
    want = np.asarray(
        block_jacobi(
            sparse.csr_from_dense(a), block_size=bs, executor=XlaExecutor()
        )(jnp.asarray(V[0]))
    )
    for builder in ("batch_csr_from_dense", "batch_ell_from_dense"):
        A = getattr(batch_lib, builder)(stack)
        M = batch_block_jacobi(A, block_size=bs, executor=XlaExecutor())
        got = np.asarray(M(jnp.asarray(V)))[0]
        np.testing.assert_allclose(got, want, atol=1e-5, err_msg=builder)


# -----------------------------------------------------------------------------
# launch-configuration plumbing
# -----------------------------------------------------------------------------


def test_block_jacobi_uses_launch_config():
    shapes = {"nb": 64, "bs": 8, "itemsize": 4}
    ex = PallasInterpretExecutor()
    base = ex.launch_config("block_jacobi", shapes)
    assert set(base.block) == {"block_nb"}
    try:
        tuning.set_table_entry("block_jacobi", ex.hw.name, {"block_nb": 16})
        pinned = ex.launch_config("block_jacobi", shapes)
        assert pinned["block_nb"] == 16
    finally:
        tuning._TABLE.pop(("block_jacobi", ex.hw.name), None)


def test_block_jacobi_vmem_fallback():
    """A starved target still serves the apply (portable formulation inside
    the pallas binding) and matches the oracle."""
    rng = np.random.default_rng(6)
    inv = jnp.asarray(rng.normal(size=(16, 8, 8)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    starved = dataclasses.replace(hw_params.CPU_INTERPRET, vmem_limit_bytes=64)
    op = registry.operation("block_jacobi_apply")
    got = op(inv, vp, executor=PallasInterpretExecutor(starved))
    want = op(inv, vp, executor=ReferenceExecutor())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
