"""Smoothed-aggregation AMG: hierarchy construction, cycles, solver seam.

Pins the setup pipeline (strength → aggregation → smoothed P → Galerkin
R·A·P via the registered SpGEMM family), the V/W-cycle as a convergent
preconditioner, the ``M="amg"`` string seam into every Krylov solver, and the
serve-path pattern/values split (:func:`amg_serve_pattern` /
:func:`amg_serve_factors` / :func:`batch_amg_apply`).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sparse
from repro.core import make_executor
from repro.precond import Multigrid, amg_preconditioner, make_preconditioner
from repro.precond.amg import (
    aggregate,
    amg_serve_factors,
    amg_serve_pattern,
    batch_amg_apply,
    strength_mask,
    tentative_prolongator,
)
from repro.solvers.common import Stop
from repro.solvers.krylov import (
    CgSolver,
    FcgSolver,
    bicgstab,
    cg,
    cgs,
    fcg,
    gmres,
)
from repro.sparse import csr_from_arrays
from repro.sparse.gallery import anisotropic_2d, poisson_2d


def _poisson(n_side=16):
    indptr, indices, values, shape = poisson_2d(n_side)
    return csr_from_arrays(indptr, indices, values, shape)


def _dense(C):
    return np.asarray(sparse.to_dense(C, executor=make_executor("reference")))


# =============================================================================
# setup pipeline
# =============================================================================


def test_strength_mask_drops_weak_direction():
    indptr, indices, values, shape = anisotropic_2d(8, 0.001)
    strong = strength_mask(indptr, indices, values, theta=0.08)
    n = shape[0]
    rows = np.repeat(np.arange(n), np.diff(indptr))
    # x-neighbours (|i-j| == 1) carry the unit coupling — all strong;
    # y-neighbours (|i-j| == 8) carry the ε coupling — all weak
    off = np.abs(rows - indices)
    assert strong[off == 1].all()
    assert not strong[off == 8].any()


def test_aggregate_covers_every_row():
    A = _poisson(12)
    indptr, indices = np.asarray(A.indptr), np.asarray(A.indices)
    values = np.asarray(A.values)
    strong = strength_mask(indptr, indices, values)
    agg, n_agg = aggregate(indptr, indices, strong, A.shape[0])
    assert agg.min() >= 0 and agg.max() == n_agg - 1
    assert n_agg < A.shape[0]  # actually coarsens
    # every aggregate id in range is used
    assert np.unique(agg).size == n_agg


def test_tentative_prolongator_partition_of_unity():
    agg = np.array([0, 0, 1, 2, 1])
    T = tentative_prolongator(agg, 3)
    d = _dense(T)
    assert d.shape == (5, 3)
    np.testing.assert_array_equal(d.sum(axis=1), np.ones(5))
    np.testing.assert_array_equal(np.argmax(d, axis=1), agg)


def test_galerkin_matches_dense_triple_product():
    A = _poisson(10)
    M = Multigrid(A, max_levels=1, coarse_size=8)
    L = M.levels[0]
    a, p, r = _dense(L.A), _dense(L.P), _dense(L.R)
    np.testing.assert_allclose(r, p.T, atol=1e-6)
    np.testing.assert_allclose(
        _dense(M.coarse_A), r @ a @ p, atol=1e-3, rtol=1e-3
    )


def test_hierarchy_coarsens_and_reports_complexity():
    A = _poisson(24)
    M = amg_preconditioner(A, coarse_size=32)
    assert M.num_levels >= 3
    rows = [L.A.shape[0] for L in M.levels] + [M.coarse_A.shape[0]]
    assert all(a > b for a, b in zip(rows, rows[1:]))
    assert rows[-1] <= 32
    assert 1.0 < M.operator_complexity < 3.0


# =============================================================================
# the cycle as a preconditioner
# =============================================================================


def test_vcycle_reduces_residual():
    A = _poisson(16)
    M = amg_preconditioner(A)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=A.shape[0]).astype(np.float32))
    x = M.apply(b)
    r = b - sparse.apply(A, x)
    assert float(jnp.linalg.norm(r)) < 0.5 * float(jnp.linalg.norm(b))


@pytest.mark.parametrize("cycle", ["v", "w"])
def test_amg_cg_cuts_iterations(cycle):
    A = _poisson(16)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.normal(size=A.shape[0]).astype(np.float32))
    stop = Stop(max_iters=1000, reduction_factor=1e-6)
    base = cg(A, b, stop=stop, M="block_jacobi")
    amg = cg(A, b, stop=stop, M="amg", precond_opts={"cycle": cycle})
    assert bool(base.converged) and bool(amg.converged)
    assert int(amg.iterations) * 3 <= int(base.iterations)


def test_wcycle_not_weaker_than_vcycle():
    A = _poisson(16)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.normal(size=A.shape[0]).astype(np.float32))
    stop = Stop(max_iters=1000, reduction_factor=1e-8)
    it_v = int(cg(A, b, stop=stop, M="amg",
                  precond_opts={"cycle": "v"}).iterations)
    it_w = int(cg(A, b, stop=stop, M="amg",
                  precond_opts={"cycle": "w"}).iterations)
    assert it_w <= it_v


@pytest.mark.parametrize("solver_fn", [cg, fcg, bicgstab, cgs, gmres])
def test_amg_string_seam_all_solvers(solver_fn):
    """``M="amg"`` resolves through make_preconditioner in every solver."""
    A = _poisson(8)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.normal(size=A.shape[0]).astype(np.float32))
    stop = Stop(max_iters=300, reduction_factor=1e-5)
    res = solver_fn(A, b, stop=stop, M="amg")
    assert bool(res.converged), solver_fn.__name__
    r = b - sparse.apply(A, res.x)
    assert float(jnp.linalg.norm(r)) <= 1e-4 * max(
        1.0, float(jnp.linalg.norm(b))
    ) * 10


def test_amg_options_via_make_preconditioner():
    A = _poisson(8)
    M = make_preconditioner(
        A, "amg", theta=0.1, cycle="w", smooth_prolongator=False,
        coarse_solver="cg", coarse_size=16,
    )
    assert isinstance(M, Multigrid)
    assert M.cycle == "w" and M._coarse_inv is None
    with pytest.raises(ValueError):
        make_preconditioner(A, "amg", cycle="x")
    with pytest.raises(TypeError):
        make_preconditioner(np.eye(4, dtype=np.float32), "amg")


def test_block_jacobi_smoother_variant():
    A = _poisson(12)
    M = amg_preconditioner(A, smoother="block_jacobi",
                           smoother_opts={"block_size": 4})
    rng = np.random.default_rng(4)
    b = jnp.asarray(rng.normal(size=A.shape[0]).astype(np.float32))
    stop = Stop(max_iters=500, reduction_factor=1e-6)
    res = cg(A, b, stop=stop, M=M)
    assert bool(res.converged)


def test_solver_as_linop_composition():
    """Inner-outer: a generated AMG-CG solver IS a LinOp, so it slots in as
    the preconditioner of an outer flexible method — Ginkgo's factory
    composability.  FCG tolerates the iteration-varying inner operator."""
    A = _poisson(8)
    inner = CgSolver(A, stop=Stop(max_iters=8, reduction_factor=1e-10),
                     M="amg")
    outer = FcgSolver(A, stop=Stop(max_iters=100, reduction_factor=1e-6),
                      M=inner)
    rng = np.random.default_rng(5)
    b = jnp.asarray(rng.normal(size=A.shape[0]).astype(np.float32))
    res = outer.solve(b)
    assert bool(res.converged)
    assert int(res.iterations) <= 5  # a near-exact inner solve ≈ one step


def test_jit_apply_traceable():
    A = _poisson(8)
    M = amg_preconditioner(A)
    b = jnp.ones(A.shape[0], jnp.float32)
    eager = M.apply(b)
    jitted = jax.jit(M.apply)(b)
    np.testing.assert_allclose(
        np.asarray(eager), np.asarray(jitted), atol=1e-5
    )


import jax  # noqa: E402


# =============================================================================
# serve-path pattern/values split
# =============================================================================


def test_serve_pattern_values_split_matches_direct():
    """Factors from the split path must equal factors computed from scratch —
    the cache-reuse correctness property."""
    A = _poisson(8)
    indptr, indices = np.asarray(A.indptr), np.asarray(A.indices)
    pat = amg_serve_pattern(indptr, indices, A.shape[0])
    assert pat.flat_len == A.shape[0] + pat.n_agg**2
    flat = amg_serve_factors(pat, A.values)
    # inv_diag segment: Poisson diagonal is 4
    np.testing.assert_allclose(
        np.asarray(flat[: A.shape[0]]), 0.25, atol=1e-6
    )
    # coarse block: A_c = Pᵀ A P with the unit tentative P over pat.agg
    a = _dense(A)
    p = np.zeros((A.shape[0], pat.n_agg), np.float32)
    p[np.arange(A.shape[0]), pat.agg] = 1.0
    c_inv = np.asarray(flat[A.shape[0]:]).reshape(pat.n_agg, pat.n_agg)
    np.testing.assert_allclose(
        np.linalg.inv(c_inv.astype(np.float64)), p.T @ a @ p,
        atol=1e-2, rtol=1e-3,
    )


def test_batch_amg_apply_rows_independent():
    """Each batch row applies its own factors — slot independence is what
    lets the serve engine freeze/swap rows without touching neighbours."""
    A = _poisson(8)
    n = A.shape[0]
    indptr, indices = np.asarray(A.indptr), np.asarray(A.indices)
    pat = amg_serve_pattern(indptr, indices, n)
    f1 = amg_serve_factors(pat, A.values)
    f2 = amg_serve_factors(pat, 2.0 * A.values)
    flat = jnp.stack([f1, f2])
    rng = np.random.default_rng(6)
    R = jnp.asarray(rng.normal(size=(2, n)).astype(np.float32))
    out = batch_amg_apply(pat, flat, R)
    solo0 = batch_amg_apply(pat, f1[None], R[:1])
    solo1 = batch_amg_apply(pat, f2[None], R[1:])
    np.testing.assert_allclose(
        np.asarray(out[0]), np.asarray(solo0[0]), atol=1e-6, rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(out[1]), np.asarray(solo1[0]), atol=1e-6, rtol=1e-6
    )
    # scaling A by 2 scales M⁻¹ by 1/2 (same input vector, scaled factors)
    half = batch_amg_apply(pat, f2[None], R[:1])
    np.testing.assert_allclose(
        np.asarray(half[0]), 0.5 * np.asarray(solo0[0]), atol=1e-5, rtol=1e-5
    )
