"""End-to-end behaviour: training learns, checkpoints resume exactly,
preemption checkpoints, serving generates — the framework as a user sees it."""

import tempfile

import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, entropy_floor
from repro.launch.train import train
from repro.launch.serve import serve
from repro.runtime import PreemptionHandler


def test_training_learns_synthetic_chain():
    """Loss must drop materially toward the synthetic chain's entropy floor."""
    cfg = get_smoke_config("smollm_135m")
    _, losses = train(cfg, steps=60, global_batch=8, seq_len=64)
    start = np.mean(losses[:5])
    end = np.mean(losses[-5:])
    floor = entropy_floor(
        DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=17)
    )
    assert end < start - 0.25, (start, end)
    assert end > floor - 0.05  # sanity: can't beat the information floor


def test_checkpoint_resume_exact():
    """A restarted run continues with identical losses (determinism across
    save/restore of params, optimizer state, and data-iterator position)."""
    cfg = get_smoke_config("smollm_135m")
    with tempfile.TemporaryDirectory() as d:
        _, losses_full = train(cfg, steps=20, global_batch=4, seq_len=32,
                               ckpt_dir=None)
        # same 20-step schedule, interrupted at step 10, then resumed
        _, losses_a = train(cfg, steps=20, global_batch=4, seq_len=32,
                            ckpt_dir=d, ckpt_every=10, stop_at_step=10)
        _, losses_b = train(cfg, steps=20, global_batch=4, seq_len=32,
                            ckpt_dir=d, ckpt_every=10, resume=True)
        np.testing.assert_allclose(losses_full[:10], losses_a, rtol=1e-5)
        np.testing.assert_allclose(
            losses_full[10:], losses_b, rtol=2e-4, atol=2e-4
        )


def test_preemption_checkpoints_and_exits():
    cfg = get_smoke_config("smollm_135m")
    handler = PreemptionHandler()
    handler.simulate()
    with tempfile.TemporaryDirectory() as d:
        train(cfg, steps=50, global_batch=4, seq_len=32, ckpt_dir=d,
              ckpt_every=1000, preemption=handler)
        from repro.checkpoint import CheckpointManager

        assert CheckpointManager(d).latest_step() == 1  # stopped at step 0


@pytest.mark.parametrize("arch", ["smollm_135m", "rwkv6_3b", "zamba2_2_7b"])
def test_serving_generates(arch):
    cfg = get_smoke_config(arch)
    out = serve(cfg, batch=2, prompt_len=8, gen_len=6)
    assert out.shape == (2, 6)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()
