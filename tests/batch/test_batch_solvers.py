"""Masked batched Krylov solvers vs a loop of single-system solves.

The acceptance gate for the batched subsystem: on a batch of >= 64 systems
whose conditioning varies across the batch, the batched solver must agree
with a loop of single-system solves — allclose solutions, exactly matching
per-system converged flags — in all three kernel spaces, with per-system
iteration counts differing across the batch (the convergence mask is doing
real work, not a fixed batch-wide iteration count).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import batch, solvers
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    use_executor,
)
import repro.kernels  # noqa: F401 — populate the pallas kernel space

NB, N = 64, 32
STOP = solvers.Stop(max_iters=100, reduction_factor=1e-5)


def spd_batch(nb=NB, n=N, nonsym=False, seed=3):
    """Shifted tridiagonal systems; the shift cycles so iteration counts vary."""
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    stack = np.zeros((nb, n, n), np.float32)
    for b in range(nb):
        a = stack[b]
        a[idx, idx] = 3.0 + 2.0 * (b % 8)
        a[idx[1:], idx[:-1]] = -1.0
        a[idx[:-1], idx[1:]] = -1.0
        if nonsym:
            a += np.triu(rng.normal(size=(n, n)).astype(np.float32) * 0.05, 1)
    xstar = rng.normal(size=(nb, n)).astype(np.float32)
    B = np.einsum("bmn,bn->bm", stack, xstar)
    return stack, xstar, B


def _singles(fn, A, B, executor):
    jfn = jax.jit(lambda A, b: fn(A, b, stop=STOP))
    return [jfn(A.system(b), jnp.asarray(B[b])) for b in range(B.shape[0])]


@pytest.mark.parametrize("exec_cls", [ReferenceExecutor, XlaExecutor,
                                      PallasInterpretExecutor])
def test_batch_cg_matches_single_solves(exec_cls):
    stack, xstar, B = spd_batch()
    A = batch.batch_ell_from_dense(stack)
    ex = exec_cls()
    with use_executor(ex):
        res = jax.jit(lambda B: batch.batch_cg(A, B, stop=STOP))(jnp.asarray(B))
        singles = _singles(solvers.cg, A, B, ex)

    conv_b = np.asarray(res.converged)
    conv_s = np.array([bool(s.converged) for s in singles])
    np.testing.assert_array_equal(conv_b, conv_s)
    assert conv_b.all()

    xs = np.stack([np.asarray(s.x) for s in singles])
    np.testing.assert_allclose(np.asarray(res.x), xs, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.x), xstar, atol=1e-3)

    iters = np.asarray(res.iterations)
    # the mask works: different systems stopped at different iterations, and
    # none kept iterating after its single-system twin converged
    assert len(np.unique(iters)) >= 4, iters
    iters_s = np.array([int(s.iterations) for s in singles])
    np.testing.assert_array_equal(iters, iters_s)


@pytest.mark.parametrize("exec_cls", [ReferenceExecutor, XlaExecutor,
                                      PallasInterpretExecutor])
def test_batch_bicgstab_matches_single_solves(exec_cls):
    stack, xstar, B = spd_batch(nonsym=True)
    A = batch.batch_ell_from_dense(stack)
    ex = exec_cls()
    with use_executor(ex):
        res = jax.jit(lambda B: batch.batch_bicgstab(A, B, stop=STOP))(
            jnp.asarray(B)
        )
        singles = _singles(solvers.bicgstab, A, B, ex)

    conv_b = np.asarray(res.converged)
    conv_s = np.array([bool(s.converged) for s in singles])
    np.testing.assert_array_equal(conv_b, conv_s)
    assert conv_b.all()
    xs = np.stack([np.asarray(s.x) for s in singles])
    np.testing.assert_allclose(np.asarray(res.x), xs, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(res.x), xstar, atol=5e-3)
    assert len(np.unique(np.asarray(res.iterations))) >= 3


def test_batch_csr_format_agrees():
    stack, xstar, B = spd_batch(nb=8)
    Ac = batch.batch_csr_from_dense(stack)
    Ae = batch.batch_ell_from_dense(stack)
    with use_executor(XlaExecutor()):
        rc = batch.batch_cg(Ac, jnp.asarray(B), stop=STOP)
        re = batch.batch_cg(Ae, jnp.asarray(B), stop=STOP)
    np.testing.assert_array_equal(
        np.asarray(rc.iterations), np.asarray(re.iterations)
    )
    np.testing.assert_allclose(np.asarray(rc.x), np.asarray(re.x), atol=1e-4)


def test_batch_jacobi_preconditioner_helps():
    """Badly scaled diagonals: per-system Jacobi cuts iterations for every
    system, and preconditioned results still match the known solutions."""
    rng = np.random.default_rng(7)
    nb, n = 16, 48
    stack, _, _ = spd_batch(nb=nb, n=n)
    d = 10.0 ** rng.uniform(-1.5, 1.5, size=(nb, n)).astype(np.float32)
    stack = stack * np.sqrt(d[:, :, None] * d[:, None, :])
    xstar = rng.normal(size=(nb, n)).astype(np.float32)
    B = np.einsum("bmn,bn->bm", stack, xstar)
    A = batch.batch_ell_from_dense(stack)
    stop = solvers.Stop(max_iters=2000, reduction_factor=1e-6)
    with use_executor(XlaExecutor()):
        plain = batch.batch_cg(A, jnp.asarray(B), stop=stop)
        M = batch.batch_jacobi_preconditioner(A)
        pre = batch.batch_cg(A, jnp.asarray(B), stop=stop, M=M)
    assert np.asarray(pre.converged).all()
    np.testing.assert_allclose(np.asarray(pre.x), xstar, rtol=1e-2, atol=1e-2)
    assert (np.asarray(pre.iterations) < np.asarray(plain.iterations)).all()


def test_frozen_systems_do_not_drift():
    """Once a system converges its state must not change while the rest of
    the batch keeps iterating (the freeze, not just the exit, is correct):
    capping the loop mid-batch leaves already-converged systems bit-identical
    to the full run."""
    stack, xstar, B = spd_batch(nb=16)
    A = batch.batch_ell_from_dense(stack)
    with use_executor(XlaExecutor()):
        full = batch.batch_cg(A, jnp.asarray(B), stop=STOP)
        iters = np.asarray(full.iterations)
        cap = int(np.median(iters))  # between min and max convergence iters
        capped = batch.batch_cg(
            A, jnp.asarray(B),
            stop=solvers.Stop(max_iters=cap, reduction_factor=1e-5),
        )
    early = np.asarray(capped.converged)
    assert early.any() and not early.all()  # the cap really splits the batch
    np.testing.assert_array_equal(
        np.asarray(capped.iterations)[early], iters[early]
    )
    np.testing.assert_allclose(
        np.asarray(capped.x)[early], np.asarray(full.x)[early],
        rtol=0, atol=1e-7,
    )


def test_max_iters_caps_every_system():
    stack, _, B = spd_batch(nb=8)
    A = batch.batch_ell_from_dense(stack)
    with use_executor(XlaExecutor()):
        res = batch.batch_cg(
            A, jnp.asarray(B),
            stop=solvers.Stop(max_iters=2, reduction_factor=1e-12),
        )
    assert (np.asarray(res.iterations) == 2).all()
    assert not np.asarray(res.converged).any()


def test_abs_tol_only_stopping():
    """The Stop fix: abs_tol-only criteria work, degenerate ones raise."""
    stack, xstar, B = spd_batch(nb=8)
    A = batch.batch_ell_from_dense(stack)
    with use_executor(XlaExecutor()):
        res = batch.batch_cg(
            A, jnp.asarray(B),
            stop=solvers.Stop(max_iters=200, reduction_factor=0.0, abs_tol=1e-3),
        )
    assert np.asarray(res.converged).all()
    assert (np.asarray(res.residual_norms) <= 1e-3).all()

    with pytest.raises(ValueError, match="degenerate stopping criterion"):
        batch.batch_cg(
            A, jnp.asarray(B),
            stop=solvers.Stop(max_iters=5, reduction_factor=0.0, abs_tol=0.0),
        )
