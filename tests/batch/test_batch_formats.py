"""Batched formats: shared-pattern fast path, union-pattern conversion,
batched SpMV vs a stack of dense matvecs — all executors."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import batch, sparse
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    use_executor,
)
import repro.kernels  # noqa: F401 — populate the pallas kernel space

EXECUTORS = [ReferenceExecutor, XlaExecutor, PallasInterpretExecutor]


def random_stack(rng, nb, m, n, density=0.2, shared=True):
    if shared:
        pattern = rng.random((m, n)) < density
        return np.where(
            pattern[None], rng.normal(size=(nb, m, n)).astype(np.float32), 0.0
        )
    stack = rng.normal(size=(nb, m, n)).astype(np.float32)
    stack[rng.random(stack.shape) < 1 - density] = 0.0
    return stack


def test_shared_pattern_fast_path(rng):
    """Identical patterns: one index array, stacked values, zero rebuilds."""
    stack = random_stack(rng, 6, 20, 20, shared=True)
    csrs = [sparse.csr_from_dense(a) for a in stack]
    A = batch.batch_csr_from_list(csrs)
    assert A.num_batch == 6
    assert A.nnz == csrs[0].nnz
    np.testing.assert_array_equal(np.asarray(A.indices), np.asarray(csrs[0].indices))
    for b in range(6):
        np.testing.assert_array_equal(np.asarray(A.values[b]), np.asarray(csrs[b].values))

    ells = [sparse.ell_from_dense(a) for a in stack]
    Ae = batch.batch_ell_from_list(ells)
    np.testing.assert_array_equal(np.asarray(Ae.col_idx), np.asarray(ells[0].col_idx))


def test_union_pattern_conversion(rng):
    """Heterogeneous patterns rebuild on the union with explicit zeros."""
    stack = random_stack(rng, 5, 18, 14, shared=False)
    A = batch.batch_csr_from_list([sparse.csr_from_dense(a) for a in stack])
    Ae = batch.batch_ell_from_list([sparse.ell_from_dense(a) for a in stack])
    X = rng.normal(size=(5, 14)).astype(np.float32)
    want = np.einsum("bmn,bn->bm", stack, X)
    with use_executor(XlaExecutor()):
        got_c = batch.apply_batch(A, jnp.asarray(X))
        got_e = batch.apply_batch(Ae, jnp.asarray(X))
    np.testing.assert_allclose(got_c, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_e, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("exec_cls", EXECUTORS)
@pytest.mark.parametrize("fmt", ["csr", "ell"])
def test_batch_spmv_all_executors(rng, exec_cls, fmt):
    stack = random_stack(rng, 7, 33, 29, shared=True)
    build = batch.batch_csr_from_dense if fmt == "csr" else batch.batch_ell_from_dense
    A = build(stack)
    X = rng.normal(size=(7, 29)).astype(np.float32)
    want = np.einsum("bmn,bn->bm", stack, X)
    with use_executor(exec_cls()):
        got = batch.apply_batch(A, jnp.asarray(X))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_system_extraction_roundtrip(rng):
    stack = random_stack(rng, 4, 12, 12, shared=False)
    A = batch.batch_csr_from_dense(stack)
    with use_executor(ReferenceExecutor()):
        for b in range(4):
            np.testing.assert_allclose(
                sparse.to_dense(A.system(b)), stack[b], atol=1e-6
            )


def test_batch_ell_from_batch_csr(rng):
    stack = random_stack(rng, 5, 16, 16, shared=True)
    Ac = batch.batch_csr_from_dense(stack)
    Ae = batch.batch_ell_from_batch_csr(Ac)
    X = rng.normal(size=(5, 16)).astype(np.float32)
    want = np.einsum("bmn,bn->bm", stack, X)
    with use_executor(XlaExecutor()):
        got = batch.apply_batch(Ae, jnp.asarray(X))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_shape_mismatch_rejected(rng):
    a = sparse.csr_from_dense(random_stack(rng, 1, 8, 8)[0])
    b = sparse.csr_from_dense(random_stack(rng, 1, 9, 8)[0])
    with pytest.raises(ValueError, match="share a shape"):
        batch.batch_csr_from_list([a, b])
    with pytest.raises(ValueError, match="empty list"):
        batch.batch_csr_from_list([])


def test_memory_accounting(rng):
    """nnz / memory_bytes on batched and single formats agree with numpy."""
    stack = random_stack(rng, 3, 10, 10, shared=True)
    A = batch.batch_csr_from_dense(stack)
    assert A.memory_bytes == (
        A.indptr.size * 4 + A.indices.size * 4 + A.values.size * 4
    )
    single = sparse.csr_from_dense(stack[0])
    assert single.memory_bytes == (
        single.indptr.size * 4 + single.indices.size * 4 + single.nnz * 4
    )
    ell = sparse.ell_from_dense(stack[0])
    assert ell.nnz == ell.values.size  # stored entries, padding included
    assert ell.memory_bytes == ell.col_idx.size * 4 + ell.values.size * 4
    sl = sparse.sellp_from_dense(stack[0])
    assert sl.memory_bytes > 0 and sl.nnz == sl.values.size
    dense = sparse.Dense(jnp.asarray(stack[0]))
    assert dense.nnz == 100 and dense.memory_bytes == 400
