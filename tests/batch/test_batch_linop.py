"""BatchLinOp: batched operator composition feeding the batched solvers."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import batch, solvers
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    use_executor,
)
import repro.kernels  # noqa: F401 — populate the pallas kernel space

NB, N = 16, 24


def spd_stack(nb=NB, n=N, seed=3):
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    stack = np.zeros((nb, n, n), np.float32)
    for b in range(nb):
        a = stack[b]
        a[idx, idx] = 3.0 + (b % 4)
        a[idx[1:], idx[:-1]] = -1.0
        a[idx[:-1], idx[1:]] = -1.0
    return stack


def test_batch_formats_are_batch_linops():
    stack = spd_stack()
    X = np.random.default_rng(0).normal(size=(NB, N)).astype(np.float32)
    want = np.einsum("bmn,bn->bm", stack, X)
    for build in (batch.batch_csr_from_dense, batch.batch_ell_from_dense):
        A = build(stack)
        assert isinstance(A, batch.BatchLinOp)
        assert A.num_batch == NB
        with use_executor(XlaExecutor()):
            np.testing.assert_allclose(
                A.apply(jnp.asarray(X)), want, rtol=1e-4, atol=1e-4
            )
            # advanced apply, batched
            got = A.apply(2.0, jnp.asarray(X), -1.0, jnp.asarray(want))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_batch_sum_and_composition():
    stack = spd_stack()
    A = batch.batch_ell_from_dense(stack)
    X = np.random.default_rng(1).normal(size=(NB, N)).astype(np.float32)
    shifted = batch.BatchSum(A, batch.BatchScaledIdentity(0.5, N))
    assert shifted.shape == (N, N)
    assert shifted.num_batch == NB
    want = np.einsum("bmn,bn->bm", stack, X) + 0.5 * X
    with use_executor(XlaExecutor()):
        np.testing.assert_allclose(
            shifted(jnp.asarray(X)), want, rtol=1e-4, atol=1e-4
        )
        comp = batch.BatchComposition(A, A)
        want2 = np.einsum("bmn,bn->bm", stack, np.einsum("bmn,bn->bm", stack, X))
        np.testing.assert_allclose(
            comp(jnp.asarray(X)), want2, rtol=1e-3, atol=1e-3
        )


@pytest.mark.parametrize(
    "exec_cls", [ReferenceExecutor, XlaExecutor, PallasInterpretExecutor]
)
def test_batch_solvers_accept_composed_operators(exec_cls):
    """batch_cg over Sum(A, sigma*I) — shifted batch without touching A."""
    stack = spd_stack()
    sigma = 1.0
    A = batch.batch_ell_from_dense(stack)
    shifted = batch.BatchSum(A, batch.BatchScaledIdentity(sigma, N))
    rng = np.random.default_rng(2)
    xstar = rng.normal(size=(NB, N)).astype(np.float32)
    dense_shifted = stack + sigma * np.eye(N, dtype=np.float32)
    B = np.einsum("bmn,bn->bm", dense_shifted, xstar)
    with use_executor(exec_cls()):
        res = batch.batch_cg(
            shifted, jnp.asarray(B),
            stop=solvers.Stop(max_iters=200, reduction_factor=1e-5),
        )
    assert bool(np.asarray(res.converged).all()), exec_cls.__name__
    np.testing.assert_allclose(np.asarray(res.x), xstar, atol=2e-3)


def test_batch_identity_preconditioner_is_linop():
    assert isinstance(batch.batch_identity_preconditioner, batch.BatchIdentity)
    assert batch.batch_identity_preconditioner.storage_bytes == 0
    V = jnp.ones((3, 4), jnp.float32)
    np.testing.assert_array_equal(batch.batch_identity_preconditioner(V), V)


def test_batch_matrix_free_op():
    stack = spd_stack()
    dense = jnp.asarray(stack)
    A = batch.BatchMatrixFreeOp(
        lambda X: jnp.einsum("bmn,bn->bm", dense, X),
        shape=(N, N), num_batch=NB,
    )
    assert A.num_batch == NB
    rng = np.random.default_rng(4)
    xstar = rng.normal(size=(NB, N)).astype(np.float32)
    B = np.einsum("bmn,bn->bm", stack, xstar)
    with use_executor(XlaExecutor()):
        res = batch.batch_cg(
            A, jnp.asarray(B),
            stop=solvers.Stop(max_iters=200, reduction_factor=1e-5),
        )
    assert bool(np.asarray(res.converged).all())
    np.testing.assert_allclose(np.asarray(res.x), xstar, atol=2e-3)


def test_batch_operator_sugar_stays_batched():
    """A1 + A2 / A1 @ A2 over batched operands build Batch* combinators."""
    stack = spd_stack()
    A1 = batch.batch_csr_from_dense(stack)
    A2 = batch.batch_csr_from_dense(stack * 2.0)
    s = A1 + A2
    assert isinstance(s, batch.BatchSum)
    assert s.num_batch == NB
    c = A1 @ A2
    assert isinstance(c, batch.BatchComposition)
    X = np.random.default_rng(5).normal(size=(NB, N)).astype(np.float32)
    with use_executor(XlaExecutor()):
        np.testing.assert_allclose(
            batch.apply_batch(s, jnp.asarray(X)),
            3.0 * np.einsum("bmn,bn->bm", stack, X), rtol=1e-4, atol=1e-4,
        )


def test_unregistered_batch_format_subclass_raises():
    """A BatchMatrixLinOp subclass missing from the dispatch table must get
    the loud TypeError, not bounce into infinite recursion."""

    class MyBatchCsr(batch.BatchCsr):
        pass

    A = batch.batch_csr_from_dense(spd_stack())
    weird = MyBatchCsr(A.indptr, A.indices, A.values, A.shape)
    with pytest.raises(TypeError, match="no batched spmv registered"):
        batch.apply_batch(weird, jnp.ones((NB, N), jnp.float32))


def test_batch_astype():
    A = batch.batch_csr_from_dense(spd_stack())
    A16 = A.astype(jnp.bfloat16)
    assert A16.dtype == jnp.bfloat16
    assert A16.indices is A.indices  # structure shared, values cast
