"""Degenerate batch shapes continuous batching hits constantly.

The serve engine's admit/retire cycle routinely produces an empty batch (all
slots drained between bursts), a single-system batch (one straggler), and a
batch where every slot is already converged at entry (a chunked advance that
landed exactly on convergence).  None of these may issue a zero-size kernel
launch or run a vacuous while_loop sweep.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import batch, solvers
from repro.core import XlaExecutor, use_executor

from test_batch_solvers import STOP, spd_batch  # same shifted-tridiag suite

SOLVERS = [batch.batch_cg, batch.batch_bicgstab]


@pytest.mark.parametrize("solve", SOLVERS)
def test_empty_batch_no_dispatches(solve):
    stack, _, _ = spd_batch(nb=2, n=8)
    A2 = batch.batch_csr_from_dense(stack)
    A = batch.BatchCsr(A2.indptr, A2.indices, A2.values[:0], A2.shape)
    B = jnp.zeros((0, 8), jnp.float32)
    ex = XlaExecutor()
    ex.dispatch_log.clear()
    with use_executor(ex):
        res = solve(A, B, stop=STOP)
    assert res.x.shape == (0, 8)
    assert res.iterations.shape == (0,)
    assert res.converged.shape == (0,)
    assert res.residual_norms.shape == (0,)
    assert dict(ex.dispatch_log) == {}, "empty batch must not launch kernels"


@pytest.mark.parametrize("solve", SOLVERS)
def test_empty_batch_still_rejects_degenerate_stop(solve):
    stack, _, _ = spd_batch(nb=1, n=8)
    A1 = batch.batch_csr_from_dense(stack)
    A = batch.BatchCsr(A1.indptr, A1.indices, A1.values[:0], A1.shape)
    bad = solvers.Stop(max_iters=10, reduction_factor=0.0, abs_tol=0.0)
    with pytest.raises(ValueError):
        solve(A, jnp.zeros((0, 8), jnp.float32), stop=bad)


@pytest.mark.parametrize("solve,single", [
    (batch.batch_cg, solvers.cg),
    (batch.batch_bicgstab, solvers.bicgstab),
])
def test_single_system_batch(solve, single):
    nonsym = solve is batch.batch_bicgstab
    stack, xstar, B = spd_batch(nb=1, n=16, nonsym=nonsym)
    A = batch.batch_csr_from_dense(stack)
    ex = XlaExecutor()
    with use_executor(ex):
        res = solve(A, jnp.asarray(B), stop=STOP)
        ref = single(A.system(0), jnp.asarray(B[0]), stop=STOP)
    assert bool(res.converged[0]) and bool(ref.converged)
    np.testing.assert_allclose(np.asarray(res.x[0]), xstar[0],
                               rtol=1e-3, atol=1e-3)
    assert int(res.iterations[0]) == int(ref.iterations)


@pytest.mark.parametrize("solve", SOLVERS)
def test_all_converged_at_entry(solve):
    """Exact X0 for every system: zero sweeps, X bitwise untouched."""
    stack, xstar, B = spd_batch(nb=4, n=12)
    A = batch.batch_csr_from_dense(stack)
    X0 = jnp.asarray(xstar)
    # B was built as A @ xstar in float32, so R = B - A X0 is exactly where
    # the solver's own residual lands — rnorm is tiny but may not be zero;
    # use a stop whose absolute tolerance clears it at entry.
    stop = solvers.Stop(max_iters=50, reduction_factor=0.0, abs_tol=1e-2)
    ex = XlaExecutor()
    with use_executor(ex):
        res = solve(A, jnp.asarray(B), X0=X0, stop=stop)
    assert bool(jnp.all(res.converged))
    assert np.array_equal(np.asarray(res.iterations), np.zeros(4, np.int32))
    # frozen-at-entry systems ride through bitwise unchanged
    assert np.array_equal(np.asarray(res.x), np.asarray(X0))


def test_init_advance_composition_is_batch_cg():
    """init + advance must reproduce batch_cg bitwise — the contract the
    continuous-batching engine builds on."""
    from repro.batch import ops as batch_ops

    stack, _, B = spd_batch(nb=8, n=16)
    A = batch.batch_csr_from_dense(stack)
    ex = XlaExecutor()
    B = jnp.asarray(B)
    with use_executor(ex):
        whole = batch.batch_cg(A, B, stop=STOP)
        thresh = STOP.threshold(batch_ops.batch_norm2(B, executor=ex))
        st = batch.batch_cg_init(A, B, jnp.zeros_like(B), executor=ex)
        # chunked advance: several small sweeps instead of one long loop
        for _ in range(25):
            st = batch.batch_cg_advance(A, st, thresh, stop=STOP,
                                        num_sweeps=4, executor=ex)
    assert np.array_equal(np.asarray(whole.x), np.asarray(st.X))
    assert np.array_equal(np.asarray(whole.iterations), np.asarray(st.iters))
    assert np.array_equal(np.asarray(whole.residual_norms),
                          np.asarray(st.rnorm))


def test_init_advance_composition_is_batch_bicgstab():
    from repro.batch import ops as batch_ops

    stack, _, B = spd_batch(nb=6, n=16, nonsym=True)
    A = batch.batch_csr_from_dense(stack)
    ex = XlaExecutor()
    B = jnp.asarray(B)
    with use_executor(ex):
        whole = batch.batch_bicgstab(A, B, stop=STOP)
        thresh = STOP.threshold(batch_ops.batch_norm2(B, executor=ex))
        st = batch.batch_bicgstab_init(A, B, jnp.zeros_like(B), executor=ex)
        for _ in range(20):
            st = batch.batch_bicgstab_advance(A, st, thresh, stop=STOP,
                                              num_sweeps=5, executor=ex)
    assert np.array_equal(np.asarray(whole.x), np.asarray(st.X))
    assert np.array_equal(np.asarray(whole.iterations), np.asarray(st.iters))
