"""Cross-executor conformance suite — the prerequisite for adding backends.

The HIP-porting testimonial (arXiv:2006.14290) names systematic
(format x operation x executor) coverage as what makes adding a backend safe:
every combination must agree with the reference space before a new target can
claim support.  This suite is that matrix for this repo:

    (Coo / Csr / Ell / Sellp / Dense) x (spmv, to_dense, BLAS-1, linop_apply)
        x (reference, xla, pallas-interpret)
    + (DistCsr / DistEll) x dist_spmv x (reference, xla)

where the ``linop_apply`` axis applies *composed* operators (``Sum``,
``Composition``, ``ScaledIdentity`` over each format) — the combinator layer
must be semantics-free in every kernel space.

over hypothesis-generated sparsity patterns (the deterministic ``_hyp_compat``
shim when hypothesis is absent).  Assertions are two-tier:

* **structure is bitwise-stable**: shapes and dtypes match the reference
  space exactly — a backend may not silently widen, pad, or promote;
* **values agree** with the reference space to f32 tolerance.

``REPRO_EXECUTOR`` restricts the executor axis (CI runs one job per backend:
``REPRO_EXECUTOR=xla`` and ``REPRO_EXECUTOR=pallas_interpret``); unset, the
full matrix runs.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro import sparse
from repro.core import Composition, ScaledIdentity, Sum, make_executor, registry
from repro.sparse import gallery
import repro.kernels  # noqa: F401 — populate the pallas kernel space

_KINDS = ("reference", "xla", "pallas_interpret")
_ENV = os.environ.get("REPRO_EXECUTOR", "").replace("-", "_")
if _ENV:
    if _ENV not in _KINDS:
        raise ValueError(
            f"REPRO_EXECUTOR={_ENV!r} is not a conformance executor; "
            f"expected one of {_KINDS}"
        )
    EXEC_KINDS = (_ENV,)
else:
    EXEC_KINDS = _KINDS

FORMATS = ("coo", "csr", "ell", "sellp", "dense")

BUILD = {
    "coo": sparse.coo_from_dense,
    "csr": sparse.csr_from_dense,
    "ell": sparse.ell_from_dense,
    "sellp": sparse.sellp_from_dense,
    "dense": lambda a: sparse.Dense(jnp.asarray(a)),
}


def _pattern(m, n, density, seed):
    """Deterministic sparse matrix for a (shape, density, seed) sample."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    mask = rng.random((m, n)) < density
    return np.where(mask, a, 0.0)


def _reference():
    return make_executor("reference")


def _assert_conforms(got, ref, *, what, atol=1e-4):
    got, ref_arr = jnp.asarray(got), jnp.asarray(ref)
    assert got.shape == ref_arr.shape, (
        f"{what}: shape {got.shape} != reference {ref_arr.shape}"
    )
    assert got.dtype == ref_arr.dtype, (
        f"{what}: dtype {got.dtype} != reference {ref_arr.dtype}"
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float64),
        np.asarray(ref_arr, np.float64),
        atol=atol,
        rtol=1e-4,
        err_msg=f"{what} diverged from the reference space",
    )


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@pytest.mark.parametrize("fmt", FORMATS)
@settings(max_examples=6)
@given(
    m=st.integers(1, 48),
    n=st.integers(1, 48),
    density=st.floats(0.02, 0.8),
    seed=st.integers(0, 10_000),
)
def test_spmv_conformance(fmt, exec_kind, m, n, density, seed):
    a = _pattern(m, n, density, seed)
    x = np.random.default_rng(seed + 1).normal(size=(n,)).astype(np.float32)
    A = BUILD[fmt](a)
    ref = sparse.apply(A, jnp.asarray(x), executor=_reference())
    got = sparse.apply(A, jnp.asarray(x), executor=make_executor(exec_kind))
    _assert_conforms(got, ref, what=f"spmv[{fmt}] on {exec_kind}", atol=1e-3)


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@pytest.mark.parametrize("fmt", FORMATS)
@settings(max_examples=4)
@given(
    m=st.integers(1, 32),
    n=st.integers(1, 32),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 10_000),
)
def test_to_dense_conformance(fmt, exec_kind, m, n, density, seed):
    a = _pattern(m, n, density, seed)
    A = BUILD[fmt](a)
    ref = sparse.to_dense(A, executor=_reference())
    got = sparse.to_dense(A, executor=make_executor(exec_kind))
    _assert_conforms(got, ref, what=f"to_dense[{fmt}] on {exec_kind}")
    # and both must reproduce the construction input exactly-ish
    np.testing.assert_allclose(np.asarray(got), a, atol=1e-6)


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@settings(max_examples=6)
@given(n=st.integers(1, 300), seed=st.integers(0, 10_000))
def test_blas1_conformance(exec_kind, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    alpha = jnp.float32(rng.normal())
    ref_ex, ex = _reference(), make_executor(exec_kind)
    for name, args in (
        ("blas_dot", (x, y)),
        ("blas_axpy", (alpha, x, y)),
        ("blas_scal", (alpha, x)),
        ("blas_norm2", (x,)),
    ):
        op = registry.operation(name)
        ref = op(*args, executor=ref_ex)
        got = op(*args, executor=ex)
        _assert_conforms(got, ref, what=f"{name} on {exec_kind}", atol=1e-4)


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@pytest.mark.parametrize("fmt", ("csr", "ell"))
@settings(max_examples=6)
@given(
    n=st.integers(1, 48),
    density=st.floats(0.05, 0.8),
    seed=st.integers(0, 10_000),
)
def test_spmv_dot_conformance(fmt, exec_kind, n, density, seed):
    """The fused SpMV+dot family joins the conformance matrix: every kernel
    space must return the same (y, w·y) pair as the reference space."""
    a = _pattern(n, n, density, seed)
    rng = np.random.default_rng(seed + 3)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    A = BUILD[fmt](a)
    op = registry.operation(f"spmv_dot_{fmt}")
    y_ref, d_ref = op(A, x, w, executor=_reference())
    y_got, d_got = op(A, x, w, executor=make_executor(exec_kind))
    _assert_conforms(y_got, y_ref, what=f"spmv_dot_{fmt}.y on {exec_kind}", atol=1e-3)
    _assert_conforms(d_got, d_ref, what=f"spmv_dot_{fmt}.dot on {exec_kind}", atol=1e-2)
    np.testing.assert_allclose(
        float(d_ref), float(np.asarray(w) @ (a @ np.asarray(x))),
        rtol=1e-3, atol=1e-2,
    )


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@settings(max_examples=6)
@given(n=st.integers(1, 300), seed=st.integers(0, 10_000))
def test_axpy_norm_conformance(exec_kind, n, seed):
    """The fused axpy+norm family: (z, ‖z‖²) must conform across spaces for
    both single vectors and batched (nb, n) operands (the batched solvers
    dispatch the same operation)."""
    rng = np.random.default_rng(seed)
    op = registry.operation("axpy_norm")
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    alpha = jnp.float32(rng.normal())
    z_ref, ss_ref = op(alpha, x, y, executor=_reference())
    z_got, ss_got = op(alpha, x, y, executor=make_executor(exec_kind))
    _assert_conforms(z_got, z_ref, what=f"axpy_norm.z on {exec_kind}", atol=1e-4)
    _assert_conforms(ss_got, ss_ref, what=f"axpy_norm.ss on {exec_kind}", atol=1e-2)
    # batched operands ride the same op
    nb = 3
    X = jnp.asarray(rng.normal(size=(nb, n)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(nb, n)).astype(np.float32))
    al = jnp.asarray(rng.normal(size=(nb,)).astype(np.float32))
    Z_ref, SS_ref = op(al, X, Y, executor=_reference())
    Z_got, SS_got = op(al, X, Y, executor=make_executor(exec_kind))
    _assert_conforms(Z_got, Z_ref, what=f"axpy_norm.batch.z on {exec_kind}", atol=1e-4)
    _assert_conforms(SS_got, SS_ref, what=f"axpy_norm.batch.ss on {exec_kind}", atol=1e-2)


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@settings(max_examples=4)
@given(
    n=st.integers(4, 64),
    bs=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 10_000),
)
def test_block_jacobi_apply_conformance(exec_kind, n, bs, seed):
    """The new kernel family joins the conformance matrix like every op."""
    rng = np.random.default_rng(seed)
    nb = -(-n // bs)
    inv = jnp.asarray(rng.normal(size=(nb, bs, bs)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(nb, bs)).astype(np.float32))
    op = registry.operation("block_jacobi_apply")
    ref = op(inv, vp, executor=_reference())
    got = op(inv, vp, executor=make_executor(exec_kind))
    _assert_conforms(got, ref, what=f"block_jacobi_apply on {exec_kind}", atol=1e-4)


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@settings(max_examples=4)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 24),
    n=st.integers(1, 24),
    density=st.floats(0.05, 0.7),
    seed=st.integers(0, 10_000),
)
def test_spgemm_conformance(exec_kind, m, k, n, density, seed):
    """Sparse-sparse composition joins the matrix.  The structure pass is
    shared host code, so indptr/indices must agree *bitwise* with the
    reference space; only the numeric pass may differ in summation order."""
    a = _pattern(m, k, density, seed)
    b = _pattern(k, n, density, seed + 1)
    A = sparse.csr_from_dense(a)
    B = sparse.csr_from_dense(b)
    ref = sparse.spgemm(A, B, executor=_reference())
    got = sparse.spgemm(A, B, executor=make_executor(exec_kind))
    assert got.shape == ref.shape
    np.testing.assert_array_equal(
        np.asarray(got.indptr), np.asarray(ref.indptr),
        err_msg=f"spgemm indptr diverged on {exec_kind}",
    )
    np.testing.assert_array_equal(
        np.asarray(got.indices), np.asarray(ref.indices),
        err_msg=f"spgemm indices diverged on {exec_kind}",
    )
    _assert_conforms(
        got.values, ref.values, what=f"spgemm.values on {exec_kind}", atol=1e-3
    )
    # and the reference evaluation must match the dense math
    np.testing.assert_allclose(
        np.asarray(sparse.to_dense(ref, executor=_reference())),
        a @ b, atol=1e-3, rtol=1e-3,
    )


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@settings(max_examples=4)
@given(
    m=st.integers(1, 32),
    n=st.integers(1, 32),
    density=st.floats(0.05, 0.8),
    seed=st.integers(0, 10_000),
)
def test_sptranspose_conformance(exec_kind, m, n, density, seed):
    a = _pattern(m, n, density, seed)
    A = sparse.csr_from_dense(a)
    ref = sparse.sptranspose(A, executor=_reference())
    got = sparse.sptranspose(A, executor=make_executor(exec_kind))
    assert got.shape == ref.shape == (n, m)
    np.testing.assert_array_equal(
        np.asarray(got.indptr), np.asarray(ref.indptr),
        err_msg=f"sptranspose indptr diverged on {exec_kind}",
    )
    np.testing.assert_array_equal(
        np.asarray(got.indices), np.asarray(ref.indices),
        err_msg=f"sptranspose indices diverged on {exec_kind}",
    )
    _assert_conforms(
        got.values, ref.values, what=f"sptranspose.values on {exec_kind}",
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(sparse.to_dense(ref, executor=_reference())),
        a.T, atol=1e-6,
    )


#: the linop_apply axis: composed-operator constructions over a square format
#: operand.  Each entry builds an operator from (A, n) and the dense ``a`` it
#: was built from, returning (linop, expected_dense).
_LINOP_CASES = {
    "sum_shift": lambda A, a, n: (
        Sum(A, ScaledIdentity(np.float32(0.75), n)),
        a + 0.75 * np.eye(n, dtype=np.float32),
    ),
    "composition": lambda A, a, n: (Composition(A, A), a @ a),
    "scaled_composition": lambda A, a, n: (
        Composition(ScaledIdentity(np.float32(-2.0), n), A),
        -2.0 * a,
    ),
    "sum_of_compositions": lambda A, a, n: (
        Sum(Composition(A, A), A, ScaledIdentity(np.float32(0.5), n)),
        a @ a + a + 0.5 * np.eye(n, dtype=np.float32),
    ),
}


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@pytest.mark.parametrize("case", sorted(_LINOP_CASES))
@pytest.mark.parametrize("fmt", FORMATS)
@settings(max_examples=4)
@given(
    n=st.integers(2, 32),
    density=st.floats(0.05, 0.8),
    seed=st.integers(0, 10_000),
)
def test_linop_apply_conformance(fmt, case, exec_kind, n, density, seed):
    """Composed operators (Sum / Composition / ScaledIdentity over each
    format) must match the reference executor — the combinator layer may not
    change semantics in any kernel space."""
    a = _pattern(n, n, density, seed)
    x = np.random.default_rng(seed + 2).normal(size=(n,)).astype(np.float32)
    A = BUILD[fmt](a)
    op, want = _LINOP_CASES[case](A, a, n)
    ref = op.apply(jnp.asarray(x), executor=_reference())
    got = op.apply(jnp.asarray(x), executor=make_executor(exec_kind))
    _assert_conforms(got, ref, what=f"linop[{case}/{fmt}] on {exec_kind}", atol=1e-3)
    # and the reference evaluation must match the dense math
    np.testing.assert_allclose(
        np.asarray(ref, np.float64), want @ x, atol=1e-2, rtol=1e-3
    )


#: the dist_spmv axis: the distributed path joins the conformance matrix on
#: the reference and xla kernel spaces (the spaces the per-shard local/halo
#: SpMV dispatches into on CPU); partition over as many parts as this process
#: has devices, capped at 2 — the per-backend conformance CI steps force a
#: 2-device host platform so a real halo exchange is pinned there, and a
#: plain single-device run still covers the P=1 degenerate.
_DIST_FORMATS = ("csr", "ell")


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@pytest.mark.parametrize("fmt", _DIST_FORMATS)
@settings(max_examples=4)
@given(
    n=st.integers(1, 40),
    density=st.floats(0.05, 0.6),
    seed=st.integers(0, 10_000),
)
def test_dist_spmv_conformance(fmt, exec_kind, n, density, seed):
    if exec_kind == "pallas_interpret":
        pytest.skip("distributed path is pinned on the reference/xla spaces")
    import jax

    from repro.distributed import DistCsr, DistEll, Partition

    a = _pattern(n, n, density, seed)
    x = np.random.default_rng(seed + 3).normal(size=(n,)).astype(np.float32)
    A = BUILD[fmt](a)
    ref = sparse.apply(A, jnp.asarray(x), executor=_reference())
    parts = min(2, len(jax.devices()), n)
    dist_cls = {"csr": DistCsr, "ell": DistEll}[fmt]
    Ad = dist_cls.from_matrix(A, Partition.uniform(n, parts))
    got = Ad.apply(jnp.asarray(x), executor=make_executor(exec_kind))
    _assert_conforms(
        got, ref, what=f"dist_spmv[{fmt}/{parts}p] on {exec_kind}", atol=1e-3
    )


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
def test_executor_reports_expected_space(exec_kind):
    """The dispatch layer must actually route to the space the matrix names —
    a conformance suite that silently tested reference three times would be
    worthless."""
    ex = make_executor(exec_kind)
    op = registry.operation("spmv_ell")
    expected = {
        "reference": "reference",
        "xla": "xla",
        "pallas_interpret": "pallas",
    }[exec_kind]
    assert op.space_used(ex) == expected


# =============================================================================
# observability conformance: every op axis must emit well-formed trace events
# =============================================================================

from repro.observability import trace as trace_mod  # noqa: E402


def _axis_spmv(ex):
    a = _pattern(12, 12, 0.4, 7)
    sparse.apply(BUILD["csr"](a), jnp.ones(12, jnp.float32), executor=ex)
    return {"spmv_csr"}


def _axis_to_dense(ex):
    sparse.to_dense(BUILD["ell"](_pattern(10, 10, 0.4, 8)), executor=ex)
    return {"sparse_to_dense"}


def _axis_blas1(ex):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=16).astype(np.float32))
    y = jnp.asarray(rng.normal(size=16).astype(np.float32))
    al = jnp.float32(0.5)
    registry.operation("blas_dot")(x, y, executor=ex)
    registry.operation("blas_axpy")(al, x, y, executor=ex)
    registry.operation("blas_scal")(al, x, executor=ex)
    registry.operation("blas_norm2")(x, executor=ex)
    return {"blas_dot", "blas_axpy", "blas_scal", "blas_norm2"}


def _axis_spmv_dot(ex):
    a = _pattern(12, 12, 0.4, 9)
    x = jnp.ones(12, jnp.float32)
    registry.operation("spmv_dot_csr")(BUILD["csr"](a), x, x, executor=ex)
    return {"spmv_dot_csr"}


def _axis_axpy_norm(ex):
    x = jnp.ones(16, jnp.float32)
    registry.operation("axpy_norm")(jnp.float32(0.5), x, x, executor=ex)
    return {"axpy_norm"}


def _axis_block_jacobi(ex):
    rng = np.random.default_rng(6)
    inv = jnp.asarray(rng.normal(size=(4, 4, 4)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))
    registry.operation("block_jacobi_apply")(inv, vp, executor=ex)
    return {"block_jacobi_apply"}


def _axis_linop_apply(ex):
    n = 12
    a = _pattern(n, n, 0.4, 11)
    A = BUILD["csr"](a)
    op, _ = _LINOP_CASES["sum_shift"](A, a, n)
    op.apply(jnp.ones(n, jnp.float32), executor=ex)
    return {"spmv_csr"}  # the composed operator dispatches its leaves


def _axis_spgemm(ex):
    a = _pattern(10, 10, 0.4, 12)
    b = _pattern(10, 10, 0.4, 13)
    sparse.spgemm(
        sparse.csr_from_dense(a), sparse.csr_from_dense(b), executor=ex
    )
    return {"spgemm"}


def _axis_sptranspose(ex):
    sparse.sptranspose(sparse.csr_from_dense(_pattern(9, 13, 0.4, 14)),
                       executor=ex)
    return {"sptranspose"}


_TRACE_AXES = {
    "spmv": _axis_spmv,
    "spgemm": _axis_spgemm,
    "sptranspose": _axis_sptranspose,
    "to_dense": _axis_to_dense,
    "blas1": _axis_blas1,
    "spmv_dot": _axis_spmv_dot,
    "axpy_norm": _axis_axpy_norm,
    "block_jacobi_apply": _axis_block_jacobi,
    "linop_apply": _axis_linop_apply,
}


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@pytest.mark.parametrize("axis", sorted(_TRACE_AXES))
def test_dispatch_trace_conformance(axis, exec_kind):
    """Every conformance op axis must emit a well-formed dispatch event on
    every executor while tracing: correct op name, the space the dispatch
    actually resolved to, operand shapes, and a schema-valid Chrome event."""
    ex = make_executor(exec_kind)
    trace_mod.reset()
    tracer = trace_mod.enable()
    try:
        ex.dispatch_log.clear()
        expected = _TRACE_AXES[axis](ex)
        events = list(ex.dispatch_log.events)
        data = tracer.to_json()
    finally:
        trace_mod.reset()

    got_ops = {e.op for e in events}
    assert expected <= got_ops, (
        f"{axis} on {exec_kind}: expected dispatch events for {expected}, "
        f"got {got_ops}"
    )
    by_op = {e.op: e for e in events}
    for e in events:
        space, _ = registry.operation(e.op).resolve(ex)
        assert e.space == space, f"{e.op}: event space {e.space} != {space}"
        assert e.executor == type(ex).__name__
        assert e.target == ex.hw.name
        assert isinstance(e.shapes, tuple)
        assert all(
            isinstance(s, tuple) and all(isinstance(d, int) for d in s)
            for s in e.shapes
        ), f"{e.op}: malformed shapes {e.shapes!r}"
        assert e.shape_bucket >= 1 and (e.shape_bucket & (e.shape_bucket - 1)) == 0
        assert e.wall_us >= 0.0 and e.est_bytes >= 0
        assert isinstance(e.to_args(), dict)
    for name in expected:
        assert by_op[name].shapes, f"{name}: no operand shapes recorded"

    # the Chrome stream carries the same dispatches and passes the CI schema
    assert trace_mod.validate_trace(data) == []
    chrome_ops = {
        ev["name"] for ev in data["traceEvents"] if ev.get("cat") == "dispatch"
    }
    assert expected <= chrome_ops


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
def test_dispatch_counts_unchanged_by_tracing(exec_kind):
    """Tracing may add events, never launches: the Counter face of the
    dispatch log must be identical with tracing on and off (the BENCH
    launch-count pins diff these counts exactly)."""
    ex = make_executor(exec_kind)
    trace_mod.reset()
    ex.dispatch_log.clear()
    _axis_spmv(ex)
    off_counts = dict(ex.dispatch_log)
    assert not ex.dispatch_log.events  # disabled tracing records no events

    trace_mod.enable()
    try:
        ex.dispatch_log.clear()
        _axis_spmv(ex)
        on_counts = dict(ex.dispatch_log)
    finally:
        trace_mod.reset()
    assert on_counts == off_counts


# -- gallery-operand axis (PR-10): realistic spectra through every space ------

_GALLERY_CASES = {
    "convdiff_upwind": lambda: gallery.convection_diffusion_2d(
        7, peclet=5.0, scheme="upwind"),
    "convdiff_centered": lambda: gallery.convection_diffusion_2d(
        7, peclet=0.5, scheme="centered"),
    "powerlaw": lambda: gallery.power_law_laplacian(50, seed=3),
}


@pytest.mark.parametrize("exec_kind", EXEC_KINDS)
@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("case", sorted(_GALLERY_CASES))
def test_gallery_spmv_conformance(case, fmt, exec_kind):
    """The nonsymmetric/irregular gallery corpus must conform exactly like
    the synthetic patterns: same structure, same values, every format x
    executor — nonsymmetry and power-law degree spreads exercise row-length
    imbalance the uniform-density samples can't."""
    indptr, indices, values, shape = _GALLERY_CASES[case]()
    a = np.zeros(shape, np.float32)
    rows = np.repeat(np.arange(shape[0]), np.diff(indptr))
    a[rows, indices] = values
    x = np.random.default_rng(5).normal(size=(shape[1],)).astype(np.float32)
    A = BUILD[fmt](a)
    ref = sparse.apply(A, jnp.asarray(x), executor=_reference())
    got = sparse.apply(A, jnp.asarray(x), executor=make_executor(exec_kind))
    _assert_conforms(
        got, ref, what=f"gallery[{case}] spmv[{fmt}] on {exec_kind}", atol=1e-3
    )
