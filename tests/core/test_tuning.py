"""Launch-configuration subsystem: tables, VMEM budget, alignment, autotune."""

import dataclasses

import pytest

from repro.core import params as hw_params
from repro.core import tuning
from repro.core.executor import PallasInterpretExecutor, XlaExecutor

# pull in every kernel family's spec registration
import repro.kernels  # noqa: F401


OPS_AND_SHAPES = {
    "nn_attention": {"S": 2048, "Skv": 2048, "D": 128, "itemsize": 2},
    "nn_attention_chunked": {"S": 2048, "Skv": 2048, "D": 128, "itemsize": 2},
    "nn_rmsnorm": {"rows": 8192, "d": 4096, "itemsize": 2},
    "nn_rwkv6_scan": {"S": 2048, "K": 64, "V": 64, "itemsize": 4},
    "nn_ssd_scan": {"S": 2048, "N": 128, "P": 64, "itemsize": 4},
    "spmv_ell": {"m": 4096, "k": 128, "n": 4096, "itemsize": 4},
    "spmv_sellp": {
        "m": 4096, "n": 4096, "slice_size": 8, "stride_factor": 8, "itemsize": 4
    },
}


@pytest.mark.parametrize("op", sorted(OPS_AND_SHAPES))
@pytest.mark.parametrize("target", sorted(hw_params.TARGETS))
def test_resolved_config_fits_vmem_and_alignment(op, target):
    """Every kernel family's resolved config respects the target's budget and
    MXU/lane alignment — for ALL hardware targets (the portability claim)."""
    hw = hw_params.get_target(target)
    cfg = tuning.resolve(op, OPS_AND_SHAPES[op], hw)
    assert cfg.op == op and cfg.target == target
    assert cfg.fits_vmem, f"{op}@{target} over budget: {cfg}"
    assert cfg.vmem_bytes <= hw.vmem_limit_bytes // tuning.VMEM_HEADROOM
    spec = tuning.get_spec(op)
    assert set(cfg.block) == set(spec.params)
    for param, value in cfg.block.items():
        assert value >= spec.floor(param), (param, cfg)
    # alignment rules per family
    if op == "nn_attention":
        assert cfg["block_q"] % hw.sublane_count == 0
        assert cfg["block_kv"] % hw.sublane_count == 0
    if op == "nn_rmsnorm":
        assert cfg["block_rows"] % hw.sublane_count == 0
    if op == "spmv_ell":
        assert cfg["block_m"] % hw.sublane_count == 0
        bk = cfg["block_k"]
        assert bk & (bk - 1) == 0  # power of two: coop butterfly stays legal
    if op == "spmv_sellp":
        assert OPS_AND_SHAPES[op]["stride_factor"] % cfg["block_cols"] == 0
    if op in ("nn_rwkv6_scan", "nn_ssd_scan"):
        c = cfg["chunk"]
        assert c & (c - 1) == 0


@pytest.mark.parametrize("op", sorted(OPS_AND_SHAPES))
def test_default_table_covers_all_targets(op):
    table = tuning.default_table()
    for target in hw_params.TARGETS:
        assert (op, target) in table


def test_vmem_shrink_never_overflows():
    """A starved target shrinks the geometry instead of overflowing."""
    tiny = dataclasses.replace(
        hw_params.CPU_INTERPRET, vmem_limit_bytes=4 * 1024 * 1024
    )
    big = hw_params.CPU_INTERPRET
    # the VMEM-resident pallas tile families; spmv is x-residency-dominated
    # (covered by the fallback test) and the chunked-xla scan is XLA-managed
    for op in ("nn_attention", "nn_rmsnorm", "nn_rwkv6_scan", "nn_ssd_scan"):
        shapes = OPS_AND_SHAPES[op]
        cfg_tiny = tuning.resolve(op, shapes, tiny)
        cfg_big = tuning.resolve(op, shapes, big)
        assert cfg_tiny.vmem_bytes <= tiny.vmem_limit_bytes // tuning.VMEM_HEADROOM
        assert sum(cfg_tiny.block.values()) <= sum(cfg_big.block.values())


def test_spmv_infeasible_reports_not_fitting():
    """When x cannot be VMEM-resident no shrink helps: fits_vmem goes False
    (the binding then falls back to the portable kernel space)."""
    tiny = dataclasses.replace(
        hw_params.CPU_INTERPRET, vmem_limit_bytes=256 * 1024
    )
    shapes = {"m": 10**6, "k": 64, "n": 10**6, "itemsize": 4}
    cfg = tuning.resolve("spmv_ell", shapes, tiny)
    assert not cfg.fits_vmem


def test_table_override_wins_over_seed():
    target = "tpu_v4"
    try:
        tuning.set_table_entry("nn_rmsnorm", target, {"block_rows": 512})
        cfg = tuning.resolve(
            "nn_rmsnorm", {"rows": 4096, "d": 1024, "itemsize": 4},
            hw_params.get_target(target),
        )
        assert cfg["block_rows"] == 512
        assert cfg.source == "table"
    finally:
        tuning._TABLE.pop(("nn_rmsnorm", target), None)


def test_autotune_cache_roundtrip(tmp_path):
    shapes = {"rows": 1000, "d": 333, "itemsize": 4}
    try:
        tuning.record_autotuned("nn_rmsnorm", "tpu_v5e", shapes, {"block_rows": 64})
        # same bucket (pow2-rounded sizes) hits the cache
        cfg = tuning.resolve(
            "nn_rmsnorm", {"rows": 1024, "d": 512, "itemsize": 4},
            hw_params.TPU_V5E,
        )
        assert cfg["block_rows"] == 64
        assert cfg.source == "autotuned"
        # a different bucket falls back to the table
        other = tuning.resolve(
            "nn_rmsnorm", {"rows": 64, "d": 64, "itemsize": 4}, hw_params.TPU_V5E
        )
        assert other.source == "table"
        # persistence roundtrip
        path = tmp_path / "tpu_v5e.json"
        n = tuning.save_table(str(path), target="tpu_v5e")
        assert n == 1
        tuning.clear_autotune_cache()
        assert tuning.load_table(str(path)) == 1
        again = tuning.resolve(
            "nn_rmsnorm", {"rows": 1024, "d": 512, "itemsize": 4},
            hw_params.TPU_V5E,
        )
        assert again.source == "autotuned" and again["block_rows"] == 64
    finally:
        tuning.clear_autotune_cache()


def test_stale_cache_entry_missing_params_is_ignored():
    """Entries from hand-edited / older-spec tables that lack the current
    spec's params must fall back to the seed, not crash the kernel call."""
    try:
        tuning.record_autotuned(
            "nn_attention", "tpu_v5e",
            {"S": 128, "Skv": 128, "D": 64, "itemsize": 4},
            {"block_q": 64},  # missing block_kv
        )
        cfg = tuning.resolve(
            "nn_attention", {"S": 128, "Skv": 128, "D": 64, "itemsize": 4},
            hw_params.TPU_V5E,
        )
        assert cfg.source.startswith("table")  # fell back to the seed
        assert set(cfg.block) == {"block_q", "block_kv"}
    finally:
        tuning.clear_autotune_cache()


def test_executor_launch_config_entry_point():
    ex = PallasInterpretExecutor()
    cfg = ex.launch_config("nn_attention", {"S": 128, "Skv": 128, "D": 64,
                                            "itemsize": 4})
    assert cfg.target == "cpu_interpret"
    assert cfg["block_q"] >= 8 and cfg["block_kv"] >= 8
    # the xla executor resolves against its own target row
    cfg_xla = XlaExecutor().launch_config(
        "nn_rwkv6_scan", {"S": 256, "K": 64, "V": 64, "itemsize": 4}
    )
    assert cfg_xla.target == "cpu_xla"


def test_unknown_op_raises():
    with pytest.raises(KeyError):
        tuning.resolve("no_such_op", {}, hw_params.CPU_XLA)


def test_bucketing_pow2():
    assert tuning.next_pow2(1) == 1
    assert tuning.next_pow2(3) == 4
    assert tuning.next_pow2(1024) == 1024
    b1 = tuning.bucket_shapes({"S": 1000, "itemsize": 4})
    b2 = tuning.bucket_shapes({"S": 1024, "itemsize": 4})
    assert b1 == b2
    assert tuning.bucket_shapes({"S": 1025, "itemsize": 4}) != b1
