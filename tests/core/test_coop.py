"""Cooperative groups: paper §4 mask arithmetic, shuffle/ballot semantics."""

import numpy as np
import jax
from jax import experimental as jax_experimental
import jax.numpy as jnp
import pytest
from _hyp_compat import given, st

from repro.core import coop


SIZES = (2, 4, 8, 16, 32)


@pytest.mark.parametrize("size", SIZES + (64, 128))
def test_reduce_matches_segment_sum(rng, size):
    a = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    got = coop.subgroup(a, size).sum()
    seg = np.asarray(a).reshape(4, 128 // size, size)
    want = np.broadcast_to(seg.sum(-1, keepdims=True), seg.shape).reshape(4, 128)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op,npop", [(jnp.maximum, np.max), (jnp.minimum, np.min)])
def test_reduce_minmax(rng, op, npop):
    a = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    got = coop.subgroup(a, 8).reduce(op)
    seg = np.asarray(a).reshape(2, 8, 8)
    want = np.broadcast_to(npop(seg, -1, keepdims=True), seg.shape).reshape(2, 64)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("size", SIZES)
def test_inclusive_scan(rng, size):
    a = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
    got = coop.subgroup(a, size).inclusive_scan()
    want = np.cumsum(np.asarray(a).reshape(3, 64 // size, size), -1).reshape(3, 64)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    bitmask=st.integers(0, 7),
    size=st.sampled_from([8, 16, 32]),
)
def test_shfl_xor_property(bitmask, size):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32))
    got = coop.subgroup(a, size).shfl_xor(bitmask)
    seg = np.asarray(a).reshape(2, 128 // size, size)
    want = seg[..., np.arange(size) ^ bitmask].reshape(2, 128)
    np.testing.assert_allclose(got, want)
    # involution: applying twice restores the input
    again = coop.subgroup(got, size).shfl_xor(bitmask)
    np.testing.assert_allclose(again, a)


def test_shfl_and_shfl_down(rng):
    a = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    sg = coop.subgroup(a, 8)
    got = sg.shfl(3)
    seg = np.asarray(a).reshape(2, 4, 8)
    want = np.broadcast_to(seg[..., 3:4], seg.shape).reshape(2, 32)
    np.testing.assert_allclose(got, want)
    got = sg.shfl_down(2)
    lane = np.arange(8)
    idx = np.where(lane + 2 >= 8, lane, lane + 2)
    np.testing.assert_allclose(got, seg[..., idx].reshape(2, 32))


@given(size=st.sampled_from([2, 4, 8, 16, 32]), seed=st.integers(0, 100))
def test_ballot_paper_semantics(size, seed):
    """(warp.ballot & Mask) >> LaneOffset — bit i set iff member i's pred."""
    rng = np.random.default_rng(seed)
    pred = rng.integers(0, 2, size=(128,)).astype(bool)
    sg = coop.subgroup(jnp.zeros((128,)), size, warp_size=32)
    b = np.asarray(sg.ballot(jnp.asarray(pred)))
    pr = pred.reshape(128 // size, size)
    for gidx in range(128 // size):
        expect = sum(int(pr[gidx, i]) << i for i in range(size))
        assert (b.reshape(128 // size, size)[gidx] == expect).all()
    got_any = np.asarray(sg.any(jnp.asarray(pred))).reshape(-1, size)[:, 0]
    got_all = np.asarray(sg.all(jnp.asarray(pred))).reshape(-1, size)[:, 0]
    got_cnt = np.asarray(sg.count(jnp.asarray(pred))).reshape(-1, size)[:, 0]
    np.testing.assert_array_equal(got_any, pr.any(1))
    np.testing.assert_array_equal(got_all, pr.all(1))
    np.testing.assert_array_equal(got_cnt, pr.sum(1))


def test_ballot_wavefront64_needs_x64():
    sg = coop.subgroup(jnp.zeros((128,)), 64, warp_size=64)
    if not jax.config.jax_enable_x64:
        with pytest.raises(ValueError, match="uint64"):
            sg.ballot(jnp.ones((128,), bool))


def test_ballot_wavefront64_under_x64():
    with jax_experimental.enable_x64(True):
        pred = jnp.asarray(np.tile(np.arange(64) % 3 == 0, 2))
        sg = coop.subgroup(jnp.zeros((128,)), 8, warp_size=64)
        cnt = np.asarray(sg.count(pred)).reshape(16, 8)[:, 0]
        want = np.tile((np.arange(64) % 3 == 0).reshape(8, 8).sum(1), 2)
        np.testing.assert_array_equal(cnt, want)


def test_popcnt_overloads():
    x32 = jnp.asarray([0, 1, 3, 255], jnp.uint32)
    np.testing.assert_array_equal(coop.popcnt(x32), [0, 1, 2, 8])
    with pytest.raises(TypeError):
        coop.popcnt(jnp.zeros(3, jnp.float32))


def test_thread_rank():
    sg = coop.subgroup(jnp.zeros((2, 32)), 8)
    ranks = np.asarray(sg.thread_rank())
    assert (ranks == np.tile(np.arange(8), 4)).all()


def test_subgroup_size_validation():
    with pytest.raises(ValueError):
        coop.subgroup(jnp.zeros((32,)), 3)  # not a power of two
    with pytest.raises(ValueError):
        coop.subgroup(jnp.zeros((31,)), 8).sum()  # not divisible
