"""Executor model: dispatch, fallback chains, strict mode — the paper's §3."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    NotCompiledError,
    PallasInterpretExecutor,
    PallasTpuExecutor,
    ReferenceExecutor,
    XlaExecutor,
    instantiate_common,
    make_executor,
    operation,
    use_executor,
)


@pytest.fixture(scope="module")
def demo_op():
    op = operation("test_demo_op")

    @op.register("reference")
    def _ref(ex, x):
        return x + 1.0

    @op.register("xla")
    def _xla(ex, x):
        return x + 1.0

    return op


def test_dispatch_per_space(demo_op):
    x = jnp.zeros(3)
    assert demo_op.space_used(ReferenceExecutor()) == "reference"
    assert demo_op.space_used(XlaExecutor()) == "xla"
    np.testing.assert_allclose(demo_op(x, executor=XlaExecutor()), 1.0)


def test_fallback_chain(demo_op):
    # pallas executor has no pallas kernel for this op -> falls to xla
    assert demo_op.space_used(PallasInterpretExecutor()) == "xla"


def test_strict_raises_notcompiled(demo_op):
    # Ginkgo's gko::NotCompiled semantics
    ex = PallasTpuExecutor(strict=True)
    with pytest.raises(NotCompiledError):
        demo_op.space_used(ex)
    with pytest.raises(NotCompiledError):
        demo_op(jnp.zeros(3), executor=ex)


def test_ambient_executor(demo_op):
    ex = ReferenceExecutor()
    with use_executor(ex):
        demo_op(jnp.zeros(2))
    assert ex.dispatch_log["test_demo_op"] == 1


def test_dispatch_telemetry(demo_op):
    ex = XlaExecutor()
    for _ in range(3):
        demo_op(jnp.zeros(2), executor=ex)
    assert ex.dispatch_log["test_demo_op"] == 3


def test_master_executor():
    # paper: every device executor carries a CPU-side master
    ex = PallasInterpretExecutor()
    assert isinstance(ex.master, ReferenceExecutor)
    assert ex.master.master is ex.master


def test_make_executor_factory():
    for kind in ("reference", "xla", "pallas", "pallas_interpret"):
        ex = make_executor(kind)
        assert ex.kernel_space in ("reference", "xla", "pallas")
    with pytest.raises(KeyError):
        make_executor("cuda")


def test_instantiate_common():
    # the "common/ folder" analogue: one skeleton, per-space parameters
    def skeleton(ex, x, *, block):
        return x * block

    op = instantiate_common(
        "test_common_skel", skeleton, {"reference": {"block": 2}, "xla": {"block": 3}}
    )
    assert float(op(jnp.ones(()), executor=ReferenceExecutor())) == 2.0
    assert float(op(jnp.ones(()), executor=XlaExecutor())) == 3.0


def test_duplicate_registration_rejected(demo_op):
    with pytest.raises(ValueError):
        demo_op.register("reference")(lambda ex, x: x)


# -- PR: launch-config subsystem satellites -----------------------------------


def test_make_executor_accepts_target_names():
    from repro.core import params as hw_params

    ex = make_executor("tpu_v4")
    assert isinstance(ex, PallasTpuExecutor)
    assert ex.hw is hw_params.TPU_V4
    ex2 = make_executor("cpu_interpret")
    assert isinstance(ex2, PallasInterpretExecutor)
    assert ex2.interpret
    ex3 = make_executor("cpu_xla")
    assert isinstance(ex3, XlaExecutor)
    ex4 = make_executor("cpu_reference")
    assert isinstance(ex4, ReferenceExecutor)


def test_reset_default_executor():
    from repro.core import default_executor, reset_default_executor

    reset_default_executor()
    first = default_executor()
    assert default_executor() is first  # cached
    reset_default_executor()
    second = default_executor()
    assert second is not first  # cache actually dropped
    assert type(second) is type(first)


KERNEL_OPS = (
    "nn_attention",
    "nn_rmsnorm",
    "nn_rwkv6_scan",
    "nn_ssd_scan",
    "spmv_ell",
    "spmv_sellp",
)


@pytest.mark.parametrize("op_name", KERNEL_OPS)
def test_each_registered_op_serves_expected_space(op_name):
    """Dispatch telemetry: every kernel family serves each executor from the
    expected kernel space (paper: executor picks the backend, not the op)."""
    import repro.kernels  # noqa: F401

    op = operation(op_name)
    assert op.space_used(ReferenceExecutor()) == "reference"
    assert op.space_used(PallasInterpretExecutor()) == "pallas"
    # xla executors fall back to reference only when no xla impl exists
    expected_xla = "xla" if "xla" in op._impls else "reference"
    assert op.space_used(XlaExecutor()) == expected_xla


@pytest.mark.parametrize("op_name", ("spmv_coo", "spmv_csr", "blas_dot"))
def test_strict_mode_raises_for_missing_pallas_kernels(op_name):
    """strict=True refuses the fallback chain: ops without a pallas kernel
    raise NotCompiledError on a strict pallas executor (gko::NotCompiled)."""
    import repro.sparse.ops  # noqa: F401 — populate the operations

    ex = PallasTpuExecutor(strict=True)
    with pytest.raises(NotCompiledError):
        operation(op_name).space_used(ex)


def test_dispatch_log_counts_model_ops(rng):
    import numpy as np
    import repro.kernels  # noqa: F401

    ex = PallasInterpretExecutor()
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    op = operation("nn_rmsnorm")
    op(x, w, executor=ex)
    op(x, w, executor=ex)
    assert ex.dispatch_log["nn_rmsnorm"] == 2
