"""Executor model: dispatch, fallback chains, strict mode — the paper's §3."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    NotCompiledError,
    PallasInterpretExecutor,
    PallasTpuExecutor,
    ReferenceExecutor,
    XlaExecutor,
    instantiate_common,
    make_executor,
    operation,
    use_executor,
)


@pytest.fixture(scope="module")
def demo_op():
    op = operation("test_demo_op")

    @op.register("reference")
    def _ref(ex, x):
        return x + 1.0

    @op.register("xla")
    def _xla(ex, x):
        return x + 1.0

    return op


def test_dispatch_per_space(demo_op):
    x = jnp.zeros(3)
    assert demo_op.space_used(ReferenceExecutor()) == "reference"
    assert demo_op.space_used(XlaExecutor()) == "xla"
    np.testing.assert_allclose(demo_op(x, executor=XlaExecutor()), 1.0)


def test_fallback_chain(demo_op):
    # pallas executor has no pallas kernel for this op -> falls to xla
    assert demo_op.space_used(PallasInterpretExecutor()) == "xla"


def test_strict_raises_notcompiled(demo_op):
    # Ginkgo's gko::NotCompiled semantics
    ex = PallasTpuExecutor(strict=True)
    with pytest.raises(NotCompiledError):
        demo_op.space_used(ex)
    with pytest.raises(NotCompiledError):
        demo_op(jnp.zeros(3), executor=ex)


def test_ambient_executor(demo_op):
    ex = ReferenceExecutor()
    with use_executor(ex):
        demo_op(jnp.zeros(2))
    assert ex.dispatch_log["test_demo_op"] == 1


def test_dispatch_telemetry(demo_op):
    ex = XlaExecutor()
    for _ in range(3):
        demo_op(jnp.zeros(2), executor=ex)
    assert ex.dispatch_log["test_demo_op"] == 3


def test_master_executor():
    # paper: every device executor carries a CPU-side master
    ex = PallasInterpretExecutor()
    assert isinstance(ex.master, ReferenceExecutor)
    assert ex.master.master is ex.master


def test_make_executor_factory():
    for kind in ("reference", "xla", "pallas", "pallas_interpret"):
        ex = make_executor(kind)
        assert ex.kernel_space in ("reference", "xla", "pallas")
    with pytest.raises(KeyError):
        make_executor("cuda")


def test_instantiate_common():
    # the "common/ folder" analogue: one skeleton, per-space parameters
    def skeleton(ex, x, *, block):
        return x * block

    op = instantiate_common(
        "test_common_skel", skeleton, {"reference": {"block": 2}, "xla": {"block": 3}}
    )
    assert float(op(jnp.ones(()), executor=ReferenceExecutor())) == 2.0
    assert float(op(jnp.ones(()), executor=XlaExecutor())) == 3.0


def test_duplicate_registration_rejected(demo_op):
    with pytest.raises(ValueError):
        demo_op.register("reference")(lambda ex, x: x)
