"""Cost model: exact scan trip counts (the thing XLA's analysis gets wrong)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.launch import costmodel
from repro.launch.costmodel import function_cost


def test_scan_trip_counts_exact():
    d = 128
    w = jnp.ones((d, d), jnp.float32)
    x = jnp.ones((8, d), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None

        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    c = function_cost(scanned, x, w)
    want = 10 * 2 * 8 * d * d
    np.testing.assert_allclose(c["flops"], want, rtol=0.01)


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY the jaxpr walker exists: XLA counts the body once."""
    d = 128
    w = jnp.ones((d, d), jnp.float32)
    x = jnp.ones((8, d), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None

        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    compiled = jax.jit(scanned).lower(x, w).compile()
    hlo_flops = costmodel.hlo_cost_analysis(compiled).get("flops", 0.0)
    one_body = 2 * 8 * d * d
    assert hlo_flops < 2 * one_body  # ~1x body, not 10x


def test_dot_flops_batched():
    a = jnp.ones((4, 16, 32), jnp.float32)
    b = jnp.ones((4, 32, 8), jnp.float32)
    c = function_cost(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
    np.testing.assert_allclose(c["flops"], 2 * 4 * 16 * 32 * 8, rtol=0.01)


def test_remat_grad_counts_recompute():
    d = 64
    w = jnp.ones((d, d), jnp.float32)
    x = jnp.ones((4, d), jnp.float32)

    def loss(w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        c, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=6)
        return jnp.sum(c)

    c_fwd = function_cost(lambda w: loss(w), w)
    c_grad = function_cost(jax.grad(loss), w)
    # grad with remat ~ fwd + recompute-fwd + 2x bwd matmuls >= 3x fwd dots
    assert c_grad["flops"] > 2.5 * c_fwd["flops"]


def test_fused_bytes_leq_unfused():
    x = jnp.ones((128, 128), jnp.float32)

    def f(x):
        return jnp.sum(jnp.tanh(x * 2.0 + 1.0))

    c = function_cost(f, x)
    assert c["fused_bytes"] <= c["bytes"]
    assert c["fused_bytes"] > 0


def test_collective_census_parser():
    from repro.launch.dryrun import collective_census, _shape_bytes

    hlo = """
      %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups=...
      %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
      %rs = (f32[16,16]{1,0}, f32[16,16]{1,0}) reduce-scatter(%a, %b)
      %cp = bf16[4,4]{1,0} collective-permute-start(%z)
      %dot = f32[8,8]{1,0} dot(%p, %q)
    """
    census = collective_census(hlo)
    assert census["all-gather"]["bytes"] == 8 * 128 * 2
    assert census["all-reduce"]["bytes"] == 1024 * 4
    assert census["reduce-scatter"]["bytes"] == 2 * 16 * 16 * 4
    assert census["collective-permute"]["bytes"] == 4 * 4 * 2
    assert "dot" not in census
