"""Distributed convergence telemetry: the 8-shard history acceptance case.

Under ``dist_solve`` the solver source records the *psum'd global* residual
norms — every shard holds an identical copy — so the history surfaced on the
distributed :class:`SolveResult` must match the single-device run sample for
sample, and its last entry must equal the final residual, exactly as on one
device.  An env-guard twin runs in-process when the parent already has 8
devices; the spawn twin keeps the acceptance case alive in single-device
parents (same pattern as test_multidevice).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import sparse
from repro.core import make_executor
from repro.distributed import DistCsr, Partition
from repro.observability import convergence
from repro.solvers import krylov
from repro.solvers.common import Stop

from test_dist_parity import spd_system  # same SPD fixture


@pytest.mark.parametrize("opts", [{}, {"pipeline": True}])
def test_dist_history_matches_single_device(require_devices, opts):
    require_devices(8)
    a, _, b = spd_system(101)
    A = sparse.csr_from_dense(a)
    Ad = DistCsr.from_matrix(A, Partition.uniform(101, 8))
    ex = make_executor("xla")
    stop = Stop(max_iters=300, reduction_factor=1e-6)

    single = krylov.cg(A, jnp.asarray(b), stop=stop, executor=ex,
                       history=True, **opts)
    dist = krylov.cg(Ad, jnp.asarray(b), stop=stop, executor=ex,
                     history=True, **opts)
    assert dist.converged

    hs = convergence.trim(single.history)
    hd = convergence.trim(dist.history)
    assert hd is not None and len(hd) == int(dist.iterations)
    np.testing.assert_allclose(
        hd[-1], float(dist.residual_norm), rtol=1e-4,
        err_msg="distributed history last entry != final residual",
    )
    # psum'd norms == single-device norms modulo reduction-order drift;
    # the pipelined recurrence compounds that drift over iterations, so it
    # gets the looser band (observed ~1% at convergence)
    assert len(hd) == len(hs)
    np.testing.assert_allclose(hd, hs, rtol=5e-2 if opts else 1e-3)

    # history off -> None, and the solve itself is unchanged
    off = krylov.cg(Ad, jnp.asarray(b), stop=stop, executor=ex, **opts)
    assert off.history is None
    assert int(off.iterations) == int(dist.iterations)


def test_dist_history_in_subprocess(run_with_devices):
    """Acceptance: the 8-shard history case must run even when the parent
    pytest process is locked to one device."""
    out = run_with_devices(
        """
        import numpy as np
        import jax.numpy as jnp
        from repro import sparse
        from repro.core import make_executor
        from repro.distributed import DistCsr, Partition
        from repro.observability import convergence
        from repro.solvers import krylov
        from repro.solvers.common import Stop

        n = 101
        rng = np.random.default_rng(3)
        a = np.zeros((n, n), np.float32)
        for i in range(n):
            a[i, i] = 4.0
            if i > 0:
                a[i, i - 1] = a[i - 1, i] = -1.0
            if i > 2:
                a[i, i - 3] = a[i - 3, i] = -0.5
        x = rng.normal(size=n).astype(np.float32)
        b = (a @ x).astype(np.float32)

        A = sparse.csr_from_dense(a)
        Ad = DistCsr.from_matrix(A, Partition.uniform(n, 8))
        ex = make_executor("xla")
        stop = Stop(max_iters=300, reduction_factor=1e-6)
        single = krylov.cg(A, jnp.asarray(b), stop=stop, executor=ex,
                           history=True)
        dist = krylov.cg(Ad, jnp.asarray(b), stop=stop, executor=ex,
                         history=True)
        assert bool(dist.converged)
        hs = convergence.trim(single.history)
        hd = convergence.trim(dist.history)
        assert len(hd) == int(dist.iterations)
        np.testing.assert_allclose(hd[-1], float(dist.residual_norm),
                                   rtol=1e-4)
        np.testing.assert_allclose(hd, hs, rtol=1e-3)
        print("OK shards=8 iters=", int(dist.iterations), "hist=", len(hd))
        """
    )
    assert "OK shards=8" in out
