"""Multi-device harness for the distributed suite.

Two patterns, mirroring how the paper's cross-backend CI runs the same tests
on every vendor's hardware:

* **env-guard**: when this conftest is imported before jax (i.e. running
  ``pytest tests/distributed`` standalone, or the dedicated CI job), it
  forces ``--xla_force_host_platform_device_count=8`` so the whole suite
  runs in-process against 8 virtual CPU devices.  When jax is already
  imported (the full tier-1 run, where other suites came first and the
  device count is locked at 1), the guard is inert and device-hungry tests
  skip cleanly via :func:`require_devices` — single-shard cases still run.
* **spawn**: ``run_with_devices`` executes a script in a subprocess with the
  flag set, for the acceptance-critical cases that must run even inside a
  single-device parent (same pattern as tests/distributed/test_multidevice).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")

# env-guard: only effective if jax has not initialized its backend yet; never
# override a device count the environment already chose
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()


def _require_devices(n: int):
    """Skip (cleanly, with the remedy in the message) unless ``n`` devices."""
    import jax

    have = len(jax.devices())
    if have < n:
        pytest.skip(
            f"needs {n} devices, have {have} — run this suite standalone or "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )


@pytest.fixture
def require_devices():
    """Callable fixture: ``require_devices(n)`` skips unless n devices."""
    return _require_devices


def _run_with_devices(body: str, n: int = 8) -> str:
    """Run a python script in a subprocess with ``n`` forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        env=env,
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture
def run_with_devices():
    """Callable fixture: run a script in a subprocess with forced devices."""
    return _run_with_devices
