"""Communication-avoiding (pipelined) CG at 8 shards.

Acceptance pins, per the Ghysels–Vanroose contract:

* exactly ONE ``psum`` per iteration in the lowered loop body (classic
  distributed CG carries three: p·Ap, r·z, ‖r‖);
* iteration counts within ±2 of the unfused/unpipelined baseline;
* solution parity with the single-device direct solve.

The psum count is asserted on the jaxpr of the sharded solve — the only
level where "one collective per iteration" is a structural property rather
than a timing observation.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import sparse
from repro.distributed import DistCsr, DistEll, Partition
from repro.solvers import krylov
from repro.solvers.common import Stop

from test_dist_parity import spd_system

DIST_BUILD = {"csr": DistCsr, "ell": DistEll}


def _find_while(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            return eqn
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
            if sub is not None:
                w = _find_while(sub)
                if w is not None:
                    return w
    return None


def _count_psums(jaxpr, acc=None):
    acc = [] if acc is None else acc
    for eqn in jaxpr.eqns:
        if eqn.primitive.name.startswith("psum"):
            acc.append(eqn.primitive.name)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
            if sub is not None:
                _count_psums(sub, acc)
    return acc


def _psums_per_iteration(Ad, b, **options):
    jaxpr = jax.make_jaxpr(
        lambda bb: krylov.cg(
            Ad, bb, stop=Stop(max_iters=400, reduction_factor=1e-6), **options
        ).x
    )(b)
    w = _find_while(jaxpr.jaxpr)
    assert w is not None, "no while loop in lowered solve"
    return len(_count_psums(w.params["body_jaxpr"].jaxpr))


@pytest.mark.parametrize("fmt", ("csr", "ell"))
def test_pipelined_cg_one_psum_per_iteration(fmt, require_devices):
    require_devices(8)
    a, _, b = spd_system()
    Ad = DIST_BUILD[fmt].from_matrix(
        sparse.csr_from_dense(a), Partition.uniform(a.shape[0], 8)
    )
    bj = jnp.asarray(b)
    assert _psums_per_iteration(Ad, bj, pipeline=True) == 1
    # the classic loop needs one collective per dependent reduction
    assert _psums_per_iteration(Ad, bj, pipeline=False) >= 3


def test_pipelined_cg_8shard_parity(require_devices):
    require_devices(8)
    a, xtrue, b = spd_system()
    n = a.shape[0]
    A = sparse.csr_from_dense(a)
    stop = Stop(max_iters=500, reduction_factor=1e-6)
    baseline = krylov.cg(A, jnp.asarray(b), stop=stop, fused=False)
    Ad = DistCsr.from_matrix(A, Partition.uniform(n, 8))
    piped = krylov.cg(Ad, jnp.asarray(b), stop=stop, pipeline=True)
    assert bool(piped.converged)
    assert abs(int(piped.iterations) - int(baseline.iterations)) <= 2
    np.testing.assert_allclose(
        np.asarray(piped.x, np.float64), np.asarray(xtrue, np.float64),
        rtol=2e-4, atol=2e-4,
    )


def test_pipelined_cg_8shard_subprocess(run_with_devices):
    """Spawn-isolated twin of the acceptance case (runs even when the parent
    pytest process is locked to one device): 8-shard pipelined CG in f64,
    one psum per iteration, iterations within ±2 of the unfused baseline."""
    run_with_devices("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np, jax.numpy as jnp
        from repro import sparse
        from repro.distributed import DistCsr, Partition
        from repro.solvers import krylov
        from repro.solvers.common import Stop

        assert len(jax.devices()) == 8
        rng = np.random.default_rng(3)
        n = 96
        a = np.zeros((n, n))
        for i in range(n):
            a[i, i] = 4.0
            if i > 0:
                a[i, i - 1] = a[i - 1, i] = -1.0
            if i > 2:
                a[i, i - 3] = a[i - 3, i] = -0.5
        b = a @ rng.normal(size=n)
        A = sparse.csr_from_dense(a)
        stop = Stop(max_iters=500, reduction_factor=1e-10)
        single = krylov.cg(A, jnp.asarray(b), stop=stop, fused=False)
        Ad = DistCsr.from_matrix(A, Partition.uniform(n, 8))
        piped = krylov.cg(Ad, jnp.asarray(b), stop=stop, pipeline=True)
        assert bool(piped.converged)
        assert abs(int(piped.iterations) - int(single.iterations)) <= 2
        np.testing.assert_allclose(
            np.asarray(piped.x), np.asarray(single.x), rtol=1e-8, atol=1e-10
        )

        def find_while(jaxpr):
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == "while":
                    return eqn
                for v in eqn.params.values():
                    sub = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
                    if sub is not None:
                        w = find_while(sub)
                        if w is not None:
                            return w
            return None

        def count_psums(jaxpr, acc):
            for eqn in jaxpr.eqns:
                if eqn.primitive.name.startswith("psum"):
                    acc.append(eqn.primitive.name)
                for v in eqn.params.values():
                    sub = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
                    if sub is not None:
                        count_psums(sub, acc)
            return acc

        jaxpr = jax.make_jaxpr(
            lambda bb: krylov.cg(Ad, bb, stop=stop, pipeline=True).x
        )(jnp.asarray(b))
        w = find_while(jaxpr.jaxpr)
        n_psum = len(count_psums(w.params["body_jaxpr"].jaxpr, []))
        assert n_psum == 1, f"expected 1 psum/iteration, found {n_psum}"
        print("PIPELINED DIST CG ACCEPTANCE OK", int(piped.iterations))
    """)
