"""Multi-device semantics under 8 virtual CPU devices (subprocess-isolated —
the device-count flag must never leak into other tests' jax runtime)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_with_devices(body: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_pjit_train_step_executes():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch import steps as steps_lib
        from repro.launch.mesh import make_host_mesh, use_mesh
        from repro.distributed import sharding as shd
        from repro.models import lm
        from repro.optim import adamw, constant_schedule

        mesh = make_host_mesh(2, 4)
        cfg = get_smoke_config("granite_8b")
        opt = adamw(constant_schedule(1e-3))
        params, axes = lm.init_model(jax.random.PRNGKey(0), cfg)
        shapes, _, p_sh, _, opt_sh = steps_lib.train_shardings(mesh, cfg, opt)
        params = jax.device_put(params, p_sh)
        state = jax.device_put(opt.init(params), opt_sh)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
        }
        b_sh = shd.batch_shardings(mesh, batch)
        batch = jax.device_put(batch, b_sh)
        fn = jax.jit(steps_lib.make_train_step(cfg, opt),
                     in_shardings=(p_sh, opt_sh, b_sh),
                     out_shardings=(p_sh, opt_sh, None))
        with use_mesh(mesh):
            p2, s2, m = fn(params, state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        print("LOSS", loss)
    """)


def test_ring_collective_matmuls():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collective_matmul import (
            ring_reduce_scatter_matmul, ring_all_gather_matmul)
        from repro.launch.mesh import make_host_mesh, use_mesh, shard_map

        mesh = make_host_mesh(1, 8)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
        want = np.asarray(x @ w)
        with use_mesh(mesh):
            got = jax.jit(shard_map(
                lambda xs, ws: ring_reduce_scatter_matmul(xs, ws, "model"),
                in_specs=(P(None, "model"), P("model", None)),
                out_specs=P(None, "model")))(x, w)
            assert np.abs(np.asarray(got) - want).max() < 1e-3
            got2 = jax.jit(shard_map(
                lambda xs, ws: ring_all_gather_matmul(xs, ws, "model"),
                in_specs=(P("model", None), P(None, "model")),
                out_specs=P(None, "model")))(x, w)
            assert np.abs(np.asarray(got2) - want).max() < 1e-3
        print("RING OK")
    """)


def test_moe_expert_parallel_matches_dense():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ModelConfig
        from repro.nn import moe as moe_lib
        from repro.launch.mesh import make_host_mesh, use_mesh

        mesh = make_host_mesh(2, 4)
        cfg = ModelConfig(name='t', family='moe', n_layers=1, d_model=32,
                          vocab=64, n_experts=8, top_k=2, d_expert=64,
                          shared_expert_ff=48, moe_spec=(("data",), "model"),
                          moe_capacity_factor=8.0)
        p, _ = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(50)
        x = jnp.asarray(rng.normal(size=(4, 16, 32)).astype(np.float32))
        with use_mesh(mesh):
            y_ep, m = jax.jit(lambda x: moe_lib.moe_forward(p, x, cfg, impl="ep"))(x)
        y_dense, _ = moe_lib.moe_forward(p, x, cfg, impl="dense")
        assert np.abs(np.asarray(y_ep) - np.asarray(y_dense)).max() < 1e-4
        assert float(m["moe_drop_frac"]) == 0.0

        def loss_ep(p, x):
            y, _ = moe_lib.moe_forward(p, x, cfg, impl="ep")
            return jnp.sum(y**2)
        def loss_dense(p, x):
            y, _ = moe_lib.moe_forward(p, x, cfg, impl="dense")
            return jnp.sum(y**2)
        with use_mesh(mesh):
            g_ep = jax.jit(jax.grad(loss_ep))(p, x)
        g_dense = jax.grad(loss_dense)(p, x)
        for key in ("gate", "up", "down", "router"):
            e = np.abs(np.asarray(g_ep[key]) - np.asarray(g_dense[key])).max()
            rel = e / max(np.abs(np.asarray(g_dense[key])).max(), 1e-9)
            assert rel < 1e-3, (key, rel)
        print("MOE EP OK")
    """)


def test_compressed_psum_shard_map():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim import compressed_psum, init_error_state
        from repro.launch.mesh import make_host_mesh, use_mesh, shard_map

        mesh = make_host_mesh(8, 1)
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
        err = jnp.zeros((8, 64), jnp.float32)

        def body(g_l, e_l):
            out, new_e = compressed_psum({"w": g_l[0]}, {"w": e_l[0]}, "data")
            return out["w"][None], new_e["w"][None]

        with use_mesh(mesh):
            out, new_err = jax.jit(shard_map(
                body, in_specs=(P("data", None), P("data", None)),
                out_specs=(P("data", None), P("data", None))))(g, err)
        want = np.asarray(g).mean(axis=0)
        got = np.asarray(out)[0]
        # int8 quantization error bounded by the shared scale
        scale = np.abs(np.asarray(g)).max() / 127.0
        assert np.abs(got - want).max() < scale * 1.5
        # every shard got the same reduced value
        assert np.abs(np.asarray(out) - got[None]).max() < 1e-7
        print("COMPRESSED PSUM OK")
    """)


def test_elastic_checkpoint_reshard():
    run_with_devices("""
        import tempfile
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_host_mesh

        rng = np.random.default_rng(2)
        w = rng.normal(size=(16, 32)).astype(np.float32)
        mesh_a = make_host_mesh(2, 4)
        mesh_b = make_host_mesh(8, 1)
        sh_a = NamedSharding(mesh_a, P("data", "model"))
        sh_b = NamedSharding(mesh_b, P("data", None))
        tree = {"w": jax.device_put(jnp.asarray(w), sh_a)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, tree, block=True)
            proto = {"w": jnp.zeros((16, 32), jnp.float32)}
            got, _ = mgr.restore(target=proto, shardings={"w": sh_b})
            assert got["w"].sharding == sh_b
            np.testing.assert_array_equal(np.asarray(got["w"]), w)
        print("ELASTIC RESHARD OK")
    """)


def test_sequence_parallel_constraint_executes():
    run_with_devices("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_host_mesh, use_mesh
        from repro.models import lm

        mesh = make_host_mesh(2, 4)
        cfg = dataclasses.replace(get_smoke_config("granite_8b"),
                                  sp_spec=(("data",), "model"),
                                  attn_impl="chunked")
        params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
        with use_mesh(mesh):
            logits_sp, _ = jax.jit(lambda t: lm.forward(params, cfg, tokens=t))(tokens)
        cfg0 = dataclasses.replace(cfg, sp_spec=(), attn_impl="dense")
        logits, _ = lm.forward(params, cfg0, tokens=tokens)
        err = np.abs(np.asarray(logits_sp) - np.asarray(logits)).max()
        assert err < 2e-3, err
        print("SP OK", err)
    """)


def test_compressed_dp_training_converges():
    """End-to-end DP training with int8-EF gradient compression: the
    compressed run must track the uncompressed loss trajectory."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.data import DataConfig, global_step_batch
        from repro.launch import steps as steps_lib
        from repro.launch.mesh import make_host_mesh, use_mesh
        from repro.models import lm
        from repro.optim import adamw, constant_schedule

        mesh = make_host_mesh(8, 1)
        cfg = get_smoke_config("smollm_135m")
        opt = adamw(constant_schedule(3e-3), weight_decay=0.0)
        params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=3)

        # uncompressed reference
        step_ref = jax.jit(steps_lib.make_train_step(cfg, opt))
        p_ref, s_ref = params, opt.init(params)
        ref_losses = []
        for i in range(12):
            batch = {k: jnp.asarray(v) for k, v in global_step_batch(dcfg, i).items()}
            p_ref, s_ref, m = step_ref(p_ref, s_ref, batch)
            ref_losses.append(float(m["loss"]))

        # compressed DP
        step_c, init_err = steps_lib.make_compressed_dp_train_step(cfg, opt)
        p_c, s_c = params, opt.init(params)
        err = init_err(params, 8)
        c_losses = []
        with use_mesh(mesh):
            fn = jax.jit(step_c)
            for i in range(12):
                batch = {k: jnp.asarray(v) for k, v in global_step_batch(dcfg, i).items()}
                p_c, s_c, err, m = fn(p_c, s_c, err, batch)
                c_losses.append(float(m["loss"]))

        ref, com = np.array(ref_losses), np.array(c_losses)
        assert com[-1] < com[0] - 0.1, com          # learning
        assert np.abs(ref - com).max() < 0.05, (ref, com)  # tracks reference
        print("COMPRESSED DP OK", ref[-1], com[-1])
    """)


def test_dryrun_cell_end_to_end():
    """One real dry-run cell (lower+compile+roofline) under 64 placeholder
    devices with a shrunken production-mesh shape — covers the launch path."""
    run_with_devices("""
        import json
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_lib
        import jax

        # shrink the production mesh to the available 64 devices
        orig = mesh_lib.make_production_mesh
        def small(*, multi_pod=False):
            shape = (2, 4, 8) if multi_pod else (8, 8)
            axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            return mesh_lib.compat_make_mesh(shape, axes)
        mesh_lib.make_production_mesh = small
        dr.make_production_mesh = small

        res = dr.run_cell("granite_8b", "decode_32k", multi_pod=False,
                          save=False, verbose=False)
        assert res["roofline"]["bottleneck"] in ("compute", "memory", "collective")
        assert res["per_device"]["logical_flops"] > 0
        assert res["memory_analysis"]["peak_bytes"] is not None
        res_mp = dr.run_cell("granite_8b", "decode_32k", multi_pod=True,
                             save=False, verbose=False)
        assert res_mp["chips"] == 64
        print("DRYRUN CELL OK", res["roofline"]["bottleneck"])
    """, n=64)
