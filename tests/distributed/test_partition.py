"""Property tests for the Partition abstraction (host-side, no devices).

Hypothesis-driven (the deterministic ``_hyp_compat`` shim when hypothesis is
absent): local<->global index round-trips, coverage/disjointness of the row
ranges, the padded-layout bijection, and halo-column-set correctness of the
matrix split against a brute-force reference.
"""

import numpy as np
import pytest

from _hyp_compat import given, settings, st

from repro import sparse
from repro.distributed import Partition, split_by_rows


@settings(max_examples=20)
@given(n=st.integers(0, 300), parts=st.integers(1, 9))
def test_uniform_coverage_and_disjointness(n, parts):
    p = Partition.uniform(n, parts)
    assert p.num_parts == parts
    assert p.global_size == n
    assert sum(p.part_sizes) == n
    # contiguous, ordered, disjoint by construction of offsets; check cover
    seen = np.concatenate(
        [np.arange(*p.range_of(q)) for q in range(parts)]
    ) if n else np.zeros(0, np.int64)
    np.testing.assert_array_equal(seen, np.arange(n))
    # balanced: sizes differ by at most one
    assert max(p.part_sizes) - min(p.part_sizes) <= 1


@settings(max_examples=20)
@given(n=st.integers(1, 300), parts=st.integers(1, 9), seed=st.integers(0, 999))
def test_local_global_round_trip(n, parts, seed):
    rng = np.random.default_rng(seed)
    # ragged and empty parts both appear in these random sizes
    sizes = rng.multinomial(n, np.ones(parts) / parts)
    p = Partition.from_part_sizes(sizes)
    rows = rng.integers(0, n, size=min(n, 64))
    q, loc = p.to_local(rows)
    np.testing.assert_array_equal(p.to_global(q, loc), rows)
    # local indices are in range of their part
    assert (loc >= 0).all() and (loc < np.asarray(sizes)[q]).all()
    # part_of agrees with the ranges
    for r, part in zip(rows, q):
        lo, hi = p.range_of(int(part))
        assert lo <= r < hi


@settings(max_examples=12)
@given(n=st.integers(1, 200), parts=st.integers(1, 8))
def test_padded_layout_bijection(n, parts):
    import jax.numpy as jnp

    p = Partition.uniform(n, parts)
    x = np.random.default_rng(0).normal(size=n).astype(np.float32)
    xp = np.asarray(p.pad(jnp.asarray(x)))
    assert xp.shape == (parts, p.max_part_size)
    # padding slots are zero, real slots carry the global values
    assert np.all(xp[~p.pad_mask] == 0.0)
    np.testing.assert_array_equal(np.asarray(p.unpad(jnp.asarray(xp))), x)
    # every real slot is hit exactly once
    assert p.pad_mask.sum() == n


def test_validation_errors():
    with pytest.raises(ValueError):
        Partition((1, 3))  # must start at 0
    with pytest.raises(ValueError):
        Partition((0, 5, 3))  # decreasing
    with pytest.raises(ValueError):
        Partition.from_part_sizes([4, -1])
    with pytest.raises(IndexError):
        Partition.uniform(10, 2).part_of([10])


@settings(max_examples=10)
@given(n=st.integers(1, 60), parts=st.integers(1, 6), seed=st.integers(0, 999))
def test_halo_column_sets_match_brute_force(n, parts, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n)).astype(np.float32)
    a[rng.random((n, n)) > 0.25] = 0.0
    A = sparse.csr_from_dense(a)
    part = Partition.uniform(n, parts)
    indptr, indices, values = sparse.csr_host_arrays(A)
    split = split_by_rows(indptr, indices, values, part)
    for p in range(parts):
        lo, hi = part.range_of(p)
        # brute force: every column with a nonzero in this row block that
        # falls outside the block's own range
        rows, cols = np.nonzero(a[lo:hi])
        want = np.unique(cols[(cols < lo) | (cols >= hi)])
        np.testing.assert_array_equal(split[p]["halo_cols"], want)
        # and the split reassembles the exact row block
        li, lj, lv = split[p]["local"]
        hi_, hj, hv = split[p]["halo"]
        block = np.zeros((hi - lo, n), np.float32)
        lrows = np.repeat(np.arange(hi - lo), np.diff(li))
        block[lrows, lj + lo] = lv
        hrows = np.repeat(np.arange(hi - lo), np.diff(hi_))
        if len(hrows):
            block[hrows, split[p]["halo_cols"][hj]] = hv
        np.testing.assert_allclose(block, a[lo:hi])
