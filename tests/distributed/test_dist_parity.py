"""Sharded-vs-single-device parity: the distributed layer may not change math.

For each shard count x format x operation, the mesh-sharded result must match
the single-device LinOp result to tight tolerance — including ragged
partitions (rows % devices != 0) and an empty-shard degenerate.  The CG
acceptance case runs in f64 against the convergence-regression SPD fixture
(same construction as tests/solvers/test_convergence_regression.py) and pins
iteration count (±1) and residual/solution parity at rtol 1e-10; a spawn-based
twin keeps that acceptance check running even when the parent pytest process
is locked to one device.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import sparse
from repro.distributed import (
    DistCsr,
    DistEll,
    DistVector,
    Partition,
    dist_dot,
    dist_norm2,
)
from repro.solvers import krylov
from repro.solvers.common import Stop

SHARDS = (1, 2, 4, 8)
FORMATS = ("csr", "ell")
N = 101  # prime: ragged under every multi-shard count

DIST_BUILD = {"csr": DistCsr, "ell": DistEll}
BUILD = {"csr": sparse.csr_from_dense, "ell": sparse.ell_from_dense}


def _sparse_pattern(n=N, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n)).astype(dtype)
    a[rng.random((n, n)) > 0.15] = 0.0
    a[np.arange(n), np.arange(n)] = 6.0
    return a


def spd_system(n=96, dtype=np.float32, rng=None):
    """The convergence-regression SPD fixture (same construction)."""
    rng = rng or np.random.default_rng(3)
    a = np.zeros((n, n), dtype)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 2:
            a[i, i - 3] = a[i - 3, i] = -0.5
    x = rng.normal(size=n).astype(dtype)
    return a, x, (a @ x).astype(dtype)


def _partition(n, parts, kind="uniform"):
    if kind == "uniform":
        return Partition.uniform(n, parts)
    if kind == "empty_shard":
        # one shard owns nothing — the degenerate every collective must survive
        sizes = list(Partition.uniform(n, parts - 1).part_sizes) + [0]
        return Partition.from_part_sizes(sizes)
    raise ValueError(kind)


# -----------------------------------------------------------------------------
# SpMV
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("parts", SHARDS)
def test_spmv_parity(parts, fmt, require_devices):
    require_devices(parts)
    a = _sparse_pattern()
    x = np.random.default_rng(1).normal(size=N).astype(np.float32)
    A = BUILD[fmt](a)
    want = np.asarray(sparse.apply(A, jnp.asarray(x)))
    Ad = DIST_BUILD[fmt].from_matrix(A, Partition.uniform(N, parts))
    got = np.asarray(Ad.apply(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fmt", FORMATS)
def test_spmv_empty_shard_degenerate(fmt, require_devices):
    require_devices(3)
    a = _sparse_pattern()
    x = np.random.default_rng(2).normal(size=N).astype(np.float32)
    Ad = DIST_BUILD[fmt].from_matrix(BUILD[fmt](a), _partition(N, 3, "empty_shard"))
    np.testing.assert_allclose(
        np.asarray(Ad.apply(jnp.asarray(x))), a @ x, rtol=1e-4, atol=1e-4
    )


# -----------------------------------------------------------------------------
# BLAS-1 (dot / norm) — psum reductions, ragged partitions
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("parts", SHARDS)
def test_dot_norm_parity(parts, require_devices):
    require_devices(parts)
    rng = np.random.default_rng(5)
    x = rng.normal(size=N).astype(np.float32)
    y = rng.normal(size=N).astype(np.float32)
    part = Partition.uniform(N, parts)
    xv = DistVector.from_global(jnp.asarray(x), part)
    yv = DistVector.from_global(jnp.asarray(y), part)
    assert np.allclose(float(dist_dot(xv, yv)), float(x @ y), rtol=1e-5)
    assert np.allclose(
        float(dist_norm2(xv)), float(np.linalg.norm(x)), rtol=1e-6
    )
    np.testing.assert_allclose(np.asarray(xv.to_global()), x)


def test_psum_norm_padding_regression(require_devices):
    """The padded-shard double-count guard (Stop.threshold-style audit).

    On a ragged partition the shards carry padding slots; a psum'd norm must
    mask them, or whatever sits there is double-counted into every stopping
    criterion.  Poison the padding explicitly and demand the unsharded norm.
    """
    require_devices(2)
    x = np.random.default_rng(7).normal(size=N).astype(np.float32)  # N odd
    part = Partition.uniform(N, 2)
    xv = DistVector.from_global(jnp.asarray(x), part)
    mask = jnp.asarray(part.pad_mask)
    assert not bool(mask.all()), "ragged partition must actually have padding"
    poisoned = dataclasses.replace(
        xv, local=jnp.where(mask, xv.local, jnp.float32(1e9))
    )
    want = float(np.linalg.norm(x))
    assert np.allclose(float(dist_norm2(poisoned)), want, rtol=1e-6)
    assert np.allclose(float(dist_dot(poisoned, poisoned)), float(x @ x), rtol=1e-5)
    # and the round-trip drops the poison
    np.testing.assert_allclose(np.asarray(poisoned.to_global()), x)


# -----------------------------------------------------------------------------
# CG solve — the acceptance case (f64, iterations ±1, rtol 1e-10)
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("parts", SHARDS)
def test_cg_parity_f64(parts, fmt, require_devices):
    require_devices(parts)
    with jax.experimental.enable_x64():
        a, _, b = spd_system(dtype=np.float64)
        A = BUILD[fmt](a)
        stop = Stop(max_iters=500, reduction_factor=1e-12)
        single = krylov.cg(A, jnp.asarray(b), stop=stop)
        Ad = DIST_BUILD[fmt].from_matrix(A, Partition.uniform(a.shape[0], parts))
        dist = krylov.cg(Ad, jnp.asarray(b), stop=stop)
        assert dist.x.dtype == jnp.float64
        assert bool(dist.converged)
        assert abs(int(dist.iterations) - int(single.iterations)) <= 1
        np.testing.assert_allclose(
            float(dist.residual_norm), float(single.residual_norm), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(dist.x), np.asarray(single.x), rtol=1e-10, atol=1e-12
        )


@pytest.mark.parametrize("precond", ("jacobi", "block_jacobi"))
def test_cg_preconditioned_dist(precond, require_devices):
    require_devices(4)
    a, xstar, b = spd_system()
    Ad = DistCsr.from_matrix(sparse.csr_from_dense(a), Partition.uniform(96, 4))
    opts = {"block_size": 4} if precond == "block_jacobi" else None
    res = krylov.cg(
        Ad, jnp.asarray(b), stop=Stop(max_iters=300), M=precond,
        precond_opts=opts,
    )
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), xstar, rtol=1e-3, atol=1e-3)


def test_dist_precond_surfaces(require_devices):
    """The distributed preconditioners' non-solver surfaces: global LinOp
    apply parity, partition-mismatch rejection, adaptive=True rejection."""
    require_devices(2)
    from repro.distributed import (
        dist_block_jacobi,
        dist_preconditioner,
        dist_scalar_jacobi,
    )

    a, _, b = spd_system()
    part = Partition.uniform(96, 2)
    Ad = DistCsr.from_matrix(sparse.csr_from_dense(a), part)

    # global apply of both preconditioners matches the dense block math
    Ms = dist_scalar_jacobi(Ad)
    np.testing.assert_allclose(
        np.asarray(Ms.apply(jnp.asarray(b))), b / np.diagonal(a), rtol=1e-6
    )
    Mb = dist_block_jacobi(Ad, block_size=4)
    want = np.zeros_like(b)
    for lo in range(0, 96, 4):
        want[lo : lo + 4] = np.linalg.solve(
            a[lo : lo + 4, lo : lo + 4], b[lo : lo + 4]
        )
    np.testing.assert_allclose(
        np.asarray(Mb.apply(jnp.asarray(b))), want, rtol=1e-4, atol=1e-5
    )

    # a preconditioner generated against a different partition is refused
    M_other = dist_scalar_jacobi(
        DistCsr.from_matrix(sparse.csr_from_dense(a), Partition.uniform(96, 1))
    )
    with pytest.raises(ValueError, match="partition"):
        dist_preconditioner(Ad, M_other)
    # per-shard adaptive precision selection cannot stack: explicit dtype only
    with pytest.raises(ValueError, match="uniform storage precision"):
        dist_scalar_jacobi(Ad, adaptive=True)
    with pytest.raises(ValueError, match="uniform storage precision"):
        dist_block_jacobi(Ad, block_size=4, adaptive=True)


@pytest.mark.parametrize("solver", ("bicgstab", "gmres"))
def test_nonsym_solver_parity(solver, require_devices):
    require_devices(4)
    rng = np.random.default_rng(11)
    a, _, _ = spd_system()
    a = a + np.triu(rng.normal(size=a.shape).astype(np.float32) * 0.05, 1)
    x = rng.normal(size=96).astype(np.float32)
    b = (a @ x).astype(np.float32)
    A = sparse.csr_from_dense(a)
    fn = getattr(krylov, solver)
    stop = Stop(max_iters=300)
    single = fn(A, jnp.asarray(b), stop=stop)
    dist = fn(
        DistCsr.from_matrix(A, Partition.uniform(96, 4)), jnp.asarray(b),
        stop=stop,
    )
    assert bool(dist.converged)
    assert abs(int(dist.iterations) - int(single.iterations)) <= 1
    np.testing.assert_allclose(
        np.asarray(dist.x), np.asarray(single.x), rtol=2e-3, atol=2e-4
    )


def test_cg_8shard_acceptance_subprocess(run_with_devices):
    """The acceptance criterion, spawn-isolated so it ALWAYS runs: CG on a
    DistCsr across 8 forced host devices matches the single-device solve on
    the convergence-regression matrix — iterations ±1, rtol 1e-10 in f64."""
    run_with_devices("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np, jax.numpy as jnp
        from repro import sparse
        from repro.distributed import DistCsr, Partition
        from repro.solvers import krylov
        from repro.solvers.common import Stop

        assert len(jax.devices()) == 8
        rng = np.random.default_rng(3)
        n = 96
        a = np.zeros((n, n))
        for i in range(n):
            a[i, i] = 4.0
            if i > 0:
                a[i, i - 1] = a[i - 1, i] = -1.0
            if i > 2:
                a[i, i - 3] = a[i - 3, i] = -0.5
        b = a @ rng.normal(size=n)
        A = sparse.csr_from_dense(a)
        stop = Stop(max_iters=500, reduction_factor=1e-12)
        single = krylov.cg(A, jnp.asarray(b), stop=stop)
        Ad = DistCsr.from_matrix(A, Partition.uniform(n, 8))
        dist = krylov.cg(Ad, jnp.asarray(b), stop=stop)
        assert bool(dist.converged)
        assert abs(int(dist.iterations) - int(single.iterations)) <= 1
        np.testing.assert_allclose(
            np.asarray(dist.x), np.asarray(single.x), rtol=1e-10, atol=1e-12
        )
        print("DIST CG ACCEPTANCE OK", int(dist.iterations))
    """)
