"""Distributed path on the nonsymmetric gallery corpus at 10^5-row scale.

The sharded SpMV must agree with the single-device apply on the
convection-diffusion operator (whose halo exchange is asymmetric: upwind
coupling differs by direction), and the sharded GMRES solve must converge on
a smaller instance — the nonsymmetric analogue of the pinned SPD dist tests.
"""


def test_dist_spmv_convection_diffusion_1e5_rows(run_with_devices):
    out = run_with_devices(
        """
        import numpy as np
        import jax.numpy as jnp
        from repro.distributed import DistCsr, Partition
        from repro.sparse import csr_from_arrays
        from repro.sparse.gallery import convection_diffusion_2d

        indptr, indices, values, shape = convection_diffusion_2d(
            317, peclet=5.0)  # 100489 rows
        assert shape[0] >= 100_000
        A = csr_from_arrays(indptr, indices, values, shape)
        Ad = DistCsr.from_matrix(A, Partition.uniform(shape[0], 8))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=shape[0]).astype(np.float32))
        ref = np.asarray(A.apply(x))
        got = np.asarray(Ad.apply(x))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
        print("OK rows=", shape[0])
        """
    )
    assert "OK rows= 100489" in out


def test_dist_gmres_converges_on_nonsym(run_with_devices):
    out = run_with_devices(
        """
        import numpy as np
        import jax.numpy as jnp
        from repro.distributed import DistCsr, Partition
        from repro.solvers import krylov
        from repro.solvers.common import Stop
        from repro.sparse import csr_from_arrays
        from repro.sparse.gallery import convection_diffusion_2d

        indptr, indices, values, shape = convection_diffusion_2d(
            16, peclet=2.0)
        A = csr_from_arrays(indptr, indices, values, shape)
        Ad = DistCsr.from_matrix(A, Partition.uniform(shape[0], 8))
        rng = np.random.default_rng(1)
        b = jnp.asarray(rng.normal(size=shape[0]).astype(np.float32))
        stop = Stop(max_iters=500, reduction_factor=1e-6)
        single = krylov.gmres(A, b, stop=stop)
        dist = krylov.gmres(Ad, b, stop=stop)
        assert bool(single.converged) and bool(dist.converged)
        # distinct reduction orders may shift the restart boundary; demand the
        # *true* residual meet the same tolerance instead of iteration parity
        rows = np.repeat(np.arange(shape[0]), np.diff(indptr))
        a = np.zeros(shape, np.float32); a[rows, indices] = values
        bn = np.asarray(b)
        rel = np.linalg.norm(bn - a @ np.asarray(dist.x)) / np.linalg.norm(bn)
        assert rel <= 1e-4, rel
        print("OK iters=", int(dist.iterations))
        """
    )
    assert "OK iters=" in out
