"""Per-kernel interpret-mode validation against pure-jnp oracles.

Every Pallas kernel sweeps shapes/dtypes (hypothesis + parametrize) and must
match its ref.py oracle.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro import sparse
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rwkv6.kernel import rwkv6_scan_log
from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.kernels.rwkv6.xla import rwkv6_chunked_xla
from repro.kernels.spmv_ell.kernel import spmv_ell
from repro.kernels.spmv_ell.ref import spmv_ell_ref
from repro.kernels.spmv_sellp.kernel import spmv_sellp
from repro.kernels.spmv_sellp.ref import spmv_sellp_ref
from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.ssd.xla import ssd_chunked_xla


# -- rmsnorm ---------------------------------------------------------------------

@given(
    rows=st.integers(1, 200),
    d=st.sampled_from([32, 64, 128, 384]),
    block=st.sampled_from([8, 32, 128]),
    dtype=st.sampled_from([np.float32, "bfloat16"]),
)
@settings(max_examples=15)
def test_rmsnorm_sweep(rows, d, block, dtype):
    rng = np.random.default_rng(rows * d)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    x = jnp.asarray(rng.normal(size=(rows, d)), dt)
    w = jnp.asarray(rng.normal(size=(d,)), dt)
    got = rmsnorm(x, w, interpret=True, block_rows=block)
    want = rmsnorm_ref(x, w)
    atol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )


def test_rmsnorm_nd_input(rng):
    x = jnp.asarray(rng.normal(size=(2, 7, 3, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    got = rmsnorm(x, w, interpret=True)
    np.testing.assert_allclose(got, rmsnorm_ref(x, w), atol=1e-5)


# -- spmv ------------------------------------------------------------------------

@pytest.mark.parametrize("bm,bk,coop", [(64, 8, True), (128, 16, False), (37, 5, True)])
def test_spmv_ell_blocks(rng, bm, bk, coop):
    a = rng.normal(size=(150, 97)).astype(np.float32)
    a[rng.random(a.shape) < 0.85] = 0
    A = sparse.ell_from_dense(a)
    x = jnp.asarray(rng.normal(size=(97,)).astype(np.float32))
    got = spmv_ell(A.col_idx, A.values, x, block_m=bm, block_k=bk,
                   use_coop=coop, interpret=True)
    want = spmv_ell_ref(A.col_idx, A.values, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(want), a @ np.asarray(x), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize(
    "bm,bk,coop", [(64, 8, True), (128, 16, False), (37, 5, True)]
)
def test_spmv_batch_ell_blocks(rng, bm, bk, coop):
    from repro import batch
    from repro.kernels.spmv_batch_ell.kernel import spmv_batch_ell as kern
    from repro.kernels.spmv_batch_ell.ref import spmv_batch_ell_ref

    nb = 6
    stack = rng.normal(size=(nb, 150, 97)).astype(np.float32)
    stack[rng.random(stack.shape) < 0.85] = 0
    A = batch.batch_ell_from_dense(stack)
    X = jnp.asarray(rng.normal(size=(nb, 97)).astype(np.float32))
    got = kern(A.col_idx, A.values, X, block_m=bm, block_k=bk,
               use_coop=coop, interpret=True)
    want = spmv_batch_ell_ref(A.col_idx, A.values, X)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(want), np.einsum("bmn,bn->bm", stack, np.asarray(X)),
        rtol=1e-3, atol=1e-4,
    )


@given(m=st.integers(1, 120), n=st.integers(1, 90), seed=st.integers(0, 99))
@settings(max_examples=10)
def test_spmv_sellp_sweep(m, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    a[rng.random(a.shape) < 0.8] = 0
    A = sparse.sellp_from_dense(a, slice_size=8, stride_factor=8)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    got = spmv_sellp(A.col_idx, A.values, A.slice_sets, x, m=m,
                     slice_size=A.slice_size, block_cols=A.stride_factor,
                     max_slice_cols=A.max_slice_cols, interpret=True)
    want = spmv_sellp_ref(A.col_idx, A.values, A.slice_sets, x, m, A.slice_size)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -- flash attention ----------------------------------------------------------------

@pytest.mark.parametrize(
    "B,Hq,Hkv,S,Skv,D,bq,bkv,causal",
    [
        (2, 4, 2, 64, 64, 32, 16, 16, True),
        (1, 3, 1, 100, 100, 16, 32, 16, True),
        (1, 2, 2, 48, 96, 32, 16, 16, True),  # Skv > S: chunked-prefill align
        (1, 2, 1, 64, 64, 32, 64, 64, False),
        (1, 2, 1, 50, 70, 32, 16, 32, False),  # padded kv, non-causal
    ],
)
def test_flash_attention_shapes(rng, B, Hq, Hkv, S, Skv, D, bq, bkv, causal):
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                          interpret=True)
    want = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-3)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 32, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 32, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 32, 32)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=16, block_kv=16, interpret=True)
    want = mha_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


# -- ssd -------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 32, 96, 64])
def test_ssd_chunks(rng, chunk):
    B, S, H, P, G, N = 2, 96, 4, 32, 2, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.log1p(np.exp(rng.normal(size=(B, S, H)))).astype(np.float32))
    A = jnp.asarray(-np.exp(rng.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    want_y, want_h = ssd_ref(x, dt, A, Bm, C)
    got_y, got_h = ssd_scan(x, dt, A, Bm, C, chunk=chunk, interpret=True)
    np.testing.assert_allclose(got_y, want_y, atol=2e-3)
    rel = np.abs(np.asarray(got_h - want_h)).max() / max(
        np.abs(np.asarray(want_h)).max(), 1.0
    )
    assert rel < 2e-3
    # the portable chunked-XLA path must agree too
    xy, xh = ssd_chunked_xla(x, dt, A, Bm, C, chunk=chunk)
    np.testing.assert_allclose(xy, want_y, atol=2e-3)


# -- rwkv6 -----------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 32, 80])
def test_rwkv6_chunks(rng, chunk):
    B, S, H, K, V = 2, 80, 3, 32, 32
    r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, V)).astype(np.float32))
    xw = rng.normal(-1.0, 1.0, size=(B, S, H, K)).astype(np.float32)
    logw = jnp.asarray(-np.exp(xw))
    w = jnp.asarray(np.exp(-np.exp(xw.astype(np.float64))).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
    want_y, want_s = rwkv6_ref(r, k, v, w, u)
    got_y, got_s = rwkv6_scan_log(r, k, v, logw, u, chunk=chunk, interpret=True)
    scale = max(np.abs(np.asarray(want_y)).max(), 1.0)
    assert np.abs(np.asarray(got_y - want_y)).max() / scale < 2e-3
    xy, xs = rwkv6_chunked_xla(r, k, v, logw, u, chunk=chunk)
    assert np.abs(np.asarray(xy - want_y)).max() / scale < 2e-3


def test_rwkv6_extreme_decay_stability(rng):
    """w -> 0 (strong decay): the log-space ratio form must stay finite."""
    B, S, H, K = 1, 64, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    xw = rng.normal(2.5, 1.0, size=(B, S, H, K)).astype(np.float32)
    logw = jnp.asarray(-np.exp(xw))
    u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
    y, s = rwkv6_scan_log(r, k, v, logw, u, chunk=16, interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
    w = jnp.asarray(np.exp(-np.exp(xw.astype(np.float64))).astype(np.float32))
    want_y, _ = rwkv6_ref(r, k, v, w, u)
    scale = max(np.abs(np.asarray(want_y)).max(), 1.0)
    assert np.abs(np.asarray(y - want_y)).max() / scale < 2e-3


def test_flash_binding_vmem_autofit(rng):
    """The launch-config resolver shrinks blocks until the set fits VMEM."""
    import dataclasses

    from repro.core import PallasInterpretExecutor, params as hw_params, tuning
    from repro.core.registry import operation

    tiny_vmem = dataclasses.replace(
        hw_params.CPU_INTERPRET, vmem_limit_bytes=1 * 1024 * 1024
    )
    ex_small = PallasInterpretExecutor(tiny_vmem)
    ex_big = PallasInterpretExecutor()
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 64, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 64, 64)).astype(np.float32))
    op = operation("nn_attention")
    out_small = op(q, k, v, executor=ex_small)
    out_big = op(q, k, v, executor=ex_big)
    np.testing.assert_allclose(
        np.asarray(out_small), np.asarray(out_big), atol=2e-5
    )
    shapes = {"S": 64, "Skv": 64, "D": 64, "itemsize": 4}
    cfg_small = tuning.resolve("nn_attention", shapes, tiny_vmem)
    cfg_big = tuning.resolve("nn_attention", shapes, ex_big.hw)
    assert cfg_small.fits_vmem
    assert cfg_small.source.endswith("+shrunk")
    assert cfg_small.vmem_bytes <= tiny_vmem.vmem_limit_bytes // tuning.VMEM_HEADROOM
    assert cfg_small["block_q"] < cfg_big["block_q"]
