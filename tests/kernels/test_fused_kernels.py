"""Interpret-mode validation of the fused-reduction Pallas kernels.

``spmv_dot_ell`` (SpMV emitting w·y in the same pass) and ``axpy_norm``
(axpy emitting ‖z‖²) against their ref.py oracles and dense numpy, across
block geometries that exercise tail padding on both grid axes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro import sparse
from repro.kernels.axpy_norm.kernel import axpy_norm
from repro.kernels.axpy_norm.ref import axpy_norm_ref
from repro.kernels.spmv_dot.kernel import spmv_dot_ell
from repro.kernels.spmv_dot.ref import spmv_dot_ell_ref


# -- spmv_dot_ell ----------------------------------------------------------------

@pytest.mark.parametrize("bm,bk,coop", [(64, 8, True), (128, 16, False), (37, 5, True)])
def test_spmv_dot_ell_blocks(rng, bm, bk, coop):
    a = rng.normal(size=(150, 150)).astype(np.float32)
    a[rng.random(a.shape) < 0.85] = 0
    A = sparse.ell_from_dense(a)
    x = jnp.asarray(rng.normal(size=(150,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(150,)).astype(np.float32))
    y, d = spmv_dot_ell(A.col_idx, A.values, x, w, block_m=bm, block_k=bk,
                        use_coop=coop, interpret=True)
    y_ref, d_ref = spmv_dot_ell_ref(A.col_idx, A.values, x, w)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(d), float(d_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y_ref), a @ np.asarray(x), rtol=1e-3, atol=1e-4
    )
    np.testing.assert_allclose(
        float(d_ref), float(np.asarray(w) @ (a @ np.asarray(x))),
        rtol=1e-3,
    )


@given(m=st.integers(1, 120), seed=st.integers(0, 99))
@settings(max_examples=10)
def test_spmv_dot_ell_sweep(m, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, m)).astype(np.float32)
    a[rng.random(a.shape) < 0.8] = 0
    A = sparse.ell_from_dense(a)
    x = jnp.asarray(rng.normal(size=(m,)).astype(np.float32))
    y, d = spmv_dot_ell(A.col_idx, A.values, x, x, interpret=True)
    y_ref, d_ref = spmv_dot_ell_ref(A.col_idx, A.values, x, x)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(d), float(d_ref), rtol=1e-4, atol=1e-3)


# -- axpy_norm -------------------------------------------------------------------

@pytest.mark.parametrize("block_n", [128, 1024, 100])
def test_axpy_norm_blocks(rng, block_n):
    n = 777
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    z, ss = axpy_norm(-0.37, x, y, block_n=block_n, interpret=True)
    z_ref, ss_ref = axpy_norm_ref(-0.37, x, y)
    np.testing.assert_allclose(z, z_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(ss), float(ss_ref), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(z_ref), -0.37 * np.asarray(x) + np.asarray(y), atol=1e-6
    )


@given(n=st.integers(1, 3000), seed=st.integers(0, 99))
@settings(max_examples=10)
def test_axpy_norm_sweep(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    alpha = float(rng.normal())
    z, ss = axpy_norm(alpha, x, y, interpret=True)
    z_ref, ss_ref = axpy_norm_ref(alpha, x, y)
    np.testing.assert_allclose(z, z_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(ss), float(ss_ref), rtol=1e-4, atol=1e-5)


def test_axpy_norm_traced_alpha(rng):
    # alpha arrives as a traced scalar inside solver loops — the (1, 1)
    # operand path must accept a jax array, not only a python float
    import jax

    x = jnp.asarray(rng.normal(size=(500,)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(500,)).astype(np.float32))

    def f(a):
        return axpy_norm(a, x, y, interpret=True)[1]

    got = jax.jit(f)(jnp.float32(0.5))
    _, want = axpy_norm_ref(0.5, x, y)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)
