"""Interpret-mode parity for all six kernel families across the three kernel
spaces, with block geometry resolved through the launch-config subsystem.

This is the acceptance gate for the tuning refactor: no ops.py binding
hard-codes tile sizes anymore, so dispatching the same operation through
reference / xla / pallas executors exercises the resolver end-to-end and must
produce matching numerics.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sparse
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    registry,
)
import repro.kernels  # noqa: F401 — populate the kernel spaces

EXECUTORS = (ReferenceExecutor, XlaExecutor, PallasInterpretExecutor)


def _spaces_outputs(op_name, *args):
    op = registry.operation(op_name)
    outs = {}
    for cls in EXECUTORS:
        ex = cls()
        outs[op.space_used(ex)] = op(*args, executor=ex)
    return outs


def _assert_all_match(outs, atol):
    ref = outs.pop("reference")
    for space, got in outs.items():
        ref_leaves = ref if isinstance(ref, tuple) else (ref,)
        got_leaves = got if isinstance(got, tuple) else (got,)
        for r, g in zip(ref_leaves, got_leaves):
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(r, np.float32),
                atol=atol, err_msg=f"space {space} diverged",
            )


def test_attention_parity(rng):
    q = jnp.asarray(rng.normal(size=(1, 4, 48, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 48, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 48, 32)).astype(np.float32))
    outs = _spaces_outputs("nn_attention", q, k, v)
    assert set(outs) == {"reference", "xla", "pallas"}
    _assert_all_match(outs, atol=2e-3)


def test_rmsnorm_parity(rng):
    x = jnp.asarray(rng.normal(size=(33, 129, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    outs = _spaces_outputs("nn_rmsnorm", x, w)
    assert set(outs) == {"reference", "xla", "pallas"}
    _assert_all_match(outs, atol=1e-4)


def test_rwkv6_parity(rng):
    B, S, H, K = 1, 70, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    logw = jnp.asarray(-np.exp(rng.normal(-1.0, 0.5, size=(B, S, H, K))).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
    outs = _spaces_outputs("nn_rwkv6_scan", r, k, v, logw, u)
    assert set(outs) == {"reference", "xla", "pallas"}
    _assert_all_match(outs, atol=5e-3)


def test_ssd_parity(rng):
    B, S, H, P, G, N = 1, 96, 2, 16, 1, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.log1p(np.exp(rng.normal(size=(B, S, H)))).astype(np.float32))
    A = jnp.asarray(-np.exp(rng.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    outs = _spaces_outputs("nn_ssd_scan", x, dt, A, Bm, C)
    assert set(outs) == {"reference", "xla", "pallas"}
    _assert_all_match(outs, atol=5e-3)


@pytest.mark.parametrize("fmt", ["ell", "sellp"])
def test_spmv_parity(rng, fmt):
    n = 150
    a = rng.normal(size=(n, n)).astype(np.float32)
    a[rng.random(a.shape) < 0.85] = 0.0
    A = sparse.ell_from_dense(a) if fmt == "ell" else sparse.sellp_from_dense(a)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    outs = _spaces_outputs(f"spmv_{fmt}", A, x)
    assert set(outs) == {"reference", "xla", "pallas"}
    _assert_all_match(outs, atol=1e-3)


def test_spmv_batch_ell_parity(rng):
    """Batched ELL SpMV: three-space parity with geometry resolved through
    the launch-config subsystem (batch axis on the outer grid axis)."""
    from repro import batch

    nb, n = 9, 120
    stack = rng.normal(size=(nb, n, n)).astype(np.float32)
    stack[rng.random(stack.shape) < 0.85] = 0.0
    A = batch.batch_ell_from_dense(stack)
    X = jnp.asarray(rng.normal(size=(nb, n)).astype(np.float32))
    outs = _spaces_outputs("spmv_batch_ell", A, X)
    assert set(outs) == {"reference", "xla", "pallas"}
    _assert_all_match(outs, atol=1e-3)


def test_spmv_batch_ell_uses_launch_config(rng):
    """The pallas binding resolves tile geometry via Executor.launch_config —
    a pinned table override must change nothing numerically but be the
    geometry the resolver hands back."""
    from repro.core import tuning

    shapes = {"nb": 8, "m": 64, "k": 16, "n": 64, "itemsize": 4}
    ex = PallasInterpretExecutor()
    base = ex.launch_config("spmv_batch_ell", shapes)
    assert base.source.startswith("table")
    assert set(base.block) == {"block_m", "block_k"}
    try:
        tuning.set_table_entry(
            "spmv_batch_ell", ex.hw.name, {"block_m": 32, "block_k": 8}
        )
        pinned = ex.launch_config("spmv_batch_ell", shapes)
        assert (pinned["block_m"], pinned["block_k"]) == (32, 8)
    finally:
        tuning._TABLE.pop(("spmv_batch_ell", ex.hw.name), None)


def test_spmv_batch_ell_vmem_fallback(rng):
    """A starved target still answers through the pallas space (xla kernel
    inside the binding) and matches the oracle."""
    import dataclasses

    from repro import batch
    from repro.core import params as hw_params

    nb, n = 4, 96
    stack = rng.normal(size=(nb, n, n)).astype(np.float32)
    stack[rng.random(stack.shape) < 0.9] = 0.0
    A = batch.batch_ell_from_dense(stack)
    X = jnp.asarray(rng.normal(size=(nb, n)).astype(np.float32))
    starved = dataclasses.replace(hw_params.CPU_INTERPRET, vmem_limit_bytes=1024)
    ex = PallasInterpretExecutor(starved)
    got = registry.operation("spmv_batch_ell")(A, X, executor=ex)
    want = registry.operation("spmv_batch_ell")(A, X, executor=ReferenceExecutor())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_spmv_vmem_fallback_serves_pallas_space(rng):
    """A target whose VMEM cannot hold x still answers (via the xla kernel
    inside the pallas binding) and matches the oracle."""
    import dataclasses

    from repro.core import params as hw_params

    n = 200
    a = rng.normal(size=(n, n)).astype(np.float32)
    a[rng.random(a.shape) < 0.9] = 0.0
    A = sparse.ell_from_dense(a)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    starved = dataclasses.replace(hw_params.CPU_INTERPRET, vmem_limit_bytes=1024)
    ex = PallasInterpretExecutor(starved)
    got = registry.operation("spmv_ell")(A, x, executor=ex)
    want = registry.operation("spmv_ell")(A, x, executor=ReferenceExecutor())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
