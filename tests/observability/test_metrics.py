"""Metrics registry: series identity, kinds, exporters, dispatch folding."""

import pytest

from repro.observability import metrics
from repro.observability.events import DispatchEvent


@pytest.fixture(autouse=True)
def _fresh_registry():
    metrics.reset()
    yield
    metrics.reset()


def test_counter_gauge_histogram_basics():
    c = metrics.counter("reqs", op="spmv")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)

    g = metrics.gauge("gbs", op="spmv")
    g.set(12.5)
    g.set(10.0)
    assert g.value == 10.0

    h = metrics.histogram("wall_us", op="spmv")
    for v in (1.0, 3.0, 100.0):
        h.observe(v)
    assert h.count == 3 and h.min == 1.0 and h.max == 100.0
    assert h.mean == pytest.approx(104.0 / 3)
    assert h.buckets == {1: 1, 4: 1, 128: 1}  # pow2 upper bounds


def test_subunit_bucket_boundaries_for_wall_times():
    """The _bucket_of fix: second-scale samples no longer collapse into the
    ``1`` bucket — microsecond-scale values resolve to 2^-k bounds, pinned."""
    h = metrics.histogram("latency_s", path="serve")
    # (value, expected power-of-two upper bound)
    cases = [
        (3e-6, 2.0 ** -18),     # 1.907e-6 < 3e-6 <= 3.815e-6
        (1e-6, 2.0 ** -19),     # 9.537e-7 < 1e-6 <= 1.907e-6
        (250e-6, 2.0 ** -11),   # 2.44e-4 < 2.5e-4 <= 4.88e-4
        (0.003, 2.0 ** -8),     # 1.95e-3 < 3e-3 <= 3.9e-3
        (0.6, 1),               # (0.5, 1] keeps the historical ``1`` label
        (0.5, 0.5),
        (0.25, 0.25),
    ]
    for v, _ in cases:
        h.observe(v)
    for v, bound in cases:
        assert metrics._bucket_of(v) == bound, v
    assert sum(h.buckets.values()) == len(cases)
    # distinct second-scale magnitudes land in distinct buckets
    assert len(h.buckets) == len({b for _, b in cases})


def test_bucket_floor_and_legacy_labels():
    # everything at or below 2^-30 (incl. zero/negative) clamps to 2^-30
    floor = 2.0 ** metrics._MIN_BUCKET_EXP
    assert metrics._bucket_of(1e-12) == floor
    assert metrics._bucket_of(0.0) == floor
    assert metrics._bucket_of(floor) == floor
    # >= 1 buckets keep their integer labels exactly as before the fix
    assert metrics._bucket_of(1.0) == 1
    assert metrics._bucket_of(3.0) == 4
    assert metrics._bucket_of(100.0) == 128
    assert isinstance(metrics._bucket_of(3.0), int)
    # sample() stringifies mixed int/float bucket keys without conflict
    h = metrics.histogram("mixed")
    h.observe(0.003)
    h.observe(3.0)
    keys = set(h.sample()["buckets"])
    assert str(2.0 ** -8) in keys and "4" in keys


def test_histogram_quantile():
    h = metrics.histogram("q")
    assert h.quantile(0.5) is None
    for v in (1e-6,) * 50 + (1e-3,) * 45 + (0.8,) * 5:
        h.observe(v)
    assert h.quantile(0.5) == 2.0 ** -19   # median is a microsecond sample
    assert h.quantile(0.99) == 1.0         # p99 reaches the second-scale tail
    assert h.quantile(1.0) == 1.0
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_series_identity_and_kind_conflicts():
    # same (name, labels) -> same object; label order must not matter
    a = metrics.counter("n", op="x", space="xla")
    b = metrics.counter("n", space="xla", op="x")
    assert a is b
    assert metrics.counter("n", op="y") is not a
    with pytest.raises(TypeError):
        metrics.gauge("n", op="x", space="xla")


def test_jsonl_roundtrip_and_table(tmp_path):
    metrics.counter("dispatch_total", op="spmv_csr").inc(4)
    metrics.gauge("gbs", op="spmv_csr").set(1.25)
    metrics.histogram("wall", op="spmv_csr").observe(7.0)
    path = str(tmp_path / "m.jsonl")
    metrics.export_jsonl(path)
    records = metrics.load_jsonl(path)
    assert len(records) == 3
    by_name = {r["name"]: r for r in records}
    assert by_name["dispatch_total"]["value"] == 4
    assert by_name["dispatch_total"]["labels"] == {"op": "spmv_csr"}
    assert by_name["wall"]["count"] == 1

    table = metrics.render_table()
    assert "dispatch_total" in table and "op=spmv_csr" in table
    assert metrics.render_table() != "(no metrics recorded)"
    metrics.reset()
    assert metrics.render_table() == "(no metrics recorded)"


def _event(wall_us=10.0, est_bytes=8000):
    return DispatchEvent(
        op="spmv_csr", space="xla", executor="XlaExecutor", target="cpu_xla",
        shapes=((8,), (8, 8)), shape_bucket=64, launch=None,
        wall_us=wall_us, est_bytes=est_bytes, ts_us=0.0,
    )


def test_observe_dispatch_folds_counters_and_gauges():
    labels = dict(op="spmv_csr", space="xla", target="cpu_xla")
    metrics.observe_dispatch(_event(), hbm_bandwidth=100e9)
    metrics.observe_dispatch(_event(wall_us=5.0), hbm_bandwidth=100e9)
    assert metrics.counter("dispatch_total", **labels).value == 2
    assert metrics.histogram("dispatch_wall_us", **labels).count == 2
    # last event: 8000 B / 5 us = 1.6 GB/s; bound 100 GB/s -> 0.016
    assert metrics.gauge("dispatch_gbs", **labels).value == pytest.approx(1.6)
    assert metrics.gauge(
        "dispatch_frac_of_bound", **labels
    ).value == pytest.approx(0.016)


def test_observe_dispatch_without_bytes_skips_gauges():
    metrics.observe_dispatch(_event(est_bytes=0))
    names = {r["name"] for r in metrics.samples()}
    assert "dispatch_gbs" not in names
    assert "dispatch_total" in names
