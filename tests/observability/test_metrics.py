"""Metrics registry: series identity, kinds, exporters, dispatch folding."""

import pytest

from repro.observability import metrics
from repro.observability.events import DispatchEvent


@pytest.fixture(autouse=True)
def _fresh_registry():
    metrics.reset()
    yield
    metrics.reset()


def test_counter_gauge_histogram_basics():
    c = metrics.counter("reqs", op="spmv")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)

    g = metrics.gauge("gbs", op="spmv")
    g.set(12.5)
    g.set(10.0)
    assert g.value == 10.0

    h = metrics.histogram("wall_us", op="spmv")
    for v in (1.0, 3.0, 100.0):
        h.observe(v)
    assert h.count == 3 and h.min == 1.0 and h.max == 100.0
    assert h.mean == pytest.approx(104.0 / 3)
    assert h.buckets == {1: 1, 4: 1, 128: 1}  # pow2 upper bounds


def test_series_identity_and_kind_conflicts():
    # same (name, labels) -> same object; label order must not matter
    a = metrics.counter("n", op="x", space="xla")
    b = metrics.counter("n", space="xla", op="x")
    assert a is b
    assert metrics.counter("n", op="y") is not a
    with pytest.raises(TypeError):
        metrics.gauge("n", op="x", space="xla")


def test_jsonl_roundtrip_and_table(tmp_path):
    metrics.counter("dispatch_total", op="spmv_csr").inc(4)
    metrics.gauge("gbs", op="spmv_csr").set(1.25)
    metrics.histogram("wall", op="spmv_csr").observe(7.0)
    path = str(tmp_path / "m.jsonl")
    metrics.export_jsonl(path)
    records = metrics.load_jsonl(path)
    assert len(records) == 3
    by_name = {r["name"]: r for r in records}
    assert by_name["dispatch_total"]["value"] == 4
    assert by_name["dispatch_total"]["labels"] == {"op": "spmv_csr"}
    assert by_name["wall"]["count"] == 1

    table = metrics.render_table()
    assert "dispatch_total" in table and "op=spmv_csr" in table
    assert metrics.render_table() != "(no metrics recorded)"
    metrics.reset()
    assert metrics.render_table() == "(no metrics recorded)"


def _event(wall_us=10.0, est_bytes=8000):
    return DispatchEvent(
        op="spmv_csr", space="xla", executor="XlaExecutor", target="cpu_xla",
        shapes=((8,), (8, 8)), shape_bucket=64, launch=None,
        wall_us=wall_us, est_bytes=est_bytes, ts_us=0.0,
    )


def test_observe_dispatch_folds_counters_and_gauges():
    labels = dict(op="spmv_csr", space="xla", target="cpu_xla")
    metrics.observe_dispatch(_event(), hbm_bandwidth=100e9)
    metrics.observe_dispatch(_event(wall_us=5.0), hbm_bandwidth=100e9)
    assert metrics.counter("dispatch_total", **labels).value == 2
    assert metrics.histogram("dispatch_wall_us", **labels).count == 2
    # last event: 8000 B / 5 us = 1.6 GB/s; bound 100 GB/s -> 0.016
    assert metrics.gauge("dispatch_gbs", **labels).value == pytest.approx(1.6)
    assert metrics.gauge(
        "dispatch_frac_of_bound", **labels
    ).value == pytest.approx(0.016)


def test_observe_dispatch_without_bytes_skips_gauges():
    metrics.observe_dispatch(_event(est_bytes=0))
    names = {r["name"] for r in metrics.samples()}
    assert "dispatch_gbs" not in names
    assert "dispatch_total" in names
