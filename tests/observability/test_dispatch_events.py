"""Structured dispatch events: the log's two faces, shapes, roofline rows."""

import collections

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sparse
from repro.core import make_executor, registry
from repro.observability import trace
from repro.observability.events import (
    DispatchLog,
    make_event,
    roofline_summary,
    shape_bucket,
    summarize_operands,
)


@pytest.fixture(autouse=True)
def _clean_tracer():
    trace.reset()
    yield
    trace.reset()


def test_dispatch_log_counter_face_is_plain_counter():
    """The Counter face must behave bitwise like the pre-observability log:
    portability tests and BENCH pins diff ``dict(ex.dispatch_log)``."""
    log = DispatchLog()
    assert isinstance(log, collections.Counter)
    log.record("spmv_csr")
    log.record("spmv_csr")
    log.record("blas_dot")
    assert dict(log) == {"spmv_csr": 2, "blas_dot": 1}
    assert log.most_common(1) == [("spmv_csr", 2)]
    assert not log.events  # no event objects without tracing
    log.clear()
    assert dict(log) == {} and not log.events


def test_shape_bucket_and_operand_summary():
    assert shape_bucket([(8,), (8, 8)]) == 64
    assert shape_bucket([(5,)]) == 8
    assert shape_bucket([]) == 1

    x = jnp.ones((16,), jnp.float32)
    shapes, nbytes = summarize_operands([x, 3, None, "label", [x, {"k": x}]])
    assert shapes == [(16,)] * 3
    assert nbytes == 3 * 16 * 4

    A = sparse.csr_from_dense(np.eye(8, dtype=np.float32))
    shapes, nbytes = summarize_operands([A])
    assert (8, 8) in shapes
    assert nbytes == A.memory_bytes  # format accounting wins over dense size


def test_events_recorded_only_while_tracing():
    ex = make_executor("xla")
    op = registry.operation("blas_norm2")
    x = jnp.ones(32, jnp.float32)
    ex.dispatch_log.clear()
    op(x, executor=ex)
    assert ex.dispatch_log["blas_norm2"] == 1
    assert len(ex.dispatch_events) == 0

    trace.enable()
    op(x, executor=ex)
    assert ex.dispatch_log["blas_norm2"] == 2
    (ev,) = ex.dispatch_events
    assert ev.op == "blas_norm2"
    assert ev.shapes == ((32,),)
    assert ev.wall_us >= 0.0
    assert ev.ts_us >= 0.0


def test_event_carries_resolved_launch_config():
    """When the kernel consults the tuning table, the event records the
    resolved LaunchConfig (the tile geometry that actually ran)."""
    ex = make_executor("pallas_interpret")
    a = np.eye(16, dtype=np.float32)
    A = sparse.ell_from_dense(a)
    trace.enable()
    ex.dispatch_log.clear()
    sparse.apply(A, jnp.ones(16, jnp.float32), executor=ex)
    events = [e for e in ex.dispatch_events if e.op == "spmv_ell"]
    assert events
    launches = [e.launch for e in events if e.launch is not None]
    if launches:  # kernels that consulted launch_config expose the geometry
        assert isinstance(launches[0], dict) and launches[0]


def test_roofline_summary_aggregates_per_op_space_target():
    def ev(op, wall, nbytes):
        return make_event(
            op=op, space="xla", executor=make_executor("xla"), launch=None,
            wall_us=wall, ts_us=0.0,
            operands=[jnp.ones(max(nbytes // 4, 1), jnp.float32)], out=None,
        )

    rows = roofline_summary(
        [ev("a", 10.0, 4000), ev("a", 10.0, 4000), ev("b", 5.0, 1000)],
        hbm_bandwidth=100e9,
    )
    assert [r["op"] for r in rows] == ["a", "b"]
    ra = rows[0]
    assert ra["count"] == 2 and ra["est_bytes"] == 8000
    assert ra["gbs"] == pytest.approx(8000 / 20e-6 / 1e9)
    assert ra["frac_of_bound"] == pytest.approx(ra["gbs"] / 100.0)


def test_event_deque_is_bounded():
    from repro.observability.events import EVENT_CAPACITY

    log = DispatchLog()
    for i in range(EVENT_CAPACITY + 10):
        log.record("op", event=object())
    assert len(log.events) == EVENT_CAPACITY
    assert log["op"] == EVENT_CAPACITY + 10  # counts are never dropped
