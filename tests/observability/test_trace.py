"""Tracer unit tests: spans, export, validation, and the zero-overhead pin."""

import gc
import json
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_executor, registry
from repro.observability import trace


@pytest.fixture(autouse=True)
def _clean_tracer():
    trace.reset()
    yield
    trace.reset()


def test_disabled_span_is_shared_singleton():
    assert not trace.enabled()
    s1 = trace.span("a", n=1)
    s2 = trace.span("b", other="x")
    assert s1 is s2  # no allocation on the disabled path
    with s1:
        pass


def test_nested_spans_record_complete_events():
    tracer = trace.enable()
    with trace.span("outer", level=0):
        with trace.span("inner", level=1):
            pass
    trace.disable()
    names = [ev["name"] for ev in tracer.events]
    assert names == ["inner", "outer"]  # inner closes first
    outer = tracer.events[1]
    inner = tracer.events[0]
    assert outer["ph"] == "X" and inner["ph"] == "X"
    # containment: outer starts before inner and ends after it
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"level": 0}


def test_instant_events_and_validation():
    tracer = trace.enable()
    trace.instant("marker", detail="here")
    data = tracer.to_json()
    assert trace.validate_trace(data) == []
    (ev,) = data["traceEvents"]
    assert ev["ph"] == "i" and ev["s"] == "t"


def test_export_roundtrip(tmp_path):
    path = str(tmp_path / "trace.json")
    with trace.tracing(path):
        with trace.span("work", n=3):
            pass
    assert trace.validate_trace(path) == []
    with open(path) as f:
        data = json.load(f)
    assert data["traceEvents"][0]["name"] == "work"
    assert data["displayTimeUnit"] == "ms"
    # context manager disabled tracing on exit
    assert not trace.enabled()


def test_validate_catches_malformed_events():
    bad = {
        "traceEvents": [
            {"name": "", "ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 1},
            {"name": "x", "ph": "?", "ts": 0, "pid": 1, "tid": 1},
            {"name": "y", "ph": "X", "ts": 0, "pid": 1, "tid": 1},  # no dur
            {"name": "z", "ph": "X", "ts": 0, "dur": 1, "pid": "a", "tid": 1},
        ]
    }
    errors = trace.validate_trace(bad)
    assert len(errors) == 4
    assert trace.validate_trace({"nope": []}) == ["missing 'traceEvents' list"]
    assert trace.validate_trace([1, 2]) != []


def test_enable_from_args_and_cli_flag(tmp_path):
    import argparse

    ap = argparse.ArgumentParser()
    trace.add_cli_flag(ap)
    path = str(tmp_path / "t.json")
    args = ap.parse_args(["--trace", path])
    assert trace.enable_from_args(args) == path
    assert trace.enabled()
    with trace.span("s"):
        pass
    assert trace.export() == path
    assert trace.validate_trace(path) == []
    # no flag -> stays disabled
    trace.reset()
    assert trace.enable_from_args(ap.parse_args([])) is None
    assert not trace.enabled()


def test_disabled_dispatch_retains_no_allocations():
    """The overhead pin: with tracing off, repeated dispatches must not
    retain memory (no event objects, no trace records, no per-call state).

    Measured as live-block growth across a batch of dispatches after a
    warmup round (the warmup pays one-time costs: Counter entries, jit/XLA
    caches, dtype interning)."""
    ex = make_executor("xla")
    op = registry.operation("blas_dot")
    x = jnp.asarray(np.ones(64, np.float32))

    def run(n):
        for _ in range(n):
            op(x, x, executor=ex)

    assert not trace.enabled()
    run(20)  # warmup: first-call caches, Counter keys
    deltas = []
    for _ in range(3):
        gc.collect()
        before = sys.getallocatedblocks()
        run(50)
        gc.collect()
        deltas.append(sys.getallocatedblocks() - before)
    # interpreter noise can wiggle a few blocks; 50 retained events would
    # show up as hundreds
    assert min(deltas) <= 8, f"dispatch path leaked blocks: {deltas}"
    assert not ex.dispatch_log.events
