"""Convergence telemetry: ``history=`` on every solver, plus the acceptance
pin that a traced CG solve reproduces the PR-6 launch-count structure."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import sparse
from repro.core import make_executor
from repro.observability import convergence, trace
from repro.solvers import krylov
from repro.solvers.common import Stop
from repro.solvers.ir import ir, mixed_precision_ir

BENCH_PR6 = os.path.join(
    os.path.dirname(__file__), "..", "..", "BENCH_pr6.json"
)


@pytest.fixture(autouse=True)
def _clean_tracer():
    trace.reset()
    yield
    trace.reset()


def _spd(n=64):
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 2:
            a[i, i - 3] = a[i - 3, i] = -0.5
    return a


def _system(n=64, nonsym=False, seed=0):
    a = _spd(n)
    if nonsym:
        rng = np.random.default_rng(seed)
        a = a + np.triu(rng.normal(size=(n, n)).astype(np.float32), 1) * 0.05
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=n).astype(np.float32)
    return a, (a @ x).astype(np.float32)


STOP = Stop(max_iters=200, reduction_factor=1e-6)


def _check_history(res, *, rtol=1e-4):
    hist = convergence.trim(res.history)
    assert hist is not None and len(hist) > 0
    assert np.all(np.isfinite(hist))
    np.testing.assert_allclose(
        hist[-1], float(res.residual_norm), rtol=rtol,
        err_msg="last recorded residual != SolveResult.residual_norm",
    )
    return hist


@pytest.mark.parametrize(
    "solver,opts",
    [
        ("cg", {}),
        ("cg", {"fused": False}),
        ("cg", {"pipeline": True}),
        ("fcg", {}),
        ("bicgstab", {}),
        ("bicgstab", {"fused": False}),
        ("cgs", {}),
    ],
)
def test_history_matches_residual(solver, opts):
    nonsym = solver in ("bicgstab", "cgs")
    a, b = _system(nonsym=nonsym)
    A = sparse.csr_from_dense(a)
    ex = make_executor("xla")
    fn = getattr(krylov, solver)
    res = fn(A, jnp.asarray(b), stop=STOP, executor=ex, history=True, **opts)
    assert res.converged
    hist = _check_history(res)
    assert len(hist) == int(res.iterations)
    # without the option the field stays None (no buffer in the loop state)
    res_off = fn(A, jnp.asarray(b), stop=STOP, executor=ex, **opts)
    assert res_off.history is None
    np.testing.assert_allclose(
        float(res_off.residual_norm), float(res.residual_norm), rtol=1e-4
    )


def test_gmres_history_per_restart_cycle():
    a, b = _system(nonsym=True)
    A = sparse.csr_from_dense(a)
    res = krylov.gmres(
        A, jnp.asarray(b), stop=STOP, executor=make_executor("xla"),
        restart=20, history=True,
    )
    assert res.converged
    hist = _check_history(res)
    # gmres records once per restart cycle, not per inner iteration
    cycles = -(-int(res.iterations) // 20)
    assert len(hist) == cycles


def test_history_capacity_and_ring_buffer():
    stop = Stop(max_iters=100, reduction_factor=1e-6)
    assert convergence.capacity(None, stop) == 0
    assert convergence.capacity(False, stop) == 0
    assert convergence.capacity(True, stop) == 100
    assert convergence.capacity(7, stop) == 7

    hist = convergence.init(4, dtype=jnp.float32)
    assert hist.shape == (4,) and bool(jnp.all(jnp.isnan(hist)))
    for k in range(6):  # wraps: 4,5 overwrite slots 0,1
        hist = convergence.push(hist, k, float(k))
    np.testing.assert_allclose(np.asarray(hist), [4.0, 5.0, 2.0, 3.0])

    empty = convergence.init(0)
    assert convergence.push(empty, 0, 1.0) is empty  # static no-op
    assert convergence.finalize(empty) is None
    assert convergence.trim(None) is None


def test_history_int_cap_rings_on_solver():
    a, b = _system()
    A = sparse.csr_from_dense(a)
    res = krylov.cg(
        A, jnp.asarray(b), stop=STOP, executor=make_executor("xla"), history=4
    )
    assert res.history.shape == (4,)
    # ran longer than the cap: every ring slot was overwritten with a real norm
    assert int(res.iterations) > 4
    assert np.all(np.isfinite(np.asarray(res.history)))


def test_history_under_jit():
    a, b = _system()
    A = sparse.csr_from_dense(a)
    ex = make_executor("xla")

    @jax.jit
    def solve(bb):
        return krylov.cg(A, bb, stop=STOP, executor=ex, history=True)

    res = solve(jnp.asarray(b))
    hist = _check_history(res)
    assert res.history.shape == (STOP.max_iters,)
    assert len(hist) == int(res.iterations)


def test_ir_history():
    a, b = _system()
    A = sparse.csr_from_dense(a)
    ex = make_executor("xla")
    stop = Stop(max_iters=200, reduction_factor=1e-5)
    res = ir(A, jnp.asarray(b), stop=stop, executor=ex, relaxation=0.15,
             history=True)
    assert res.converged
    _check_history(res)
    res_mp = mixed_precision_ir(A, jnp.asarray(b), stop=stop, executor=ex,
                                history=True)
    assert res_mp.converged
    _check_history(res_mp, rtol=1e-3)


def test_batch_history():
    from repro.batch import formats as bf
    from repro.batch.solvers import batch_cg

    nb, n = 4, 32
    rng = np.random.default_rng(0)
    a = _spd(n)
    # vary the diagonal per system so iteration counts differ across the batch
    mats = np.stack([a + np.eye(n, dtype=np.float32) * s
                     for s in (0.0, 0.5, 1.0, 2.0)])
    xs = rng.normal(size=(nb, n)).astype(np.float32)
    bs = np.einsum("bij,bj->bi", mats, xs).astype(np.float32)
    A = bf.batch_csr_from_dense(mats)
    ex = make_executor("xla")
    stop = Stop(max_iters=100, reduction_factor=1e-6)
    res = batch_cg(A, jnp.asarray(bs), stop=stop, executor=ex, history=True)
    assert bool(np.asarray(res.converged).all())
    assert res.history.shape == (100, nb)
    hist = convergence.trim(res.history)
    np.testing.assert_allclose(
        hist[-1], np.asarray(res.residual_norms), rtol=1e-3
    )
    res_off = batch_cg(A, jnp.asarray(bs), stop=stop, executor=ex)
    assert res_off.history is None


# =============================================================================
# acceptance: the traced solve reproduces the PR-6 launch structure
# =============================================================================


def _body_launches(counts, fused):
    if fused:
        return counts.get("spmv_dot_csr", 0) + counts.get("axpy_norm", 0)
    return (
        (counts.get("spmv_csr", 0) - 1)
        + (counts.get("blas_dot", 0) - 1)
        + (counts.get("blas_norm2", 0) - 2)
        + counts.get("blas_axpy", 0)
    )


@pytest.mark.skipif(
    not os.path.exists(BENCH_PR6), reason="BENCH_pr6.json not present"
)
@pytest.mark.parametrize("fused", [True, False])
def test_traced_cg_matches_bench_pins(tmp_path, fused):
    """A traced CG solve must produce a valid Chrome trace whose dispatch
    span counts reproduce the pinned PR-6 launch structure (2 fused / 7
    unfused body launches) — the trace is the pins' live counterpart."""
    with open(BENCH_PR6) as f:
        pinned = json.load(f)["pinned"]
    want = pinned[
        "fused_cg_body_launches" if fused else "unfused_cg_body_launches"
    ]

    a, b = _system(n=96, seed=3)
    A = sparse.csr_from_dense(a)
    ex = make_executor("xla")
    path = str(tmp_path / "cg_trace.json")
    stop = Stop(max_iters=500, reduction_factor=1e-6)
    with trace.tracing(path):
        ex.dispatch_log.clear()
        res = krylov.cg(A, jnp.asarray(b), stop=stop, executor=ex,
                        fused=fused, history=True)
        counts = dict(ex.dispatch_log)
        events = list(ex.dispatch_events)
    assert res.converged
    assert trace.validate_trace(path) == []

    # the Counter face, the event stream, and the Chrome trace must agree
    assert _body_launches(counts, fused) == want
    ev_counts = {}
    for e in events:
        ev_counts[e.op] = ev_counts.get(e.op, 0) + 1
    assert ev_counts == counts
    with open(path) as f:
        data = json.load(f)
    span_counts = {}
    for ev in data["traceEvents"]:
        if ev.get("cat") == "dispatch":
            span_counts[ev["name"]] = span_counts.get(ev["name"], 0) + 1
    assert _body_launches(span_counts, fused) == want

    # and history telemetry rode along without adding launches
    assert convergence.trim(res.history) is not None
