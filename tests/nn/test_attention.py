"""Attention layers: chunked==dense, custom VJP, GQA/MLA decode==full."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ModelConfig
from repro.kernels.flash_attention.ref import mha_ref
from repro.nn.attention import (
    KVCache,
    MLACache,
    attention_xla_chunked,
    gqa_decode,
    gqa_forward,
    gqa_init,
    gqa_prefill,
    mla_decode,
    mla_forward,
    mla_init,
    mla_prefill,
)


def test_chunked_matches_dense(rng):
    q = jnp.asarray(rng.normal(size=(2, 4, 50, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 70, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 70, 16)).astype(np.float32))
    got = attention_xla_chunked(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(got, mha_ref(q, k, v, causal=True), atol=2e-5)


def test_chunked_custom_vjp_grads(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 24, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 40, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 40, 8)).astype(np.float32))

    def loss_c(q, k, v):
        return jnp.sum(jnp.sin(attention_xla_chunked(q, k, v, chunk=16)))

    def loss_d(q, k, v):
        return jnp.sum(jnp.sin(mha_ref(q, k, v)))

    gc = jax.grad(loss_c, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_d, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gd):
        np.testing.assert_allclose(a, b, atol=5e-5)


@pytest.fixture
def gqa_cfg():
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                       vocab=64, n_heads=4, n_kv_heads=2, d_ff=64)


def test_gqa_decode_matches_full(rng, gqa_cfg):
    cfg = gqa_cfg
    p, _ = gqa_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = jnp.asarray(rng.normal(size=(B, S, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y_full = gqa_forward(p, x, cfg, pos)
    cache = KVCache.zeros(B, 2, S + 4, 8, jnp.float32)
    y_pre, cache = gqa_prefill(p, x[:, :8], cfg, pos[:, :8], cache)
    np.testing.assert_allclose(y_pre, y_full[:, :8], atol=1e-5)
    ys = []
    for t in range(8, S):
        y_t, cache = gqa_decode(p, x[:, t : t + 1], cfg, jnp.int32(t), cache)
        ys.append(y_t)
    np.testing.assert_allclose(
        jnp.concatenate(ys, axis=1), y_full[:, 8:], atol=1e-4
    )


def test_mla_decode_matches_full(rng):
    cfg = ModelConfig(
        name="m", family="mla", n_layers=2, d_model=32, vocab=64, n_heads=4,
        n_kv_heads=4, d_ff=64, q_lora_rank=16, kv_lora_rank=12,
        qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8,
    )
    p, _ = mla_init(jax.random.PRNGKey(1), cfg)
    B, S = 2, 12
    x = jnp.asarray(rng.normal(size=(B, S, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y_full = mla_forward(p, x, cfg, pos)
    cache = MLACache.zeros(B, S + 4, 12, 4, jnp.float32)
    y_pre, cache = mla_prefill(p, x[:, :8], cfg, pos[:, :8], cache)
    np.testing.assert_allclose(y_pre, y_full[:, :8], atol=1e-4)
    ys = []
    for t in range(8, S):
        y_t, cache = mla_decode(p, x[:, t : t + 1], cfg, jnp.int32(t), cache)
        ys.append(y_t)
    np.testing.assert_allclose(
        jnp.concatenate(ys, axis=1), y_full[:, 8:], atol=1e-3
    )
